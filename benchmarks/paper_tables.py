"""One benchmark per paper table/figure, each returning (rows, checks).

``rows``  — the reproduced numbers.
``checks`` — (name, ok, detail) validations against the paper's claims.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

Check = Tuple[str, bool, str]


# ---------------------------------------------------------------------------
# Fig. 6: per-configuration parallelism, latency, II
# ---------------------------------------------------------------------------
def fig6_parallelism():
    from repro.core.mac import MacConfig
    from repro.core.packing import PAPER_PARALLELISM, solve_lane_plan
    from repro.core.pipeline import Op, XtraMACPipeline
    rows, checks = [], []
    for (fa, fb), p_paper in PAPER_PARALLELISM.items():
        plan = solve_lane_plan(fa, fb, max_parallelism=4)
        plan_free = solve_lane_plan(fa, fb)
        rows.append({"combo": f"{fa}x{fb}", "paper_P": p_paper,
                     "P(cap4)": plan.parallelism,
                     "P(uncapped)": plan_free.parallelism,
                     "util": round(plan.dsp_utilization, 3)})
        checks.append((f"fig6 P {fa}x{fb}", plan.parallelism >= p_paper,
                       f"{plan.parallelism} >= paper {p_paper}"))
    # latency-4 / II-1 under per-cycle runtime switching
    cfgs = [MacConfig.make("int4", "bf16", "bf16", "bf16"),
            MacConfig.make("bf16", "bf16", "bf16", "bf16")]
    pipe = XtraMACPipeline(cfgs)
    rng = np.random.default_rng(0)
    ops = [Op(int(rng.integers(2)),
              rng.integers(0, 16, pipe.plans[0].parallelism * 2),
              rng.integers(0, 65536, 2),
              rng.integers(0, 65536, pipe.parallelism)) for _ in range(64)]
    res = pipe.run(ops)
    checks.append(("fig6 latency=4 II=1", pipe.latency == 4 and len(res) == 64,
                   f"latency {pipe.latency}, {len(res)} results for 64 issues"))
    return rows, checks


# ---------------------------------------------------------------------------
# Figs. 3/4/9: DSP utilization — XtraMAC vs upcast/spatial/temporal
# ---------------------------------------------------------------------------
def fig9_dsp_utilization():
    from repro.core.packing import (solve_lane_plan, utilization_temporal_bf16_over_int8,
                                    utilization_upcast)
    combos = [("int8", "int8"), ("int4", "bf16"), ("fp4_e2m1", "bf16"),
              ("fp8_e4m3", "fp8_e4m3"), ("bf16", "bf16"),
              ("fp8_e4m3", "bf16"), ("int8", "fp16")]
    rows, checks = [], []
    ours, upcast = [], []
    for fa, fb in combos:
        u_x = solve_lane_plan(fa, fb, max_parallelism=4).dsp_utilization
        u_up = utilization_upcast(fa, fb)
        ours.append(u_x)
        upcast.append(u_up)
        rows.append({"combo": f"{fa}x{fb}", "xtramac": round(u_x, 3),
                     "upcast": round(u_up, 3)})
    mean_up = float(np.mean(upcast))
    checks.append(("fig3 upcast mean util ~32.4% (+/-0.10 abs — bar-chart "
                   "figure, operand-set dependent)",
                   abs(mean_up - 0.324) < 0.10,
                   f"model {mean_up:.3f} vs paper 0.324"))
    spatial = mean_up / 2    # two replicated datapaths, one active
    checks.append(("fig4 ordering: temporal-BF16 < spatial < upcast < XtraMAC",
                   utilization_temporal_bf16_over_int8() < spatial < mean_up
                   < float(np.mean(ours)),
                   f"{utilization_temporal_bf16_over_int8():.3f} < "
                   f"{spatial:.3f} < {mean_up:.3f} < {np.mean(ours):.3f}"))
    t_bf16 = utilization_temporal_bf16_over_int8()
    checks.append(("fig4 TATAA bf16 util ~8.9%", abs(t_bf16 - 0.089) < 0.01,
                   f"model {t_bf16:.3f} vs paper 0.089"))
    int8_util = solve_lane_plan("int8", "int8", max_parallelism=4).dsp_utilization
    checks.append(("fig4 INT8 2-lane util ~71.1%", abs(int8_util - 0.711) < 0.01,
                   f"model {int8_util:.3f} vs paper 0.711"))
    checks.append(("fig9 xtramac > upcast everywhere",
                   all(x > u for x, u in zip(ours, upcast)),
                   f"mean {np.mean(ours):.3f} vs {mean_up:.3f}"))
    return rows, checks


# ---------------------------------------------------------------------------
# Table IV: per-lane resources + compute density 1.4-2.0x
# ---------------------------------------------------------------------------
def table_iv_density():
    from repro.core.resource_model import (PAPER_MEAN_REDUCTION, TABLE_IV,
                                           compute_density)
    rows, checks = [], []
    reductions = {"lut": [], "ff": [], "dsp": []}
    densities = []
    for (fa, fb), (vend, ours) in TABLE_IV.items():
        d = compute_density(fa, fb)
        densities.extend(d.values())
        for res in ("lut", "ff", "dsp"):
            v = getattr(vend, res)
            x = getattr(ours, res)
            reductions[res].append(1 - x / v)
        rows.append({"combo": f"{fa}x{fb}",
                     **{f"density_{k}": round(v, 2) for k, v in d.items()}})
    ok_band = min(densities) >= 1.35 and max(densities) <= 2.05
    checks.append(("table4 density in 1.4-2.0x (paper rounds per row)",
                   ok_band,
                   f"range {min(densities):.2f}-{max(densities):.2f}"))
    for res, claim in PAPER_MEAN_REDUCTION.items():
        mean = float(np.mean(reductions[res]))
        checks.append((f"table4 mean {res} reduction ~{claim:.1%}",
                       abs(mean - claim) < 0.02, f"{mean:.3f} vs {claim}"))
    return rows, checks


# ---------------------------------------------------------------------------
# Table V: runtime-switching per-op resources vs vendor / TATAA
# ---------------------------------------------------------------------------
def table_v_switching():
    from repro.core.resource_model import TABLE_V
    x, v, t = TABLE_V["xtramac"], TABLE_V["vendor"], TABLE_V["tataa"]
    rows = [{"design": k, **{r: getattr(val["bf16"], r) for r in ("lut", "ff", "dsp")}}
            for k, val in TABLE_V.items()]
    checks = [
        ("table5 vs TATAA: LUT -59.7%",
         abs(1 - x["bf16"].lut / t["bf16"].lut - 0.597) < 0.01,
         f"{1 - x['bf16'].lut / t['bf16'].lut:.3f}"),
        ("table5 vs TATAA: FF -72.5%",
         abs(1 - x["bf16"].ff / t["bf16"].ff - 0.725) < 0.01,
         f"{1 - x['bf16'].ff / t['bf16'].ff:.3f}"),
        ("table5 vs TATAA: DSP -93.8%",
         abs(1 - x["bf16"].dsp / t["bf16"].dsp - 0.938) < 0.01,
         f"{1 - x['bf16'].dsp / t['bf16'].dsp:.3f}"),
        ("table5 vs vendor: LUT -35.5%",
         abs(1 - x["bf16"].lut / v["bf16"].lut - 0.355) < 0.01,
         f"{1 - x['bf16'].lut / v['bf16'].lut:.3f}"),
        ("table5 vs vendor: FF -58.7%",
         abs(1 - x["bf16"].ff / v["bf16"].ff - 0.587) < 0.01,
         f"{1 - x['bf16'].ff / v['bf16'].ff:.3f}"),
        ("table5 vs vendor: DSP -75.0%",
         abs(1 - x["bf16"].dsp / v["bf16"].dsp - 0.75) < 0.01,
         f"{1 - x['bf16'].dsp / v['bf16'].dsp:.3f}"),
    ]
    return rows, checks


# ---------------------------------------------------------------------------
# Fig. 8: fmax scaling with datatype count; Table III resource sharing
# ---------------------------------------------------------------------------
def fig8_scaling():
    from repro.core.mac import MacConfig
    from repro.core.resource_model import (FMAX_FLOOR_MHZ, estimate_instance,
                                           fmax_mhz, CALIBRATION_R2)
    rows, checks = [], []
    seq = ["bf16", "int8", "fp8_e4m3", "fp4_e2m1"]
    luts = []
    for n in range(1, 5):
        cfgs = [MacConfig.make(f, "bf16", "bf16", "bf16") for f in seq[:n]]
        est = estimate_instance(cfgs)
        luts.append(est.lut)
        rows.append({"n_datatypes": n, "fmax_mhz": fmax_mhz(n),
                     "est_lut": round(est.lut, 1), "dsp": est.dsp})
    checks.append(("fig8 fmax 483 -> 462 MHz",
                   fmax_mhz(1) == 483.0 and fmax_mhz(4) == 462.0,
                   f"{fmax_mhz(1)} -> {fmax_mhz(4)}"))
    checks.append(("fig8 all fmax > 400 MHz",
                   all(fmax_mhz(n) > FMAX_FLOOR_MHZ for n in range(1, 5)),
                   "floor holds"))
    checks.append(("fig8 LUT grows with datatypes",
                   all(b >= a - 1e-6 for a, b in zip(luts, luts[1:])),
                   f"{[round(l) for l in luts]}"))
    checks.append(("fig8 DSP constant = 1",
                   all(r["dsp"] == 1.0 for r in rows), "shared multiplier"))
    checks.append(("table3 nonneg calibration R^2 > 0.5 (4 rows, physical "
                   "coefficients; measured tables drive all other benches)",
                   CALIBRATION_R2 > 0.5, f"R2 {CALIBRATION_R2:.4f}"))
    return rows, checks


# ---------------------------------------------------------------------------
# Table VII: mixed-precision GEMV vs H100
# ---------------------------------------------------------------------------
def table_vii_gemv():
    from repro.core.gemv_engine import GemvEngineConfig, table_vii
    rows_d = table_vii(GemvEngineConfig())
    rows, checks = [], []
    for shape, r in rows_d.items():
        rows.append({"shape": "x".join(map(str, shape)),
                     **{k: (round(v, 5) if isinstance(v, float) else v)
                        for k, v in r.items()}})
        checks.append((f"table7 {shape} model within 10% of paper FPGA time",
                       abs(r["model_vs_paper"] - 1) < 0.10,
                       f"ratio {r['model_vs_paper']:.3f}"))
        checks.append((f"table7 {shape} speedup ~1.2x",
                       1.0 < r["speedup"] < 1.4, f"{r['speedup']:.2f}"))
        checks.append((f"table7 {shape} energy eff ~1.9x",
                       1.6 < r["energy_eff"] < 2.2, f"{r['energy_eff']:.2f}"))
    return rows, checks


# ---------------------------------------------------------------------------
# Fig. 14 + Fig. 1: end-to-end simulation + MAC distribution
# ---------------------------------------------------------------------------
def fig14_end_to_end():
    from repro.perfmodel import fig14_simulation
    sim = fig14_simulation()
    rows, checks = [], []
    for name, per_batch in sim.items():
        rows.append({"model": name,
                     **{f"b{b}_speedup": round(r["speedup"], 2)
                        for b, r in per_batch.items()},
                     "b1_ms": round(per_batch[1]["xtramac_ms"], 2)})
        checks.append((f"fig14 {name} b1 memory-bound, no gain",
                       abs(per_batch[1]["speedup"] - 1.0) < 0.01
                       and per_batch[1]["bound"] == "memory",
                       f"x{per_batch[1]['speedup']:.2f}"))
    b1_lat = [per[1]["xtramac_ms"] for per in sim.values()]
    checks.append(("fig14 b1 latency in paper's 4.4-10.0 ms band (+/-20%)",
                   min(b1_lat) > 3.5 and max(b1_lat) < 12.0,
                   f"{min(b1_lat):.1f}-{max(b1_lat):.1f} ms"))
    fp_gains = [per[32]["speedup"] for name, per in sim.items()
                if "W8A8" not in name]
    checks.append(("fig14 b32 compute-bound gains (paper 1.5-1.8x; "
                   "our reconstruction 1.2-1.6x, W8A8 deviates — see "
                   "EXPERIMENTS.md)",
                   min(fp_gains) > 1.2, f"{min(fp_gains):.2f}-{max(fp_gains):.2f}"))
    return rows, checks


def fig1_distribution():
    from repro.configs.xtramac_paper import PAPER_CHECKPOINTS
    from repro.perfmodel import mac_distribution
    rows, checks = [], []
    for name, (cfg, scheme) in PAPER_CHECKPOINTS.items():
        for ctx in (512, 4096, 32768):
            dist = mac_distribution(cfg, scheme, ctx)
            rows.append({"model": name, "ctx": ctx,
                         **{k: round(v, 3) for k, v in dist.items()}})
    qwen512 = mac_distribution(*PAPER_CHECKPOINTS["Qwen-3-8B-AWQ"], 512)
    checks.append(("fig1 Qwen3-AWQ >68% INT4xBF16 at decode",
                   qwen512["INT4xBF16"] > 0.68,
                   f"{qwen512['INT4xBF16']:.1%}"))
    return rows, checks


# ---------------------------------------------------------------------------
# Kernel micro-bench (CPU interpret timings; correctness vs oracle)
# ---------------------------------------------------------------------------
def kernel_bench():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.packed_matmul import packed_matmul
    from repro.quant.schemes import get_scheme, quantize_weights
    rng = np.random.default_rng(0)
    rows, checks = [], []
    for scheme_name in ("awq_int4", "mxfp4", "fp8"):
        w = rng.standard_normal((512, 256)).astype(np.float32) * 0.05
        x = jnp.asarray(rng.standard_normal((8, 512)), jnp.bfloat16)
        qw = quantize_weights(get_scheme(scheme_name), w)
        t0 = time.perf_counter()
        out_k = packed_matmul(x, qw, bm=8, bn=128, bk=256, interpret=True)
        out_k.block_until_ready()
        t_k = (time.perf_counter() - t0) * 1e6
        out_r = ref.packed_matmul_ref(x, qw)
        err = float(jnp.max(jnp.abs(out_k - out_r)) /
                    (jnp.max(jnp.abs(out_r)) + 1e-9))
        rows.append({"kernel": f"packed_matmul[{scheme_name}]",
                     "us_per_call": round(t_k, 1), "rel_err": err})
        checks.append((f"kernel {scheme_name} matches oracle", err < 1e-5,
                       f"rel err {err:.2e}"))
    return rows, checks


ALL = {
    "fig6_parallelism": fig6_parallelism,
    "fig9_dsp_utilization": fig9_dsp_utilization,
    "table_iv_density": table_iv_density,
    "table_v_switching": table_v_switching,
    "fig8_scaling": fig8_scaling,
    "table_vii_gemv": table_vii_gemv,
    "fig14_end_to_end": fig14_end_to_end,
    "fig1_distribution": fig1_distribution,
    "kernel_bench": kernel_bench,
}
