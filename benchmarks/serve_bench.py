"""Continuous-batching serving benchmark: Poisson arrivals over the slot
pool, reporting the serving-level metrics the paper's end-to-end workloads
are judged by (TTFT, inter-token latency, tokens/s, slot occupancy).

Requests arrive by a seeded Poisson process while the scheduler steps, so
later requests are admitted mid-flight — between decode steps of the
earlier ones — exercising chunked-prefill interleaving and slot reuse
exactly as production traffic would.

``--kv-dtype`` sweeps the pool storage dtype (DESIGN.md §9): each sweep
point runs the same seeded workload at one dtype and reports slots x tok/s
x TTFT for its cache cost.  With ``--cache-budget-mb`` the slot count is
*derived* from the budget per dtype, so the sweep directly measures the
quantization -> concurrency trade (int8/fp8 fit ~2x the slots of bf16).
One JSON is emitted per sweep point (``--out-dir`` to write files);
``--baseline-json PATH`` appends the whole sweep (bench args + points) to
PATH, so multi-regime baselines are built by invoking the bench several
times against the same file.  ``benchmarks/BENCH_serve_baseline.json`` is
produced exactly that way:

    rm -f benchmarks/BENCH_serve_baseline.json
    python benchmarks/serve_bench.py --kv-dtype bf16,int8 --requests 6 \
        --rate 1 --seed 6 --max-new 33 --max-burst 8 \
        --baseline-json benchmarks/BENCH_serve_baseline.json
    # ... then the same line with --max-burst 1, the contended pair
    # (--requests 8 --rate 3 --seed 0) at --max-burst 8 and 1, and the
    # mixed-tier capacity sweep (DESIGN.md §12):
    python benchmarks/serve_bench.py --tiers bf16,int8 --d-head 128 \
        --cache-budget-mb 1 --requests 8 --rate 2 --seed 0 --max-new 16 \
        --max-burst 8 --baseline-json benchmarks/BENCH_serve_baseline.json
    # ... and the weight-kernel pair (DESIGN.md §14) — the first regime at
    # --weight-kernel on (packed Pallas kernels on the decode weight path)
    # and --weight-kernel off (jnp dequantize-then-dot), so the baseline
    # records the serving metrics of BOTH weight paths
    # ... and the paged-vs-slab pair (DESIGN.md §15): the shared-prefix
    # workload at a fixed cache budget, once on the slab pool and once
    # with --paged:
    python benchmarks/serve_bench.py --kv-dtype bf16 --requests 12 \
        --rate 20 --seed 2 --prefix-len 32 --prefix-share 0.75 \
        --prompt-len 16 --max-new 16 --n-slots 12 --cache-budget-mb 2 \
        --max-burst 8 --baseline-json benchmarks/BENCH_serve_baseline.json
    # ... then the same line with --paged.  The paged point reports
    # prefix hit-rate, hit-vs-miss TTFT, pages in use, and a
    # peak_in_flight_requests that the slab point cannot reach at the
    # same budget (worst-case slot reservation vs pages actually used).

Shared-prefix workload knobs (``--prefix-len N --prefix-share F``): a
fraction F of requests carry ONE common N-token prefix ahead of their
unique tail; every point (slab or paged) reports
``peak_in_flight_requests``, and paged points add prefix hit/miss
counts, hit-vs-miss TTFT split (from ServeMetrics), page-size/arena
geometry and peak/cached page counts.  Every point also carries an
``env`` stamp (jax/jaxlib versions, backend, device kind) so committed
baselines stay attributable across environments.

``--max-burst`` caps the device-resident decode burst (DESIGN.md §11);
each point reports ``decode_dispatches_per_token``, ``host_syncs_per_token``
and a burst-length histogram, so sweeping ``--max-burst 1`` vs ``8``
measures the dispatch/sync amortization directly — pool geometry is a pure
function of the workload shape, identical across burst caps.  Warmup
compiles the whole power-of-two burst ladder off the clock (one throwaway
request per reachable burst length), so the timed run is steady-state.

``--tiers bf16,int8`` switches to MIXED-TIER mode (DESIGN.md §12): ONE
engine serves every named KV tier concurrently — one pool per tier
(budget-derived slots per tier with ``--cache-budget-mb``), requests
assigned tiers round-robin via ``Request.kv_policy``, decode batches
cohorted per tier by the scheduler.  The point reports per-tier slot
counts and ``tier_slot_ratio_vs_bf16`` — at ``--d-head 128`` (the paper
models' head dim; smoke configs default to 16) the int8 tier fits ~1.94x
the bf16 slots from the same budget, served from the same engine.
``--policy policy.json`` drives the engine from a serialized
``PrecisionPolicy`` instead of the legacy flags (which keep working and
print their policy equivalent).

Every point carries a ``model_measured`` block (DESIGN.md §13): per-
step-shape and per-KV-tier model/measured ratios joining each dispatch's
host wall against the analytical decode model (perfmodel/analytical.py)
priced at the pool tier's KV bytes/token.  ``--trace-dir`` additionally
writes per-point Chrome traces (Perfetto-loadable), Prometheus-style
expositions and registry snapshots; ``--hlo-cost`` joins trip-count-aware
FLOP/byte counts of the compiled step.

**Adversarial workloads + SLO (DESIGN.md §16)**: ``--arrival bursty
--burst-size B`` replaces the smooth Poisson process with Poisson-spaced
bursts of B simultaneous arrivals, and ``--prompt-dist heavy`` draws
prompt lengths from a clipped Pareto (many short, a heavy tail at
``--prompt-len``) — the two shapes that break schedulers tuned on smooth
traffic.  ``--priority-mix 0:0.25,5:0.75`` assigns seeded priority
classes; the report then carries per-priority TTFT/e2e percentiles and
queue waits.  ``--slo-max-waiting`` / ``--slo-max-queue-delay-s`` /
``--slo-downgrade FROM:TO --slo-high-s H --slo-low-s L`` /
``--slo-max-step-s`` attach a ``serve.slo.SLOPolicy`` (admission
control, tier downgrade with hysteresis, cost-model burst planning).
``--fault-rate R`` arms a seeded fault injector AFTER warmup: each
engine dispatch dies (or NaN-poisons) with probability R and the
scheduler recovers by preempt-and-requeue — the bench asserts the
accounting identity (every submitted request lands in exactly one
finish reason) on every run, faults or not.  The committed overload
pair in ``BENCH_serve_baseline.json``:

    # ~2x sustained overload, FCFS: every class queues behind everyone,
    # so tail TTFT ~ the whole backlog drain (grows without bound as
    # load is sustained)
    python benchmarks/serve_bench.py --requests 24 --rate 40 --seed 0 \
        --n-slots 2 --max-new 16 --max-burst 8 --arrival bursty \
        --burst-size 4 --prompt-dist heavy \
        --baseline-json benchmarks/BENCH_serve_baseline.json
    # same workload, 25% priority-0 traffic + admission control: the
    # high class preempts its way to a bounded p99 TTFT (~20x below the
    # FCFS tail) while best-effort is queued/shed with typed rejections
    # (counters account for every submitted request)
    python benchmarks/serve_bench.py --requests 24 --rate 40 --seed 0 \
        --n-slots 2 --max-new 16 --max-burst 8 --arrival bursty \
        --burst-size 4 --prompt-dist heavy --priority-mix 0:0.25,5:0.75 \
        --slo-max-waiting 8 \
        --baseline-json benchmarks/BENCH_serve_baseline.json

**Speculative decoding (DESIGN.md §17)**: ``--spec`` attaches the
draft/verify engine — K draft tokens under ``--draft-policy``'s
aggressive KV tier (default int8, the self-drafting configuration:
SAME weights, cheaper numerics), verified in ONE target dispatch with
longest-agreeing-prefix acceptance.  Accepted output is bit-identical
to a spec-off run; the point reports the ``spec`` metrics block
(acceptance rate, accepted-per-verify-dispatch, spec-aware
dispatches_per_token) plus the planner snapshot.  ``--spec-corrupt``
garbles every draft (seeded collapse harness), demonstrating the
K-controller's fall-back to plain bursts.  The committed spec triple:

    # the dispatch-reduction pair: per-token target dispatch cadence
    # (--max-burst 1), spec off vs on — spec-on emits >1 accepted token
    # per verify dispatch, cutting dispatches-per-token below the
    # one-per-token floor
    python benchmarks/serve_bench.py --kv-dtype bf16 --requests 6 \
        --rate 2 --seed 9 --max-new 33 --max-burst 1 \
        --baseline-json benchmarks/BENCH_serve_baseline.json
    python benchmarks/serve_bench.py --kv-dtype bf16 --requests 6 \
        --rate 2 --seed 9 --max-new 33 --max-burst 1 --spec \
        --baseline-json benchmarks/BENCH_serve_baseline.json
    # the collapse guard: corrupted drafts (0 acceptance) against the
    # plain-burst reference (same line without --spec/--spec-corrupt) —
    # the planner collapses to plain bursts and switches off after
    # max_collapses failed probes, so dispatches_per_token stays within
    # probe-overhead of spec-off
    python benchmarks/serve_bench.py --kv-dtype bf16 --requests 6 \
        --rate 2 --seed 9 --max-new 33 --max-burst 8 \
        --baseline-json benchmarks/BENCH_serve_baseline.json
    python benchmarks/serve_bench.py --kv-dtype bf16 --requests 6 \
        --rate 2 --seed 9 --max-new 33 --max-burst 8 --spec \
        --spec-corrupt \
        --baseline-json benchmarks/BENCH_serve_baseline.json

Smoke (CPU, ~1 min incl. compile):
    python benchmarks/serve_bench.py
Burst amortization sweep:
    python benchmarks/serve_bench.py --max-burst 1 --out-dir bench_out
    python benchmarks/serve_bench.py --max-burst 8 --out-dir bench_out
Quantized-cache sweep at a fixed budget:
    python benchmarks/serve_bench.py --kv-dtype bf16,fp8,int8 \
        --cache-budget-mb 2 --out-dir bench_out
Sharded sweep on forced host devices (DESIGN.md §10):
    python benchmarks/serve_bench.py --dp 2 --tp 4 --force-host-devices 8 \
        --kv-dtype int8 --out-dir bench_out
Mixed-tier serving from one engine (DESIGN.md §12):
    python benchmarks/serve_bench.py --tiers bf16,int8 --d-head 128 \
        --cache-budget-mb 1 --out-dir bench_out
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: jax (and repro modules, which import it) are imported inside main()
# so --force-host-devices can set XLA_FLAGS before backend initialization
# (repro.launch.cli is deliberately jax-free at module level).
import numpy as np

from repro.launch.cli import force_host_devices, serving_mesh


def parse_priority_mix(spec):
    """``"0:0.25,5:0.75"`` -> (classes, normalized weights) or None."""
    if not spec:
        return None
    classes, weights = [], []
    for part in spec.split(","):
        prio, w = part.split(":")
        classes.append(int(prio))
        weights.append(float(w))
    total = sum(weights)
    if total <= 0:
        raise SystemExit("--priority-mix weights must sum > 0")
    return classes, [w / total for w in weights]


def build_slo(args):
    """An ``SLOPolicy`` from the --slo-* flags, or None when none given."""
    downgrade = None
    if args.slo_downgrade:
        src, dst = args.slo_downgrade.split(":")
        downgrade = {src: dst}
    if not any([args.slo_max_waiting, args.slo_max_queue_delay_s,
                downgrade, args.slo_max_step_s]):
        return None
    from repro.serve import SLOPolicy
    return SLOPolicy(
        max_waiting=args.slo_max_waiting,
        max_queue_delay_s=args.slo_max_queue_delay_s,
        protect_priority=args.slo_protect_priority,
        downgrade_map=downgrade,
        downgrade_high_s=args.slo_high_s,
        downgrade_low_s=args.slo_low_s,
        max_step_s=args.slo_max_step_s)


def build_fault_injector(args):
    """Seeded ``(kind, seq) -> mode`` injector, DISARMED until the timed
    run starts (warmup compiles off the clock and must not fault).
    Deterministic: its own generator, decoupled from the workload rng, is
    consulted once per dispatch in dispatch order.  Returns
    (injector, arm) — call ``arm()`` after warmup."""
    if not args.fault_rate:
        return None, lambda: None
    frng = np.random.default_rng(args.seed + 7919)
    armed = []

    def injector(kind, seq):
        if not armed or frng.random() >= args.fault_rate:
            return None
        # half the faults kill the dispatch (StepFault), half NaN-poison
        # the sampled tokens (prefill kills regardless: 'nan' only
        # applies to decode paths, see ServeConfig.fault_injector)
        return "nan" if frng.random() < 0.5 else "injected"

    return injector, lambda: armed.append(True)


def build_spec(args):
    """A ``SpecConfig`` from the --spec-* flags, or None when --spec is
    off.  ``--draft-policy`` names the draft KV tier (int8/fp8/bf16) or a
    PrecisionPolicy JSON path for the whole draft engine."""
    if not args.spec:
        return None
    from repro.serve import SpecConfig
    draft_kv, draft_policy = args.draft_policy, None
    if os.path.exists(args.draft_policy):
        from repro.quant.policy import PrecisionPolicy
        with open(args.draft_policy) as f:
            draft_policy = PrecisionPolicy.from_json(f.read())
        draft_kv = draft_policy.kv
    return SpecConfig(draft_kv=draft_kv, draft_policy=draft_policy,
                      k_max=args.spec_k, k_init=args.spec_k,
                      corrupt_drafts=args.spec_corrupt)


def build_engine(args, cfg, params, kv_dtype, mesh, policy=None,
                 fault_injector=None):
    import dataclasses

    from repro.quant.policy import PrecisionPolicy
    from repro.serve import ServeConfig, ServingEngine
    budget = int(args.cache_budget_mb * 1e6) if args.cache_budget_mb else None
    if policy is None:
        policy = PrecisionPolicy.from_legacy(kv_dtype=kv_dtype)
    elif policy.kv != kv_dtype:
        # --policy + a --kv-dtype sweep: each point re-tiers the policy
        policy = dataclasses.replace(policy, kv=kv_dtype)
    if args.weight_kernel != "auto":
        # --weight-kernel on|off pins the decode-step weight path: 'on'
        # routes quantized linears through the packed Pallas kernels
        # (packed_gemv/w8a8_matmul, DESIGN.md §14), 'off' pins the jnp
        # dequantize-then-dot fallback.  'auto' keeps the policy default
        # (pallas under a multi-device mesh, jnp meshless).
        policy = dataclasses.replace(
            policy, kernel={"on": "pallas", "off": "jnp"}[args.weight_kernel])
    # NOTE: pool geometry (max_len, and any budget-derived slot count) is a
    # pure function of the workload shape — NOT of --max-burst — so sweep
    # points at different burst caps measure dispatch amortization against
    # an identical engine configuration
    scfg = ServeConfig(max_len=args.prefix_len + args.prompt_len
                       + args.max_new,
                       temperature=args.temperature,
                       n_slots=args.n_slots, prefill_chunk=args.chunk,
                       cache_budget_bytes=budget,
                       paged=args.paged, page_size=args.page_size,
                       max_burst=args.max_burst, mesh=mesh, policy=policy,
                       fault_injector=fault_injector,
                       max_fault_retries=args.max_fault_retries)
    engine = ServingEngine(cfg, params, scfg)
    print(f"== precision policy: {engine.policy.to_json()}")
    return engine


def make_workload(args, vocab):
    """Seeded arrivals with jittered prompt lengths and priority classes.

    Arrivals: ``--arrival poisson`` (default) is the smooth process;
    ``--arrival bursty --burst-size B`` draws Poisson-spaced burst epochs
    at rate/B and drops B simultaneous arrivals on each — same long-run
    rate, adversarial short-run backlog (DESIGN.md §16).

    Prompt lengths: uniform jitter by default; ``--prompt-dist heavy``
    draws a clipped Pareto (alpha=1.2) — mostly short prompts with a
    heavy tail pinned at ``--prompt-len``, so occasional giants stall
    chunked prefill behind them.  Both stay within the slot geometry
    (``max_len`` is sized from ``--prompt-len``).

    Priorities: ``--priority-mix "0:0.25,5:0.75"`` assigns each request a
    seeded class draw (smaller = more important); None -> all class 0.

    With ``--prefix-len N --prefix-share F`` a fraction F of the requests
    share ONE common N-token prefix ahead of their unique tail (the
    shared-system-prompt workload); the rest get fully unique prompts of
    the same total length, so the two cohorts differ only in
    shareability.  On a paged pool the shared cohort prefix-hits once the
    first of them has prefilled and registered (DESIGN.md §15); on the
    slab pool the same workload measures the no-sharing baseline."""
    rng = np.random.default_rng(args.seed)
    if args.arrival == "bursty" and args.burst_size > 1:
        B = args.burst_size
        n_bursts = -(-args.requests // B)
        epochs = np.cumsum(rng.exponential(B / args.rate, n_bursts))
        arrivals = np.repeat(epochs, B)[:args.requests]
    else:
        arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    arrivals = arrivals - arrivals[0]      # first request starts the clock
    if args.prompt_dist == "heavy":
        scale = max(2, args.prompt_len // 8)
        raw = (rng.pareto(1.2, args.requests) + 1.0) * scale
        lens = np.clip(raw.astype(np.int64), 2, args.prompt_len)
    else:
        lens = rng.integers(max(2, args.prompt_len // 2),
                            args.prompt_len + 1, args.requests)
    shared = rng.random(args.requests) < args.prefix_share
    prefix = rng.integers(1, vocab, (args.prefix_len,)).astype(np.int32)
    prompts = []
    for n, s in zip(lens, shared):
        tail = rng.integers(1, vocab,
                            (int(n) + (0 if s else args.prefix_len),)
                            ).astype(np.int32)
        prompts.append(np.concatenate([prefix, tail]) if s else tail)
    mix = parse_priority_mix(args.priority_mix)
    if mix is None:
        priorities = np.zeros(args.requests, np.int64)
    else:
        classes, weights = mix
        priorities = rng.choice(classes, size=args.requests, p=weights)
    return arrivals, prompts, priorities


def warmup(engine, prompts, max_new, tiers=None, spec=None):
    """Compile the chunk/decode/burst steps off the clock so the first
    request's TTFT measures scheduling, not XLA.

    The timed run can only ever plan power-of-two burst lengths
    K <= min(max_burst, max_new - 1) (a row's remaining budget after its
    prefill-sampled first token is max_new - 1), so one throwaway request
    per such K — with max_new = K + 1, whose lone burst is planned exactly
    K — compiles the complete ladder without touching the engine's pool
    geometry.  With ``tiers`` the ladder runs once per KV tier (each tier
    is its own compiled step set, keyed per pool in the engine).

    With ``spec`` the draft/verify ladder compiles too: one throwaway
    request per reachable K (the planner can halve down to 1, so the
    whole power-of-two ladder <= k_max), each through a scheduler pinned
    at k_init = k_max = K.  The DraftEngine caches its inner compute
    engine on the target engine, so the timed scheduler's own DraftEngine
    reuses every draft/verify compile from here."""
    import dataclasses

    from repro.serve import Request, SamplingParams, Scheduler
    sched = Scheduler(engine, tiers=tiers)
    top = min(engine.scfg.max_burst, max(max_new - 1, 1))
    ladder = [1 << i for i in range(top.bit_length()) if (1 << i) <= top]
    for k in ladder:
        for tier in (tiers or [None]):
            sched.submit(Request(prompt=prompts[0], kv_policy=tier,
                                 sampling=SamplingParams(
                                     temperature=engine.scfg.temperature,
                                     max_new_tokens=k + 1)))
            sched.run(max_steps=200)
    if spec is None:
        return
    stop = min(spec.k_max, max(max_new - 2, 1))
    for k in [1 << i for i in range(stop.bit_length()) if (1 << i) <= stop]:
        # k_init == k_max == K pins the first spec round's draft length at
        # exactly K (budget max_new-1 = K+1 covers the K+1-token window),
        # compiling the K-step draft burst and the S=K+1 verify
        wcfg = dataclasses.replace(spec, k_init=k, k_max=k,
                                   corrupt_drafts=False)
        wsched = Scheduler(engine, tiers=tiers, spec=wcfg)
        for tier in (tiers or [None]):
            wsched.submit(Request(prompt=prompts[0], kv_policy=tier,
                                  sampling=SamplingParams(
                                      temperature=engine.scfg.temperature,
                                      max_new_tokens=k + 2)))
            wsched.run(max_steps=200)


def point_label(cfg, kv_dtype, tiers, max_burst, weight_kernel="auto",
                paged=False, args=None):
    label = "+".join(tiers) if tiers else kv_dtype
    stem = f"serve_{cfg.name}_{label.replace('+', '-')}_burst{max_burst}"
    if weight_kernel != "auto":
        stem += f"_wk{weight_kernel}"   # --weight-kernel on|off points
    if paged:
        stem += "_paged"                # paged-vs-slab pairs (DESIGN.md §15)
    if args is not None:                # adversarial pairs (DESIGN.md §16):
        if args.priority_mix:           # FCFS-vs-priority points must not
            stem += "_prio"             # collide in a shared --out-dir
        if args.fault_rate:
            stem += "_fault"
        if args.spec:                   # spec-on/off pairs (DESIGN.md §17)
            stem += "_speccorrupt" if args.spec_corrupt else "_spec"
    return stem


def bench_env():
    """Environment stamp carried by every bench point: the perf
    trajectory in a committed baseline is only attributable if each point
    records what software/hardware produced it."""
    import jax
    import jaxlib
    dev = jax.devices()[0]
    return {"jax": jax.__version__, "jaxlib": jaxlib.__version__,
            "backend": jax.default_backend(),
            "device_kind": dev.device_kind, "n_devices": jax.device_count()}


def run_point(args, cfg, engine, kv_dtype, tiers=None, arm_fault=None):
    """One sweep point: the seeded workload at one pool dtype — or, with
    ``tiers``, the MIXED-TIER workload: one engine, one pool per KV tier,
    requests assigned tiers round-robin (``Request.kv_policy``) so
    bf16/int8/fp8 traffic interleaves, mid-flight admission included.

    Every point runs with the model-vs-measured profiler attached
    (DESIGN.md §13) — the sweep JSON carries per-tier and per-step-shape
    model/measured ratios, which is what makes a KV-tier sweep comparable
    against the analytical model rather than only against itself.  With
    ``--trace-dir`` the point additionally writes a Chrome trace, a
    Prometheus-style exposition and periodic registry snapshots."""
    from repro.obs import (MetricsRegistry, Observability, SnapshotWriter,
                           StepProfiler, Tracer)
    from repro.serve import Request, SamplingParams, Scheduler
    arrivals, prompts, priorities = make_workload(args, cfg.vocab)
    spec = build_spec(args)
    if not args.no_warmup:
        t0 = time.monotonic()
        warmup(engine, prompts, args.max_new, tiers=tiers, spec=spec)
        print(f"== warmup (compile) {time.monotonic() - t0:.1f}s")
    if arm_fault is not None:
        arm_fault()        # faults only in the timed run, never in warmup
    slo = build_slo(args)
    if slo is not None:
        print(f"== slo: {json.dumps(slo.snapshot())}")

    obs = Observability(profiler=StepProfiler(cfg))
    stem = None
    if args.trace_dir:
        os.makedirs(args.trace_dir, exist_ok=True)
        stem = os.path.join(args.trace_dir,
                            point_label(cfg, kv_dtype, tiers, args.max_burst,
                                        args.weight_kernel, args.paged,
                                        args=args))
        obs.tracer = Tracer()
        obs.registry = MetricsRegistry()
        obs.snapshots = SnapshotWriter(obs.registry, stem + ".metrics.jsonl")
    sched = Scheduler(engine, tiers=tiers, obs=obs, slo=slo, spec=spec)
    for tier, pool in sorted(sched.pools.items()):
        print(f"== pool[{tier}]: {pool.n_slots} slots x {pool.max_len} "
              f"positions; {pool.bytes_per_token} B/token, "
              f"{pool.cache_bytes / 1e6:.2f} MB cache; prefill chunk "
              f"{args.chunk}; {args.requests} requests @ ~{args.rate}/s")
    reqs = []
    admitted_after_first_decode = 0
    peak_in_flight = 0          # concurrent admitted (PREFILL+DECODE) reqs
    peak_pages = 0              # paged pools: peak arena pages in use
    i = 0
    t0 = time.monotonic()
    while i < args.requests or sched.has_work:
        now = time.monotonic() - t0
        while i < args.requests and arrivals[i] <= now:
            if sched.n_decode_steps > 0:
                admitted_after_first_decode += 1
            reqs.append(sched.submit(Request(
                prompt=prompts[i],
                kv_policy=tiers[i % len(tiers)] if tiers else None,
                priority=int(priorities[i]),
                sampling=SamplingParams(temperature=args.temperature,
                                        max_new_tokens=args.max_new,
                                        seed=args.seed))))
            i += 1
        if sched.has_work:
            sched.step()
            peak_in_flight = max(peak_in_flight, sum(
                1 for r in reqs if r.slot is not None and not r.is_finished))
            peak_pages = max(peak_pages, sum(
                p.pages_in_use for p in sched.pools.values()
                if getattr(p, "paged", False)))
        elif i < args.requests:
            time.sleep(min(float(arrivals[i]) - now, 0.01))

    assert all(r.is_finished for r in reqs)
    # accounting identity (DESIGN.md §16): every submitted request —
    # including rejected/shed/faulted ones, which never emit a token —
    # lands in exactly one finish reason
    finish_reasons = dict(sched.metrics.finish_reasons)
    assert sum(finish_reasons.values()) == len(reqs) == args.requests, \
        (finish_reasons, len(reqs))
    # token accounting identity (DESIGN.md §17): every emitted token is a
    # prefill first token, a plain decode emission, or a spec-round
    # emission — speculation must never double-count or drop tokens
    m = sched.metrics
    assert m.total_new_tokens == (len(m.ttft) + m.decode_tokens_emitted
                                  + m.spec_tokens_emitted), \
        (m.total_new_tokens, len(m.ttft), m.decode_tokens_emitted,
         m.spec_tokens_emitted)
    print(f"\n{'req':>4} {'arrive':>7} {'tier':>5} {'prio':>4} {'P':>4} "
          f"{'new':>4} {'ttft_s':>7} {'e2e_s':>7}  reason")
    for a, r in zip(arrivals, reqs):
        # rejected / deadline-shed / faulted requests may never have
        # emitted a first token
        ttft = (f"{r.first_token_time - r.arrival_time:>7.3f}"
                if r.first_token_time is not None else f"{'-':>7}")
        e2e = (f"{r.finish_time - r.arrival_time:>7.3f}"
               if r.finish_time is not None else f"{'-':>7}")
        print(f"{r.id:>4} {a:>7.2f} {r.tier:>5} {r.priority:>4} "
              f"{r.prompt_len:>4} {r.n_generated:>4} {ttft} {e2e}  "
              f"{r.finish_reason}")

    pool = sched.pool
    rep = sched.metrics.report()
    rep["scheduler_steps"] = sched.n_steps
    rep["decode_steps"] = sched.n_decode_steps
    rep["admitted_mid_flight"] = admitted_after_first_decode
    rep["kv_dtype"] = "+".join(tiers) if tiers else kv_dtype
    rep["n_slots"] = sum(p.n_slots for p in sched.pools.values())
    rep["env"] = bench_env()
    # in-flight concurrency is THE paged-vs-slab capacity number: at a
    # fixed cache budget the slab admits worst-case-sized slots, the
    # paged pool admits on pages actually needed (+ prefix sharing)
    rep["peak_in_flight_requests"] = peak_in_flight
    rep["paged"] = bool(args.paged)
    # SLO / adversarial-workload stamp (DESIGN.md §16): workload shape +
    # policy state, so committed overload points are self-describing
    rep["n_submitted"] = len(reqs)
    rep["arrival"] = args.arrival
    if args.arrival == "bursty":
        rep["burst_size"] = args.burst_size
    rep["prompt_dist"] = args.prompt_dist
    if args.priority_mix:
        rep["priority_mix"] = args.priority_mix
    if args.fault_rate:
        rep["fault_rate"] = args.fault_rate
    if slo is not None:
        rep["slo"] = slo.snapshot()
    if args.paged:
        rep["page_size"] = pool.page_size
        rep["n_pages"] = sum(p.n_pages for p in sched.pools.values())
        rep["pages_in_use_peak"] = peak_pages
        rep["pages_cached_final"] = sum(p.pages_cached
                                        for p in sched.pools.values())
        rep["prefix_hits"] = sum(p.n_prefix_hits
                                 for p in sched.pools.values())
        rep["prefix_misses"] = sum(p.n_prefix_misses
                                   for p in sched.pools.values())
        rep["prefix_hit_tokens"] = sum(p.prefix_hit_tokens_total
                                       for p in sched.pools.values())
    if args.prefix_len:
        rep["prefix_len"] = args.prefix_len
        rep["prefix_share"] = args.prefix_share
    if not tiers:
        # scalar bytes/token is only meaningful for a single-tier pool;
        # mixed points carry tier_bytes_per_token instead
        rep["kv_bytes_per_token"] = pool.bytes_per_token
    rep["kv_cache_mb"] = round(
        sum(p.cache_bytes for p in sched.pools.values()) / 1e6, 3)
    if tiers:
        # the mixed-tier capacity story (DESIGN.md §12): per-tier slot
        # counts from ONE engine's budget — the int8/fp8 tiers fit ~1.9-2x
        # the bf16 slots at d_head=128, served concurrently
        rep["tier_slots"] = {t: p.n_slots
                             for t, p in sorted(sched.pools.items())}
        rep["tier_bytes_per_token"] = {
            t: p.bytes_per_token for t, p in sorted(sched.pools.items())}
        rep["tier_new_tokens"] = {
            t: sum(r.n_generated for r in reqs if r.tier == t)
            for t in sorted(sched.pools)}
        if "bf16" in sched.pools:
            base = sched.pools["bf16"].n_slots
            rep["tier_slot_ratio_vs_bf16"] = {
                t: round(p.n_slots / base, 4)
                for t, p in sorted(sched.pools.items())}
    # burst amortization (DESIGN.md §11): dispatches / host syncs per token
    # (decode_dispatches_per_token and burst_hist come from the metrics
    # report itself)
    rep["max_burst"] = sched.max_burst
    rep["weight_kernel"] = engine.policy.kernel
    rep["host_syncs"] = sched.n_host_syncs
    if rep.get("total_new_tokens"):
        rep["host_syncs_per_token"] = round(
            sched.n_host_syncs / rep["total_new_tokens"], 4)
    if spec is not None:
        # speculative point stamp (DESIGN.md §17): config + controller
        # end-state, plus the analytical draft/verify price at the
        # MEASURED acceptance — the model-vs-measured join for spec mode
        rep["spec_args"] = {"draft_kv": spec.draft_kv, "k_max": spec.k_max,
                            "corrupt_drafts": spec.corrupt_drafts}
        rep["spec_planner"] = sched.spec_planner.snapshot()
        from repro.perfmodel.analytical import spec_round_latency
        acc = (rep.get("spec") or {}).get("acceptance_rate") or 0.0
        dpool = (sched.draft.pools.get(sched.default_tier)
                 if sched.draft is not None else None)
        rep["spec_model"] = spec_round_latency(
            cfg, k=spec.k_max, batch=rep["n_slots"],
            context=engine.scfg.max_len, acceptance=acc,
            kv_bytes_per_token=(None if tiers else pool.bytes_per_token),
            draft_kv_bytes_per_token=(dpool.bytes_per_token
                                      if dpool is not None else None))
    if args.cache_budget_mb:
        rep["cache_budget_mb"] = args.cache_budget_mb
    # model-vs-measured join (always on): per step shape and per KV tier
    rep["model_measured"] = obs.profiler.report()
    if args.hlo_cost:
        # static compiled-step costs per pool (trip-count-aware HLO walk);
        # offline lowering — never touches the timed run above
        from repro.obs import compiled_step_cost
        rep["compiled_step_cost"] = {
            t: compiled_step_cost(engine, p)
            for t, p in sorted(sched.pools.items())}
    if stem is not None:
        obs.tracer.write(stem + ".trace.json")
        with open(stem + ".metrics.txt", "w") as f:
            f.write(obs.registry.expose())
        print(f"== trace: {stem}.trace.json ({len(obs.tracer)} events); "
              f"metrics: {stem}.metrics.txt "
              f"(+{obs.snapshots.n_written} snapshots)")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--rate", type=float, default=6.0, help="req/s (Poisson)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true")
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty"],
                    help="arrival process: smooth Poisson, or Poisson-"
                         "spaced bursts of --burst-size simultaneous "
                         "arrivals at the same long-run rate "
                         "(DESIGN.md §16)")
    ap.add_argument("--burst-size", type=int, default=4,
                    help="arrivals per burst in --arrival bursty mode")
    ap.add_argument("--prompt-dist", default="uniform",
                    choices=["uniform", "heavy"],
                    help="prompt-length law: uniform jitter, or 'heavy' "
                         "(clipped Pareto: mostly short, heavy tail at "
                         "--prompt-len)")
    ap.add_argument("--priority-mix", default=None,
                    help="seeded priority classes, e.g. '0:0.25,5:0.75' "
                         "(class:weight; smaller = more important). "
                         "Default: every request class 0 (pure FCFS)")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="per-dispatch fault probability in the timed run "
                         "(seeded; half killed dispatches, half NaN-"
                         "poisoned tokens).  The scheduler recovers by "
                         "preempt-and-requeue with bounded retries")
    ap.add_argument("--max-fault-retries", type=int, default=3,
                    help="step faults one request may survive before "
                         "finish_reason='fault'")
    ap.add_argument("--slo-max-waiting", type=int, default=None,
                    help="SLO: reject unprotected arrivals once this many "
                         "requests are queued")
    ap.add_argument("--slo-max-queue-delay-s", type=float, default=None,
                    help="SLO: reject unprotected arrivals once modeled "
                         "queue drain exceeds this")
    ap.add_argument("--slo-protect-priority", type=int, default=0,
                    help="SLO: requests with priority <= this are never "
                         "rejected")
    ap.add_argument("--slo-downgrade", default=None, metavar="FROM:TO",
                    help="SLO: kv-tier downgrade applied while degraded, "
                         "e.g. bf16:int8 (needs --slo-high-s/--slo-low-s "
                         "and --tiers naming both, so the target pool "
                         "exists)")
    ap.add_argument("--slo-high-s", type=float, default=None,
                    help="SLO: modeled drain that ENGAGES tier downgrade")
    ap.add_argument("--slo-low-s", type=float, default=None,
                    help="SLO: modeled drain that RELEASES it (< high)")
    ap.add_argument("--slo-max-step-s", type=float, default=None,
                    help="SLO: modeled per-round latency budget sizing "
                         "decode bursts / prefill chunks per step")
    ap.add_argument("--max-burst", type=int, default=8,
                    help="device-resident decode burst cap (1 = per-token "
                         "dispatch, DESIGN.md §11)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding (DESIGN.md §17): K draft "
                         "tokens under the aggressive --draft-policy tier, "
                         "verified in one target dispatch, longest-"
                         "agreeing-prefix acceptance — output stays "
                         "bit-identical to a spec-off run")
    ap.add_argument("--draft-policy", default="int8",
                    help="draft engine precision: a KV tier name "
                         "(int8/fp8/bf16) for the self-drafting "
                         "configuration, or a PrecisionPolicy JSON path")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft-length ceiling k_max (power-of-two "
                         "ladder); the acceptance-EMA controller walks K "
                         "below it")
    ap.add_argument("--spec-corrupt", action="store_true",
                    help="adversarial collapse harness: garble every "
                         "draft token (0 acceptance) to demonstrate the "
                         "plain-burst fallback — output is STILL "
                         "bit-identical")
    ap.add_argument("--baseline-json", default=None,
                    help="write {args, points} for the whole sweep here")
    ap.add_argument("--kv-dtype", default="bf16",
                    help="comma-separated pool dtypes to sweep: bf16,fp8,int8")
    ap.add_argument("--tiers", default=None,
                    help="comma-separated KV tiers served CONCURRENTLY from "
                         "one engine (e.g. bf16,int8): requests are "
                         "assigned tiers round-robin via Request.kv_policy "
                         "(DESIGN.md §12).  One mixed point instead of a "
                         "per-dtype sweep")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV pool (page-table arena, "
                         "COW prefix sharing, DESIGN.md §15) instead of "
                         "the fixed slab — run the same line with and "
                         "without this flag for a paged-vs-slab pair")
    ap.add_argument("--page-size", type=int, default=0,
                    help="positions per arena page (0 = prefill chunk)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared-prefix workload: this many tokens of ONE "
                         "common prefix ahead of each shared request's "
                         "unique tail (0 disables)")
    ap.add_argument("--prefix-share", type=float, default=0.0,
                    help="fraction of requests that carry the shared "
                         "prefix (the rest get unique prompts of the same "
                         "total length)")
    ap.add_argument("--weight-kernel", default="auto",
                    choices=["auto", "on", "off"],
                    help="decode-step quantized weight path: 'on' pins the "
                         "packed Pallas kernels, 'off' pins the jnp "
                         "dequantize-then-dot fallback, 'auto' keeps the "
                         "policy default (pallas under a multi-device "
                         "mesh, jnp meshless) — DESIGN.md §14")
    ap.add_argument("--policy", default=None, metavar="POLICY_JSON",
                    help="path to a PrecisionPolicy JSON for the engine "
                         "(weight patterns + kv tier + kernel); legacy "
                         "flags keep working and print their policy "
                         "equivalent")
    ap.add_argument("--d-head", type=int, default=None,
                    help="override the config's head dim (e.g. 128 to run "
                         "the paper-scale KV geometry on a smoke-depth "
                         "model: the int8-vs-bf16 bytes/token ratio is "
                         "2*d/(d+4), so capacity claims need d_head=128)")
    ap.add_argument("--cache-budget-mb", type=float, default=None,
                    help="derive n_slots from this cache budget per dtype "
                         "(per tier in --tiers mode)")
    ap.add_argument("--out-dir", default=None,
                    help="write one JSON per sweep point here")
    ap.add_argument("--trace-dir", default=None,
                    help="write per-point observability artifacts here: "
                         "Chrome trace (.trace.json, open in Perfetto), "
                         "Prometheus exposition (.metrics.txt) and registry "
                         "snapshots (.metrics.jsonl) — DESIGN.md §13")
    ap.add_argument("--hlo-cost", action="store_true",
                    help="also report trip-count-aware FLOP/byte counts of "
                         "the compiled decode step per pool "
                         "(launch/hlo_analysis.py; offline lowering)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel mesh axis (pool slots shard here)")
    ap.add_argument("--tp", type=int, default=1,
                    help="model-parallel mesh axis (weights/heads)")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="CPU validation: fake this many host devices")
    args = ap.parse_args()

    force_host_devices(args.force_host_devices)
    import jax
    from repro.configs import get_config
    from repro.models.common import QuantMaker
    from repro.models import transformer as T

    mesh = serving_mesh(args.dp, args.tp)

    cfg = get_config(args.arch, smoke=not args.full)
    if args.d_head:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, d_head=args.d_head)
    print(f"== {cfg.name}: {cfg.n_layers}L d={cfg.d_model} ({cfg.family}); "
          f"schemes proj={cfg.scheme_proj} ffn={cfg.scheme_ffn}"
          + (f"; d_head={cfg.head_dim}" if args.d_head else "")
          + (f"; mesh dp={args.dp} x tp={args.tp}" if mesh is not None else ""))
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))

    policy = None
    if args.policy:
        from repro.quant.policy import PrecisionPolicy
        with open(args.policy) as f:
            policy = PrecisionPolicy.from_json(f.read())

    tiers = [t.strip() for t in args.tiers.split(",") if t.strip()] \
        if args.tiers else None
    if tiers:
        sweep = [tiers[0]]               # one mixed point, default tier first
    elif policy is not None and args.kv_dtype == "bf16":
        sweep = [policy.kv]              # the policy's tier, unless swept
    else:
        sweep = [d.strip() for d in args.kv_dtype.split(",") if d.strip()]

    reports = []
    for kv_dtype in sweep:
        injector, arm_fault = build_fault_injector(args)
        engine = build_engine(args, cfg, params, kv_dtype, mesh, policy,
                              fault_injector=injector)
        rep = run_point(args, cfg, engine, kv_dtype, tiers=tiers,
                        arm_fault=arm_fault)
        label = "+".join(tiers) if tiers else kv_dtype
        print(f"\n== serving metrics [{label}]")
        print(json.dumps(rep, indent=2))
        if args.out_dir:
            os.makedirs(args.out_dir, exist_ok=True)
            path = os.path.join(
                args.out_dir,
                point_label(cfg, kv_dtype, tiers, args.max_burst,
                            args.weight_kernel, args.paged,
                            args=args) + ".json")
            with open(path, "w") as f:
                json.dump(rep, f, indent=2, allow_nan=False)
            print(f"== wrote {path}")
        reports.append(rep)

    if len(reports) > 1:
        print(f"\n== sweep summary ({cfg.name})")
        print(f"{'kv_dtype':>8} {'slots':>6} {'B/tok':>6} {'tok/s':>8} "
              f"{'disp/tok':>9} {'ttft_p50':>9} {'occupancy':>10}")
        for r in reports:
            # missing/null fields print as '-' (reports are NaN-free JSON)
            print(f"{r['kv_dtype']:>8} {r['n_slots']:>6} "
                  f"{r['kv_bytes_per_token']:>6} "
                  f"{str(r.get('tokens_per_s') or '-'):>8} "
                  f"{str(r.get('decode_dispatches_per_token', '-')):>9} "
                  f"{str(r.get('ttft_p50_s', '-')):>9} "
                  f"{r['slot_occupancy_mean']:>10}")

    if args.baseline_json:
        # append semantics: each invocation adds one sweep, so a multi-
        # regime baseline (e.g. benchmarks/BENCH_serve_baseline.json) is
        # reproduced by re-running the recorded bench_args command lines
        # against the same path
        sweep = {"bench_args": {k: v for k, v in vars(args).items()
                                if not k.startswith("_")},
                 "points": reports}
        payload = {"generated_by": "benchmarks/serve_bench.py",
                   "arch": cfg.name, "sweeps": []}
        if os.path.exists(args.baseline_json):
            with open(args.baseline_json) as f:
                payload = json.load(f)
        payload["sweeps"].append(sweep)
        d = os.path.dirname(args.baseline_json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.baseline_json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"== wrote {args.baseline_json} "
              f"({len(payload['sweeps'])} sweeps)")


if __name__ == "__main__":
    main()
