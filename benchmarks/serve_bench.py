"""Continuous-batching serving benchmark: Poisson arrivals over the slot
pool, reporting the serving-level metrics the paper's end-to-end workloads
are judged by (TTFT, inter-token latency, tokens/s, slot occupancy).

Requests arrive by a seeded Poisson process while the scheduler steps, so
later requests are admitted mid-flight — between decode steps of the
earlier ones — exercising chunked-prefill interleaving and slot reuse
exactly as production traffic would.

Smoke (CPU, ~1 min incl. compile):
    python benchmarks/serve_bench.py
Heavier:
    python benchmarks/serve_bench.py --arch qwen3-moe-30b-a3b \
        --requests 32 --n-slots 8 --rate 8
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models.common import QuantMaker
from repro.models import transformer as T
from repro.serve import Request, SamplingParams, ServeConfig, ServingEngine, \
    Scheduler


def build_engine(args):
    cfg = get_config(args.arch, smoke=not args.full)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0), plan={}))
    scfg = ServeConfig(max_len=args.prompt_len + args.max_new,
                       temperature=args.temperature,
                       n_slots=args.n_slots, prefill_chunk=args.chunk)
    return cfg, ServingEngine(cfg, params, scfg)


def make_workload(args, vocab):
    """Seeded Poisson arrivals with jittered prompt lengths."""
    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    arrivals[0] = 0.0                      # first request starts the clock
    lens = rng.integers(max(2, args.prompt_len // 2), args.prompt_len + 1,
                        args.requests)
    prompts = [rng.integers(1, vocab, (int(n),)).astype(np.int32)
               for n in lens]
    return arrivals, prompts


def warmup(engine, prompts):
    """Compile the chunk/decode/sample steps off the clock so the first
    request's TTFT measures scheduling, not XLA."""
    sched = Scheduler(engine)
    sched.submit(Request(prompt=prompts[0],
                         sampling=SamplingParams(
                             temperature=engine.scfg.temperature,
                             max_new_tokens=2)))
    sched.run(max_steps=100)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--rate", type=float, default=6.0, help="req/s (Poisson)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true")
    args = ap.parse_args()

    cfg, engine = build_engine(args)
    print(f"== {cfg.name}: {cfg.n_layers}L d={cfg.d_model} ({cfg.family}); "
          f"schemes proj={cfg.scheme_proj} ffn={cfg.scheme_ffn}")
    print(f"== pool: {args.n_slots} slots x {engine.scfg.max_len} positions; "
          f"prefill chunk {args.chunk}; {args.requests} requests @ "
          f"~{args.rate}/s")

    arrivals, prompts = make_workload(args, cfg.vocab)
    if not args.no_warmup:
        t0 = time.monotonic()
        warmup(engine, prompts)
        print(f"== warmup (compile) {time.monotonic() - t0:.1f}s")

    sched = Scheduler(engine)
    reqs = []
    admitted_after_first_decode = 0
    i = 0
    t0 = time.monotonic()
    while i < args.requests or sched.has_work:
        now = time.monotonic() - t0
        while i < args.requests and arrivals[i] <= now:
            if sched.n_decode_steps > 0:
                admitted_after_first_decode += 1
            reqs.append(sched.submit(Request(
                prompt=prompts[i],
                sampling=SamplingParams(temperature=args.temperature,
                                        max_new_tokens=args.max_new,
                                        seed=args.seed))))
            i += 1
        if sched.has_work:
            sched.step()
        elif i < args.requests:
            time.sleep(min(float(arrivals[i]) - now, 0.01))

    assert all(r.is_finished for r in reqs)
    print(f"\n{'req':>4} {'arrive':>7} {'P':>4} {'new':>4} {'ttft_s':>7} "
          f"{'e2e_s':>7}  reason")
    for a, r in zip(arrivals, reqs):
        print(f"{r.id:>4} {a:>7.2f} {r.prompt_len:>4} {r.n_generated:>4} "
              f"{r.first_token_time - r.arrival_time:>7.3f} "
              f"{r.finish_time - r.arrival_time:>7.3f}  {r.finish_reason}")

    rep = sched.metrics.report()
    rep["scheduler_steps"] = sched.n_steps
    rep["decode_steps"] = sched.n_decode_steps
    rep["admitted_mid_flight"] = admitted_after_first_decode
    print("\n== serving metrics")
    print(json.dumps(rep, indent=2))


if __name__ == "__main__":
    main()
