"""Benchmark harness: one entry per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig6_parallelism]

Prints ``name,us_per_call,derived`` CSV rows where timing applies, a
validation summary against the paper's claims, and writes
results/benchmarks.json.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.paper_tables import ALL  # noqa: E402

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only")
    args = ap.parse_args()

    out = {}
    n_ok = n_fail = 0
    for name, fn in ALL.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        rows, checks = fn()
        dt = (time.perf_counter() - t0) * 1e6
        out[name] = {"rows": rows,
                     "checks": [{"name": c, "ok": ok, "detail": d}
                                for c, ok, d in checks]}
        print(f"{name},{dt:.0f},rows={len(rows)}")
        for c, ok, d in checks:
            mark = "PASS" if ok else "FAIL"
            n_ok += ok
            n_fail += not ok
            print(f"  [{mark}] {c}: {d}")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "benchmarks.json").write_text(json.dumps(out, indent=1,
                                                        default=str))
    print(f"\n{n_ok} checks passed, {n_fail} failed "
          f"-> results/benchmarks.json")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
