"""Substrate tests: optimizer, compression, checkpoint, data pipeline,
fault tolerance, MoE dispatch invariants, HLO analyzer."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------
def test_adamw_descends_quadratic():
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200)
    params = {"w": jnp.full((4,), 5.0)}
    state = adamw_init(params, cfg)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_bf16_moments_shape_dtype():
    from repro.optim import AdamWConfig, adamw_init
    cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
    params = {"w": jnp.zeros((3, 3), jnp.bfloat16)}
    st = adamw_init(params, cfg)
    assert st.mu["w"].dtype == jnp.bfloat16
    assert st.nu["w"].shape == (3, 3)


def test_clip_bounds_update():
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=1, total_steps=10)
    params = {"w": jnp.zeros((8,))}
    state = adamw_init(params, cfg)
    grads = {"w": jnp.full((8,), 1e6)}
    _, _, metrics = adamw_update(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5   # raw norm reported


# ---------------------------------------------------------------------------
# Gradient compression: error feedback is lossless over accumulation
# ---------------------------------------------------------------------------
def test_compression_error_feedback_unbiased():
    from repro.optim import compress_decompress, init_compression
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
    state = init_compression({"g": g_true})
    total_sent = jnp.zeros((64,))
    for step in range(50):
        out, state = compress_decompress({"g": g_true}, state)
        total_sent = total_sent + out["g"]
    # accumulated transmitted grads -> accumulated true grads (EF property)
    np.testing.assert_allclose(np.asarray(total_sent) / 50,
                               np.asarray(g_true), atol=0.02)


def test_compressed_psum_agrees_with_mean():
    from repro.optim import compressed_psum, init_compression
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.asarray(np.random.default_rng(1).standard_normal((16,)),
                    jnp.float32)
    state = init_compression({"g": g})

    def body(g, err):
        out, new_state = compressed_psum({"g": g}, type(state)(
            {"g": err}), "pod")
        return out["g"], new_state.error["g"]

    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    out, _ = fn(g, state.error["g"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=0.05)


# ---------------------------------------------------------------------------
# Checkpoint: atomic save/load, async manager, elastic dtype round-trip
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_bf16():
    from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
    tree = {"a": jnp.asarray([[1.5, -2.25]], jnp.bfloat16),
            "b": {"c": jnp.arange(6, dtype=jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree, extra={"note": "x"})
        assert latest_step(d) == 7
        out, extra = load_checkpoint(d, 7, tree)
        assert extra["note"] == "x"
        np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))
        np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_manager_async_and_gc():
    from repro.checkpoint import CheckpointManager, latest_step
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save_async(s, {"w": jnp.full((2,), float(s))})
        mgr.wait()
        assert latest_step(d) == 4
        import pathlib
        steps = sorted(p.name for p in pathlib.Path(d).glob("step_*"))
        assert len(steps) == 2   # retention


def test_checkpoint_atomicity_no_partial_visible():
    from repro.checkpoint import latest_step
    with tempfile.TemporaryDirectory() as d:
        # a torn write: tmp dir exists but LATEST never written
        os.makedirs(os.path.join(d, ".tmp_step_000000009_1"))
        assert latest_step(d) is None


# ---------------------------------------------------------------------------
# Data pipeline: determinism + restore
# ---------------------------------------------------------------------------
def test_data_deterministic_and_restorable():
    from repro.data import DataConfig, SyntheticTokenPipeline
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4, seed=3)
    p1 = SyntheticTokenPipeline(cfg)
    b0, b1, b2 = next(p1), next(p1), next(p1)
    state = p1.state()
    p1.close()
    p2 = SyntheticTokenPipeline.restore(cfg, state)
    b3 = next(p2)
    p2.close()
    p3 = SyntheticTokenPipeline(cfg)
    c0 = next(p3)
    p3.close()
    np.testing.assert_array_equal(b0["tokens"], c0["tokens"])
    assert not np.array_equal(b2["tokens"], b3["tokens"])
    assert (b0["labels"][:, :-1] == b0["tokens"][:, 1:]).all()


def test_data_host_sharding_disjoint():
    from repro.data import DataConfig, SyntheticTokenPipeline
    cfgs = [DataConfig(vocab=1000, seq_len=8, global_batch=8, seed=1,
                       n_hosts=2, host_id=h) for h in (0, 1)]
    ps = [SyntheticTokenPipeline(c) for c in cfgs]
    b = [next(p) for p in ps]
    [p.close() for p in ps]
    assert b[0]["tokens"].shape == (4, 8)
    assert not np.array_equal(b[0]["tokens"], b[1]["tokens"])


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------
def test_straggler_monitor_flags_outlier():
    import time
    from repro.runtime.fault_tolerance import StragglerMonitor
    mon = StragglerMonitor(alpha=0.5, sigma=3.0, warmup_steps=3)
    for step in range(12):
        mon.start_step()
        time.sleep(0.02 if step != 9 else 0.2)
        mon.end_step(step)
    assert any(e.step == 9 for e in mon.events)


def test_restart_manager_retries():
    from repro.runtime.fault_tolerance import RestartManager
    calls = {"n": 0, "ckpt": None}

    def body(resume):
        calls["n"] += 1
        if calls["n"] < 3:
            calls["ckpt"] = calls["n"] * 10
            raise RuntimeError("boom")
        return (resume or 0) + 1

    mgr = RestartManager(lambda: calls["ckpt"], max_restarts=5)
    out = mgr.run(body)
    assert out == 21 and mgr.restarts == 2


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------
def test_moe_capacity_and_combine():
    from repro.models import moe as M
    from repro.models.common import InitMaker
    cfg = M.MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=2,
                      capacity_factor=2.0)   # full capacity: no drops
    params = M.moe_params(InitMaker(jax.random.PRNGKey(0)), cfg, ())
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.bfloat16)
    y, aux = M.moe_forward(params, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) >= 1.0 - 1e-3   # GShard aux >= 1 at optimum

    # grouping must not change results when capacity is unconstrained
    y1, _ = M.moe_forward(params, cfg, x, n_groups=1)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y, np.float32), rtol=0.05,
                               atol=0.05)


def test_moe_drops_when_over_capacity():
    from repro.models import moe as M
    from repro.models.common import InitMaker
    cfg = M.MoEConfig(d_model=8, d_ff=16, n_experts=4, top_k=1,
                      capacity_factor=0.25)
    params = M.moe_params(InitMaker(jax.random.PRNGKey(0)), cfg, ())
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 8), jnp.bfloat16)
    y, _ = M.moe_forward(params, cfg, x)
    # with tiny capacity some outputs must be exactly zero (dropped tokens)
    norms = np.linalg.norm(np.asarray(y, np.float32), axis=-1)
    assert (norms < 1e-6).any()


# ---------------------------------------------------------------------------
# HLO analyzer unit behaviour
# ---------------------------------------------------------------------------
def test_hlo_analyzer_counts_scan_trips():
    from repro.launch.hlo_analysis import analyze
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    txt = jax.jit(f).lower(x, ws).compile().as_text()
    a = analyze(txt)
    assert abs(a.flops - 7 * 2 * 64**3) / (7 * 2 * 64**3) < 0.01
