"""Speculative-decoding tests (DESIGN.md §17).

The contract under test: a draft/verify round — K draft tokens under the
aggressive low-precision draft engine, one (K+1)-position target verify,
longest-agreeing-prefix acceptance — emits tokens BIT-IDENTICAL to the
non-speculative scheduler, greedy AND seeded temperature, slab AND paged
pools, single-device AND dp x tp.  Correctness never depends on the
draft: the adversarial corrupt-drafts harness (0 acceptance) must still
produce identical output AND identical committed KV bytes (the
length-only rollback invariant), and the K-controller must fall back to
plain bursts with bounded O(1) probe cost when acceptance collapses.
EDF admission ordering and the spec accounting identities ride along.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import InitMaker, QuantMaker
from repro.models import transformer as T
from repro.serve import (Request, SamplingParams, ServeConfig,
                         ServingEngine, Scheduler, SpecConfig, SpecPlanner)
from repro.serve.spec import DraftEngine, accept_longest_prefix

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    return cfg, params


def make_engine(setup, *, paged=False, mesh=None, max_len=48, n_slots=4):
    cfg, params = setup
    return ServingEngine(cfg, params, ServeConfig(
        max_len=max_len, n_slots=n_slots, prefill_chunk=8, max_burst=8,
        paged=paged, mesh=mesh))


@pytest.fixture(scope="module")
def engine(setup):
    return make_engine(setup)


@pytest.fixture(scope="module")
def paged_engine(setup):
    return make_engine(setup, paged=True)


def _prompts(engine, n, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, engine.cfg.vocab, (lens[i % len(lens)],))
            .astype(np.int32) for i in range(n)]


def _run(engine, prompts, *, spec=None, max_new=9, temperature=0.0,
         seed=0, max_burst=8, deadlines=None, priorities=None):
    sched = Scheduler(engine, max_burst=max_burst, spec=spec)
    sp = SamplingParams(temperature=temperature, max_new_tokens=max_new,
                        seed=seed)
    reqs = [sched.submit(Request(
        prompt=p, sampling=sp,
        ttft_deadline_s=deadlines[i] if deadlines else None,
        priority=priorities[i] if priorities else 0))
        for i, p in enumerate(prompts)]
    sched.run(max_steps=600)
    assert all(r.is_finished for r in reqs)
    return [list(r.output_tokens) for r in reqs], sched


def _spec_ran(sched):
    m = sched.metrics
    assert m.spec_rounds > 0, "no speculative round ever dispatched"
    assert m.spec_tokens_accepted > 0, "speculation accepted nothing"


# ---------------------------------------------------------------------------
# THE contract: spec-on == spec-off, bit for bit
# ---------------------------------------------------------------------------
def test_spec_bit_identical_greedy_slab(engine):
    """Greedy, slab pool: accepted output equals the non-speculative run
    request for request, while verify dispatches each deliver > 1 token
    (the accepted prefix + bonus) — the whole point of drafting."""
    prompts = _prompts(engine, 3, [9, 6, 11], seed=1)
    ref, _ = _run(engine, prompts, max_new=17)
    got, s = _run(engine, prompts, max_new=17, spec=SpecConfig())
    assert got == ref
    _spec_ran(s)
    rep = s.metrics.report()["spec"]
    assert rep["emitted_per_verify_dispatch"] > 1.0


def test_spec_bit_identical_seeded_temperature_slab(engine):
    """Seeded temperature: the draft samples with each request's REAL
    per-(id, n_generated) key schedule and the verify re-samples every
    window position with the same keys, so even stochastic continuations
    are bit-identical — and a different seed still changes them."""
    prompts = _prompts(engine, 3, [8, 11, 6], seed=2)
    ref, _ = _run(engine, prompts, max_new=17, temperature=0.8, seed=13)
    got, s = _run(engine, prompts, max_new=17, temperature=0.8, seed=13,
                  spec=SpecConfig())
    assert got == ref
    _spec_ran(s)
    other, _ = _run(engine, prompts, max_new=17, temperature=0.8, seed=14,
                    spec=SpecConfig())
    assert other != ref


def test_spec_bit_identical_paged(paged_engine):
    """Paged pool: the verify window is pinned via ensure_decode(K+1) and
    rollback is the same length-only commit, so page indirection changes
    nothing — greedy and temperature."""
    prompts = _prompts(paged_engine, 3, [9, 6, 8], seed=3)
    for temp, seed in ((0.0, 0), (0.8, 13)):
        ref, _ = _run(paged_engine, prompts, max_new=17,
                      temperature=temp, seed=seed)
        got, s = _run(paged_engine, prompts, max_new=17, temperature=temp,
                      seed=seed, spec=SpecConfig())
        assert got == ref, f"temp={temp}"
        _spec_ran(s)


def test_spec_bit_identical_mesh_1x1(setup, engine):
    """A (1, 1) mesh walks the sharded verify/draft path (explicit cache
    shardings, donation) — fast-loop coverage of the §10 plumbing."""
    prompts = _prompts(engine, 2, [9, 6], seed=4)
    ref, _ = _run(engine, prompts, max_new=13)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    meng = make_engine(setup, mesh=mesh)
    got, s = _run(meng, prompts, max_new=13, spec=SpecConfig())
    assert got == ref
    _spec_ran(s)


def test_spec_eos_retires_at_identical_position(engine):
    """An EOS inside the accepted window truncates emission exactly where
    the plain scheduler would retire — acceptance never emits past EOS."""
    prompts = _prompts(engine, 1, [8], seed=5)
    probe, _ = _run(engine, prompts, max_new=12)
    seq = probe[0]
    i = next(j for j in range(1, len(seq)) if seq[j] not in seq[:j])
    eos = int(seq[i])
    sp = SamplingParams(max_new_tokens=16, eos_id=eos)

    def run(spec):
        sched = Scheduler(engine, spec=spec)
        req = sched.submit(Request(prompt=prompts[0], sampling=sp))
        sched.run(max_steps=200)
        return req, sched

    r_ref, _ = run(None)
    r_spec, s = run(SpecConfig())
    assert r_spec.output_tokens == r_ref.output_tokens
    assert r_spec.finish_reason == r_ref.finish_reason == "eos"
    assert r_spec.n_generated == i + 1
    _spec_ran(s)
    # slot returned despite the mid-window retire
    assert s.pool.n_free == s.pool.n_slots


@multi_device
def test_spec_dp2_tp4_bit_identical():
    """Speculation under the dp=2 x tp=4 mesh (8 forced host devices),
    quantized weights + int8 target KV, greedy and temperature sampling:
    identical to the non-speculative run AT THE SAME GEOMETRY.  The
    reference is the meshed plain scheduler — the spec contract is
    "speculation changes nothing", while meshed-vs-meshless numerics
    is test_sharded_serving.py's contract, pinned separately."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab, (n,)).astype(np.int32)
               for n in (9, 6, 11)]

    def eng():
        return ServingEngine(cfg, params, ServeConfig(
            max_len=32, n_slots=8, prefill_chunk=8, kv_dtype="int8",
            max_burst=8, mesh=jax.make_mesh((2, 4), ("data", "model"))))

    for temp, seed in ((0.0, 0), (0.7, 5)):
        ref, _ = _run(eng(), prompts, max_new=13,
                      temperature=temp, seed=seed)
        got, s = _run(eng(), prompts, max_new=13, temperature=temp,
                      seed=seed, spec=SpecConfig())
        assert got == ref, f"temp={temp}"
        _spec_ran(s)


# ---------------------------------------------------------------------------
# Rejection rollback: corrupted drafts, byte-equal committed KV
# ---------------------------------------------------------------------------
def _committed_kv(pool, slot, length):
    """Every cache leaf's committed prefix for ``slot`` (leaves are
    stacked [layer, slot, pos, ...]; positions >= length are
    garbage-but-masked and excluded by contract)."""
    return [np.asarray(leaf)[:, slot, :length]
            for leaf in jax.tree_util.tree_leaves(pool.cache)]


def test_corrupt_drafts_identical_output_and_kv_bytes(engine):
    """THE rollback pin: with every draft garbled (acceptance exactly 0)
    each round fully rejects, emits only the verify's own position-0
    sample, and commits lengths += 1 — output AND the committed target-KV
    prefix must be byte-equal to a never-drafted run (the garbage the
    verify wrote beyond the commit is dead state)."""
    prompts = _prompts(engine, 1, [8], seed=6)
    ref, s_ref = _run(engine, prompts, max_new=9)
    spec = SpecConfig(corrupt_drafts=True, cooldown_rounds=1,
                      max_collapses=100)   # keep probing: every round spec
    got, s = _run(engine, prompts, max_new=9, spec=spec)
    assert got == ref
    m = s.metrics
    assert m.spec_rounds > 0
    assert m.spec_tokens_accepted == 0          # total rejection
    assert m.spec_tokens_rejected == m.spec_tokens_drafted
    # committed KV prefix: prompt + outputs[:-1] (the last token is the
    # next input, never written)
    L = len(prompts[0]) + len(ref[0]) - 1
    for a, b in zip(_committed_kv(s_ref.pool, 0, L),
                    _committed_kv(s.pool, 0, L)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# K controller: collapse -> plain bursts, bounded probe cost
# ---------------------------------------------------------------------------
def test_k_controller_collapse_falls_back_to_plain(setup):
    """Collapsed acceptance (corrupt drafts) must degrade to the plain
    burst path: the planner halves K to 1, cools down with backoff,
    probes at K=1, and after max_collapses consecutive failures switches
    off permanently — total spec overhead is a bounded constant, so
    dispatches-per-token approaches the plain-burst rate as the run
    grows.  Pinned here as: dpt_spec <= dpt_plain + overhead/T with the
    overhead measured and itself bounded."""
    eng = make_engine(setup, max_len=120, n_slots=2)
    prompts = _prompts(eng, 1, [8], seed=7)
    spec = SpecConfig(k_init=4, k_max=4, corrupt_drafts=True,
                      cooldown_rounds=2, cooldown_backoff=2,
                      max_collapses=2)
    ref, s_plain = _run(eng, prompts, max_new=97)
    got, s = _run(eng, prompts, max_new=97, spec=spec)
    assert got == ref
    snap = s.spec_planner.snapshot()
    assert snap["off"] and snap["collapses"] == 2
    m = s.metrics
    # bounded probe cost: K halves k_init -> 1 (log2+1 rounds), then one
    # K=1 probe per further collapse
    max_rounds = spec.k_init.bit_length() + (spec.max_collapses - 1)
    assert m.spec_rounds <= max_rounds
    # plain bursts actually resumed at full K after the collapse
    assert any(k > 1 for k in m.burst_hist)
    rep, rep_p = m.report(), s_plain.metrics.report()
    overhead = (m.spec_draft_dispatches + m.spec_verify_dispatches
                + m.spec_catchup_dispatches)
    assert overhead <= 3 * max_rounds
    # + small slack for burst-ladder fragmentation around spec rounds
    assert rep["dispatches_per_token"] <= (
        rep_p["dispatches_per_token"]
        + (overhead + 3 * m.spec_rounds + 1) / rep["total_new_tokens"])


def test_planner_unit():
    """Pure controller mechanics: pow2/budget/capacity caps, EMA ladder,
    collapse backoff, permanent off, expected-tokens estimate."""
    cfg = SpecConfig(k_init=4, k_max=8, cooldown_rounds=2,
                     cooldown_backoff=2, max_collapses=2)
    p = SpecPlanner(cfg)

    class Pool:
        max_len, lengths = 64, {0: 10, 1: 20}

    class Req:
        def __init__(self, budget):
            self.sampling = SamplingParams(max_new_tokens=budget)
            self.n_generated = 0

    assert p.plan([(Req(10), 0)], Pool) == 4          # k_init
    assert p.plan([(Req(3), 0)], Pool) == 2           # budget-1 cap
    assert p.plan([(Req(1), 0)], Pool) == 0           # 1-token budget: plain
    tight = Pool()
    tight.lengths = {0: 61}
    assert p.plan([(Req(10), 0)], tight) == 2         # capacity 64-61-1, pow2
    # EMA ladder up at high acceptance
    p.observe(4, 4)
    assert p.k == 8 and p.ema == 1.0
    # collapse: halve to 1 over rounds, then cooldown
    for _ in range(8):
        p.observe(4, 0)
        if p.cooldown:
            break
    assert p.cooldown == 2 and p.k == 1 and p.ema is None
    assert not p.active
    assert p.plan([(Req(10), 0)], Pool) == 0 and p.cooldown == 1
    assert p.plan([(Req(10), 0)], Pool) == 0 and p.cooldown == 0
    # failed K=1 probe: second consecutive collapse -> off for good
    p.observe(1, 0)
    assert p.off and not p.active
    assert p.plan([(Req(10), 0)], Pool) == 0
    # expected tokens: geometric sum under the EMA
    q = SpecPlanner(SpecConfig(k_init=2, k_max=2))
    q.observe(2, 2)   # ema 1.0 -> clamped 0.999
    assert q.expected_tokens_per_round() == pytest.approx(3.0, abs=0.01)
    q2 = SpecPlanner(SpecConfig(k_init=2, k_max=2))
    q2.observe(2, 1)  # ema 0.5 -> 1 + 0.5 + 0.25
    assert q2.expected_tokens_per_round() == pytest.approx(1.75)


def test_accept_longest_prefix_unit():
    d = np.array([5, 6, 7])
    assert accept_longest_prefix(d, np.array([5, 6, 7, 8]), -1, 100) == (4, 3)
    assert accept_longest_prefix(d, np.array([5, 9, 7, 8]), -1, 100) == (2, 1)
    assert accept_longest_prefix(d, np.array([9, 6, 7, 8]), -1, 100) == (1, 0)
    # budget truncation caps both emitted and accepted
    assert accept_longest_prefix(d, np.array([5, 6, 7, 8]), -1, 2) == (2, 2)
    # EOS inside the window truncates emission at the EOS
    assert accept_longest_prefix(d, np.array([5, 6, 7, 8]), 6, 100) == (2, 2)
    assert accept_longest_prefix(d, np.array([9, 6, 7, 8]), 9, 100) == (1, 0)


def test_draft_engine_compute_twin_is_cached(engine):
    """Two DraftEngines over the same target and policy share ONE inner
    compute engine (jit reuse across warmup/timed schedulers) while
    keeping separate pool state."""
    a = DraftEngine(engine, SpecConfig())
    b = DraftEngine(engine, SpecConfig(corrupt_drafts=True))
    c = DraftEngine(engine, SpecConfig(draft_kv="fp8"))
    assert a.engine is b.engine
    assert c.engine is not a.engine
    assert a.pools is not b.pools


# ---------------------------------------------------------------------------
# EDF admission ordering (satellite)
# ---------------------------------------------------------------------------
def test_edf_orders_admission_within_priority_class(setup):
    """Within one priority class, a tighter absolute TTFT deadline
    (arrival + ttft_deadline_s) is admitted first even when it arrived
    later; deadline-free requests keep FCFS behind deadlined ones."""
    eng = make_engine(setup, n_slots=1)   # serialize admission
    prompts = _prompts(eng, 3, [6], seed=8)
    # submit order: A (occupies the slot), B loose (600s), C tight (300s)
    sched = Scheduler(eng)
    sp = SamplingParams(max_new_tokens=5)
    a = sched.submit(Request(prompt=prompts[0], sampling=sp))
    b = sched.submit(Request(prompt=prompts[1], sampling=sp,
                             ttft_deadline_s=600.0))
    c = sched.submit(Request(prompt=prompts[2], sampling=sp,
                             ttft_deadline_s=300.0))
    sched.run(max_steps=300)
    assert all(r.is_finished for r in (a, b, c))
    # C (tight) beat B (loose) to its first token despite arriving later
    assert c.first_token_time < b.first_token_time
    # FCFS preserved when nobody carries a deadline
    sched = Scheduler(eng)
    r1 = sched.submit(Request(prompt=prompts[0], sampling=sp))
    r2 = sched.submit(Request(prompt=prompts[1], sampling=sp))
    r3 = sched.submit(Request(prompt=prompts[2], sampling=sp))
    sched.run(max_steps=300)
    assert r1.first_token_time < r2.first_token_time < r3.first_token_time
    # priority classes still dominate deadlines entirely
    sched = Scheduler(eng)
    lo = sched.submit(Request(prompt=prompts[0], sampling=sp))
    bg = sched.submit(Request(prompt=prompts[1], sampling=sp, priority=5,
                              ttft_deadline_s=300.0))
    hi = sched.submit(Request(prompt=prompts[2], sampling=sp, priority=0,
                              ttft_deadline_s=600.0))
    sched.run(max_steps=300)
    assert hi.first_token_time < bg.first_token_time


# ---------------------------------------------------------------------------
# Accounting identities + observability lanes (satellites)
# ---------------------------------------------------------------------------
def test_spec_accounting_identities_and_registry(engine):
    """drafted == accepted + rejected; emitted == accepted + bonus with
    bonus <= one per row per round; every generated token is a prefill
    first token, a plain decode emission, or a spec emission; and the
    registry exposes the spec families."""
    from repro.obs import MetricsRegistry, Observability
    obs = Observability(registry=MetricsRegistry())
    sched = Scheduler(engine, obs=obs, spec=SpecConfig())
    sp = SamplingParams(temperature=0.6, max_new_tokens=17, seed=21)
    prompts = _prompts(engine, 3, [9, 6, 8], seed=9)
    reqs = [sched.submit(Request(prompt=p, sampling=sp)) for p in prompts]
    sched.run(max_steps=600)
    assert all(r.is_finished for r in reqs)
    m = sched.metrics
    assert m.spec_rounds > 0
    assert m.spec_tokens_drafted == (m.spec_tokens_accepted
                                     + m.spec_tokens_rejected)
    assert m.spec_tokens_emitted == (m.spec_tokens_accepted
                                     + m.spec_bonus_tokens)
    assert 0 < m.spec_bonus_tokens <= m.spec_rounds * engine.scfg.n_slots
    assert m.total_new_tokens == (len(m.ttft) + m.decode_tokens_emitted
                                  + m.spec_tokens_emitted)
    assert sum(k * v for k, v in m.spec_accept_hist.items()) \
        == m.spec_tokens_accepted
    rep = m.report()
    assert rep["spec"]["rounds"] == m.spec_rounds
    assert rep["spec"]["verify_dispatches"] == m.spec_verify_dispatches
    assert rep["dispatches_per_token"] > 0
    text = obs.registry.expose()
    for family in ("serve_spec_rounds_total", "serve_spec_dispatches_total",
                   "serve_spec_tokens_total",
                   "serve_spec_accepted_per_verify"):
        assert family in text, family


def test_spec_trace_lanes(engine, tmp_path):
    """Draft and verify dispatches land on their own trace lanes with
    planned-K and accepted-count args; a spec-off scheduler never
    registers the lanes (the byte-identical §13 trace pin stays intact)."""
    from repro.obs import Observability, Tracer
    obs = Observability(tracer=Tracer())
    sched = Scheduler(engine, obs=obs, spec=SpecConfig())
    sp = SamplingParams(max_new_tokens=13)
    req = sched.submit(Request(prompt=_prompts(engine, 1, [8], seed=10)[0],
                               sampling=sp))
    sched.run(max_steps=300)
    assert req.is_finished and sched.metrics.spec_rounds > 0
    import json
    path = tmp_path / "spec.trace.json"
    obs.tracer.write(str(path))
    events = json.loads(path.read_text())
    if isinstance(events, dict):          # either trace-event container
        events = events["traceEvents"]
    drafts = [e for e in events if e.get("name") == "spec_draft"]
    verifies = [e for e in events if e.get("name") == "spec_verify"]
    assert drafts and verifies
    assert {e["tid"] for e in drafts}.isdisjoint(
        {e["tid"] for e in verifies})
    for e in drafts:
        assert e["args"]["k"] >= 1
    for e in verifies:
        assert 0 <= e["args"]["accepted"] <= e["args"]["k"]
        assert 1 <= e["args"]["emitted"] <= e["args"]["k"] + 1
    names = [e for e in events if e.get("ph") == "M"
             and e.get("name") == "thread_name"]
    labels = {e["args"]["name"] for e in names}
    assert any(n.startswith("draft:") for n in labels)
    assert any(n.startswith("verify:") for n in labels)
    # spec-off: no spec lanes registered
    obs2 = Observability(tracer=Tracer())
    sched2 = Scheduler(engine, obs=obs2)
    r2 = sched2.submit(Request(prompt=_prompts(engine, 1, [8], seed=10)[0],
                               sampling=sp))
    sched2.run(max_steps=300)
    assert r2.is_finished
    path2 = tmp_path / "plain.trace.json"
    obs2.tracer.write(str(path2))
    events2 = json.loads(path2.read_text())
    if isinstance(events2, dict):
        events2 = events2["traceEvents"]
    labels2 = {e["args"]["name"] for e in events2 if e.get("ph") == "M"
               and e.get("name") == "thread_name"}
    assert not any(n.startswith(("draft:", "verify:")) for n in labels2)


def test_perfmodel_prices_draft_verify_pair():
    """The analytical model prices a spec round honestly: under the
    Table-III/IV slot deployment at batch 1 the MAC array has idle
    headroom, the K+1-position verify costs ~one plain step, and
    speculation wins wall clock; under the channel-streaming GEMV engine
    (throughput-matched to HBM by construction) extra verify positions
    cost linearly and speculation loses — the model must report both,
    monotone in acceptance."""
    from repro.perfmodel.analytical import spec_round_latency
    cfg = get_config("granite-8b")     # full-size paper geometry
    win = spec_round_latency(cfg, k=2, batch=1, context=512, acceptance=0.8,
                             use_engine_model=False)
    # idle-headroom regime: verify ~ a plain step, speculation pays
    assert win["t_verify_s"] < 1.1 * win["t_plain_per_token_s"]
    assert win["speedup"] > 1.0
    better = spec_round_latency(cfg, k=2, batch=1, context=512,
                                acceptance=0.95, use_engine_model=False)
    assert better["speedup"] > win["speedup"]
    # throughput-matched engine: no idle compute to hide the window in
    eng = spec_round_latency(cfg, k=2, batch=1, context=512, acceptance=0.8)
    assert eng["speedup"] < 1.0
    assert eng["t_verify_s"] <= 3 * eng["t_plain_per_token_s"] + 1e-12
    # acceptance monotonicity + geometric expected tokens
    low = spec_round_latency(cfg, k=4, batch=8, context=512, acceptance=0.1)
    high = spec_round_latency(cfg, k=4, batch=8, context=512, acceptance=0.8)
    assert low["speedup"] < high["speedup"]
    assert 1.0 <= low["expected_tokens_per_row"] \
        <= high["expected_tokens_per_row"] <= 5.0
