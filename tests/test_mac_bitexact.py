"""Bit-exactness of the vectorized XtraMAC datapath vs the exact oracle.

Coverage strategy (paper Fig. 6 configurations):
  * FP4 x BF16 + BF16  -> BF16   : exhaustive over A, dense-sampled B, C
  * FP8 x FP8 + BF16   -> BF16   : exhaustive over (A, B), sampled C
  * INT4 x BF16 + BF16 -> BF16   : exhaustive over A, sampled B, C
  * INT8 x INT8 + INT32-> INT32  : exhaustive over (A, B), sampled C
  * BF16 x BF16 + BF16 -> BF16   : randomized (incl. specials)
  * FP16 / FP32-accumulate variants: randomized
plus directed special-value cases (NaN, inf, inf*0, inf-inf, FTZ/DAZ).
"""
import numpy as np
import pytest

from repro.core import formats as F
from repro.core.mac import MacConfig, xtramac, xtramac_switching
from repro.core.ref_mac import mac_exact_vec

RNG = np.random.default_rng(0)


def _assert_bitexact(cfg: MacConfig, a, b, c, n_show=5):
    got = xtramac(cfg, a, b, c)
    want = mac_exact_vec(cfg.fmt_a, cfg.fmt_b, cfg.fmt_c, cfg.fmt_p, a, b, c)
    bad = got != want
    if bad.any():
        idx = np.argwhere(bad)[:n_show]
        msg = [f"{cfg.name}: {int(bad.sum())}/{bad.size} mismatches"]
        for i in idx:
            i = tuple(i)
            msg.append(
                f"  a={a[i]:#x} b={b[i]:#x} c={c[i]:#x} got={got[i]:#x} want={want[i]:#x}"
            )
        raise AssertionError("\n".join(msg))


def _rand_bits(fmt, n):
    return RNG.integers(0, 1 << fmt.bits, size=n, dtype=np.int64)


def test_fp4_bf16_exhaustive_a():
    cfg = MacConfig.make("fp4_e2m1", "bf16", "bf16", "bf16")
    a = np.arange(16, dtype=np.int64)
    b = _rand_bits(F.BF16, 4096)
    c = _rand_bits(F.BF16, 4096)
    A, B = np.meshgrid(a, b, indexing="ij")
    C = np.broadcast_to(c, A.shape)
    _assert_bitexact(cfg, A.ravel(), B.ravel(), C.ravel())


def test_fp8_fp8_exhaustive_ab():
    cfg = MacConfig.make("fp8_e4m3", "fp8_e4m3", "bf16", "bf16")
    a = np.arange(256, dtype=np.int64)
    b = np.arange(256, dtype=np.int64)
    A, B = np.meshgrid(a, b, indexing="ij")
    C = _rand_bits(F.BF16, A.size).reshape(A.shape)
    _assert_bitexact(cfg, A.ravel(), B.ravel(), C.ravel())


def test_fp8_e5m2_randomized():
    cfg = MacConfig.make("fp8_e5m2", "fp8_e5m2", "fp16", "fp16")
    n = 50_000
    _assert_bitexact(cfg, _rand_bits(F.FP8_E5M2, n), _rand_bits(F.FP8_E5M2, n), _rand_bits(F.FP16, n))


def test_int4_bf16_exhaustive_a():
    cfg = MacConfig.make("int4", "bf16", "bf16", "bf16")
    a = np.arange(16, dtype=np.int64)
    b = _rand_bits(F.BF16, 4096)
    c = _rand_bits(F.BF16, 4096)
    A, B = np.meshgrid(a, b, indexing="ij")
    C = np.broadcast_to(c, A.shape)
    _assert_bitexact(cfg, A.ravel(), B.ravel(), C.ravel())


def test_int8_int8_int32_exhaustive_ab():
    cfg = MacConfig.make("int8", "int8", "int32", "int32")
    a = np.arange(256, dtype=np.int64)
    b = np.arange(256, dtype=np.int64)
    A, B = np.meshgrid(a, b, indexing="ij")
    C = _rand_bits(F.INT32, A.size).reshape(A.shape)
    _assert_bitexact(cfg, A.ravel(), B.ravel(), C.ravel())


def test_int32_saturation():
    cfg = MacConfig.make("int8", "int8", "int32", "int32")
    # (-128)*(-128) repeatedly added near int32 max must saturate, not wrap
    a = np.full(4, 0x80, dtype=np.int64)   # -128
    b = np.full(4, 0x80, dtype=np.int64)
    c = np.array([0x7FFFFFFF, 0x7FFF0000, 0x80000000, 0], dtype=np.int64)
    _assert_bitexact(cfg, a, b, c)


def test_bf16_bf16_randomized():
    cfg = MacConfig.make("bf16", "bf16", "bf16", "bf16")
    n = 200_000
    _assert_bitexact(cfg, _rand_bits(F.BF16, n), _rand_bits(F.BF16, n), _rand_bits(F.BF16, n))


def test_fp16_fp16_randomized():
    cfg = MacConfig.make("fp16", "fp16", "fp16", "fp16")
    n = 200_000
    _assert_bitexact(cfg, _rand_bits(F.FP16, n), _rand_bits(F.FP16, n), _rand_bits(F.FP16, n))


def test_fp32_accumulator_randomized():
    cfg = MacConfig.make("bf16", "bf16", "fp32", "fp32")
    n = 100_000
    _assert_bitexact(cfg, _rand_bits(F.BF16, n), _rand_bits(F.BF16, n), _rand_bits(F.FP32, n))


@pytest.mark.parametrize("combo", [
    ("int2", "bf16", "bf16", "bf16"),
    ("int3", "bf16", "bf16", "bf16"),
    ("int5", "fp16", "fp16", "fp16"),
    ("int6", "bf16", "bf16", "bf16"),
    ("int7", "fp16", "fp16", "fp16"),
    ("int8", "bf16", "bf16", "bf16"),
    ("fp4_e2m1", "fp4_e2m1", "bf16", "bf16"),
    ("fp8_e4m3", "bf16", "bf16", "bf16"),
    ("fp8_e4m3", "fp16", "fp16", "fp16"),
])
def test_mixed_combos_randomized(combo):
    cfg = MacConfig.make(*combo)
    n = 30_000
    _assert_bitexact(
        cfg, _rand_bits(cfg.fmt_a, n), _rand_bits(cfg.fmt_b, n), _rand_bits(cfg.fmt_c, n)
    )


def test_special_values_directed():
    cfg = MacConfig.make("bf16", "bf16", "bf16", "bf16")
    bf = F.BF16
    qnan, pinf, ninf = bf.qnan_bits, bf.inf_bits(0), bf.inf_bits(1)
    one = 0x3F80  # 1.0 in bf16
    sub = 0x0001  # subnormal -> DAZ zero
    cases = [
        (qnan, one, one), (one, qnan, one), (one, one, qnan),       # NaN prop
        (pinf, 0, one),                                              # inf * 0
        (pinf, one, ninf), (ninf, one, pinf),                        # inf - inf
        (pinf, one, one), (one, one, pinf), (ninf, one, one),        # inf prop
        (sub, one, one), (one, sub, one), (one, one, sub),           # DAZ
        (0x0080, 0x0080, 0),                                         # FTZ underflow
        (bf.max_finite_bits(0), bf.max_finite_bits(0), 0),           # overflow sat
        (one, one, one | 0x8000),                                    # 1*1 + (-1) = +0
    ]
    a, b, c = (np.array(x, dtype=np.int64) for x in zip(*cases))
    _assert_bitexact(cfg, a, b, c)


def test_runtime_switching_mux():
    """Per-element datatype switching == running each config separately."""
    cfgs = [
        MacConfig.make("int4", "bf16", "bf16", "bf16"),
        MacConfig.make("bf16", "bf16", "bf16", "bf16"),
    ]
    n = 10_000
    a = _rand_bits(F.BF16, n)
    b = _rand_bits(F.BF16, n)
    c = _rand_bits(F.BF16, n)
    sel = RNG.integers(0, 2, size=n)
    out = xtramac_switching(cfgs, sel, a, b, c)
    for i, cfg in enumerate(cfgs):
        ref = mac_exact_vec(cfg.fmt_a, cfg.fmt_b, cfg.fmt_c, cfg.fmt_p, a, b, c)
        np.testing.assert_array_equal(out[sel == i], ref[sel == i])
