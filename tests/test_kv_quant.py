"""KV-cache quantization + fused decode-attention kernel tests.

Pins the DESIGN.md §9 contracts:
  * per-scheme round-trip error bounds (int8 half-step, fp8 half-ulp + DAZ),
  * jnp quantize path decodes identically to the core.formats codecs,
  * the Pallas flash-decode kernel (interpret mode) is BIT-exact against
    its split-KV online-softmax oracle on bf16 AND quantized KV,
  * the kernel agrees with the production einsum path to bf16 rounding,
  * end-to-end decode logits with the kernel toggled on match the einsum
    path (argmax included) for one step after a real chunked prefill.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import formats as F
from repro.kernels.decode_attention import gqa_decode_attention
from repro.kernels.ref import decode_attention_ref
from repro.models import transformer as T
from repro.models.attention import attend
from repro.models.common import InitMaker, set_use_kernel
from repro.quant.kv_cache import (QuantizedKV, cache_read, cache_write_rows,
                                  cache_write_slice, kv_slab_spec)
from repro.quant.schemes import (KV_SCHEMES, get_kv_scheme, kv_dequantize,
                                 kv_pack_codes, kv_quantize, kv_unpack_codes)

RNG = np.random.default_rng(17)


def _kv_data(b=3, s=48, hk=2, dh=16, spread=True):
    x = RNG.normal(size=(b, s, hk, dh))
    if spread:  # per-(position, head) magnitude spread: exercises the scales
        x *= np.exp(RNG.normal(size=(b, s, hk, 1)))
    return x.astype(np.float32)


def _quantized(name, x):
    packed, scales = kv_quantize(get_kv_scheme(name), jnp.asarray(x))
    return QuantizedKV(packed, scales, name)


# ---------------------------------------------------------------------------
# Round-trip error bounds per scheme
# ---------------------------------------------------------------------------
def test_pack_unpack_roundtrip_exact():
    codes = RNG.integers(0, 256, (5, 7, 2, 16))
    got = np.asarray(kv_unpack_codes(kv_pack_codes(jnp.asarray(codes))))
    np.testing.assert_array_equal(got, codes)


def test_int8_roundtrip_half_step_bound():
    """Symmetric int8: |err| <= scale/2 everywhere, scale = absmax/127 per
    (position, head) group."""
    x = _kv_data()
    scheme = get_kv_scheme("int8")
    packed, scales = kv_quantize(scheme, jnp.asarray(x))
    dq = np.asarray(kv_dequantize(scheme, packed, scales, jnp.float32))
    sc = np.asarray(scales)[..., None]
    assert (np.abs(dq - x) <= sc / 2 + 1e-6).all()
    # group extremes are exactly representable (they define the scale)
    flat_max = np.abs(x).max(-1)
    got_max = np.abs(dq).max(-1)
    np.testing.assert_allclose(got_max, flat_max, rtol=1e-5)


def test_fp8_roundtrip_half_ulp_bound():
    """E4M3: relative error <= 2^-4 (half-ulp of a 3-bit mantissa) for
    normal values; values in the subnormal band flush to zero under DAZ
    (abs err <= 2^-6 * scale)."""
    x = _kv_data()
    scheme = get_kv_scheme("fp8")
    packed, scales = kv_quantize(scheme, jnp.asarray(x))
    dq = np.asarray(kv_dequantize(scheme, packed, scales, jnp.float32))
    sc = np.asarray(scales)[..., None]
    bound = np.maximum(np.abs(x) * 2.0 ** -4, sc * 2.0 ** -6) + 1e-7
    assert (np.abs(dq - x) <= bound).all()


def test_fp8_jnp_quantize_matches_formats_codec():
    """The in-jit E4M3 encode emits bit-identical CODES to the numpy
    core.formats codec (RN-even + FTZ — the Stage-1 mapping semantics; the
    naive XLA float8 cast would fail this on round-to-even ties, which is
    why kv_quantize encodes arithmetically)."""
    x = _kv_data(b=2, s=16)
    scheme = get_kv_scheme("fp8")
    packed, scales = kv_quantize(scheme, jnp.asarray(x))
    codes_jnp = np.asarray(kv_unpack_codes(packed))
    scaled = x / np.asarray(scales)[..., None]
    codes_np = F.quantize_f64(F.FP8_E4M3, scaled.astype(np.float64))
    np.testing.assert_array_equal(codes_jnp, codes_np)


def test_kv_scheme_registry():
    assert sorted(KV_SCHEMES) == ["fp8", "int8"]
    assert get_kv_scheme("bf16") is None
    assert get_kv_scheme(jnp.bfloat16) is None
    assert get_kv_scheme(None) is None
    with pytest.raises(KeyError):
        get_kv_scheme("int4")


# ---------------------------------------------------------------------------
# Cache slab layout + write/read paths
# ---------------------------------------------------------------------------
def test_quantized_slab_spec_shapes():
    spec = kv_slab_spec((4, 32, 2, 16), "int8")
    assert isinstance(spec, QuantizedKV)
    assert spec.packed.shape == (4, 32, 2, 4) and spec.packed.dtype == jnp.int32
    assert spec.scales.shape == (4, 32, 2) and spec.scales.dtype == jnp.float32
    plain = kv_slab_spec((4, 32, 2, 16), "bf16")
    assert plain.shape == (4, 32, 2, 16) and plain.dtype == jnp.bfloat16


def test_cache_write_slice_and_rows_roundtrip():
    """Chunked writes + per-row scatters commit exactly the bytes a direct
    quantize of the same values would — batch/chunk composition cannot
    change a position's stored codes."""
    x = jnp.asarray(_kv_data(b=2, s=16), jnp.bfloat16)
    scheme = get_kv_scheme("int8")
    slab = jax.tree_util.tree_map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                                  kv_slab_spec((2, 24, 2, 16), "int8"))
    slab = cache_write_slice(slab, x[:, :8], 0)          # chunk 1
    slab = cache_write_slice(slab, x[:, 8:15], 8)        # chunk 2 (odd len)
    rows = jnp.arange(2)
    slab = cache_write_rows(slab, x[:, 15:16], rows,
                            jnp.asarray([15, 15]))       # decode write
    want_p, want_s = kv_quantize(scheme, x)
    np.testing.assert_array_equal(np.asarray(slab.packed[:, :16]),
                                  np.asarray(want_p))
    np.testing.assert_array_equal(np.asarray(slab.scales[:, :16]),
                                  np.asarray(want_s))
    dense = cache_read(slab, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(dense[:, :16]),
        np.asarray(kv_dequantize(scheme, want_p, want_s, jnp.float32)))


def test_init_cache_quantized_leaves_and_mla_guard():
    cfg = get_config("granite-8b", smoke=True)
    cache = T.init_cache(cfg, 4, 16, kv_dtype="int8")
    k_slab, v_slab = cache
    assert isinstance(k_slab, QuantizedKV) and isinstance(v_slab, QuantizedKV)
    assert k_slab.packed.shape == (cfg.n_layers, 4, 16, cfg.n_kv_heads,
                                   cfg.d_head // 4)
    assert k_slab.scales.shape == (cfg.n_layers, 4, 16, cfg.n_kv_heads)
    # MLA latent caches stay bf16 — quantized kv_dtype is rejected loudly
    mla = get_config("deepseek-v2-236b", smoke=True)
    with pytest.raises(ValueError):
        T.init_cache(mla, 2, 16, kv_dtype="int8")


# ---------------------------------------------------------------------------
# Pallas decode kernel: bit-exact vs oracle; einsum-path agreement
# ---------------------------------------------------------------------------
def _attn_inputs(b=3, sk=48, hk=2, rep=2, dh=16):
    h = hk * rep
    q = jnp.asarray(RNG.normal(size=(b, 1, h, dh)), jnp.bfloat16)
    k = jnp.asarray(_kv_data(b, sk, hk, dh), jnp.bfloat16)
    v = jnp.asarray(_kv_data(b, sk, hk, dh), jnp.bfloat16)
    lens = jnp.asarray([1, sk // 2 + 1, sk], jnp.int32)[:b]
    return q, k, v, lens


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8", "fp8"])
def test_decode_kernel_bitexact_vs_oracle(kv_dtype):
    """Interpret-mode kernel == split-KV online-softmax oracle, bit for bit
    (shared block update; the §9 equivalence contract) — including ragged
    valid lengths and blocks entirely past a row's length."""
    q, k, v, lens = _attn_inputs()
    if kv_dtype != "bf16":
        k, v = _quantized(kv_dtype, k), _quantized(kv_dtype, v)
    got = gqa_decode_attention(q, k, v, lens, interpret=True)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_decode_kernel_block_size_invariant():
    """Same result for any KV block size (split points move, math doesn't)."""
    q, k, v, lens = _attn_inputs()
    outs = [np.asarray(gqa_decode_attention(q, k, v, lens, bk=bk,
                                            interpret=True), np.float32)
            for bk in (8, 16, 48)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-6, atol=1e-6)


def test_decode_kernel_matches_einsum_path_bf16():
    """Kernel vs the production einsum path (`attend`): agreement to bf16
    rounding — the einsum path stages scores/probabilities through bf16
    storage, the fused kernel stays f32 after the loads (DESIGN.md §9)."""
    q, k, v, lens = _attn_inputs()
    want = attend(q, k, v, causal=True, q_offset=lens - 1, kv_valid_len=lens)
    got = gqa_decode_attention(q, k, v, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_decode_kernel_quantized_within_documented_bounds(kv_dtype):
    """Quantized-cache attention vs full-precision attention over the same
    values: outputs are convex combinations of V rows, so the error is
    bounded by the per-element dequant error (§9 bounds) plus softmax
    shift from the perturbed scores — loose envelope asserted here."""
    q, k, v, lens = _attn_inputs()
    want = attend(q, k, v, causal=True, q_offset=lens - 1, kv_valid_len=lens)
    got = gqa_decode_attention(q, _quantized(kv_dtype, k),
                               _quantized(kv_dtype, v), lens, interpret=True)
    atol = 0.08 if kv_dtype == "int8" else 0.35   # ~half-step vs half-ulp
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_engine_decode_logits_kernel_vs_einsum(kv_dtype):
    """End-to-end through the jitted engine steps: chunked prefill + one
    decode step with the kernel toggled on produces the same argmax and
    bf16-close logits as the einsum path."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    from repro.serve import ServeConfig, ServingEngine
    prompts = [RNG.integers(1, cfg.vocab, (n,)).astype(np.int32)
               for n in (11, 8, 5)]

    def decode_once(use_kernel):
        set_use_kernel(use_kernel)
        try:
            eng = ServingEngine(cfg, params, ServeConfig(
                max_len=32, n_slots=4, prefill_chunk=8, kv_dtype=kv_dtype))
            pool = eng.new_pool()
            slots = [pool.alloc() for _ in prompts]
            last = eng.prefill_into_slots(pool, slots, prompts)
            toks = np.zeros((pool.n_slots,), np.int32)
            for s, l in zip(slots, last):
                toks[s] = int(np.argmax(np.asarray(l)))
            return np.asarray(eng.decode_slots_with_logits(pool, toks),
                              np.float32)[:len(prompts)], toks
        finally:
            set_use_kernel(False)

    logits_e, first_e = decode_once(False)
    logits_k, first_k = decode_once(True)
    np.testing.assert_array_equal(first_e, first_k)
    np.testing.assert_allclose(logits_k, logits_e, rtol=5e-2, atol=5e-2)
    np.testing.assert_array_equal(logits_k.argmax(-1), logits_e.argmax(-1))
