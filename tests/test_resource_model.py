"""Resource / frequency / GEMV-engine model consistency with the paper."""
import pytest

from repro.core.gemv_engine import GemvEngineConfig, gemv_latency_s, table_vii
from repro.core.mac import MacConfig
from repro.core import resource_model as RM


def test_table_v_consistent_with_table_iii():
    """Table V per-op xtramac x 4 lanes == Table III config II instance."""
    per_op = RM.TABLE_V["xtramac"]["bf16"]
    inst = RM.TABLE_III["II:int8xint8+int32|bf16"]
    assert per_op.lut * 4 == pytest.approx(inst.lut, rel=1e-6)
    assert per_op.dsp * 4 == pytest.approx(inst.dsp, rel=1e-6)


def test_paper_mean_reductions():
    """Average LUT/FF/DSP reductions across Table IV match Section V-E1."""
    red = {"lut": [], "ff": [], "dsp": []}
    for (a, bcp), (vendor, ours) in RM.TABLE_IV.items():
        red["lut"].append(1 - ours.lut / vendor.lut)
        red["ff"].append(1 - ours.ff / vendor.ff)
        red["dsp"].append(1 - ours.dsp / vendor.dsp)
    for k, vals in red.items():
        mean = sum(vals) / len(vals)
        assert mean == pytest.approx(RM.PAPER_MEAN_REDUCTION[k], abs=0.01), (k, mean)


def test_compute_density_range():
    """Comp.Den. between 1.4x and 2.0x for every Table IV combo (abstract)."""
    for (a, bcp) in RM.TABLE_IV:
        d = RM.compute_density(a, bcp)
        for k, v in d.items():
            assert 1.35 <= v <= 2.05, ((a, bcp), k, v)


def test_fmax_model():
    assert RM.fmax_mhz(1) == 483.0
    assert RM.fmax_mhz(4) == 462.0
    for n in range(1, 5):
        assert RM.fmax_mhz(n) > RM.FMAX_FLOOR_MHZ
    assert RM.system_fmax_mhz(512) == 300.0
    assert 250.0 <= RM.system_fmax_mhz(1920) <= 270.0


def test_parametric_model_calibration():
    """Eq.7/8-based model reproduces the Table III instances it was fit on.

    Calibration is non-negative least squares (physical resource counts;
    plain lstsq with 4 rows x 6 features is underdetermined and produced
    negative/non-monotone coefficients), which trades fit for validity —
    hence the looser R^2 bound."""
    assert RM.CALIBRATION_R2 > 0.5
    cases = {
        "I:int4xbf16+bf16": [MacConfig.make("int4", "bf16", "bf16", "bf16"),
                             MacConfig.make("bf16", "bf16", "bf16", "bf16")],
        "III:fp8xfp8+bf16|bf16": [MacConfig.make("fp8_e4m3", "fp8_e4m3", "bf16", "bf16"),
                                  MacConfig.make("bf16", "bf16", "bf16", "bf16")],
    }
    for key, cfgs in cases.items():
        est = RM.estimate_instance(cfgs)
        meas = RM.TABLE_III[key]
        assert est.lut == pytest.approx(meas.lut, rel=0.25), key


def test_gemv_engine_dimensions():
    """Section VI-C: 512/(4x2)=64 MACs/channel; 30 channels -> 1920 units."""
    cfg = GemvEngineConfig()
    assert cfg.n_mac_per_channel == 64
    assert cfg.n_instances == 1920
    assert 250e6 <= cfg.freq_hz <= 300e6


def test_table_vii_reproduction():
    """Model-predicted GEMV latency lands on the paper's measured Table VII."""
    rows = table_vii()
    for shape, row in rows.items():
        # model within 5% of the paper's measured FPGA latency
        assert row["model_vs_paper"] == pytest.approx(1.0, abs=0.05), (shape, row)
        assert row["bound"] == "memory"  # paper: bandwidth-bound at scale
        assert row["speedup"] == pytest.approx(1.2, abs=0.1)
        assert row["energy_eff"] == pytest.approx(1.9, abs=0.15)


def test_gemv_compute_bound_at_large_batch():
    """Large m flips the kernel into the compute-bound regime (Fig. 14)."""
    cfg = GemvEngineConfig()
    r1 = gemv_latency_s(cfg, 1, 4096, 4096)
    r64 = gemv_latency_s(cfg, 64, 4096, 4096)
    assert r1["bound"] == "memory" and r64["bound"] == "compute"


def test_decode_latency_gemv_engine_pricing_is_datatype_adaptive():
    """Routing the channel-streaming GEMV engine into ``decode_latency``
    makes the compute phase per-datatype: a 4-bit scheme runs its
    projections on 4x the MAC lanes of bf16 from the same channels, and
    the memory phase is derated by the engine's measured HBM utilization
    — the serving profiler's default pricing (obs/profiler.py)."""
    from repro.configs import get_config
    from repro.perfmodel import decode_latency, gemv_engine_for

    int4 = gemv_engine_for("awq_int4")
    bf16 = gemv_engine_for("bf16")
    assert int4.n_mac_per_channel == 4 * bf16.n_mac_per_channel

    cfg = get_config("granite-8b", smoke=True)
    kw = dict(batch=8, context=512, design="xtramac")
    flat = decode_latency(cfg, "awq_int4", **kw)
    priced = decode_latency(cfg, "awq_int4", engine_model=int4, **kw)
    # engine pricing: quant units are the engine's lane count, and the
    # memory phase pays the 74% effective-bandwidth derate
    assert priced["units_quant"] == int4.macs_per_cycle
    assert priced["units_quant"] != flat["units_quant"]
    assert priced["t_mem_s"] > flat["t_mem_s"]
    # same engine, wider weights -> fewer lanes -> slower compute phase
    w8 = decode_latency(cfg, "w8a8", engine_model=gemv_engine_for("w8a8"),
                        **kw)
    assert w8["t_compute_s"] > priced["t_compute_s"]
