"""Device-resident decode-burst tests (DESIGN.md §11).

The contract: a K-step burst (one jitted ``lax.scan``, one dispatch, one
host sync) emits exactly the tokens K fused single steps emit — greedy AND
seeded temperature sampling (same per-(request, step) key schedule) — and
the scheduler's burst planning never perturbs admission latency or
chunked-prefill interleaving (K clamps to 1 while either is pending).
Multi-device tests extend the dp x tp bit-identity contract (§10) to
bursts and run under CI's 8-forced-host-device job.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import InitMaker, QuantMaker
from repro.models import transformer as T
from repro.serve import (Request, RequestState, SamplingParams, ServeConfig,
                         ServingEngine, Scheduler)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    return ServingEngine(cfg, params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8, max_burst=8))


def _prompts(engine, n, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, engine.cfg.vocab, (lens[i % len(lens)],))
            .astype(np.int32) for i in range(n)]


def _run(engine, prompts, *, max_burst, max_new=6, temperature=0.0,
         seed=0, midflight=False):
    """One scheduler run; returns (per-request token lists, scheduler)."""
    sched = Scheduler(engine, max_burst=max_burst)
    sp = SamplingParams(temperature=temperature, max_new_tokens=max_new,
                        seed=seed)
    head = prompts[:-1] if midflight else prompts
    reqs = [sched.submit(Request(prompt=p, sampling=sp)) for p in head]
    if midflight:
        while sched.n_decode_steps < 2:
            sched.step()
        reqs.append(sched.submit(Request(prompt=prompts[-1], sampling=sp)))
    sched.run(max_steps=400)
    assert all(r.is_finished for r in reqs)
    return [list(r.output_tokens) for r in reqs], sched


# ---------------------------------------------------------------------------
# Bit-identity: burst == single-step, greedy and seeded temperature
# ---------------------------------------------------------------------------
def test_burst_bit_identical_to_single_step_greedy(engine):
    """THE burst contract: greedy tokens at max_burst=8 == max_burst=1,
    request for request — including a mid-flight admission, which forces
    K=1 rounds around the admission exactly like the burst-free path."""
    prompts = _prompts(engine, 4, [9, 6, 11, 8], seed=1)
    ref, s1 = _run(engine, prompts, max_burst=1, midflight=True)
    got, s8 = _run(engine, prompts, max_burst=8, midflight=True)
    assert got == ref
    # the burst run actually burst (fewer dispatches, same token-steps)
    assert s8.n_decode_dispatches < s1.n_decode_dispatches
    assert any(k > 1 for k in s8.metrics.burst_hist)
    assert all(k == 1 for k in s1.metrics.burst_hist)


def test_burst_bit_identical_seeded_temperature(engine):
    """Temperature sampling: the precomputed [K, n_slots, 2] key schedule
    reproduces each request's step_key() sequence, so sampled (not just
    greedy) tokens are bit-identical between burst and single-step."""
    prompts = _prompts(engine, 3, [8, 11, 6], seed=2)
    ref, _ = _run(engine, prompts, max_burst=1, temperature=0.8, seed=13)
    got, s8 = _run(engine, prompts, max_burst=8, temperature=0.8, seed=13)
    assert got == ref
    assert any(k > 1 for k in s8.metrics.burst_hist)
    # and a different seed actually changes the continuation (the keys are
    # live, not dead inputs)
    other, _ = _run(engine, prompts, max_burst=8, temperature=0.8, seed=14)
    assert other != ref


def test_step_keys_match_step_key_sequence():
    """request.step_keys(n) row t == step_key() at n_generated + t — the
    on-device key-schedule contract."""
    r = Request(prompt=np.arange(1, 5, dtype=np.int32),
                sampling=SamplingParams(seed=3))
    r.id = 7
    r.output_tokens = [11, 22]            # n_generated = 2
    sched = np.asarray(r.step_keys(4))
    assert sched.shape == (4, 2) and sched.dtype == np.uint32
    for t in range(4):
        want = Request(prompt=r.prompt, sampling=r.sampling)
        want.id = 7
        want.output_tokens = [0] * (2 + t)
        np.testing.assert_array_equal(sched[t], np.asarray(want.step_key()))
    # the scheduler's batched builder (one transfer for all temperature
    # rows) produces the same bits
    from repro.serve.sampling import batched_step_keys
    np.testing.assert_array_equal(batched_step_keys([3], [7], [2], 4)[0],
                                  sched)


# ---------------------------------------------------------------------------
# Engine primitive: burst == K fused single steps, EOS freeze
# ---------------------------------------------------------------------------
def test_engine_burst_primitive_matches_single_steps(engine):
    """Low-level: decode_burst(K=4) over a prefilled pool emits exactly the
    tokens 4 decode_slots calls emit, and commits the same lengths."""
    prompts = _prompts(engine, 2, [8, 11], seed=4)

    def prefill():
        pool = engine.new_pool()
        slots = [pool.alloc(), pool.alloc()]
        last = engine.prefill_into_slots(pool, slots, prompts)
        first = np.zeros((pool.n_slots,), np.int32)
        for s, l in zip(slots, last):
            first[s] = int(np.argmax(np.asarray(l)))
        return pool, slots, first

    n = engine.scfg.n_slots
    active = np.zeros((n,), bool)
    # single-step reference: caller commits lengths for active rows
    pool, slots, tokens = prefill()
    active[slots] = True
    ref = []
    cur = tokens.copy()
    for _ in range(4):
        out = engine.decode_slots(pool, cur)
        pool.lengths[active] += 1
        ref.append(out[slots].tolist())
        cur = np.where(active, out, cur)
    ref_lengths = pool.lengths.copy()

    pool2, slots2, tokens2 = prefill()
    keys = np.zeros((4, n, 2), np.uint32)
    toks, valid = engine.decode_burst(
        pool2, tokens2, keys, np.zeros((n,), np.float32), active,
        np.full((n,), 100, np.int32), np.full((n,), -1, np.int32))
    assert valid[:, slots2].all()
    assert [row[slots2].tolist() for row in toks] == ref
    np.testing.assert_array_equal(pool2.lengths, ref_lengths)


def test_burst_freezes_row_on_eos_and_scheduler_retires(engine):
    """A row sampling EOS mid-burst freezes on device (no further valid
    tokens, lengths stop advancing) and the scheduler retires it at the
    same position the single-step path would."""
    prompts = _prompts(engine, 1, [8], seed=5)
    probe, _ = _run(engine, prompts, max_burst=1, max_new=8)
    # EOS = the first token value NOT seen earlier in the sequence (so the
    # request cannot retire before it).  With max_new=16 a solo request's
    # first burst is planned K=8 (rem 15 -> pow2 8) and covers generated
    # tokens 2..9, so an EOS inside that window freezes the row strictly
    # mid-burst on device.
    seq = probe[0]
    i = next(j for j in range(1, len(seq)) if seq[j] not in seq[:j])
    assert i < 8, "probe sequence has no novel token inside the burst"
    eos = seq[i]
    sp = SamplingParams(max_new_tokens=16, eos_id=int(eos))

    def run(max_burst):
        sched = Scheduler(engine, max_burst=max_burst)
        req = sched.submit(Request(prompt=prompts[0], sampling=sp))
        sched.run(max_steps=100)
        return req, sched

    r1, _ = run(1)
    r8, s8 = run(8)
    assert r1.output_tokens == r8.output_tokens
    assert r8.finish_reason == r1.finish_reason == "eos"
    assert r8.n_generated == i + 1
    # the EOS landed mid-burst: planned token-steps exceed emitted tokens
    hist = s8.metrics.burst_hist
    assert any(k > 1 for k in hist)
    assert s8.metrics.decode_token_steps == sum(k * v for k, v in hist.items())
    assert s8.metrics.decode_token_steps > r8.n_generated - 1
    # slot returned to the pool despite the mid-burst freeze
    assert s8.pool.n_free == s8.pool.n_slots
    assert (s8.pool.lengths == 0).all()


# ---------------------------------------------------------------------------
# Scheduling semantics: admission / prefill force K = 1
# ---------------------------------------------------------------------------
def test_waiting_queue_and_prefill_force_single_steps(engine):
    """K > 1 bursts never run while the waiting queue is non-empty or a
    prefill is mid-flight (admission latency and chunked-prefill
    interleaving stay byte-identical to the burst-free scheduler)."""
    prompts = _prompts(engine, 6, [8, 6], seed=6)   # 6 requests, 4 slots
    sched = Scheduler(engine, max_burst=8)
    seen_ks = []
    orig = engine.decode_burst

    def checked(pool, tokens, key_schedule, *args, **kw):
        k = key_schedule.shape[0]
        seen_ks.append(k)
        if k > 1:
            assert not sched.waiting, "burst dispatched with queued work"
            assert not any(r.state is RequestState.PREFILL
                           for r in sched.running.values()), \
                "burst dispatched around a mid-flight prefill"
        return orig(pool, tokens, key_schedule, *args, **kw)

    reqs = [sched.submit(Request(
        prompt=p, sampling=SamplingParams(max_new_tokens=6)))
        for p in prompts]
    try:
        engine.decode_burst = checked
        sched.run(max_steps=400)
    finally:
        engine.decode_burst = orig
    assert all(r.is_finished for r in reqs)
    # the run exercised both regimes: queued-era K=1 rounds (hist) and
    # post-drain bursts
    assert 1 in sched.metrics.burst_hist
    assert any(k > 1 for k in seen_ks)
    # ... and output still matches the all-single-step run
    ref, _ = _run(engine, prompts, max_burst=1, max_new=6)
    assert [list(r.output_tokens) for r in reqs] == ref


def test_dispatch_count_regression(engine):
    """THE perf pin: decode jit entries per generated token must amortize
    to <= 1/K at max_burst=K for an uncontended decode run (monkeypatch-
    counted on the engine methods, independent of scheduler bookkeeping)."""
    prompts = _prompts(engine, 1, [8], seed=7)
    sp = SamplingParams(max_new_tokens=33)            # 8 + 33 <= 48

    def count(max_burst):
        calls = {"n": 0}
        orig_b, orig_s = engine.decode_burst, engine.decode_slots

        def wrap(orig):
            def inner(*a, **kw):
                calls["n"] += 1
                return orig(*a, **kw)
            return inner

        sched = Scheduler(engine, max_burst=max_burst)
        req = sched.submit(Request(prompt=prompts[0], sampling=sp))
        try:
            engine.decode_burst = wrap(orig_b)
            engine.decode_slots = wrap(orig_s)
            sched.run(max_steps=200)
        finally:
            engine.decode_burst, engine.decode_slots = orig_b, orig_s
        assert req.n_generated == 33
        return calls["n"], sched

    n1, s1 = count(1)
    n8, s8 = count(8)
    assert n1 == 32                          # first token comes off prefill
    assert n8 * 8 <= n1 + 7                  # <= ceil(n1 / 8): 1/K amortized
    assert n8 / 33 <= 1 / 8                  # dispatches per generated token
    # scheduler accounting agrees with the monkeypatch count
    assert s8.n_decode_dispatches == n8
    assert s8.n_decode_steps == s1.n_decode_steps == 32
    rep = s8.metrics.report()
    assert rep["decode_dispatches"] == n8
    assert rep["decode_dispatches_per_token"] <= 1 / 8
    assert rep["itl_granularity"] == "burst"
    assert s1.metrics.report()["itl_granularity"] == "token"


def test_burst_metrics_and_host_sync_accounting(engine):
    """Greedy host syncs = one per decode dispatch + two per request
    (final-chunk logits and the sampled first token); burst histogram keys
    are powers of two bounded by max_burst."""
    prompts = _prompts(engine, 3, [8, 11], seed=8)
    _, sched = _run(engine, prompts, max_burst=8, max_new=9)
    assert sched.n_host_syncs == sched.n_decode_dispatches + 2 * len(prompts)
    # temperature rows add exactly one (batched) key-schedule transfer per
    # decode round, not one per row
    _, tsched = _run(engine, prompts, max_burst=8, max_new=9,
                     temperature=0.7, seed=3)
    assert tsched.n_host_syncs == \
        2 * tsched.n_decode_dispatches + 2 * len(prompts)
    for k in sched.metrics.burst_hist:
        assert 1 <= k <= 8 and (k & (k - 1)) == 0
    rep = sched.metrics.report()
    assert rep["decode_token_steps"] == sched.n_decode_steps
    assert 0 < rep["decode_dispatches_per_token"] <= 1.0
    # every token except the per-request prefill-sampled first one was
    # emitted by a decode dispatch
    assert rep["decode_tokens_emitted"] == \
        rep["total_new_tokens"] - len(prompts)


def test_generate_reports_burst_accounting(engine):
    """The one-shot generate() wrapper surfaces the burst accounting of its
    private scheduler (consumed by launch/serve and the bench)."""
    prompts = _prompts(engine, 2, [8], seed=9)
    out = engine.generate({"tokens": np.stack(prompts)}, max_new_tokens=10)
    # 18 decode-emitted tokens (2 first tokens come off prefill) in far
    # fewer dispatches than token-steps
    assert out["decode_token_steps"] >= 9
    assert out["decode_dispatches"] < out["decode_token_steps"]
    assert out["host_syncs"] == out["decode_dispatches"] + 2 * 2
    assert any(k > 1 for k in out["burst_hist"])


# ---------------------------------------------------------------------------
# Sharded bursts (DESIGN.md §10 contract extended to §11)
# ---------------------------------------------------------------------------
def test_mesh_single_device_burst_bit_identical(engine):
    """A (1, 1) mesh walks the whole sharded burst path (explicit carry
    shardings, key-schedule sharding, donation) — fast-loop coverage."""
    cfg, params = engine.cfg, engine.params
    prompts = _prompts(engine, 3, [9, 6], seed=10)
    ref, _ = _run(engine, prompts, max_burst=8)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8, max_burst=8, mesh=mesh))
    got, sched = _run(eng, prompts, max_burst=8)
    assert got == ref
    assert any(k > 1 for k in sched.metrics.burst_hist)


@multi_device
def test_burst_dp2_tp4_bit_identical():
    """Bursts under the dp=2 x tp=4 mesh (8 forced host devices), quantized
    weights + int8 KV pool, mid-flight admission included: bit-identical to
    the single-device single-step run, with strictly fewer dispatches."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab, (n,)).astype(np.int32)
               for n in (9, 6, 11, 8)]

    def engine(mesh):
        return ServingEngine(cfg, params, ServeConfig(
            max_len=32, n_slots=8, prefill_chunk=8, kv_dtype="int8",
            max_burst=8, mesh=mesh))

    ref, s1 = _run(engine(None), prompts, max_burst=1, midflight=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    got, s8 = _run(engine(mesh), prompts, max_burst=8, midflight=True)
    assert got == ref
    assert s8.n_decode_dispatches < s1.n_decode_dispatches
    assert any(k > 1 for k in s8.metrics.burst_hist)
    assert s8.metrics.report()["topology"] == \
        {"n_devices": 8, "dp": 2, "tp": 4}
