"""Hypothesis property tests on quantization + packing invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import formats as F
from repro.quant.pack import codes_per_word, pack_codes_np, unpack_codes
from repro.quant.schemes import (SCHEMES, dequantize, get_scheme,
                                 quantize_weights)


@given(st.integers(2, 8), st.data())
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(bits, data):
    if 32 % bits != 0:
        bits = {3: 4, 5: 4, 6: 8, 7: 8}[bits]
    per = codes_per_word(bits)
    k = per * data.draw(st.integers(1, 4))
    n = data.draw(st.integers(1, 8))
    codes = data.draw(st.lists(
        st.integers(0, (1 << bits) - 1), min_size=k * n, max_size=k * n))
    arr = np.array(codes, np.int64).reshape(k, n)
    import jax.numpy as jnp
    packed = pack_codes_np(arr, bits)
    out = np.asarray(unpack_codes(jnp.asarray(packed), bits))
    np.testing.assert_array_equal(out, arr)


@pytest.mark.parametrize("scheme_name", ["awq_int4", "w8a8", "fp8", "mxfp4"])
@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_quantize_bounded_error(scheme_name, data):
    """|W - dequant(quant(W))| <= scale * ulp-bound per group."""
    scheme = get_scheme(scheme_name)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    k = 128 if scheme.group_size == -1 else scheme.group_size
    w = rng.standard_normal((k, 16)).astype(np.float32)
    qw = quantize_weights(scheme, w)
    back = np.asarray(dequantize(qw, dtype=np.float32))
    absmax = np.abs(w).max(axis=0, keepdims=True)
    if scheme.weight_format.startswith("int"):
        qmax = (1 << (scheme.weight_bits - 1)) - 1
        bound = absmax / qmax            # half-ulp rounding, symmetric
    else:
        fmt = F.get_format(scheme.weight_format)
        # worst relative error of the float format + FTZ zone near zero
        bound = absmax * 2.0 ** (-fmt.man_bits)
        if scheme.scale_pow2:
            bound = bound * 2            # UE8M0 scales round UP to pow2
    assert (np.abs(back - w) <= bound + 1e-6).all()


@given(st.sampled_from(["fp4_e2m1", "fp8_e4m3", "fp8_e5m2", "bf16", "fp16"]))
@settings(max_examples=20, deadline=None)
def test_codec_roundtrip_all_patterns(fmt_name):
    """decode -> re-encode is the identity on canonical finite patterns."""
    fmt = F.get_format(fmt_name)
    if fmt.bits > 8:
        return
    bits = F.all_bit_patterns(fmt)
    vals = fmt.decode_to_f64(bits)
    finite = np.isfinite(vals) & (vals != 0.0)
    re = F.quantize_f64(fmt, vals[finite])
    np.testing.assert_array_equal(re, bits[finite])


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_mac_commutes_with_float_math(data):
    """For exactly-representable operands the MAC equals float math."""
    from repro.core.mac import MacConfig, xtramac
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    cfg = MacConfig.make("int4", "bf16", "bf16", "fp32")
    a = rng.integers(0, 16, 32)
    b = F.quantize_f64(F.BF16, rng.normal(size=32))
    c = F.quantize_f64(F.BF16, rng.normal(size=32))
    out = F.FP32.decode_to_f64(xtramac(cfg, a, b, c))
    a_v = F.INT4.decode_to_f64(a)
    b_v = F.BF16.decode_to_f64(b)
    c_v = F.BF16.decode_to_f64(c)
    # int4*bf16 product is exact in fp32; + bf16 exact in fp32 window
    expect = np.float32(a_v * b_v) + np.float32(c_v)
    np.testing.assert_allclose(out, expect, rtol=1e-6)
