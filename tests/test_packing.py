"""Lane-packing (Eq. 9-12) and pipeline (Section IV) behaviour tests."""
import numpy as np
import pytest

from repro.core import formats as F
from repro.core.mac import MacConfig
from repro.core.packing import (
    PAPER_PARALLELISM, SOLVER_BEYOND_PAPER, packed_multiply,
    per_lane_reference, solve_lane_plan, utilization_upcast,
    utilization_xtramac, xtramac_packed,
)
from repro.core.pipeline import Op, XtraMACPipeline

RNG = np.random.default_rng(1)


# ---------------------------------------------------------------------------
# Eq. 12 / Fig. 6: solver reaches the paper's parallelism for every datatype
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pair,expect", sorted(PAPER_PARALLELISM.items()))
def test_paper_parallelism_feasible(pair, expect):
    """The paper's deployed lane count is realizable at its own cap..."""
    plan = solve_lane_plan(*pair, max_parallelism=expect)
    plan.validate()
    assert plan.parallelism == expect, (pair, plan)


@pytest.mark.parametrize("pair,expect", sorted(PAPER_PARALLELISM.items()))
def test_solver_meets_or_beats_paper(pair, expect):
    """...and the uncapped solver never does worse than the paper."""
    plan = solve_lane_plan(*pair)
    plan.validate()
    assert plan.parallelism >= expect, (pair, plan)


@pytest.mark.parametrize("pair,expect", sorted(SOLVER_BEYOND_PAPER.items()))
def test_solver_beats_paper_cap(pair, expect):
    """Beyond-paper: e.g. FP4xFP4 admits 6 isolated lanes (paper: 4)."""
    plan = solve_lane_plan(*pair)
    plan.validate()
    assert plan.parallelism >= expect, (pair, plan)


def test_lane_isolation_exhaustive_fp8():
    """Every packed product equals the standalone product — Eq. 10/11."""
    plan = solve_lane_plan("fp8_e4m3", "fp8_e4m3", max_parallelism=4)
    n_a, n_b = len(plan.offsets_a), len(plan.offsets_b)
    # exhaustive over mantissa magnitudes (4-bit each incl implicit bit)
    mags = np.arange(16)
    grids = np.meshgrid(*([mags] * (n_a + n_b)), indexing="ij")
    a = np.stack(grids[:n_a], axis=-1).reshape(-1, n_a)
    b = np.stack(grids[n_a:], axis=-1).reshape(-1, n_b)
    prods = packed_multiply(plan, a, b)
    for lane, (i, j, _) in enumerate(plan.lane_positions):
        np.testing.assert_array_equal(prods[..., lane], a[:, i] * b[:, j])


@pytest.mark.parametrize("pair", [("bf16", "bf16"), ("int8", "int8"),
                                  ("int4", "bf16"), ("fp4_e2m1", "bf16")])
def test_lane_isolation_randomized(pair):
    plan = solve_lane_plan(*pair, max_parallelism=4)
    n_a, n_b = len(plan.offsets_a), len(plan.offsets_b)
    a = RNG.integers(0, 1 << plan.w_a, size=(20_000, n_a), dtype=np.int64)
    b = RNG.integers(0, 1 << plan.w_b, size=(20_000, n_b), dtype=np.int64)
    prods = packed_multiply(plan, a, b)
    for lane, (i, j, _) in enumerate(plan.lane_positions):
        np.testing.assert_array_equal(prods[..., lane], a[:, i] * b[:, j])


@pytest.mark.parametrize("combo", [
    ("int4", "bf16", "bf16", "bf16"),
    ("fp8_e4m3", "fp8_e4m3", "bf16", "bf16"),
    ("bf16", "bf16", "bf16", "bf16"),
    ("int8", "int8", "int32", "int32"),
    ("fp4_e2m1", "bf16", "bf16", "bf16"),
])
def test_packed_mac_equals_per_lane(combo):
    """Full packed MAC through ONE multiply == per-lane xtramac, bit-exact."""
    cfg = MacConfig.make(*combo)
    plan = solve_lane_plan(cfg.fmt_a, cfg.fmt_b, max_parallelism=4)
    n = 5_000
    a = RNG.integers(0, 1 << cfg.fmt_a.bits, size=(n, len(plan.offsets_a)), dtype=np.int64)
    b = RNG.integers(0, 1 << cfg.fmt_b.bits, size=(n, len(plan.offsets_b)), dtype=np.int64)
    c = RNG.integers(0, 1 << min(cfg.fmt_c.bits, 32), size=(n, plan.parallelism), dtype=np.int64)
    got = xtramac_packed(cfg, plan, a, b, c)
    want = per_lane_reference(cfg, plan, a, b, c)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# DSP utilization model (Fig. 3 / Fig. 9)
# ---------------------------------------------------------------------------
def test_utilization_ordering():
    # packed XtraMAC beats upcasting for every low-precision combo
    for pair in [("int4", "bf16"), ("fp8_e4m3", "fp8_e4m3"), ("fp4_e2m1", "bf16")]:
        assert utilization_xtramac(*pair) > utilization_upcast(*pair)
    # FP8xFP8 packed: 4 lanes x (4+4) operand bits = 32/45 ≈ 71.1%
    assert utilization_xtramac("fp8_e4m3", "fp8_e4m3") == pytest.approx(32 / 45)
    # INT8 2-lane packing reproduces TATAA's own 71.1% INT8 figure
    assert utilization_xtramac("int8", "int8") == pytest.approx(0.711, abs=1e-3)


# ---------------------------------------------------------------------------
# Pipeline: latency 4, II=1, cycle-level runtime datatype switching
# ---------------------------------------------------------------------------
def _random_op(cfgs, plans, sel):
    cfg, plan = cfgs[sel], plans[sel]
    a = RNG.integers(0, 1 << cfg.fmt_a.bits, size=len(plan.offsets_a), dtype=np.int64)
    b = RNG.integers(0, 1 << cfg.fmt_b.bits, size=len(plan.offsets_b), dtype=np.int64)
    c = RNG.integers(0, 1 << min(cfg.fmt_c.bits, 32), size=plan.parallelism, dtype=np.int64)
    return Op(sel, a, b, c)


def test_pipeline_latency_and_ii():
    cfgs = [MacConfig.make("int4", "bf16", "bf16", "bf16"),
            MacConfig.make("bf16", "bf16", "bf16", "bf16")]
    pipe = XtraMACPipeline(cfgs)
    assert pipe.latency == 4
    op = _random_op(cfgs, pipe.plans, 0)
    outs = [pipe.step(op)] + [pipe.step(None) for _ in range(4)]
    # result appears exactly 4 cycles after issue, never earlier
    assert all(o is None for o in outs[:4]) and outs[4] is not None


def test_pipeline_cycle_level_switching():
    """Alternate datatypes EVERY cycle; stream stays II=1 and bit-exact."""
    cfgs = [MacConfig.make("int8", "int8", "int32", "int32"),
            MacConfig.make("bf16", "bf16", "bf16", "bf16"),
            MacConfig.make("fp8_e4m3", "fp8_e4m3", "bf16", "bf16")]
    pipe = XtraMACPipeline(cfgs)
    ops = [_random_op(cfgs, pipe.plans, i % 3) for i in range(60)]
    results = pipe.run(ops)
    assert len(results) == len(ops)  # II = 1: one result per issued cycle
    for op, got in zip(ops, results):
        cfg, plan = cfgs[op.dtype_sel], pipe.plans[op.dtype_sel]
        want = per_lane_reference(cfg, plan, op.a_bits[None], op.b_bits[None], op.c_bits[None])[0]
        np.testing.assert_array_equal(got, want)


def test_pipeline_configurable_stage_latency():
    """Extra Stage-3 registers raise latency but keep II=1 (Section IV-F)."""
    cfgs = [MacConfig.make("bf16", "bf16", "bf16", "bf16")]
    pipe = XtraMACPipeline(cfgs, stage_cycles=(1, 1, 3, 1))
    assert pipe.latency == 6
    ops = [_random_op(cfgs, pipe.plans, 0) for _ in range(20)]
    results = pipe.run(ops)
    assert len(results) == len(ops)
    for op, got in zip(ops, results):
        want = per_lane_reference(cfgs[0], pipe.plans[0], op.a_bits[None],
                                  op.b_bits[None], op.c_bits[None])[0]
        np.testing.assert_array_equal(got, want)
