"""Observability-layer tests (DESIGN.md §13).

Three contracts:

1. **Zero-overhead when disabled**: ``Scheduler(engine)`` with no obs
   bundle makes exactly the baseline number of host syncs, dispatches
   AND clock calls — attaching observability must never have been able
   to perturb the un-observed hot path.
2. **Determinism**: under a virtual clock, two identical runs produce
   byte-identical Chrome trace files and identical registry snapshots.
3. **Schema stability**: the metrics report and trace event key sets are
   pinned, and every report is RFC-JSON clean (``allow_nan=False``
   round-trips) — downstream join scripts (CI artifact checks,
   benchmarks/BENCH_serve_baseline.json comparisons) key on both.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import InitMaker
from repro.models import transformer as T
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       Observability, PID_REQUESTS, PID_SCHEDULER,
                       SnapshotWriter, StepProfiler, Tracer,
                       compiled_step_cost)
from repro.serve import (Request, SamplingParams, ServeConfig, ServingEngine,
                         Scheduler)
from repro.serve.metrics import ServeMetrics, burst_spread_itl


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    return ServingEngine(cfg, params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8, max_burst=8))


def _prompts(engine, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, engine.cfg.vocab, (n,)).astype(np.int32)
            for n in lens]


class VirtualClock:
    """Deterministic ticking clock: every call advances by ``dt``."""

    def __init__(self, dt=0.125):
        self.now = 0.0
        self.dt = dt
        self.calls = 0

    def __call__(self):
        self.calls += 1
        self.now += self.dt
        return self.now


def _run(engine, *, obs=None, clock=None, max_burst=8, n=3, max_new=7,
         temperature=0.0, tiers=None):
    clock = clock or VirtualClock()
    sched = Scheduler(engine, clock=clock, max_burst=max_burst, obs=obs,
                      tiers=tiers)
    for i, p in enumerate(_prompts(engine, [9, 6, 11, 8, 7][:n], seed=3)):
        sched.submit(Request(
            prompt=p,
            kv_policy=tiers[i % len(tiers)] if tiers else None,
            sampling=SamplingParams(temperature=temperature,
                                    max_new_tokens=max_new, seed=0)))
    sched.run(max_steps=400)
    return sched, clock


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------
def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests")
    c.inc()
    c.inc(2, tier="int8")
    g = reg.gauge("depth", "queue depth")
    g.set(3)
    g.set(1)                                     # gauges overwrite
    h = reg.histogram("lat_s", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert c.value() == 1 and c.value(tier="int8") == 2
    assert g.value() == 1
    # get-or-create: same family back, kind-checked
    assert reg.counter("req_total") is c
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("req_total")

    text = reg.expose()
    assert "# TYPE req_total counter" in text
    assert 'req_total{tier="int8"} 2' in text
    assert "# TYPE lat_s histogram" in text
    # cumulative buckets + +Inf + sum/count
    assert 'lat_s_bucket{le="0.1"} 1' in text
    assert 'lat_s_bucket{le="1"} 2' in text
    assert 'lat_s_bucket{le="+Inf"} 3' in text
    assert "lat_s_count 3" in text
    assert isinstance(reg.get("depth"), Gauge)
    assert isinstance(reg.get("lat_s"), Histogram)
    assert isinstance(c, Counter)


def test_counters_only_go_up():
    c = MetricsRegistry().counter("n", "")
    with pytest.raises(AssertionError):
        c.inc(-1)


def test_snapshot_writer(tmp_path):
    reg = MetricsRegistry()
    c = reg.counter("n", "")
    path = tmp_path / "snap.jsonl"
    w = SnapshotWriter(reg, str(path), every_s=1.0)
    assert w.maybe_write(0.0)                    # first call always writes
    c.inc()
    assert not w.maybe_write(0.5)                # interval not elapsed
    assert w.maybe_write(1.5)
    lines = [json.loads(s) for s in path.read_text().splitlines()]
    assert [s["ts"] for s in lines] == [0.0, 1.5]
    assert lines[0]["metrics"]["n"] == []        # no labelset touched yet
    assert lines[1]["metrics"]["n"][0]["value"] == 1
    assert w.n_written == 2


# ---------------------------------------------------------------------------
# tracer: format + determinism
# ---------------------------------------------------------------------------
def test_tracer_chrome_format_valid_json():
    tr = Tracer()
    tr.process_name(PID_SCHEDULER, "scheduler")
    tr.thread_name(PID_SCHEDULER, 0, "prefill")
    tr.complete("decode_burst", 1.0, 1.5, pid=PID_SCHEDULER, tid=1,
                args={"k": 4})
    tr.instant("first_token", 1.25, pid=PID_REQUESTS, tid=7)
    tr.counter("queue_depth", 1.5, {"waiting": 2})
    txt = tr.to_json()
    events = json.loads(txt)                     # closed, valid JSON array
    assert len(events) == len(tr) == 5
    # one self-contained JSON object per line (greppable)
    body = txt.strip().splitlines()[1:-1]
    assert all(json.loads(line.rstrip(",")) for line in body)
    x = next(e for e in events if e["ph"] == "X")
    assert (x["ts"], x["dur"]) == (1.0e6, 0.5e6)     # microseconds
    assert x["args"]["k"] == 4
    assert {e["ph"] for e in events} == {"M", "X", "i", "C"}
    # metadata dedup: naming the same lane twice emits once
    tr.thread_name(PID_SCHEDULER, 0, "prefill")
    assert len(tr) == 5


def test_trace_byte_identical_across_virtual_clock_runs(engine, tmp_path):
    """THE determinism contract: two identical virtual-clock runs write
    byte-identical trace files (and identical registry expositions)."""
    outs = []
    for name in ("a", "b"):
        obs = Observability(tracer=Tracer(), registry=MetricsRegistry())
        _run(engine, obs=obs)
        p = tmp_path / f"{name}.trace.json"
        obs.tracer.write(str(p))
        outs.append((p.read_bytes(), obs.registry.expose()))
    assert outs[0][0] == outs[1][0]
    assert outs[0][1] == outs[1][1]


def test_trace_carries_request_spans_and_dispatch_events(engine):
    obs = Observability(tracer=Tracer())
    sched, _ = _run(engine, obs=obs)
    events = json.loads(obs.tracer.to_json())
    req_spans = [e for e in events
                 if e["ph"] == "X" and e["pid"] == PID_REQUESTS]
    names = {e["name"] for e in req_spans}
    assert names == {"WAITING", "PREFILL", "DECODE"}
    # one full span triple per retired request, on the request's own tid
    for r in sched.finished:
        mine = [e for e in req_spans if e["tid"] == r.id]
        assert {e["name"] for e in mine} == {"WAITING", "PREFILL", "DECODE"}
        dec = next(e for e in mine if e["name"] == "DECODE")
        assert dec["args"]["n_generated"] == r.n_generated
    # per-dispatch events on the scheduler process with burst metadata
    bursts = [e for e in events if e["name"] == "decode_burst"]
    assert bursts and any(e["args"]["k"] > 1 for e in bursts)
    assert all(set(e["args"]) >= {"tier", "k", "rows", "slots", "dispatch"}
               for e in bursts)
    chunks = [e for e in events if e["name"] == "prefill_chunk"]
    assert chunks and all(e["tid"] == 0 for e in chunks)
    assert sum(e["args"]["final"] for e in chunks) == len(sched.finished)
    # counter tracks sampled each step
    assert any(e["ph"] == "C" and e["name"] == "queue_depth"
               for e in events)


# ---------------------------------------------------------------------------
# the zero-overhead guard (acceptance criterion)
# ---------------------------------------------------------------------------
def test_disabled_obs_is_noop_and_enabled_changes_nothing(engine):
    """obs=None adds NOTHING to the hot path: host syncs follow the PR-5
    baseline formula, clock calls are exactly the baseline set (submit +
    per-token emit + per-step sample), and enabling full observability
    changes neither tokens, syncs, dispatches nor step count."""
    base, base_clk = _run(engine)
    n_req = len(base.finished)
    n_tok = sum(r.n_generated for r in base.finished)
    # greedy baseline sync accounting (pinned since the burst PR):
    # one per decode dispatch + 2 per request (final chunk + first token)
    assert base.n_host_syncs == base.n_decode_dispatches + 2 * n_req
    # clock-call accounting: submit (1/request) + _emit (1/token) +
    # step-end metrics sample (1/step) — nothing else may touch the clock
    assert base_clk.calls == n_req + n_tok + base.n_steps

    obs = Observability(tracer=Tracer(), registry=MetricsRegistry(),
                        profiler=StepProfiler(engine.cfg))
    full, _ = _run(engine, obs=obs)
    assert [r.output_tokens for r in full.finished] == \
        [r.output_tokens for r in base.finished]
    assert full.n_host_syncs == base.n_host_syncs
    assert full.n_decode_dispatches == base.n_decode_dispatches
    assert full.n_steps == base.n_steps
    assert full.metrics.burst_hist == base.metrics.burst_hist
    # and the observed run actually observed
    assert len(obs.tracer) > 0 and obs.profiler.n_records > 0


def test_token_dispatch_ids_recorded_without_obs(engine):
    """Dispatch attribution (the burst-spread ITL input) is always on:
    tokens of one burst share an id, ids are monotone, and the disabled
    path records them identically to the enabled one."""
    sched, _ = _run(engine)
    for r in sched.finished:
        assert len(r.token_dispatches) == r.n_generated
        assert all(d > 0 for d in r.token_dispatches)
        assert r.token_dispatches == sorted(r.token_dispatches)
    # with bursts, some request must have >1 token from one dispatch
    assert any(len(set(r.token_dispatches)) < r.n_generated
               for r in sched.finished)


# ---------------------------------------------------------------------------
# ServeMetrics edge cases (satellites)
# ---------------------------------------------------------------------------
def _req_stub(**kw):
    class R:
        id = 0
        tier = kw.get("tier")
        finish_reason = kw.get("finish_reason", "length")
        arrival_time = kw.get("arrival_time")
        first_token_time = kw.get("first_token_time")
        finish_time = kw.get("finish_time")
        token_times = kw.get("token_times", [])
        token_dispatches = kw.get("token_dispatches", [])
        n_generated = kw.get("n_generated", 0)
    return R()


def test_zero_wall_report_is_json_clean():
    """The old report emitted float('nan') for tokens_per_s at wall==0 —
    not RFC JSON.  Now: null, and the whole report round-trips with
    allow_nan=False."""
    m = ServeMetrics(4)
    m.on_arrival(1.0)
    m.on_finish(_req_stub(arrival_time=1.0, finish_time=1.0, n_generated=0))
    rep = m.report()
    assert rep["wall_s"] == 0.0
    assert rep["tokens_per_s"] is None
    assert json.loads(json.dumps(rep, allow_nan=False)) == rep


def test_report_json_roundtrip_from_real_run(engine):
    sched, _ = _run(engine, temperature=0.7)
    rep = sched.metrics.report()
    assert json.loads(json.dumps(rep, allow_nan=False)) == rep


def test_multi_tier_occupancy_weighting():
    """Per-tier occupancy weights each tier by ITS slot count: 1/2 int8
    slots busy is 0.5 for int8 even while the 6-slot total reads 3/6."""
    m = ServeMetrics(6)
    m.tiers = {"bf16": 4, "int8": 2}
    m.on_step(0.0, {"bf16": 2, "int8": 1})       # first sample: no weight
    m.on_step(1.0, {"bf16": 2, "int8": 1})       # [0,1): 2/4, 1/2
    m.on_step(3.0, {"bf16": 4, "int8": 0})       # [1,3): 4/4, 0/2
    rep = m.report()
    assert rep["slot_occupancy_mean"] == round((1 * 3 / 6 + 2 * 4 / 6) / 3, 4)
    assert rep["tier_occupancy_mean"] == {
        "bf16": round((1 * 0.5 + 2 * 1.0) / 3, 4),
        "int8": round((1 * 0.5 + 2 * 0.0) / 3, 4)}


def test_burst_histogram_mixed_k():
    m = ServeMetrics(4)
    for _ in range(3):
        m.on_decode_burst(1, 2, tier="bf16")
    for _ in range(2):
        m.on_decode_burst(8, 14, tier="bf16")
    rep = m.report()
    assert rep["burst_hist"] == {"1": 3, "8": 2}
    assert rep["decode_dispatches"] == 5
    assert rep["decode_token_steps"] == 3 + 16
    assert rep["decode_tokens_emitted"] == 6 + 28
    assert rep["itl_granularity"] == "burst"
    m2 = ServeMetrics(4)
    m2.on_decode_burst(1, 1)
    assert m2.report()["itl_granularity"] == "token"


def test_burst_spread_itl_math():
    # two bursts of 4 at t=1 (dispatch 7) and t=2 (dispatch 9): raw gaps
    # are [0,0,0,1,0,0,0]; spread: intra-first-burst gaps stay ~0 (3
    # samples of 0/3), the second burst's 1s wall spreads over 4 tokens
    times = [1.0] * 4 + [2.0] * 4
    disp = [7] * 4 + [9] * 4
    out = burst_spread_itl(times, disp)
    assert len(out) == len(times) - 1            # sample count == raw gaps
    assert out == [0.0] * 3 + [0.25] * 4
    # K=1 everywhere: spread IS the raw diff sequence
    times = [0.0, 0.5, 1.5]
    assert burst_spread_itl(times, [1, 2, 3]) == [0.5, 1.0]
    # missing dispatch ids: degrade to raw diffs
    assert burst_spread_itl(times, []) == [0.5, 1.0]


def test_itl_burst_spread_reported_alongside_raw(engine):
    """satellite (c): burst runs report both the raw (burst-granular)
    percentiles and the spread estimate; with max_burst=1 the two
    populations coincide and itl_granularity stays 'token'."""
    burst, _ = _run(engine)
    rep = burst.metrics.report()
    assert rep["itl_granularity"] == "burst"
    assert rep["itl_burst_spread_p95_s"] <= rep["itl_p95_s"]
    assert rep["itl_burst_spread_mean_s"] > 0
    single, _ = _run(engine, max_burst=1)
    rep1 = single.metrics.report()
    assert rep1["itl_granularity"] == "token"
    assert rep1["itl_burst_spread_p50_s"] == rep1["itl_p50_s"]
    assert rep1["itl_burst_spread_mean_s"] == rep1["itl_mean_s"]


def test_serve_metrics_publishes_into_registry(engine):
    reg = MetricsRegistry()
    sched, _ = _run(engine, obs=Observability(registry=reg))
    n_req = len(sched.finished)
    assert reg.get("serve_requests_arrived_total").value() == n_req
    assert reg.get("serve_requests_finished_total").value(
        tier="bf16", reason="length") == n_req
    assert reg.get("serve_decode_dispatches_total").value(tier="bf16") == \
        sched.n_decode_dispatches
    assert reg.get("serve_host_syncs_total").value() == sched.n_host_syncs
    assert reg.get("serve_admissions_total").value(tier="bf16") == n_req
    assert reg.get("serve_scheduler_steps_total").value() == sched.n_steps
    assert reg.get("serve_slots_total").value(tier="bf16") == 4
    assert reg.get("serve_queue_depth").value() == 0      # drained
    text = reg.expose()
    assert "# TYPE serve_burst_k histogram" in text


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------
def test_profiler_report_joins_model_vs_measured(engine):
    obs = Observability(profiler=StepProfiler(engine.cfg))
    sched, _ = _run(engine)
    del sched
    sched, _ = _run(engine, obs=obs)
    rep = obs.profiler.report()
    assert rep["design"] == "xtramac" and not rep["scheme_fallback"]
    decode = [g for g in rep["groups"] if g["kind"] == "decode"]
    prefill = [g for g in rep["groups"] if g["kind"] == "prefill_chunk"]
    assert decode and prefill
    for g in decode:
        assert g["model_s"] > 0 and g["measured_s"] > 0
        assert g["model_over_measured"] > 0
        assert g["context_mean"] > 0
    assert all(g["model_s"] is None for g in prefill)
    pt = rep["per_tier"]["bf16"]
    assert pt["dispatches"] == sched.n_decode_dispatches
    assert pt["token_steps"] == sched.metrics.decode_token_steps
    assert pt["model_over_measured"] > 0
    assert json.loads(json.dumps(rep, allow_nan=False)) == rep


def test_profiler_scheme_fallback():
    cfg = get_config("granite-8b", smoke=True)
    prof = StepProfiler(cfg, scheme="bf16")      # no _DEPLOY row for bf16
    assert prof.scheme == "w8a8" and prof.scheme_fallback


def test_profiler_prices_kv_tiers_differently():
    """The per-tier join must price each tier's KV bytes: an int8 pool
    streams ~half the bytes of bf16 per context position, so the model's
    per-step prediction cannot be identical across tiers."""
    cfg = get_config("granite-8b", smoke=True)
    prof = StepProfiler(cfg)
    a = prof._model_step_s(4, 1024, 1024)
    b = prof._model_step_s(4, 1024, 2048)
    assert b >= a                                 # more KV bytes, not less
    assert prof._model_step_s(4, 1024, 1024) == a  # memoized, stable


def test_compiled_step_cost(engine):
    pool = engine.new_pool()
    cost = compiled_step_cost(engine, pool)
    assert cost["k"] == 1 and cost["n_slots"] == pool.n_slots
    assert cost["flops"] > 0 and cost["hbm_bytes"] > 0
    assert cost["flops_per_token_step"] == round(
        cost["flops"] / pool.n_slots, 1)


# ---------------------------------------------------------------------------
# schema stability (CI keys on these)
# ---------------------------------------------------------------------------
REPORT_KEYS = {
    "n_requests", "total_new_tokens", "wall_s", "tokens_per_s",
    "slot_occupancy_mean", "decode_dispatches", "decode_token_steps",
    "decode_tokens_emitted", "decode_dispatches_per_step",
    "decode_dispatches_per_token", "burst_hist", "itl_granularity",
    # spec-aware amortization across both decode paths (DESIGN.md §17);
    # equals decode_dispatches_per_token when speculation is off
    "dispatches_per_token",
    "ttft_mean_s", "ttft_p50_s", "ttft_p95_s",
    "itl_mean_s", "itl_p50_s", "itl_p95_s",
    "e2e_latency_mean_s", "e2e_latency_p50_s", "e2e_latency_p95_s",
    "itl_burst_spread_mean_s", "itl_burst_spread_p50_s",
    "itl_burst_spread_p95_s",
    "finish_reasons", "queue_wait_p50_s", "queue_wait_p95_s",
}

TRACE_EVENT_KEYS = {
    "M": {"ph", "name", "pid", "tid", "args"},
    "X": {"ph", "name", "cat", "pid", "tid", "ts", "dur", "args"},
    "i": {"ph", "s", "name", "cat", "pid", "tid", "ts", "args"},
    "C": {"ph", "name", "cat", "pid", "tid", "ts", "args"},
}


def test_report_schema_stable(engine):
    sched, _ = _run(engine)
    assert set(sched.metrics.report()) == REPORT_KEYS
    # single-tier reports never carry tier keys; topology is None here
    mt, _ = _run(engine, tiers=["bf16", "int8"])
    assert set(mt.metrics.report()) == \
        REPORT_KEYS | {"tiers", "tier_occupancy_mean"}


def test_trace_schema_stable(engine):
    obs = Observability(tracer=Tracer())
    _run(engine, obs=obs)
    for e in json.loads(obs.tracer.to_json()):
        allowed = TRACE_EVENT_KEYS[e["ph"]]
        assert set(e) <= allowed, (e["ph"], set(e) - allowed)
        assert set(e) >= allowed - {"args"}
