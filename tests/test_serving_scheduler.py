"""Continuous-batching scheduler tests: one-shot equivalence, slot reuse,
mid-flight admission, EOS retirement, and metrics sanity."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import InitMaker
from repro.models import transformer as T
from repro.serve import (KVCachePool, Request, RequestState, SamplingParams,
                         ServeConfig, ServingEngine, Scheduler)


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    return ServingEngine(cfg, params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8))


def _prompts(engine, n, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, engine.cfg.vocab, (lens[i % len(lens)],))
            .astype(np.int32) for i in range(n)]


def test_scheduler_bit_identical_to_one_shot_generate(engine):
    """Greedy continuous-batching output == one-shot generate(), token for
    token, for the same prompts."""
    prompts = _prompts(engine, 3, [8, 8, 8], seed=3)
    one_shot = engine.generate({"tokens": np.stack(prompts)},
                               max_new_tokens=6)["generated"]

    sched = Scheduler(engine)
    reqs = [sched.submit(Request(prompt=p,
                                 sampling=SamplingParams(max_new_tokens=6)))
            for p in prompts]
    sched.run(max_steps=200)
    for row, req in zip(one_shot, reqs):
        np.testing.assert_array_equal(row, np.asarray(req.output_tokens))


def test_mid_flight_admission_matches_solo_run(engine):
    """A request admitted after other requests' decode has started produces
    exactly the tokens it would produce served alone."""
    prompts = _prompts(engine, 3, [8, 6, 10], seed=4)
    solo = [engine.generate({"tokens": p[None]}, max_new_tokens=5)
            ["generated"][0] for p in prompts]

    sched = Scheduler(engine)
    first = [sched.submit(Request(prompt=p,
                                  sampling=SamplingParams(max_new_tokens=5)))
             for p in prompts[:2]]
    # run until decode has definitely started for the early arrivals
    while sched.n_decode_steps < 2:
        sched.step()
    assert any(r.n_generated > 0 for r in first)
    late = sched.submit(Request(prompt=prompts[2],
                                sampling=SamplingParams(max_new_tokens=5)))
    sched.run(max_steps=200)
    for req, want in zip(first + [late], solo):
        assert req.is_finished and req.finish_reason == "length"
        np.testing.assert_array_equal(np.asarray(req.output_tokens), want)


def test_slot_reuse_after_retirement(engine):
    """More requests than slots: retirement frees slots for the queue, every
    request completes, and the pool never over-allocates."""
    prompts = _prompts(engine, 7, [6, 9, 5], seed=5)
    sched = Scheduler(engine)
    reqs = [sched.submit(Request(prompt=p,
                                 sampling=SamplingParams(max_new_tokens=4)))
            for p in prompts]
    max_used = 0
    while sched.has_work:
        sched.step()
        assert sched.pool.n_used <= sched.pool.n_slots
        max_used = max(max_used, sched.pool.n_used)
    assert max_used == sched.pool.n_slots        # queue actually saturated it
    assert all(r.n_generated == 4 for r in reqs)
    assert sched.pool.n_free == sched.pool.n_slots
    # a retired slot was reused: 7 requests > 4 slots
    assert len(sched.finished) == 7


def test_scheduler_matches_generate_under_queueing(engine):
    """B > n_slots goes through WAITING; output still equals a one-shot
    batch of the same prompts (generate() itself queues internally)."""
    prompts = _prompts(engine, 6, [8], seed=6)   # 6 requests, 4 slots
    out = engine.generate({"tokens": np.stack(prompts)},
                          max_new_tokens=4)
    assert out["generated"].shape == (6, 4)
    solo = engine.generate({"tokens": prompts[5][None]}, max_new_tokens=4)
    np.testing.assert_array_equal(out["generated"][5], solo["generated"][0])


def test_eos_retires_and_masks(engine):
    """EOS retires the request (frees its slot) and the wrapper masks
    post-EOS positions."""
    prompts = _prompts(engine, 1, [8], seed=7)
    probe = engine.generate({"tokens": prompts[0][None]}, max_new_tokens=6)
    eos = int(probe["generated"][0][2])          # force EOS at 3rd token
    cfg = engine.cfg
    eng = ServingEngine(cfg, engine.params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8, eos_id=eos))
    out = eng.generate({"tokens": prompts[0][None]}, max_new_tokens=6)
    L = int(out["lengths"][0])
    assert out["finish_reasons"][0] == "eos"
    assert out["generated"][0][L - 1] == eos
    assert (out["generated"][0][L:] == 0).all()
    assert L <= 3


def test_request_validation(engine):
    sched = Scheduler(engine)
    with pytest.raises(ValueError):
        sched.submit(Request(
            prompt=np.ones(40, np.int32),
            sampling=SamplingParams(max_new_tokens=20)))   # 60 > max_len 48


def test_injected_pool_must_be_chunk_aligned(engine):
    """An externally built pool without chunk alignment would clamp-shift
    final-chunk writes onto committed KV; the scheduler rejects it."""
    bad = KVCachePool(engine.cfg, n_slots=2, max_len=20)   # align=1 default
    with pytest.raises(ValueError):
        Scheduler(engine, pool=bad)                        # chunk 8: need 24
    ok = KVCachePool(engine.cfg, n_slots=2, max_len=20, align=8)
    Scheduler(engine, pool=ok)


def test_metrics_sanity(engine):
    """Virtual clock: TTFT <= total latency per request, ITL count matches
    token count, occupancy is a valid time-weighted fraction."""
    t = {"now": 0.0}

    def clock():
        t["now"] += 0.125
        return t["now"]

    sched = Scheduler(engine, clock=clock)
    prompts = _prompts(engine, 5, [8, 12], seed=8)
    reqs = [sched.submit(Request(prompt=p,
                                 sampling=SamplingParams(max_new_tokens=4)))
            for p in prompts]
    sched.run(max_steps=300)
    for r in reqs:
        ttft = r.first_token_time - r.arrival_time
        e2e = r.finish_time - r.arrival_time
        assert 0 < ttft <= e2e
        assert len(r.token_times) == r.n_generated
        assert r.token_times == sorted(r.token_times)
    rep = sched.metrics.report()
    assert rep["n_requests"] == 5
    assert rep["total_new_tokens"] == 20
    assert rep["ttft_mean_s"] <= rep["e2e_latency_mean_s"]
    assert 0.0 < rep["slot_occupancy_mean"] <= 1.0
    assert len(sched.metrics.itl) == sum(r.n_generated - 1 for r in reqs)


def test_moe_decode_composition_independent():
    """Per-row drop-free decode routing: a MoE request's greedy tokens do
    not depend on what else shares the decode batch (grouped capacity
    routing would let co-batched rows steal expert slots)."""
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=24, n_slots=4, prefill_chunk=8))
    p = np.random.default_rng(11).integers(
        1, cfg.vocab, (3, 7)).astype(np.int32)
    batched = eng.generate({"tokens": p}, max_new_tokens=5)["generated"]
    solo = eng.generate({"tokens": p[:1]}, max_new_tokens=5)["generated"]
    np.testing.assert_array_equal(batched[0], solo[0])


def test_prefill_into_slots_matches_scheduler_first_token(engine):
    """The whole-prompt prefill primitive lands on the same last-position
    logits the scheduler's chunk loop sees: greedy first tokens agree."""
    prompts = _prompts(engine, 2, [11, 8], seed=9)   # 11: padded final chunk
    sched = Scheduler(engine)
    reqs = [sched.submit(Request(prompt=p,
                                 sampling=SamplingParams(max_new_tokens=1)))
            for p in prompts]
    sched.run(max_steps=50)

    pool = engine.new_pool()
    slots = [pool.alloc(), pool.alloc()]
    last_logits = engine.prefill_into_slots(pool, slots, prompts)
    for req, logits, slot, p in zip(reqs, last_logits, slots, prompts):
        assert req.output_tokens[0] == int(np.argmax(np.asarray(logits)))
        assert pool.lengths[slot] == len(p)


def test_unaligned_max_len_pads_capacity(engine):
    """max_len that is not a multiple of prefill_chunk must not shift chunk
    writes (dynamic_update_slice clamps): the pool pads its slab."""
    eng = ServingEngine(engine.cfg, engine.params, ServeConfig(
        max_len=12, n_slots=2, prefill_chunk=16))
    pool = eng.new_pool()
    assert pool.max_len == 12 and pool.capacity == 16
    prompts = _prompts(engine, 2, [8], seed=10)
    out = eng.generate({"tokens": np.stack(prompts)}, max_new_tokens=4)
    out2 = eng.generate({"tokens": np.stack(prompts)}, max_new_tokens=4)
    np.testing.assert_array_equal(out["generated"], out2["generated"])
    assert out["generated"].shape == (2, 4)


@pytest.fixture(scope="module")
def engine_int8(engine):
    """Same weights, int8-quantized KV pool (DESIGN.md §9)."""
    return ServingEngine(engine.cfg, engine.params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8, kv_dtype="int8"))


def test_scheduler_one_shot_equivalence_with_quantized_kv(engine_int8):
    """The one-shot-equivalence harness holds with kv_dtype='int8': greedy
    continuous-batching output == one-shot generate(), token for token
    (quantization is per (position, head), so committed cache bytes are
    independent of chunking and batch composition)."""
    prompts = _prompts(engine_int8, 3, [8, 11, 6], seed=12)
    one_shot = [engine_int8.generate({"tokens": p[None]}, max_new_tokens=6)
                ["generated"][0] for p in prompts]

    sched = Scheduler(engine_int8)
    reqs = [sched.submit(Request(prompt=p,
                                 sampling=SamplingParams(max_new_tokens=6)))
            for p in prompts]
    # admit the last request only after decode started (mid-flight path)
    while sched.n_decode_steps < 2:
        sched.step()
    late = sched.submit(Request(prompt=prompts[2][:5],
                                sampling=SamplingParams(max_new_tokens=6)))
    solo_late = engine_int8.generate({"tokens": prompts[2][None, :5]},
                                     max_new_tokens=6)["generated"][0]
    sched.run(max_steps=300)
    for req, want in zip(reqs, one_shot):
        np.testing.assert_array_equal(np.asarray(req.output_tokens), want)
    np.testing.assert_array_equal(np.asarray(late.output_tokens), solo_late)


def test_quantized_pool_bytes_and_budget_slots(engine, engine_int8):
    """Slot capacity is a function of KV bytes/token: at a fixed cache
    budget the int8 pool fits more slots than bf16 (~2x at production head
    dims; the f32 scales overhead is proportionally larger at the smoke
    model's d_head=16)."""
    from repro.serve import bytes_per_slot, slots_for_budget
    cfg = engine.cfg
    pool_bf16, pool_int8 = engine.new_pool(), engine_int8.new_pool()
    # bf16: 2 slabs * L * Hk * Dh * 2 B; int8: codes 1 B + f32 scale / head
    L, hk, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    assert pool_bf16.bytes_per_token == 2 * L * hk * dh * 2
    assert pool_int8.bytes_per_token == 2 * L * hk * (dh + 4)
    budget = 64 * pool_bf16.bytes_per_token * pool_bf16.capacity
    n_bf16 = slots_for_budget(cfg, 48, budget, kv_dtype="bf16", align=8)
    n_int8 = slots_for_budget(cfg, 48, budget, kv_dtype="int8", align=8)
    assert n_bf16 == 64
    assert n_int8 > n_bf16
    assert bytes_per_slot(cfg, 48, kv_dtype="int8", align=8) \
        == pool_int8.bytes_per_token * pool_int8.capacity
    with pytest.raises(ValueError):
        slots_for_budget(cfg, 48, 10, kv_dtype="int8", align=8)


def test_budget_derived_pool_through_engine(engine):
    """ServeConfig.cache_budget_bytes drives new_pool(): same budget, more
    int8 slots; the scheduler runs against the derived pool unchanged."""
    cfg, params = engine.cfg, engine.params
    budget = 8 * 48 * 2 * cfg.n_layers * cfg.n_kv_heads * cfg.d_head * 2
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=48, prefill_chunk=8, kv_dtype="int8",
        cache_budget_bytes=budget))
    pool = eng.new_pool()
    assert pool.kv_dtype == "int8"
    assert pool.n_slots > 8                     # bf16 would fit exactly 8
    assert pool.n_slots * pool.capacity * pool.bytes_per_token <= budget
    sched = Scheduler(eng, pool=pool)
    assert sched.kv_bytes_per_token == pool.bytes_per_token


def test_kv_pool_alloc_free():
    cfg = get_config("granite-8b", smoke=True)
    pool = KVCachePool(cfg, n_slots=3, max_len=16)
    a, b2, c = pool.alloc(), pool.alloc(), pool.alloc()
    assert (a, b2, c) == (0, 1, 2) and pool.alloc() is None
    pool.lengths[1] = 9
    pool.free(1)
    assert pool.lengths[1] == 0 and pool.n_free == 1
    assert pool.alloc() == 1                     # lowest free id, reused
    with pytest.raises(AssertionError):
        pool.free(0)
        pool.free(0)                             # double free


def test_pool_rejects_recurrent_families():
    cfg = get_config("xlstm-350m", smoke=True)
    with pytest.raises(ValueError):
        KVCachePool(cfg, n_slots=2, max_len=16)


def test_request_state_machine():
    r = Request(prompt=np.arange(1, 5, dtype=np.int32))
    assert r.state is RequestState.WAITING and r.prompt_len == 4
    assert r.n_generated == 0 and not r.is_finished
