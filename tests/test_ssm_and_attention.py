"""Equivalence tests: chunked vs naive SSD, attention paths, decode steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm as S
from repro.models import attention as A


def _rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


@pytest.mark.parametrize("normalize", [False, True])
def test_ssd_chunked_matches_naive(normalize):
    b, s, nh, dk, dv = 2, 64, 3, 8, 5
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q, k = _rand(ks[0], (b, s, nh, dk)), _rand(ks[1], (b, s, nh, dk))
    v = _rand(ks[2], (b, s, nh, dv))
    lf = -jax.nn.softplus(_rand(ks[3], (b, s, nh)))          # log decay <= 0
    li = _rand(ks[4], (b, s, nh), 0.5)                        # log gain
    y_naive, st_naive = S.ssd_naive(q, k, v, lf, li, normalize=normalize)
    y_chunk, st_chunk = S.ssd_chunked(q, k, v, lf, li, chunk=16,
                                      normalize=normalize)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=2e-4, atol=2e-4)
    # unscaled state must agree: H_true = Hs * exp(m)
    h_naive = np.asarray(st_naive.Hs) * np.exp(np.asarray(st_naive.m))[..., None, None]
    h_chunk = np.asarray(st_chunk.Hs) * np.exp(np.asarray(st_chunk.m))[..., None, None]
    np.testing.assert_allclose(h_chunk, h_naive, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("normalize", [False, True])
def test_ssd_step_continues_chunked(normalize):
    """decode steps after a chunked prefix == one long parallel pass."""
    b, s, nh, dk, dv = 1, 48, 2, 6, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q, k = _rand(ks[0], (b, s, nh, dk)), _rand(ks[1], (b, s, nh, dk))
    v = _rand(ks[2], (b, s, nh, dv))
    lf = -jax.nn.softplus(_rand(ks[3], (b, s, nh)))
    li = _rand(ks[4], (b, s, nh), 0.5)
    y_full, _ = S.ssd_naive(q, k, v, lf, li, normalize=normalize)

    cut = 32
    _, st = S.ssd_chunked(q[:, :cut], k[:, :cut], v[:, :cut],
                          lf[:, :cut], li[:, :cut], chunk=16,
                          normalize=normalize)
    ys = []
    for t in range(cut, s):
        y, st = S.ssd_step(st, q[:, t], k[:, t], v[:, t], lf[:, t], li[:, t],
                           normalize=normalize)
        ys.append(y)
    got = np.stack([np.asarray(y) for y in ys], axis=1)
    np.testing.assert_allclose(got, np.asarray(y_full[:, cut:]),
                               rtol=2e-4, atol=2e-4)


def test_attention_chunked_matches_dense():
    b, s, h, hk, dh = 2, 128, 8, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (b, s, h, dh)).astype(jnp.bfloat16)
    k = _rand(ks[1], (b, s, hk, dh)).astype(jnp.bfloat16)
    v = _rand(ks[2], (b, s, hk, dh)).astype(jnp.bfloat16)
    dense = A.attend(q, k, v, causal=True, kv_chunk=4096)   # dense path
    chunk = A.attend(q, k, v, causal=True, kv_chunk=32)     # 4-chunk scan
    np.testing.assert_allclose(np.asarray(chunk, np.float32),
                               np.asarray(dense, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_attention_decode_matches_full():
    """single-query decode over a prefilled cache == row s-1 of full attn."""
    b, s, h, hk, dh = 2, 33, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (b, s, h, dh)).astype(jnp.bfloat16)
    k = _rand(ks[1], (b, s, hk, dh)).astype(jnp.bfloat16)
    v = _rand(ks[2], (b, s, hk, dh)).astype(jnp.bfloat16)
    full = A.attend(q, k, v, causal=True, kv_chunk=4096)
    dec = A.attend(q[:, -1:], k, v, causal=True, q_offset=s - 1,
                   kv_valid_len=jnp.full((b,), s, jnp.int32), kv_chunk=4096)
    np.testing.assert_allclose(np.asarray(dec[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_causal_mask_blocks_future():
    """perturbing future tokens must not change past outputs."""
    b, s, h, dh = 1, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = _rand(ks[0], (b, s, h, dh)).astype(jnp.bfloat16)
    k = _rand(ks[1], (b, s, h, dh)).astype(jnp.bfloat16)
    v = _rand(ks[2], (b, s, h, dh)).astype(jnp.bfloat16)
    out1 = A.attend(q, k, v, causal=True, kv_chunk=8)
    k2 = k.at[:, 10:].set(9.0)
    v2 = v.at[:, 10:].set(-9.0)
    out2 = A.attend(q, k2, v2, causal=True, kv_chunk=8)
    np.testing.assert_array_equal(np.asarray(out1[:, :10], np.float32),
                                  np.asarray(out2[:, :10], np.float32))


def test_slstm_step_matches_scan():
    from repro.models.common import InitMaker
    cfg = S.SLSTMConfig(d_model=32, n_heads=4)
    params = S.slstm_params(InitMaker(jax.random.PRNGKey(5)), cfg, ())
    x = _rand(jax.random.PRNGKey(6), (2, 12, 32)).astype(jnp.bfloat16)
    y_full, st_full = S.slstm_forward(params, cfg, x)
    st = None
    outs = []
    for t in range(12):
        y, st = S.slstm_forward(params, cfg, x[:, t: t + 1], state=st)
        outs.append(np.asarray(y[:, 0], np.float32))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, np.asarray(y_full, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_causal_conv_decode_state():
    w = _rand(jax.random.PRNGKey(7), (4, 6))
    x = _rand(jax.random.PRNGKey(8), (2, 10, 6)).astype(jnp.bfloat16)
    y_full, _ = S.causal_conv1d(x, w)
    state = None
    outs = []
    for t in range(10):
        y, state = S.causal_conv1d(x[:, t: t + 1], w, state)
        outs.append(np.asarray(y[:, 0], np.float32))
    np.testing.assert_allclose(np.stack(outs, 1),
                               np.asarray(y_full, np.float32),
                               rtol=2e-2, atol=2e-2)
