"""Hypothesis property tests for the page allocator (DESIGN.md §15).

``PageAllocator`` is deliberately pure host-side python-over-numpy so its
whole state machine — free list, refcounts, page tables, content-keyed
prefix cache, LRU eviction, reservation accounting — can be driven by
random operation sequences with ``check()`` (which asserts every §15
bookkeeping invariant, including refcount == table-refs + cache-refs by
exact bincount) after EVERY mutation.  The deterministic lifecycle tests
live in tests/test_paged_pool.py; this suite explores the long tail:
interleaved admits / ensures / registrations / frees over a tiny token
alphabet (so prefix hits, COW and eviction all trigger often) on arenas
from the legal minimum up to over-provisioned.

Operations are drawn only within the scheduler's contract (prompts fit
the slot, ``ensure`` stays within the admission reservation window), so
``RuntimeError: page arena exhausted`` would be a genuine accounting bug,
not an out-of-contract call.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve import PageAllocator  # noqa: E402

PS = 4          # page size (== align: chunk-aligned pages, engine contract)


def _ops(draw):
    """One drawn scenario: arena geometry + an operation tape."""
    n_slots = draw(st.integers(1, 4))
    pps = draw(st.integers(1, 4))
    n_pages = draw(st.integers(1 + pps, 1 + n_slots * pps + 2))
    n_ops = draw(st.integers(1, 40))
    return n_slots, pps, n_pages, n_ops


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_random_op_sequences_preserve_every_invariant(data):
    n_slots, pps, n_pages, n_ops = _ops(data.draw)
    a = PageAllocator(n_pages, PS, n_slots, pps, align=PS)
    capacity = pps * PS
    # slot -> [tokens, max_new, watermark(write-ensured positions)]
    live = {}

    for _ in range(n_ops):
        op = data.draw(st.sampled_from(["admit", "ensure", "register",
                                        "free"]))
        if op == "admit":
            p_len = data.draw(st.integers(1, capacity))
            max_new = data.draw(st.integers(0, capacity - p_len))
            # 3-token alphabet: page-content collisions (prefix hits,
            # adoption, COW) happen constantly
            tokens = data.draw(st.lists(st.integers(0, 2), min_size=p_len,
                                        max_size=p_len))
            fits = a.can_admit(tokens, max_new)
            r = a.admit(tokens, max_new)
            assert (r is not None) == fits
            if r is not None:
                slot, prefill_pos, hit_tokens, copies = r
                assert slot not in live
                assert hit_tokens % PS == 0 and 0 <= hit_tokens <= p_len
                assert 0 <= prefill_pos <= max(0, p_len - 1)
                assert prefill_pos % PS == 0
                # admission makes the first write page private NOW
                for src, dst in copies:
                    assert src != dst and int(a.refcounts[dst]) == 1
                live[slot] = [tokens, max_new, prefill_pos + 1]
        elif op == "ensure" and live:
            slot = data.draw(st.sampled_from(sorted(live)))
            tokens, max_new, w = live[slot]
            limit = min(len(tokens) + max_new, capacity)
            if w < limit:
                upto = data.draw(st.integers(w, limit))
                copies = a.ensure(slot, w, upto)
                for src, dst in copies:
                    assert src != dst and int(a.refcounts[dst]) == 1
                # the just-ensured window is privately owned (adopted
                # prefix pages sit strictly below it and MAY be shared;
                # registered pages likewise never reach the write window)
                for idx in range(w // PS, -(-upto // PS)):
                    page = int(a.table[slot, idx])
                    assert page != 0, "ensured window left unmapped"
                    assert page not in a.page_key, \
                        "write-window page is registered"
                    assert int(a.refcounts[page]) == 1, \
                        "ensured page still shared across slots"
                live[slot][2] = max(w, upto)
        elif op == "register" and live:
            slot = data.draw(st.sampled_from(sorted(live)))
            tokens, _, w = live[slot]
            if w >= len(tokens):      # only fully-prefilled prompts publish
                n = a.register_prefix(slot, tokens)
                assert 0 <= n <= len(tokens) // PS
        elif op == "free" and live:
            slot = data.draw(st.sampled_from(sorted(live)))
            a.free_slot(slot)
            del live[slot]
        a.check()

    # drain: every slot returns; only cache-held pages may remain
    for slot in sorted(live):
        a.free_slot(slot)
    a.check()
    assert a.pages_in_use == 0
    assert a.n_free_slots == n_slots
    assert a.pages_free + a.pages_cached == n_pages - 1


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_preempt_resume_tapes_never_leak(data):
    """Scheduler-shaped tapes (DESIGN.md §16): admit -> prefill+register
    -> decode ensures -> PREEMPT (free mid-decode; registered prompt
    pages stay cache-only) -> RESUME (re-admit prompt + generated[:-1]
    at the reduced budget) interleaved across slots.  Every §15 invariant
    must hold after every op, and at drain no page refcount survives
    outside the cache — preemption churn leaks nothing."""
    n_slots = data.draw(st.integers(1, 3))
    pps = data.draw(st.integers(2, 4))
    n_pages = data.draw(st.integers(1 + pps, 1 + n_slots * pps + 2))
    a = PageAllocator(n_pages, PS, n_slots, pps, align=PS)
    capacity = pps * PS
    live = {}      # slot -> req dict (w = write-ensured watermark)
    pending = []   # preempted requests waiting to resume

    def _admit(req):
        """Scheduler admission: resume buffer = prompt + generated[:-1],
        budget shrunk so prompt_len + max_new total positions hold."""
        g = len(req["gen"])
        pre = req["prompt"] + req["gen"][:-1] if g > 1 else req["prompt"]
        budget = req["max_new"] - max(g - 1, 0)
        r = a.admit(pre, budget)
        if r is None:
            return False
        slot, pos, hit, _ = r
        assert hit % PS == 0 and hit <= len(pre)
        # prefill the tail past the hit, then publish the whole prefix
        a.ensure(slot, pos + 1, len(pre))
        a.register_prefix(slot, pre)
        req["w"] = len(pre)
        req["limit"] = len(pre) + budget
        live[slot] = req
        return True

    for _ in range(data.draw(st.integers(1, 40))):
        op = data.draw(st.sampled_from(
            ["admit", "resume", "decode", "preempt", "finish"]))
        if op == "admit":
            p_len = data.draw(st.integers(1, capacity - 1))
            max_new = data.draw(st.integers(1, capacity - p_len))
            prompt = data.draw(st.lists(st.integers(0, 2), min_size=p_len,
                                        max_size=p_len))
            _admit({"prompt": prompt, "gen": [], "max_new": max_new})
        elif op == "resume" and pending:
            req = pending.pop(0)
            if not _admit(req):
                pending.append(req)       # arena full: stays queued
        elif op == "decode" and live:
            slot = data.draw(st.sampled_from(sorted(live)))
            req = live[slot]
            if req["w"] < req["limit"]:
                a.ensure(slot, req["w"], req["w"] + 1)
                req["w"] += 1
                req["gen"].append(data.draw(st.integers(0, 2)))
        elif op == "preempt" and live:
            slot = data.draw(st.sampled_from(sorted(live)))
            a.free_slot(slot)
            pending.append(live.pop(slot))
        elif op == "finish" and live:
            slot = data.draw(st.sampled_from(sorted(live)))
            a.free_slot(slot)
            del live[slot]
        a.check()

    for slot in sorted(live):
        a.free_slot(slot)
    a.check()
    assert a.pages_in_use == 0
    assert a.n_free_slots == n_slots
    assert a.pages_free + a.pages_cached == n_pages - 1
    # no refcount survives outside the cache: every remaining reference
    # is exactly one cache hold on a registered page
    held = np.flatnonzero(a.refcounts[1:]) + 1
    assert all(int(a.refcounts[p]) == 1 and p in a.page_key for p in held)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_registered_prefixes_hit_until_evicted(data):
    """Determinism of the content-keyed cache: admit -> ensure -> register
    -> free -> re-admit the SAME prompt hits every registered whole page
    (nothing else ran in between, so nothing can have been evicted)."""
    pps = data.draw(st.integers(1, 4))
    a = PageAllocator(1 + 2 * pps, PS, 2, pps, align=PS)
    p_len = data.draw(st.integers(PS, pps * PS))
    tokens = data.draw(st.lists(st.integers(0, 2), min_size=p_len,
                                max_size=p_len))
    slot, pos, hit, _ = a.admit(tokens, 0)
    assert hit == 0
    a.ensure(slot, pos + 1, p_len)
    registered = a.register_prefix(slot, tokens)
    assert registered == p_len // PS
    a.free_slot(slot)
    a.check()
    r = a.admit(tokens, 0)
    assert r is not None
    assert r[2] == (p_len // PS) * PS
    # full-cover hits resume at the final chunk so first-token logits are
    # recomputed; partial hits resume exactly past the cached pages
    if r[2] == p_len:
        assert r[1] == ((p_len - 1) // PS) * PS
    else:
        assert r[1] == r[2]
    a.check()
