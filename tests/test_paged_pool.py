"""Paged KV pool (DESIGN.md §15): bit-identity vs the slab pool, prefix
cache / copy-on-write semantics, page-granular budget accounting, and the
paged-attention oracle pin.

The pinned contract: a ``PagedKVPool`` scheduler produces EXACTLY the
tokens the slab-pool scheduler produces — greedy and seeded temperature,
single-device and dp2 x tp4, mid-flight admission, prefix hit and prefix
miss.  The sharded tests need >= 8 host devices (CI's multi-device job
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and skip
otherwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import InitMaker
from repro.models import transformer as T
from repro.serve import (PageAllocator, Request, SamplingParams, ServeConfig,
                         ServingEngine, Scheduler, bytes_per_page,
                         pages_for_budget)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def cfg():
    return get_config("granite-8b", smoke=True)


@pytest.fixture(scope="module")
def params(cfg):
    return T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))


@pytest.fixture(scope="module")
def engines(cfg, params):
    """(slab, paged) engine pair over identical weights and serve knobs."""
    slab = ServingEngine(cfg, params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8))
    paged = ServingEngine(cfg, params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8, paged=True))
    return slab, paged


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, (n,)).astype(np.int32) for n in lens]


def _serve(engine, prompts, *, max_new=5, temperature=0.0, seed=0,
           pool=None, max_steps=300):
    sched = Scheduler(engine, pool=pool)
    reqs = [sched.submit(Request(prompt=p, sampling=SamplingParams(
        max_new_tokens=max_new, temperature=temperature, seed=seed)))
        for p in prompts]
    sched.run(max_steps=max_steps)
    return [np.asarray(r.output_tokens) for r in reqs], sched, reqs


# ---------------------------------------------------------------------------
# Scheduler equivalence: paged == slab, token for token
# ---------------------------------------------------------------------------
def test_paged_bit_identical_greedy(cfg, engines):
    """Greedy paged output == slab output on mixed prompt lengths (page-
    aligned, ragged, and below one page)."""
    slab, paged = engines
    prompts = _prompts(cfg, [8, 6, 10])
    want, _, _ = _serve(slab, prompts)
    got, sched, _ = _serve(paged, prompts)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    sched.pool.allocator.check()


def test_paged_bit_identical_seeded_temperature(cfg, engines):
    """Seeded temperature sampling (bursts included) is bit-identical —
    the per-(request, step) key schedule is independent of the pool
    layout."""
    slab, paged = engines
    prompts = _prompts(cfg, [8, 9, 16], seed=7)
    want, _, _ = _serve(slab, prompts, temperature=0.8, seed=11)
    got, sched, _ = _serve(paged, prompts, temperature=0.8, seed=11)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    sched.pool.allocator.check()


def test_paged_mid_flight_admission(cfg, engines):
    """A request admitted while others decode gets its solo tokens — page
    allocation for the newcomer cannot perturb in-flight rows."""
    slab, paged = engines
    prompts = _prompts(cfg, [8, 6, 10], seed=4)
    solo = [_serve(slab, [p])[0][0] for p in prompts]

    sched = Scheduler(paged)
    first = [sched.submit(Request(prompt=p,
                                  sampling=SamplingParams(max_new_tokens=5)))
             for p in prompts[:2]]
    while sched.n_decode_steps < 2:
        sched.step()
    assert any(r.n_generated > 0 for r in first)
    late = sched.submit(Request(prompt=prompts[2],
                                sampling=SamplingParams(max_new_tokens=5)))
    sched.run(max_steps=300)
    for req, want in zip(first + [late], solo):
        np.testing.assert_array_equal(np.asarray(req.output_tokens), want)
    sched.pool.allocator.check()


def test_prefix_hit_bit_identical_and_skips_prefill(cfg, engines):
    """Resubmitting served prompts into the same pool adopts their cached
    prefix pages: whole-page prefixes are skipped (full-cover hits re-run
    only the final chunk) and the continuation is bit-identical."""
    slab, paged = engines
    prompts = _prompts(cfg, [8, 6, 10])          # page size == chunk == 8
    want, _, _ = _serve(slab, prompts)
    _, sched, _ = _serve(paged, prompts)         # populates the prefix cache
    pool = sched.pool
    got, sched2, reqs = _serve(paged, prompts, pool=pool)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    # 8-token prompt: full-cover hit (one whole page); 6-token prompt:
    # below one page, miss; 10-token prompt: first page hit, tail re-run
    assert [r.prefix_hit_tokens for r in reqs] == [8, 0, 8]
    # the hit requests resumed prefill past the adopted pages
    rep = sched2.metrics.report()
    assert rep["prefix_hits"] == 2 and rep["prefix_misses"] == 1
    assert rep["prefix_hit_tokens"] == 16
    pool.allocator.check()


def test_paged_int8_tier_bit_identical(cfg, params):
    """Quantized KV pages (packed codes + scales gathered in lockstep)
    keep the paged == slab contract."""
    slab = ServingEngine(cfg, params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8, kv_dtype="int8"))
    paged = ServingEngine(cfg, params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8, kv_dtype="int8", paged=True))
    prompts = _prompts(cfg, [9, 16, 8], seed=5)
    want, _, _ = _serve(slab, prompts)
    got, sched, _ = _serve(paged, prompts)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    sched.pool.allocator.check()


def test_paged_small_arena_queues_and_drains(cfg, params):
    """An arena too small for every request at once admits on *pages
    available*: overflow requests wait, are admitted as retirements free
    pages (evicting cache-only pages under pressure), and still produce
    their solo tokens."""
    paged = ServingEngine(cfg, params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8, paged=True))
    prompts = _prompts(cfg, [8, 8, 10, 9], seed=9)
    solo = [_serve(paged, [p])[0][0] for p in prompts]
    # minimum legal arena: garbage page + one full 6-page slot.  Each
    # request needs 2 pages (prompt + max_new 5 <= 16 positions), so only
    # three of four fit at once — the fourth queues on pages, not slots.
    from repro.serve import PagedKVPool
    pool = PagedKVPool(cfg, 4, 48, align=8, page_size=8, n_pages=7)
    sched = Scheduler(paged, pool=pool)
    reqs = [sched.submit(Request(prompt=p,
                                 sampling=SamplingParams(max_new_tokens=5)))
            for p in prompts]
    queued = False
    for _ in range(300):
        if all(r.is_finished for r in reqs):
            break
        sched.step()
        queued = queued or any(not r.is_finished and r.slot is None
                               for r in reqs)
    assert queued, "arena of 7 pages should not admit 4 x 2-page requests"
    assert len(sched.finished) == 4
    for req, want in zip(reqs, solo):
        np.testing.assert_array_equal(np.asarray(req.output_tokens), want)
    # retired prompts stay behind as cache-only pages
    assert pool.allocator.pages_cached >= 1
    pool.allocator.check()


# ---------------------------------------------------------------------------
# Sharded serving (dp2 x tp4): pages ride the slot axis
# ---------------------------------------------------------------------------
@multi_device
def test_paged_bit_identical_dp2_tp4(cfg, params):
    """Paged == slab under a 2x4 mesh, greedy and seeded temperature —
    the page arena shards where the slab's slot axis did and the table
    rides the data axis, so GSPMD's gather/scatter reassembles exactly
    the meshless bytes."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    slab = ServingEngine(cfg, params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8, mesh=mesh))
    paged = ServingEngine(cfg, params, ServeConfig(
        max_len=48, n_slots=4, prefill_chunk=8, mesh=mesh, paged=True))
    prompts = _prompts(cfg, [8, 6, 10, 8])
    for temp in (0.0, 0.7):
        want, _, _ = _serve(slab, prompts, max_new=6, temperature=temp,
                            seed=11)
        got, sched, _ = _serve(paged, prompts, max_new=6, temperature=temp,
                               seed=11)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w, g)
        sched.pool.allocator.check()


# ---------------------------------------------------------------------------
# The paged-attention oracle (kernels/ref.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_paged_oracle_pins_kernel_on_gathered_slab(kv_dtype):
    """Interpret-mode decode kernel fed the gathered virtual slab ==
    ``paged_decode_attention_ref`` on (arena, table), bit for bit — the
    §15 contract 'paged attention = page gather + slab attention' at the
    kernel level, quantized pages included."""
    from repro.kernels.decode_attention import gqa_decode_attention
    from repro.kernels.ref import paged_decode_attention_ref
    from repro.quant.kv_cache import QuantizedKV, gather_pages
    from repro.quant.schemes import get_kv_scheme, kv_quantize

    b, pp, ps, hk, dh = 3, 4, 8, 2, 16
    n_pages = 1 + b * pp
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, 1, 4, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(n_pages, ps, hk, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(n_pages, ps, hk, dh)), jnp.bfloat16)
    if kv_dtype != "bf16":
        def _q(x):
            packed, scales = kv_quantize(get_kv_scheme(kv_dtype), x)
            return QuantizedKV(packed, scales, kv_dtype)
        k, v = _q(k), _q(v)
    # ragged tables: unmapped (0) tail entries gather the garbage page
    table = np.zeros((b, pp), np.int32)
    table[0, :2] = [1, 2]
    table[1, :4] = [3, 2, 4, 5]       # page 2 shared between rows 0 and 1
    table[2, :1] = [6]
    lens = jnp.asarray([9, 25, 3], jnp.int32)
    tbl = jnp.asarray(table)

    want = paged_decode_attention_ref(q, k, v, tbl, lens)
    got = gqa_decode_attention(q, gather_pages(k, tbl), gather_pages(v, tbl),
                               lens, interpret=True)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


# ---------------------------------------------------------------------------
# Allocator semantics (unit level; random sequences in
# tests/test_paged_properties.py)
# ---------------------------------------------------------------------------
def test_allocator_cow_and_eviction_lifecycle():
    """Admission miss -> register -> full-cover hit COWs the write page;
    freeing drops refs; cache-only pages evict LRU under pressure."""
    a = PageAllocator(n_pages=9, page_size=8, n_slots=4, pages_per_slot=2,
                      align=8)
    p1 = list(range(100, 108))
    slot, pos, hit, copies = a.admit(p1, 4)
    assert (pos, hit, copies) == (0, 0, [])
    a.ensure(slot, 8, 9)              # decode write window
    a.register_prefix(slot, p1)
    a.check()
    # full-cover hit: prefill resumes at the final chunk, whose adopted
    # shared page is COW'd at admission
    slot2, pos2, hit2, copies2 = a.admit(p1, 4)
    assert (pos2, hit2) == (0, 8) and len(copies2) == 1
    src, dst = copies2[0]
    assert int(a.table[slot2, 0]) == dst and dst != src
    a.check()
    a.free_slot(slot), a.free_slot(slot2)
    a.check()
    # the registered page survives retirement as cache-only / evictable
    assert a.pages_cached == 1 and a.n_free_slots == 4
    # arena pressure evicts it: materialize all 8 usable pages for fresh
    # prompts (allocation is lazy — only a real _alloc_page evicts)
    slots = []
    for i in range(4):
        s, _, h, _ = a.admit([1000 + 16 * i + j for j in range(16)], 0)
        assert h == 0
        slots.append(s)
    for s in slots:
        a.ensure(s, 8, 16)            # second page of each slot
    assert a.pages_cached == 0 and a.n_evictions == 1
    a.check()


def test_allocator_double_free_and_exhaustion():
    a = PageAllocator(n_pages=5, page_size=8, n_slots=2, pages_per_slot=2,
                      align=8)
    r = a.admit(list(range(16)), 0)
    assert r is not None
    # second 2-page request doesn't fit 4 usable pages minus 2 held
    assert a.admit(list(range(50, 66)), 0) is not None
    assert a.admit(list(range(70, 86)), 0) is None    # slots and pages spent
    a.free_slot(r[0])
    with pytest.raises(AssertionError):
        a.free_slot(r[0])


def test_pages_for_budget_math(cfg):
    """Budget -> page count is exact division by bytes/page, with a floor
    of garbage + one worst-case request."""
    per = bytes_per_page(cfg, 8, kv_dtype="bf16")
    n = pages_for_budget(cfg, 48, per * 10 + per // 2, kv_dtype="bf16",
                         page_size=8)
    assert n == 10
    with pytest.raises(ValueError):
        # 48 positions -> 6 pages/slot; floor is 7 pages
        pages_for_budget(cfg, 48, per * 6, kv_dtype="bf16", page_size=8)


def test_paged_pool_accounting(cfg, engines):
    """Arena accounting: full provisioning matches slab capacity + the
    garbage page; bytes_per_token is position-granular."""
    _, paged = engines
    pool = paged.new_pool()
    assert pool.paged and pool.page_size == 8
    assert pool.n_pages == 1 + pool.n_slots * pool.pages_per_slot
    assert pool.capacity == 48 and pool.pages_per_slot == 6
    assert pool.pages_free == pool.n_pages - 1
    assert pool.bytes_per_token * pool.n_pages * pool.page_size \
        <= pool.cache_bytes
