"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and no NaNs.  Also exercises the quantized
(serving) parameter path and prefill+decode cache consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.common import InitMaker, QuantMaker
from repro.models import transformer as T


def _smoke_batch(cfg, key, b=2, s=32):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((b, cfg.n_patches, cfg.d_model), jnp.bfloat16) * 0.02
    elif cfg.family == "audio":
        batch["frames"] = jnp.ones((b, cfg.n_frames, cfg.d_model), jnp.bfloat16) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    logits, aux, _ = jax.jit(
        lambda p, b: T.forward(cfg, p, b, mode="train"))(params, batch)
    b, s = batch["tokens"].shape
    exp_s = s + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, exp_s, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    def loss(p):
        l, m = T.loss_fn(cfg, p, batch)
        return l
    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_quantized_forward(arch):
    """Serving path: quantized projection/FFN weights (the paper's MACs)."""
    cfg = get_config(arch, smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    logits, _, _ = jax.jit(
        lambda p, b: T.forward(cfg, p, b, mode="prefill"))(params, batch)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """logits from (prefill s tokens, decode 1) == full forward at that pos."""
    import dataclasses
    cfg = get_config(arch, smoke=True)
    if cfg.n_experts:
        # capacity drops differ between train grouping and decode grouping;
        # give full capacity so routing is drop-free and paths comparable
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    b, s = 2, 16
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1), b=b, s=s)
    max_len = s + 8 + (cfg.n_patches if cfg.family == "vlm" else 0)

    # ground truth: full causal forward over all s tokens
    full_logits, _, _ = T.forward(cfg, params, batch, mode="train")

    # prefill first s-1 tokens, then decode token s-1
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, : s - 1]
    pre_batch.pop("labels")
    cache = T.init_cache(cfg, b, max_len)
    pre_logits, _, cache = T.forward(cfg, params, pre_batch, cache=cache,
                                     cache_index=0, mode="prefill")
    n_prefix = cfg.n_patches if cfg.family == "vlm" else 0
    dec_batch = {"tokens": batch["tokens"][:, s - 1: s]}
    if cfg.family == "audio":
        dec_batch["frames"] = batch["frames"]
    dec_logits, _, _ = T.forward(cfg, params, dec_batch, cache=cache,
                                 cache_index=jnp.int32(n_prefix + s - 1),
                                 mode="decode")
    want = np.asarray(full_logits[:, -1], np.float32)
    got = np.asarray(dec_logits[:, 0], np.float32)
    np.testing.assert_allclose(got, want, rtol=0.05, atol=0.05)
