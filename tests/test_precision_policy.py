"""PrecisionPolicy: the unified datatype-adaptive contract (DESIGN.md §12).

Four tiers of coverage:
  * policy object semantics — JSON round-trip (identical resolved plan),
    first-match-wins resolution, legacy-adapter equivalence;
  * EAGER validation — unknown scheme / KV tier / kernel names and
    config/mesh incompatibilities raise at policy / ServeConfig / engine
    construction with actionable messages, not at first pool build or
    first trace (regression: these used to surface as KeyErrors or
    asserts deep in the first ``new_pool()`` / checkpoint build);
  * legacy-adapter bit-identity — ``ServeConfig(kv_dtype=...)`` /
    ``ServingEngine(plan=...)`` produce byte-identical output to the
    equivalent ``policy=`` spelling (single-device here; the dp=2 x tp=4
    twin runs in CI's multi-device job);
  * runtime tier switching — ONE engine serves bf16-KV and int8-KV
    requests interleaved (mid-flight admission included), each tier's
    output bit-identical to a single-tier engine at that precision, and
    budget-derived tier pools show the quantized-capacity win.
"""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.models.common import QuantMaker
from repro.models import transformer as T
from repro.quant.policy import PrecisionPolicy, validate_kv_tier
from repro.runtime import partitioning as PT
from repro.serve import (Request, SamplingParams, Scheduler, ServeConfig,
                         ServingEngine)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _amesh(dp, tp):
    return AbstractMesh((("data", dp), ("model", tp)))


# ---------------------------------------------------------------------------
# Policy object semantics
# ---------------------------------------------------------------------------
def test_policy_json_roundtrip_identical_resolved_plan():
    cfg = get_config("granite-8b", smoke=True)
    p = PrecisionPolicy(weights={"attn.*": "mxfp4", "ffn.w_down": "bf16"},
                        kv="int8", kernel="jnp")
    q = PrecisionPolicy.from_json(p.to_json())
    assert q == p and hash(q) == hash(p)     # frozen: usable as a cache key
    assert q.resolved_plan(cfg) == p.resolved_plan(cfg)
    # the resolved plan is concrete: every dense leaf maps to a scheme name
    plan = p.resolved_plan(cfg)
    assert plan["attn.wq"] == "mxfp4"
    assert plan["ffn.w_down"] == "bf16"
    assert plan["ffn.w_up"] == "awq_int4"        # config default untouched
    assert plan["lm_head"] == "bf16"             # dense leaves read 'bf16'


def test_policy_first_match_wins():
    p = PrecisionPolicy(weights=(("attn.wq", "w8a8"), ("attn.*", "fp8")))
    assert p.resolve("attn.wq") == "w8a8"
    assert p.resolve("attn.wk") == "fp8"
    assert p.resolve("ffn.w_up", "awq_int4") == "awq_int4"
    assert p.resolve("ffn.w_up") == "bf16"       # no default: dense


def test_legacy_adapters_emit_equivalent_policy():
    cfg = get_config("granite-8b", smoke=True)
    plan = {"ffn.w_down": "bf16"}
    via_legacy = PrecisionPolicy.from_legacy(kv_dtype="int8", plan=plan)
    via_policy = PrecisionPolicy(weights=tuple(plan.items()), kv="int8")
    assert via_legacy.resolved_plan(cfg) == via_policy.resolved_plan(cfg)
    # ServeConfig(kv_dtype=...) is the same adapter, canonicalized
    scfg = ServeConfig(max_len=32, kv_dtype="int8")
    assert scfg.policy.kv == "int8" and scfg.kv_dtype == "int8"
    assert ServeConfig(max_len=32).kv_dtype == "bf16"
    import jax.numpy as jnp
    assert ServeConfig(max_len=32, kv_dtype=jnp.bfloat16).kv_dtype == "bf16"
    # a non-bf16 raw dtype is rejected, not silently coerced to a tier
    with pytest.raises(ValueError, match="not expressible"):
        ServeConfig(max_len=32, kv_dtype=jnp.float32)


def test_param_specs_from_policy_match_plan_spelling():
    cfg = get_config("granite-8b", smoke=True)
    pol = PrecisionPolicy(weights={"ffn.w_down": "bf16", "attn.wq": "mxfp4"})
    mesh = _amesh(1, 4)
    via_policy = PT.param_specs(cfg, mesh, train=False, quantize=True,
                                policy=pol)
    via_plan = PT.param_specs(cfg, mesh, train=False, quantize=True,
                              plan=pol.resolved_plan(cfg))
    assert jax.tree_util.tree_structure(
        via_policy, is_leaf=lambda x: isinstance(x, P)) == \
        jax.tree_util.tree_structure(
            via_plan, is_leaf=lambda x: isinstance(x, P))
    assert jax.tree_util.tree_leaves(
        via_policy, is_leaf=lambda x: isinstance(x, P)) == \
        jax.tree_util.tree_leaves(
            via_plan, is_leaf=lambda x: isinstance(x, P))
    with pytest.raises(ValueError, match="not both"):
        PT.param_specs(cfg, mesh, train=False, plan={}, policy=pol)


# ---------------------------------------------------------------------------
# Eager validation (regression: used to fail at first pool build / trace)
# ---------------------------------------------------------------------------
def test_unknown_scheme_raises_at_policy_construction():
    with pytest.raises(ValueError, match="valid schemes"):
        PrecisionPolicy(weights={"attn.*": "int5"})


def test_unknown_kernel_raises_at_policy_construction():
    with pytest.raises(ValueError, match="valid modes"):
        PrecisionPolicy(kernel="cuda")


def test_unknown_kv_tier_raises_at_serveconfig_construction():
    """Previously an unknown kv_dtype was a KeyError at the FIRST
    ``engine.new_pool()`` (deep in init_cache); now it is a ValueError at
    ServeConfig construction, naming the valid tiers."""
    with pytest.raises(ValueError, match="valid tiers"):
        ServeConfig(max_len=32, kv_dtype="int88")
    with pytest.raises(ValueError, match="valid tiers"):
        PrecisionPolicy(kv="fp16")


def test_policy_kv_conflicting_legacy_knob_raises():
    with pytest.raises(ValueError, match="contradicts"):
        ServeConfig(max_len=32, kv_dtype="bf16",
                    policy=PrecisionPolicy(kv="int8"))
    # agreeing spellings are fine
    ServeConfig(max_len=32, kv_dtype="int8",
                policy=PrecisionPolicy(kv="int8"))


def test_unmatched_pattern_raises_at_engine_construction():
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    bad = ServeConfig(max_len=32,
                      policy=PrecisionPolicy(weights={"moe.*": "fp8"}))
    with pytest.raises(ValueError, match="matches no leaf"):
        ServingEngine(cfg, params, bad)      # granite-smoke has no MoE


def test_group_indivisible_k_raises_eagerly():
    """A scheme whose scale group does not divide a leaf's K used to die
    in an assert inside the offline quantizer at checkpoint build; the
    policy names the leaf and the conflict up front."""
    cfg = dataclasses.replace(get_config("granite-8b", smoke=True), d_ff=48)
    pol = PrecisionPolicy(weights={"ffn.w_down": "mxfp4"})   # group 32, K=48
    with pytest.raises(ValueError, match="scale group"):
        pol.validate_for(cfg)


def test_quantized_kv_on_mla_raises_eagerly():
    """MLA latents stay bf16 (DESIGN.md §9): the tier conflict used to
    surface at first pool build (mla_cache_spec); now at policy/engine
    validation — and per-pool tier overrides hit the same check."""
    cfg = get_config("deepseek-v2-236b", smoke=True)
    with pytest.raises(ValueError, match="MLA"):
        PrecisionPolicy(kv="int8").validate_for(cfg)
    with pytest.raises(ValueError, match="MLA"):
        validate_kv_tier("fp8", cfg)
    assert validate_kv_tier("bf16", cfg) == "bf16"


def test_pallas_kernel_validates_under_multi_device_mesh():
    """kernel='pallas' is now first-class under a mesh: the kernels run
    shard_map'd over it (DESIGN.md §14) with per-site jnp fallback, so the
    old eager GSPMD rejection is gone for every mesh shape."""
    cfg = get_config("granite-8b", smoke=True)
    pol = PrecisionPolicy(kernel="pallas")
    assert pol.validate_for(cfg, _amesh(1, 2)) is pol
    pol.validate_for(cfg, _amesh(2, 4))
    pol.validate_for(cfg, _amesh(1, 1))      # single device: allowed
    pol.validate_for(cfg)                    # meshless: allowed


def test_strict_tp_packed_k_grouping_raises():
    """tp-incompatible packed-K groupings: at tp=64 the full granite
    config's per-shard K (e.g. w_down: 14336/64 = 224) splits awq_int4's
    128-wide scale groups — strict validation raises at policy-resolution
    time instead of silently replicating the leaf."""
    cfg = get_config("granite-8b")
    pol = PrecisionPolicy()
    pol.validate_for(cfg, _amesh(1, 8), strict_tp=True)     # aligned: ok
    with pytest.raises(ValueError, match="scale group"):
        pol.validate_for(cfg, _amesh(1, 64), strict_tp=True)
    # the non-strict default keeps the historical replicate-silently rule
    pol.validate_for(cfg, _amesh(1, 64))


def test_scheduler_rejects_unserved_tier_at_submit():
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=2, prefill_chunk=8))
    sched = Scheduler(eng)
    with pytest.raises(ValueError, match="no pool at that tier"):
        sched.submit(Request(prompt=np.arange(1, 5, dtype=np.int32),
                             kv_policy="int8"))


# ---------------------------------------------------------------------------
# Legacy-adapter bit-identity + deprecated-global removal
# ---------------------------------------------------------------------------
def _generate(engine, batch, max_new=5):
    return engine.generate(batch, max_new_tokens=max_new)["generated"]


def test_legacy_kv_dtype_adapter_bit_identical_single_device():
    """ServeConfig(kv_dtype='int8') and ServeConfig(policy=...) are the
    same engine: byte-identical greedy output (the dp=2 x tp=4 twin of
    this contract runs in the CI multi-device job below)."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    batch = {"tokens": np.random.default_rng(11).integers(
        1, cfg.vocab, (3, 9)).astype(np.int32)}
    legacy = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=4, prefill_chunk=8, kv_dtype="int8"))
    pol = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=4, prefill_chunk=8,
        policy=PrecisionPolicy(kv="int8")))
    np.testing.assert_array_equal(_generate(legacy, batch),
                                  _generate(pol, batch))


def test_plan_adapter_bit_identical_to_policy_weights_under_mesh():
    """ServingEngine(plan=...) folds into the policy: same specs, same
    placement, same tokens as declaring the weights in the policy —
    exercised through the (1, 1)-mesh sharded code path."""
    cfg = get_config("granite-8b", smoke=True)
    plan = {"ffn.w_down": "bf16"}
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0), plan=plan))
    batch = {"tokens": np.random.default_rng(12).integers(
        1, cfg.vocab, (2, 7)).astype(np.int32)}
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    via_plan = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=2, prefill_chunk=8, mesh=mesh), plan=plan)
    via_policy = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=2, prefill_chunk=8, mesh=mesh,
        policy=PrecisionPolicy(weights=tuple(plan.items()))))
    np.testing.assert_array_equal(_generate(via_plan, batch),
                                  _generate(via_policy, batch))
    # without either spelling the structure check still fires eagerly
    with pytest.raises(ValueError, match="plan"):
        ServingEngine(cfg, params, ServeConfig(
            max_len=32, n_slots=2, prefill_chunk=8, mesh=mesh))


def test_serve_path_has_no_deprecated_kernel_global_call_sites():
    """Acceptance guard: ``set_use_kernel`` / ``set_under_partitioning``
    survive only as deprecation shims — the serve/launch paths drive
    ``kernels.ops.declare_execution`` instead."""
    import inspect

    import repro.launch.steps as steps
    import repro.serve.engine as engine
    import repro.serve.scheduler as scheduler
    for mod in (engine, scheduler, steps):
        src = inspect.getsource(mod)
        assert "set_under_partitioning" not in src, mod.__name__
        assert "set_use_kernel" not in src, mod.__name__


# ---------------------------------------------------------------------------
# Runtime per-request tier switching (the acceptance contract)
# ---------------------------------------------------------------------------
def _run_tiered(engine, jobs, max_new=6, tiers=None, late_from=None):
    """Serve ``jobs`` = [(prompt, kv_policy or None, temperature)];
    requests from index ``late_from`` on are admitted mid-flight."""
    sched = Scheduler(engine, tiers=tiers)
    late_from = len(jobs) if late_from is None else late_from

    def mk(i):
        p, tier, temp = jobs[i]
        return Request(prompt=p, id=i, kv_policy=tier,
                       sampling=SamplingParams(temperature=temp,
                                               max_new_tokens=max_new))
    reqs = [sched.submit(mk(i)) for i in range(late_from)]
    while sched.n_decode_steps < 2:
        sched.step()
    reqs += [sched.submit(mk(i)) for i in range(late_from, len(jobs))]
    sched.run(max_steps=400)
    assert all(r.is_finished for r in reqs)
    return [list(r.output_tokens) for r in reqs], sched


def test_mixed_tier_engine_bit_identical_per_tier():
    """THE runtime-switching contract (DESIGN.md §12): one engine serves
    bf16-KV and int8-KV requests interleaved — mid-flight admission, a
    seeded temperature row included — and every request's output is
    bit-identical to a single-tier engine run at its precision."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, cfg.vocab, (n,)).astype(np.int32)
               for n in (9, 6, 11, 8)]
    temps = (0.0, 0.0, 0.7, 0.0)

    def single(tier):
        eng = ServingEngine(cfg, params, ServeConfig(
            max_len=32, n_slots=4, prefill_chunk=8, kv_dtype=tier))
        out, _ = _run_tiered(eng, [(p, None, t)
                                   for p, t in zip(prompts, temps)])
        return out

    ref = {t: single(t) for t in ("bf16", "int8")}

    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=4, prefill_chunk=8))
    tiers = ("bf16", "int8", "bf16", "int8")
    got, sched = _run_tiered(
        eng, [(p, t, tp) for p, t, tp in zip(prompts, tiers, temps)],
        tiers=["bf16", "int8"], late_from=3)
    assert got == [ref[t][i] for i, t in enumerate(tiers)]
    # the mixed run really ran both tiers concurrently from one engine
    rep = sched.metrics.report()
    assert rep["tiers"] == {"bf16": 4, "int8": 4}
    assert rep["n_requests"] == 4


def test_mixed_tier_decode_cohorts_one_dispatch_per_tier():
    """Decode rounds issue one dispatch per ACTIVE tier cohort: a round
    with both tiers decoding counts 2 dispatches; a single-tier workload
    on the same scheduler counts 1 per round."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=2, prefill_chunk=8, max_burst=1))
    sched = Scheduler(eng, tiers=["bf16", "int8"])
    p = np.arange(1, 9, dtype=np.int32)
    for i, tier in enumerate(("bf16", "int8")):
        sched.submit(Request(prompt=p, id=i, kv_policy=tier,
                             sampling=SamplingParams(max_new_tokens=4)))
    sched.run(max_steps=100)
    # one-chunk prompts prefill on consecutive steps, then each request
    # decodes 3 tokens; the overlapping rounds dispatch once PER TIER:
    # 1 (bf16 alone) + 2 + 2 (both) + 1 (int8 alone) = 6 dispatches for
    # 6 decode token-steps — cohorts never share a dispatch across tiers
    assert sched.metrics.decode_token_steps == 6
    assert sched.metrics.decode_dispatches == 6


def test_budget_derived_tier_pools_capacity_ratio():
    """The capacity story: from ONE cache budget per tier, the int8 tier
    admits ~1.94x the bf16 slots at the paper models' d_head=128 (codes
    pack 4-per-word + one f32 scale per (position, head): 2D/(D+4))."""
    cfg = dataclasses.replace(get_config("granite-8b", smoke=True),
                              d_head=128)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=32, prefill_chunk=8, cache_budget_bytes=1_000_000))
    sched = Scheduler(eng, tiers=["bf16", "int8"])
    slots = {t: p.n_slots for t, p in sched.pools.items()}
    assert slots["int8"] >= 1.9 * slots["bf16"], slots
    # and the pools really are that tier
    assert sched.pools["int8"].kv_dtype == "int8"
    assert sched.pools["bf16"].bytes_per_token > \
        1.9 * sched.pools["int8"].bytes_per_token


# ---------------------------------------------------------------------------
# Multi-device twins (CI multi-device job)
# ---------------------------------------------------------------------------
@multi_device
def test_legacy_kv_dtype_adapter_bit_identical_dp2_tp4():
    """The adapter bit-identity contract under dp=2 x tp=4: legacy
    kv_dtype spelling == policy spelling, byte for byte."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    batch = {"tokens": np.random.default_rng(17).integers(
        1, cfg.vocab, (4, 9)).astype(np.int32)}

    def build(**kw):
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        return ServingEngine(cfg, params, ServeConfig(
            max_len=32, n_slots=8, prefill_chunk=8, mesh=mesh, **kw))

    legacy = _generate(build(kv_dtype="int8"), batch)
    pol = _generate(build(policy=PrecisionPolicy(kv="int8")), batch)
    np.testing.assert_array_equal(legacy, pol)
    # and both match the single-device engine.  Under the mesh, 'auto'
    # resolves to the pallas kernels (DESIGN.md §14); meshless it resolves
    # to jnp, a different numeric path (fused-f32 vs bf16 dequant) — so
    # the meshless reference pins the SAME resolved mode.  The mesh-vs-
    # meshless contract per mode is test_kernel_mesh_equivalence_matrix's.
    single = _generate(ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=8, prefill_chunk=8,
        policy=PrecisionPolicy(kv="int8", kernel="pallas"))), batch)
    np.testing.assert_array_equal(legacy, single)


@multi_device
def test_mixed_tier_engine_bit_identical_per_tier_dp2_tp4():
    """Runtime tier switching composed with sharded serving: one dp=2 x
    tp=4 engine, two tier pools, mid-flight admission — each tier's
    output bit-identical to the meshless single-tier engine."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(19)
    prompts = [rng.integers(1, cfg.vocab, (n,)).astype(np.int32)
               for n in (9, 6, 11, 8)]
    jobs_ref = [(p, None, 0.0) for p in prompts]

    def single(tier):
        eng = ServingEngine(cfg, params, ServeConfig(
            max_len=32, n_slots=8, prefill_chunk=8, kv_dtype=tier))
        return _run_tiered(eng, jobs_ref)[0]

    ref = {t: single(t) for t in ("bf16", "int8")}
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=8, prefill_chunk=8, mesh=mesh))
    tiers = ("bf16", "int8", "int8", "bf16")
    got, _ = _run_tiered(eng,
                         [(p, t, 0.0) for p, t in zip(prompts, tiers)],
                         tiers=["bf16", "int8"], late_from=3)
    assert got == [ref[t][i] for i, t in enumerate(tiers)]
