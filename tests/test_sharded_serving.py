"""Tensor-parallel serving tests (DESIGN.md §10).

Three tiers:
  * spec-level assertions run everywhere — they build PartitionSpec trees
    over an ``AbstractMesh`` (no devices needed);
  * single-device mesh tests run everywhere — a (1, 1) mesh exercises the
    whole mesh code path (device_put, explicit in/out shardings, donation)
    without multi-device XLA;
  * multi-device tests need >= 8 host devices and skip otherwise — CI's
    multi-device job sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    (so does ``launch/serve.py --force-host-devices 8``).
"""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.models.common import InitMaker, QLinear, QuantMaker
from repro.models import transformer as T
from repro.quant.schemes import get_scheme
from repro.runtime import partitioning as PT
from repro.serve import (Request, SamplingParams, ServeConfig, ServingEngine,
                         Scheduler)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(autouse=True)
def _reset_partitioning_flag():
    """Engines with a multi-device mesh flip the global kernel guard; keep
    it from leaking into later test files."""
    yield
    from repro.kernels import ops
    ops.set_under_partitioning(False)


def _amesh(dp, tp):
    return AbstractMesh((("data", dp), ("model", tp)))


def _qlinear_spec_leaves(cfg, specs):
    """[(name-path, QLinear spec node)] for every quantized leaf."""
    out = []
    jax.tree_util.tree_map_with_path(
        lambda path, leaf: out.append((jax.tree_util.keystr(path), leaf))
        if isinstance(leaf, QLinear) else None,
        specs, is_leaf=lambda x: isinstance(x, (QLinear, P)))
    return out


# ---------------------------------------------------------------------------
# Spec-level: packed-word / scale-group K alignment (no devices needed)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,scheme_name", [
    ("granite-8b", "awq_int4"),      # group 128
    ("starcoder2-15b", "mxfp4"),     # group 32
])
def test_param_specs_k_sharding_respects_words_and_scale_groups(
        arch, scheme_name):
    """On an 8-way model axis, a K-axis shard boundary of a packed
    quantized leaf must land on an int32 code-word boundary AND a
    scale-group boundary, and codes/scales must shard in lockstep."""
    cfg = get_config(arch)
    scheme = get_scheme(scheme_name)
    tp = 8
    mesh = _amesh(1, tp)
    specs = PT.param_specs(cfg, mesh, train=False, quantize=True)
    qleaves = _qlinear_spec_leaves(cfg, specs)
    assert qleaves, f"{arch}: expected quantized leaves"
    per_word = 32 // scheme.weight_bits
    n_k_sharded = 0
    for name_path, leaf in qleaves:
        # K axis = first dim after the layer stack
        nstack = len(leaf.packed) - 2
        pk, sk = leaf.packed[nstack], leaf.scales[nstack]
        assert pk == sk, (
            f"{name_path}: packed K-axis={pk!r} != scales K-axis={sk!r} "
            "(a shard must own the scale rows of its own K rows)")
        if pk != "model":
            continue
        n_k_sharded += 1
        k = leaf.shape[0]
        k_shard = k // tp
        assert k_shard % per_word == 0, \
            f"{name_path}: K shard {k_shard} splits an int32 code word"
        group = min(scheme.group_size, k)
        assert k_shard % group == 0, \
            f"{name_path}: K shard {k_shard} splits a scale group {group}"
    # the full-size configs genuinely exercise K sharding (wo / w_down)
    assert n_k_sharded > 0, f"{arch}: no K-sharded quantized leaf at tp={tp}"


def test_param_specs_blocks_k_shard_when_scale_groups_do_not_divide():
    """Smoke dims have single-group scales (K <= group): the K axis must
    stay replicated even though the packed word count divides the axis —
    previously this sharded codes against unsplittable scales."""
    cfg = get_config("granite-8b", smoke=True)
    specs = PT.param_specs(cfg, _amesh(1, 4), train=False, quantize=True)
    for name_path, leaf in _qlinear_spec_leaves(cfg, specs):
        nstack = len(leaf.packed) - 2
        assert leaf.packed[nstack] is None, \
            f"{name_path}: K sharded across a single scale group"


def test_param_specs_head_granularity_guard():
    """Attention projection head dims shard only when the head COUNT
    divides the model axis: granite has 32 q / 8 kv heads, so at tp=8 both
    shard, at tp=16 only q does — even though the raw dim h*dh divides 16
    in both cases (sub-head splits broke the [b,s,h,dh] reshape)."""
    cfg = get_config("granite-8b")   # 32 heads, 8 kv heads

    def axes(tp):
        specs = PT.param_specs(cfg, _amesh(1, tp), train=False, quantize=True)
        q = dict(_qlinear_spec_leaves(cfg, specs))
        wq = [v for k, v in q.items() if "wq" in k][0]
        wk = [v for k, v in q.items() if "wk" in k][0]
        return wq.packed[-1], wk.packed[-1]

    assert axes(8) == ("model", "model")
    assert axes(16) == ("model", None)   # 8 kv heads cannot split 16 ways


def test_serve_pool_pspec_axes_and_structure():
    """Pool specs: slots on 'data', heads on 'model' (iff divisible), the
    packed d_head dim NEVER sharded, scales tree mirrors the slab tree."""
    cfg = get_config("granite-8b")   # 8 kv heads
    mesh = _amesh(2, 4)
    spec = PT.serve_pool_pspec(cfg, mesh, 8, kv_dtype="int8")
    k_slab, v_slab = spec
    for slab in (k_slab, v_slab):
        # [L, slots, S, H, Dw] packed + [L, slots, S, H] scales
        assert slab.packed == P(None, "data", None, "model", None)
        assert slab.scales == P(None, "data", None, "model")
    # bf16 pool: plain specs, same axes
    spec = PT.serve_pool_pspec(cfg, mesh, 8, kv_dtype="bf16")
    assert spec[0] == P(None, "data", None, "model", None)
    # indivisible: 2 kv heads on a 4-way axis, 3 slots on a 2-way axis
    smoke = get_config("granite-8b", smoke=True)
    spec = PT.serve_pool_pspec(smoke, mesh, 3, kv_dtype="int8")
    assert spec[0].packed == P(None, None, None, None, None)
    with pytest.raises(ValueError):
        PT.serve_pool_pspec(get_config("xlstm-350m"), mesh, 8)


def test_mla_pool_pspec_latent_stays_whole():
    """MLA pools shard slots only: the compressed latent is consumed whole
    by every head's absorbed contraction."""
    cfg = get_config("deepseek-v2-236b")
    spec = PT.serve_pool_pspec(cfg, _amesh(4, 2), 8, kv_dtype="bf16")
    assert spec == (P(None, "data", None, None), P(None, "data", None, None))


# ---------------------------------------------------------------------------
# QuantMaker plan override (satellite) + spec coherence
# ---------------------------------------------------------------------------
def test_quantmaker_plan_overrides_config_scheme():
    """A plan entry wins over the config scheme per leaf name: forcing
    ffn.w_down dense and attn.wq to mxfp4 changes exactly those leaves."""
    cfg = get_config("granite-8b", smoke=True)     # config: awq_int4
    plan = {"ffn.w_down": "bf16", "attn.wq": "mxfp4"}
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0), plan=plan))
    layers = params["layers"]
    assert not isinstance(layers["ffn"]["w_down"], QLinear)   # forced dense
    assert layers["attn"]["wq"].scheme_name == "mxfp4"        # forced mxfp4
    assert layers["attn"]["wk"].scheme_name == "awq_int4"     # untouched
    # param_specs built with the same plan matches the tree leaf for leaf
    specs = PT.param_specs(cfg, _amesh(1, 4), train=False, quantize=True,
                           plan=plan)
    assert jax.tree_util.tree_structure(params) == \
        jax.tree_util.tree_structure(
            specs, is_leaf=lambda x: isinstance(x, P))
    # ... and without the plan it does NOT (the coherence failure the
    # engine guards against)
    specs_noplan = PT.param_specs(cfg, _amesh(1, 4), train=False,
                                  quantize=True)
    assert jax.tree_util.tree_structure(params) != \
        jax.tree_util.tree_structure(
            specs_noplan, is_leaf=lambda x: isinstance(x, P))


def test_engine_rejects_plan_mismatch_under_mesh():
    cfg = get_config("granite-8b", smoke=True)
    plan = {"ffn.w_down": "bf16"}
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0), plan=plan))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="plan"):
        ServingEngine(cfg, params, ServeConfig(max_len=32, mesh=mesh))
    # with the plan the engine builds (and the same params serve fine)
    ServingEngine(cfg, params, ServeConfig(max_len=32, mesh=mesh), plan=plan)


# ---------------------------------------------------------------------------
# Kernel guard under partitioning (satellite)
# ---------------------------------------------------------------------------
def test_kernel_guard_downgrades_loudly_under_partitioning():
    """The downgrade warns ONCE per process (mesh decode loops hit
    ``kernel_allowed`` on every traced step): first call warns, later
    calls downgrade silently — but every call still downgrades."""
    import warnings as _warnings

    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.quant.schemes import quantize_weights
    qw = quantize_weights(get_scheme("awq_int4"),
                          np.random.default_rng(0).normal(size=(64, 16)))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 64)),
                    jnp.bfloat16)
    ref = ops.quantized_matmul(x, qw, use_kernel=False)
    try:
        ops.set_under_partitioning(True)
        ops.reset_downgrade_warning()
        with pytest.warns(UserWarning, match="not GSPMD-partitionable"):
            out = ops.quantized_matmul(x, qw, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
        # latched: the second call must not warn again...
        with _warnings.catch_warnings():
            _warnings.simplefilter("error")
            out2 = ops.quantized_matmul(x, qw, use_kernel=True)
        # ...but must still downgrade to the jnp path
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out2))
        assert not ops.kernel_allowed(True)
    finally:
        ops.set_under_partitioning(False)
        ops.reset_downgrade_warning()


# ---------------------------------------------------------------------------
# Mesh engine: single-device path (runs in the tier-1 fast loop)
# ---------------------------------------------------------------------------
def test_mesh_engine_single_device_bit_identical():
    """A (1, 1) mesh walks the whole sharded code path — param placement,
    explicit in/out shardings, pool placement, donation — and must emit
    exactly the meshless tokens."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    batch = {"tokens": np.random.default_rng(2).integers(
        1, cfg.vocab, (3, 9)).astype(np.int32)}
    base = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=4, prefill_chunk=8, kv_dtype="int8"))
    ref = base.generate(batch, max_new_tokens=5)["generated"]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=4, prefill_chunk=8, kv_dtype="int8", mesh=mesh))
    assert eng.topology == {"n_devices": 1, "dp": 1, "tp": 1}
    out = eng.generate(batch, max_new_tokens=5)["generated"]
    np.testing.assert_array_equal(ref, out)
    # pool really is placed with the serve-side shardings
    pool = eng.new_pool()
    assert pool.shardings is not None


# ---------------------------------------------------------------------------
# Multi-device: the acceptance contract (CI multi-device job)
# ---------------------------------------------------------------------------
def _run_workload(engine, prompts, max_new=6):
    """Scheduler run with the last request admitted mid-flight."""
    sched = Scheduler(engine)
    reqs = [sched.submit(Request(prompt=p,
                                 sampling=SamplingParams(max_new_tokens=max_new)))
            for p in prompts[:-1]]
    while sched.n_decode_steps < 2:
        sched.step()
    late = sched.submit(Request(
        prompt=prompts[-1], sampling=SamplingParams(max_new_tokens=max_new)))
    sched.run(max_steps=400)
    assert all(r.is_finished for r in reqs + [late])
    return [list(r.output_tokens) for r in reqs + [late]], sched


@multi_device
def test_dp2_tp4_bit_identical_greedy_with_mid_flight_admission():
    """THE sharded-serving contract: greedy output on a dp=2 x tp=4 mesh,
    quantized weights AND int8 KV pool, including a mid-flight admission,
    is bit-identical to the single-device run (DESIGN.md §10)."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab, (n,)).astype(np.int32)
               for n in (9, 6, 11, 8)]

    def engine(mesh):
        return ServingEngine(cfg, params, ServeConfig(
            max_len=32, n_slots=8, prefill_chunk=8, kv_dtype="int8",
            mesh=mesh))

    ref, _ = _run_workload(engine(None), prompts)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    got, sched = _run_workload(engine(mesh), prompts)
    assert got == ref
    assert sched.metrics.report()["topology"] == \
        {"n_devices": 8, "dp": 2, "tp": 4}


@multi_device
def test_tp8_bit_identical_bf16_pool():
    """Pure model parallelism, plain bf16 pool: same contract."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab, (n,)).astype(np.int32)
               for n in (8, 10, 5)]

    def engine(mesh):
        return ServingEngine(cfg, params, ServeConfig(
            max_len=32, n_slots=4, prefill_chunk=8, mesh=mesh))

    ref, _ = _run_workload(engine(None), prompts)
    got, _ = _run_workload(
        engine(jax.make_mesh((1, 8), ("data", "model"))), prompts)
    assert got == ref


@multi_device
def test_sharded_pool_placement_and_donation():
    """The pool cache is actually laid out per serve_pool_pspec (slots on
    'data'), and the decode step donates: the cache buffer is rebound, not
    copied (same sharding in and out)."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=8, prefill_chunk=8, kv_dtype="int8", mesh=mesh))
    pool = eng.new_pool()
    leaf = jax.tree_util.tree_leaves(pool.cache)[0]
    assert leaf.sharding.spec[1] == "data"          # slots axis sharded
    slot = pool.alloc()
    prompt = np.arange(1, 9, dtype=np.int32)
    eng.prefill_into_slots(pool, [slot], [prompt])
    before = jax.tree_util.tree_leaves(pool.cache)[0].sharding
    toks = np.zeros((8,), np.int32)
    sampled = eng.decode_slots(pool, toks)           # fused: ids, not logits
    assert sampled.shape == (8,) and sampled.dtype == np.int32
    after = jax.tree_util.tree_leaves(pool.cache)[0].sharding
    assert before == after                           # layout is pinned
