"""Tensor-parallel serving tests (DESIGN.md §10).

Three tiers:
  * spec-level assertions run everywhere — they build PartitionSpec trees
    over an ``AbstractMesh`` (no devices needed);
  * single-device mesh tests run everywhere — a (1, 1) mesh exercises the
    whole mesh code path (device_put, explicit in/out shardings, donation)
    without multi-device XLA;
  * multi-device tests need >= 8 host devices and skip otherwise — CI's
    multi-device job sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    (so does ``launch/serve.py --force-host-devices 8``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.models.common import InitMaker, QLinear, QuantMaker
from repro.models import transformer as T
from repro.quant.schemes import get_scheme
from repro.runtime import partitioning as PT
from repro.serve import (Request, SamplingParams, ServeConfig, ServingEngine,
                         Scheduler)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(autouse=True)
def _reset_execution_record():
    """Engines with a multi-device mesh declare themselves into the global
    execution record (kernel mode, mesh, per-leaf weight specs); reset it —
    and the per-site fallback-warning registry — so nothing leaks into
    later test files."""
    yield
    from repro.kernels import ops
    ops.declare_execution(kernel="auto", mesh=None, weight_specs=None)
    ops.reset_site_warnings()


def _amesh(dp, tp):
    return AbstractMesh((("data", dp), ("model", tp)))


def _qlinear_spec_leaves(cfg, specs):
    """[(name-path, QLinear spec node)] for every quantized leaf."""
    out = []
    jax.tree_util.tree_map_with_path(
        lambda path, leaf: out.append((jax.tree_util.keystr(path), leaf))
        if isinstance(leaf, QLinear) else None,
        specs, is_leaf=lambda x: isinstance(x, (QLinear, P)))
    return out


# ---------------------------------------------------------------------------
# Spec-level: packed-word / scale-group K alignment (no devices needed)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,scheme_name", [
    ("granite-8b", "awq_int4"),      # group 128
    ("starcoder2-15b", "mxfp4"),     # group 32
])
def test_param_specs_k_sharding_respects_words_and_scale_groups(
        arch, scheme_name):
    """On an 8-way model axis, a K-axis shard boundary of a packed
    quantized leaf must land on an int32 code-word boundary AND a
    scale-group boundary, and codes/scales must shard in lockstep."""
    cfg = get_config(arch)
    scheme = get_scheme(scheme_name)
    tp = 8
    mesh = _amesh(1, tp)
    specs = PT.param_specs(cfg, mesh, train=False, quantize=True)
    qleaves = _qlinear_spec_leaves(cfg, specs)
    assert qleaves, f"{arch}: expected quantized leaves"
    per_word = 32 // scheme.weight_bits
    n_k_sharded = 0
    for name_path, leaf in qleaves:
        # K axis = first dim after the layer stack
        nstack = len(leaf.packed) - 2
        pk, sk = leaf.packed[nstack], leaf.scales[nstack]
        assert pk == sk, (
            f"{name_path}: packed K-axis={pk!r} != scales K-axis={sk!r} "
            "(a shard must own the scale rows of its own K rows)")
        if pk != "model":
            continue
        n_k_sharded += 1
        k = leaf.shape[0]
        k_shard = k // tp
        assert k_shard % per_word == 0, \
            f"{name_path}: K shard {k_shard} splits an int32 code word"
        group = min(scheme.group_size, k)
        assert k_shard % group == 0, \
            f"{name_path}: K shard {k_shard} splits a scale group {group}"
    # the full-size configs genuinely exercise K sharding (wo / w_down)
    assert n_k_sharded > 0, f"{arch}: no K-sharded quantized leaf at tp={tp}"


def test_param_specs_blocks_k_shard_when_scale_groups_do_not_divide():
    """Smoke dims have single-group scales (K <= group): the K axis must
    stay replicated even though the packed word count divides the axis —
    previously this sharded codes against unsplittable scales."""
    cfg = get_config("granite-8b", smoke=True)
    specs = PT.param_specs(cfg, _amesh(1, 4), train=False, quantize=True)
    for name_path, leaf in _qlinear_spec_leaves(cfg, specs):
        nstack = len(leaf.packed) - 2
        assert leaf.packed[nstack] is None, \
            f"{name_path}: K sharded across a single scale group"


def test_param_specs_head_granularity_guard():
    """Attention projection head dims shard only when the head COUNT
    divides the model axis: granite has 32 q / 8 kv heads, so at tp=8 both
    shard, at tp=16 only q does — even though the raw dim h*dh divides 16
    in both cases (sub-head splits broke the [b,s,h,dh] reshape)."""
    cfg = get_config("granite-8b")   # 32 heads, 8 kv heads

    def axes(tp):
        specs = PT.param_specs(cfg, _amesh(1, tp), train=False, quantize=True)
        q = dict(_qlinear_spec_leaves(cfg, specs))
        wq = [v for k, v in q.items() if "wq" in k][0]
        wk = [v for k, v in q.items() if "wk" in k][0]
        return wq.packed[-1], wk.packed[-1]

    assert axes(8) == ("model", "model")
    assert axes(16) == ("model", None)   # 8 kv heads cannot split 16 ways


def test_serve_pool_pspec_axes_and_structure():
    """Pool specs: slots on 'data', heads on 'model' (iff divisible), the
    packed d_head dim NEVER sharded, scales tree mirrors the slab tree."""
    cfg = get_config("granite-8b")   # 8 kv heads
    mesh = _amesh(2, 4)
    spec = PT.serve_pool_pspec(cfg, mesh, 8, kv_dtype="int8")
    k_slab, v_slab = spec
    for slab in (k_slab, v_slab):
        # [L, slots, S, H, Dw] packed + [L, slots, S, H] scales
        assert slab.packed == P(None, "data", None, "model", None)
        assert slab.scales == P(None, "data", None, "model")
    # bf16 pool: plain specs, same axes
    spec = PT.serve_pool_pspec(cfg, mesh, 8, kv_dtype="bf16")
    assert spec[0] == P(None, "data", None, "model", None)
    # indivisible: 2 kv heads on a 4-way axis, 3 slots on a 2-way axis
    smoke = get_config("granite-8b", smoke=True)
    spec = PT.serve_pool_pspec(smoke, mesh, 3, kv_dtype="int8")
    assert spec[0].packed == P(None, None, None, None, None)
    with pytest.raises(ValueError):
        PT.serve_pool_pspec(get_config("xlstm-350m"), mesh, 8)


def test_mla_pool_pspec_latent_stays_whole():
    """MLA pools shard slots only: the compressed latent is consumed whole
    by every head's absorbed contraction."""
    cfg = get_config("deepseek-v2-236b")
    spec = PT.serve_pool_pspec(cfg, _amesh(4, 2), 8, kv_dtype="bf16")
    assert spec == (P(None, "data", None, None), P(None, "data", None, None))


# ---------------------------------------------------------------------------
# QuantMaker plan override (satellite) + spec coherence
# ---------------------------------------------------------------------------
def test_quantmaker_plan_overrides_config_scheme():
    """A plan entry wins over the config scheme per leaf name: forcing
    ffn.w_down dense and attn.wq to mxfp4 changes exactly those leaves."""
    cfg = get_config("granite-8b", smoke=True)     # config: awq_int4
    plan = {"ffn.w_down": "bf16", "attn.wq": "mxfp4"}
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0), plan=plan))
    layers = params["layers"]
    assert not isinstance(layers["ffn"]["w_down"], QLinear)   # forced dense
    assert layers["attn"]["wq"].scheme_name == "mxfp4"        # forced mxfp4
    assert layers["attn"]["wk"].scheme_name == "awq_int4"     # untouched
    # param_specs built with the same plan matches the tree leaf for leaf
    specs = PT.param_specs(cfg, _amesh(1, 4), train=False, quantize=True,
                           plan=plan)
    assert jax.tree_util.tree_structure(params) == \
        jax.tree_util.tree_structure(
            specs, is_leaf=lambda x: isinstance(x, P))
    # ... and without the plan it does NOT (the coherence failure the
    # engine guards against)
    specs_noplan = PT.param_specs(cfg, _amesh(1, 4), train=False,
                                  quantize=True)
    assert jax.tree_util.tree_structure(params) != \
        jax.tree_util.tree_structure(
            specs_noplan, is_leaf=lambda x: isinstance(x, P))


def test_engine_rejects_plan_mismatch_under_mesh():
    cfg = get_config("granite-8b", smoke=True)
    plan = {"ffn.w_down": "bf16"}
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0), plan=plan))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="plan"):
        ServingEngine(cfg, params, ServeConfig(max_len=32, mesh=mesh))
    # with the plan the engine builds (and the same params serve fine)
    ServingEngine(cfg, params, ServeConfig(max_len=32, mesh=mesh), plan=plan)


# ---------------------------------------------------------------------------
# Kernel fallback warnings: keyed by SITE, not latched per process (satellite)
# ---------------------------------------------------------------------------
def test_kernel_fallback_warns_once_per_site():
    """Fallback warnings are keyed by the call SITE (the weight leaf name):
    the first fallback at a site warns, repeats at the same site are silent
    — but a DIFFERENT site still gets its own warning instead of being
    consumed by the old per-process latch.  Every fallback still computes
    the same math on the jnp path."""
    import dataclasses as _dc
    import warnings as _warnings

    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.quant.schemes import quantize_weights
    qw_a = quantize_weights(get_scheme("awq_int4"),
                            np.random.default_rng(0).normal(size=(64, 16)))
    qw_a = _dc.replace(qw_a, name="attn.wq")
    qw_b = _dc.replace(qw_a, name="ffn.w_up")
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 64)),
                    jnp.bfloat16)
    ref = ops.quantized_matmul(x, qw_a, use_kernel=False)
    try:
        # legacy shim spelling: partitioned with no mesh — every kernel
        # site falls back (nothing to shard_map over), each warning once
        ops.declare_execution(kernel="pallas", partitioned=True)
        ops.reset_site_warnings()
        with pytest.warns(UserWarning, match="attn.wq"):
            out = ops.quantized_matmul(x, qw_a)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
        # same site again: silent...
        with _warnings.catch_warnings():
            _warnings.simplefilter("error")
            out2 = ops.quantized_matmul(x, qw_a)
        # ...but still the jnp fallback, same math
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out2))
        # a different site was NOT consumed by the first warning
        with pytest.warns(UserWarning, match="ffn.w_up"):
            ops.quantized_matmul(x, qw_b)
        # explicit use_kernel=True bools keep the blanket downgrade: raw
        # kernel calls bypass the shard_map dispatch entirely
        with pytest.warns(UserWarning, match="explicit use_kernel"):
            out3 = ops.quantized_matmul(x, qw_a, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out3))
        assert not ops.kernel_allowed(True)
    finally:
        ops.declare_execution(kernel="auto", mesh=None, weight_specs=None)
        ops.reset_site_warnings()


# ---------------------------------------------------------------------------
# Mesh engine: single-device path (runs in the tier-1 fast loop)
# ---------------------------------------------------------------------------
def test_mesh_engine_single_device_bit_identical():
    """A (1, 1) mesh walks the whole sharded code path — param placement,
    explicit in/out shardings, pool placement, donation — and must emit
    exactly the meshless tokens."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    batch = {"tokens": np.random.default_rng(2).integers(
        1, cfg.vocab, (3, 9)).astype(np.int32)}
    base = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=4, prefill_chunk=8, kv_dtype="int8"))
    ref = base.generate(batch, max_new_tokens=5)["generated"]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=4, prefill_chunk=8, kv_dtype="int8", mesh=mesh))
    assert eng.topology == {"n_devices": 1, "dp": 1, "tp": 1}
    out = eng.generate(batch, max_new_tokens=5)["generated"]
    np.testing.assert_array_equal(ref, out)
    # pool really is placed with the serve-side shardings
    pool = eng.new_pool()
    assert pool.shardings is not None


# ---------------------------------------------------------------------------
# Multi-device: the acceptance contract (CI multi-device job)
# ---------------------------------------------------------------------------
def _run_workload(engine, prompts, max_new=6):
    """Scheduler run with the last request admitted mid-flight."""
    sched = Scheduler(engine)
    reqs = [sched.submit(Request(prompt=p,
                                 sampling=SamplingParams(max_new_tokens=max_new)))
            for p in prompts[:-1]]
    while sched.n_decode_steps < 2:
        sched.step()
    late = sched.submit(Request(
        prompt=prompts[-1], sampling=SamplingParams(max_new_tokens=max_new)))
    sched.run(max_steps=400)
    assert all(r.is_finished for r in reqs + [late])
    return [list(r.output_tokens) for r in reqs + [late]], sched


@multi_device
def test_dp2_tp4_bit_identical_greedy_with_mid_flight_admission():
    """THE sharded-serving contract: greedy output on a dp=2 x tp=4 mesh,
    quantized weights AND int8 KV pool, including a mid-flight admission,
    is bit-identical to the single-device run AT THE SAME KERNEL MODE
    (DESIGN.md §10, §14).  The default ``kernel='auto'`` resolves to
    pallas under the mesh, so its reference is the meshless run with
    pallas pinned — the mesh never changes the math; the kernel choice
    may (fused-f32 kernel vs bf16-dequant jnp, a bf16-rounding delta)."""
    from repro.quant.policy import PrecisionPolicy
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab, (n,)).astype(np.int32)
               for n in (9, 6, 11, 8)]

    def engine(mesh, kernel="auto"):
        return ServingEngine(cfg, params, ServeConfig(
            max_len=32, n_slots=8, prefill_chunk=8,
            policy=PrecisionPolicy(kv="int8", kernel=kernel), mesh=mesh))

    ref, _ = _run_workload(engine(None, "pallas"), prompts)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    got, sched = _run_workload(engine(mesh), prompts)   # auto -> pallas
    assert got == ref
    assert sched.metrics.report()["topology"] == \
        {"n_devices": 8, "dp": 2, "tp": 4}


@multi_device
def test_tp8_bit_identical_bf16_pool():
    """Pure model parallelism, plain bf16 pool: the jnp-path contract,
    kernel pinned on BOTH sides.  At tp=8 the smoke FFN (d_ff=128) shards
    8-way and GSPMD's split reduction drifts the logits by bf16 ulps vs
    the meshless single reduction — for either kernel mode (measured:
    ~0.017 max on jnp itself) — so token equality here is a property of
    this pinned workload, not of the mesh; it is pinned at the historical
    jnp trajectory.  Kernel-mode mesh equivalence lives in
    ``test_kernel_mesh_equivalence_matrix`` (dp2 x tp4, both modes)."""
    from repro.quant.policy import PrecisionPolicy
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab, (n,)).astype(np.int32)
               for n in (8, 10, 5)]

    def engine(mesh):
        return ServingEngine(cfg, params, ServeConfig(
            max_len=32, n_slots=4, prefill_chunk=8,
            policy=PrecisionPolicy(kernel="jnp"), mesh=mesh))

    ref, _ = _run_workload(engine(None), prompts)
    got, _ = _run_workload(
        engine(jax.make_mesh((1, 8), ("data", "model"))), prompts)
    assert got == ref


@multi_device
def test_sharded_pool_placement_and_donation():
    """The pool cache is actually laid out per serve_pool_pspec (slots on
    'data'), and the decode step donates: the cache buffer is rebound, not
    copied (same sharding in and out)."""
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=8, prefill_chunk=8, kv_dtype="int8", mesh=mesh))
    pool = eng.new_pool()
    leaf = jax.tree_util.tree_leaves(pool.cache)[0]
    assert leaf.sharding.spec[1] == "data"          # slots axis sharded
    slot = pool.alloc()
    prompt = np.arange(1, 9, dtype=np.int32)
    eng.prefill_into_slots(pool, [slot], [prompt])
    before = jax.tree_util.tree_leaves(pool.cache)[0].sharding
    toks = np.zeros((8,), np.int32)
    sampled = eng.decode_slots(pool, toks)           # fused: ids, not logits
    assert sampled.shape == (8,) and sampled.dtype == np.int32
    after = jax.tree_util.tree_leaves(pool.cache)[0].sharding
    assert before == after                           # layout is pinned


# ---------------------------------------------------------------------------
# Kernel-under-mesh equivalence matrix (DESIGN.md §14, CI multi-device job)
# ---------------------------------------------------------------------------
@multi_device
@pytest.mark.parametrize("kv", ["bf16", "int8", "fp8"])
def test_kernel_mesh_equivalence_matrix(kv):
    """THE sharded-kernel contract: greedy decode on a dp=2 x tp=4 mesh
    with ``kernel='pallas'`` (shard_map'd Pallas decode attention AND the
    packed-weight matvec path) emits tokens bit-identical to the meshless
    pallas run, and ``kernel='jnp'`` on the same mesh bit-identical to the
    meshless jnp run — per KV tier over awq_int4 weights, with a
    mid-flight admission and K>1 decode bursts in the workload.  The mesh
    NEVER changes the math for either mode; pallas-vs-jnp is a
    bf16-rounding-level delta (fused-f32 kernel vs bf16-dequant fallback),
    so the two modes are pinned against their own meshless baselines."""
    from repro.quant.policy import PrecisionPolicy
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab, (n,)).astype(np.int32)
               for n in (9, 6, 11, 8)]

    def run(mesh, kernel):
        eng = ServingEngine(cfg, params, ServeConfig(
            max_len=48, n_slots=8, prefill_chunk=8, max_burst=4,
            policy=PrecisionPolicy(kv=kv, kernel=kernel), mesh=mesh))
        return _run_workload(eng, prompts)

    ref_j, _ = run(None, "jnp")
    ref_p, _ = run(None, "pallas")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    jn, _ = run(mesh, "jnp")
    pl, sched = run(mesh, "pallas")
    assert jn == ref_j
    assert pl == ref_p
    assert any(k > 1 for k in sched.metrics.burst_hist)   # bursts really ran


@multi_device
def test_pallas_policy_validates_and_serves_under_mesh():
    """The PR 3 eager rejection is gone end to end: a ``kernel='pallas'``
    policy validates against a dp2 x tp4 mesh and the engine serves with
    it (the acceptance criterion's smoke form of the matrix above)."""
    from repro.quant.policy import PrecisionPolicy
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    pol = PrecisionPolicy(kernel="pallas").validate_for(cfg, mesh)
    eng = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=8, prefill_chunk=8, policy=pol, mesh=mesh))
    batch = {"tokens": np.random.default_rng(3).integers(
        1, cfg.vocab, (4, 9)).astype(np.int32)}
    base = ServingEngine(cfg, params, ServeConfig(
        max_len=32, n_slots=8, prefill_chunk=8,
        policy=PrecisionPolicy(kernel="pallas")))
    ref = base.generate(batch, max_new_tokens=5)["generated"]
    out = eng.generate(batch, max_new_tokens=5)["generated"]
    np.testing.assert_array_equal(ref, out)


# ---------------------------------------------------------------------------
# Sharded kernels vs their ref.py oracles (bitwise)
# ---------------------------------------------------------------------------
@multi_device
@pytest.mark.parametrize("kv_dtype", ["bf16", "int8", "fp8"])
def test_sharded_decode_attention_bitwise_vs_oracle(kv_dtype):
    """``sharded_gqa_decode_attention`` on dp2 x tp4 (slots on 'data', KV
    heads on 'model') is BITWISE equal to the meshless kernel and to the
    shard-decomposed oracle — no cross-shard collective exists to change
    the f32 association."""
    from repro.kernels import ref as KREF
    from repro.kernels.decode_attention import (gqa_decode_attention,
                                                sharded_gqa_decode_attention)
    from repro.quant.kv_cache import QuantizedKV
    from repro.quant.schemes import get_kv_scheme, kv_quantize

    rng = np.random.default_rng(23)
    b, sk, hk, rep, dh = 4, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, 1, hk * rep, dh)), jnp.bfloat16)
    kc = rng.normal(size=(b, sk, hk, dh)).astype(np.float32)
    vc = rng.normal(size=(b, sk, hk, dh)).astype(np.float32)
    kc *= np.exp(rng.normal(size=(b, sk, hk, 1)))
    lens = np.array([64, 17, 33, 48], np.int32)
    if kv_dtype == "bf16":
        k = jnp.asarray(kc, jnp.bfloat16)
        v = jnp.asarray(vc, jnp.bfloat16)
    else:
        scheme = get_kv_scheme(kv_dtype)
        k = QuantizedKV(*kv_quantize(scheme, jnp.asarray(kc)), kv_dtype)
        v = QuantizedKV(*kv_quantize(scheme, jnp.asarray(vc)), kv_dtype)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    got = sharded_gqa_decode_attention(q, k, v, lens, mesh=mesh)
    meshless = gqa_decode_attention(q, k, v, lens, interpret=True)
    oracle = KREF.sharded_decode_attention_ref(q, k, v, lens, dp=2, tp=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(meshless))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))


@multi_device
@pytest.mark.parametrize("scheme_name", ["awq_int4", "mxfp4", "fp8"])
@pytest.mark.parametrize("m", [2, 16])   # gemv and matmul block plans
def test_sharded_packed_matmul_bitwise_vs_oracle(scheme_name, m):
    """The shard_map'd weight kernel (policy dispatch under a mesh) is
    bitwise equal to ``sharded_packed_matmul_ref`` for both shard
    decompositions: N on 'model' (bitwise == meshless too — the K loop is
    untouched) and K on 'model' (psum over f32 partials, same left-to-
    right association as the oracle's shard sum)."""
    import jax.numpy as jnp
    from repro.kernels import ops, ref as KREF
    from repro.quant.schemes import quantize_weights

    tp = 4
    k, n = 512, 256
    rng = np.random.default_rng(31)
    qw = quantize_weights(get_scheme(scheme_name),
                          rng.normal(size=(k, n)).astype(np.float32))
    import dataclasses as _dc
    qw = _dc.replace(qw, name="lin")
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.bfloat16)
    mesh = jax.make_mesh((2, tp), ("data", "model"))

    def mesh_out(k_ax, n_ax):
        specs = {"lin": {"packed": (k_ax, n_ax), "scales": (k_ax, n_ax)}}
        try:
            ops.declare_execution(kernel="pallas", mesh=mesh,
                                  weight_specs=specs)
            return np.asarray(ops.quantized_matmul(
                x, qw, out_dtype=jnp.float32))
        finally:
            ops.declare_execution(kernel="auto", mesh=None, weight_specs=None)

    bm, bn, bk = (m, 256, 1024) if m <= 8 else (128, 128, 512)
    # N sharded over 'model': bitwise == meshless kernel == tiled oracle
    got_n = mesh_out(None, "model")
    meshless = np.asarray(ops.quantized_matmul(
        x, qw, use_kernel=True, out_dtype=jnp.float32))
    oracle_n = np.asarray(KREF.sharded_packed_matmul_ref(
        x, qw, tp=tp, shard_dim=1, bm=bm, bn=bn, bk=bk))
    np.testing.assert_array_equal(got_n, meshless)
    np.testing.assert_array_equal(got_n, oracle_n)

    # K sharded over 'model' (joint word/scale-group boundaries): psum
    # matches the oracle's left-to-right shard sum
    if qw.scales.shape[0] % tp == 0:     # K-shard legal (group divides)
        got_k = mesh_out("model", None)
        oracle_k = np.asarray(KREF.sharded_packed_matmul_ref(
            x, qw, tp=tp, shard_dim=0, bm=bm, bn=bn, bk=bk))
        np.testing.assert_array_equal(got_k, oracle_k)


@multi_device
def test_sharded_w8a8_matmul_bitwise_vs_meshless():
    """w8a8 under the mesh: activations quantize globally (per-tensor
    absmax) OUTSIDE shard_map, the int8 kernel N-shards — int32
    accumulation is exact, so sharded == meshless bitwise."""
    import dataclasses as _dc
    from repro.kernels import ops
    from repro.quant.schemes import quantize_weights

    rng = np.random.default_rng(37)
    qw = quantize_weights(get_scheme("w8a8"),
                          rng.normal(size=(256, 128)).astype(np.float32))
    qw = _dc.replace(qw, name="lin")
    x = jnp.asarray(rng.normal(size=(4, 256)), jnp.bfloat16)
    meshless = np.asarray(ops.quantized_matmul(
        x, qw, use_kernel=True, out_dtype=jnp.float32))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    try:
        ops.declare_execution(kernel="pallas", mesh=mesh, weight_specs={
            "lin": {"packed": (None, "model"), "scales": (None, "model")}})
        got = np.asarray(ops.quantized_matmul(x, qw, out_dtype=jnp.float32))
    finally:
        ops.declare_execution(kernel="auto", mesh=None, weight_specs=None)
    np.testing.assert_array_equal(got, meshless)
