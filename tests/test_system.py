"""End-to-end system tests: serving engine, train loop w/ resume,
partition-spec/param tree coherence, multi-device pjit subprocess."""
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.common import InitMaker, QuantMaker
from repro.models import transformer as T


def test_serving_engine_generates():
    from repro.serve import ServeConfig, ServingEngine
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    eng = ServingEngine(cfg, params, ServeConfig(max_len=48))
    batch = {"tokens": np.random.default_rng(0).integers(
        1, cfg.vocab, (2, 8)).astype(np.int32)}
    out = eng.generate(batch, max_new_tokens=6)
    assert out["generated"].shape == (2, 6)
    assert (out["generated"] >= 0).all() and (out["generated"] < cfg.vocab).all()


def test_serving_greedy_deterministic():
    from repro.serve import ServeConfig, ServingEngine
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    eng = ServingEngine(cfg, params, ServeConfig(max_len=32))
    batch = {"tokens": np.random.default_rng(1).integers(
        1, cfg.vocab, (2, 6)).astype(np.int32)}
    a = eng.generate(batch, max_new_tokens=4)["generated"]
    b = eng.generate(batch, max_new_tokens=4)["generated"]
    np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_train_resume_bit_identical():
    from repro.launch.train import train
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        ref = train("whisper-medium", smoke=True, steps=12, batch_size=2,
                    seq_len=16, ckpt_dir=d1, ckpt_every=4, log_every=100)
        try:
            train("whisper-medium", smoke=True, steps=12, batch_size=2,
                  seq_len=16, ckpt_dir=d2, ckpt_every=4, log_every=100,
                  fail_at=6)
        except RuntimeError:
            pass
        res = train("whisper-medium", smoke=True, steps=12, batch_size=2,
                    seq_len=16, ckpt_dir=d2, ckpt_every=4, log_every=100)
        assert abs(ref["final_loss"] - res["final_loss"]) < 1e-6


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_match_tree(arch):
    """PartitionSpec tree has exactly the parameter tree's structure, for
    both the dense (train) and quantized (serve) parameterizations."""
    from repro.runtime import partitioning as PT
    from repro.launch.steps import abstract_params
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for train_mode in (True, False):
        params = abstract_params(get_config(arch), quantize=not train_mode)
        specs = PT.param_specs(get_config(arch), mesh, train=train_mode)
        t1 = jax.tree_util.tree_structure(params)
        t2 = jax.tree_util.tree_structure(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert t1 == t2, f"{arch} train={train_mode}"


_SUBPROCESS_PJIT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.launch.steps import build_cell
from repro.models.common import InitMaker
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
cfg = get_config("granite-8b", smoke=True)
mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = ShapeSpec("t", 32, 8, "train")
fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh)
params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
opt = adamw_init(params, AdamWConfig())
batch = {"tokens": jnp.ones((8, 32), jnp.int32),
         "labels": jnp.ones((8, 32), jnp.int32)}
params = jax.device_put(params, in_sh[0])
opt = jax.device_put(opt, in_sh[1])
batch = jax.device_put(batch, in_sh[2])
step = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
               donate_argnums=donate)
p2, o2, m = step(params, opt, batch)
loss = float(m["loss"])
assert np.isfinite(loss), loss
print("SUBPROCESS_OK", loss)
"""


@pytest.mark.slow
def test_pjit_train_step_runs_on_8_devices():
    """Actually EXECUTES the sharded train step on 8 host devices."""
    import os
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_PJIT],
                       capture_output=True, text=True, timeout=900,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd="/root/repo")
    assert "SUBPROCESS_OK" in r.stdout, r.stderr[-2000:]
