"""SLO-aware scheduling tests (DESIGN.md §16): priority preemption with
bit-identical resume, admission control / degradation, deadline
enforcement, and fault-tolerant serving.

THE contract pinned here: scheduling policy changes WHEN tokens are
produced, never WHICH tokens.  A preempted-then-resumed request (and a
fault-recovered one) must emit exactly the unpreempted run's tokens —
greedy and seeded temperature, slab and paged pools, meshless and
dp2 x tp4 — because the resume path recomputes the evicted KV from
prompt + generated[:-1] and decode continues at the preserved
per-(request, step) key schedule.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import InitMaker, QuantMaker
from repro.models import transformer as T
from repro.serve import (Request, RequestState, SamplingParams, Scheduler,
                         ServeConfig, ServingEngine, SLOPolicy, StepFault)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(0)))
    return cfg, params


def _engine(setup, **kw):
    cfg, params = setup
    args = dict(max_len=48, n_slots=2, prefill_chunk=8)
    args.update(kw)
    return ServingEngine(cfg, params, ServeConfig(**args))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, (n,)).astype(np.int32) for n in lens]


def _sp(temp=0.0, max_new=24):
    return SamplingParams(max_new_tokens=max_new, temperature=temp, seed=7)


def _outputs(sched):
    return {r.id: list(r.output_tokens) for r in sched.finished}


def _contended_run(engine, prompts, temp):
    """Two low-priority requests fill both slots and reach DECODE; a
    high-priority arrival then forces a preemption."""
    s = Scheduler(engine)
    s.submit(Request(prompts[1], _sp(temp), id=1, priority=5))
    s.submit(Request(prompts[2], _sp(temp), id=2, priority=5))
    for _ in range(5):
        s.step()
    s.submit(Request(prompts[0], _sp(temp), id=0, priority=0))
    s.run(max_steps=500)
    return s


# ---------------------------------------------------------------------------
# Preempt-and-resume bit-identity (the tentpole contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("paged", [False, True], ids=["slab", "paged"])
@pytest.mark.parametrize("temp", [0.0, 0.8], ids=["greedy", "temp"])
def test_preempt_resume_bit_identical(setup, paged, temp):
    cfg, _ = setup
    engine = _engine(setup, paged=paged)
    prompts = _prompts(cfg, [16, 12, 9])

    base = Scheduler(engine)
    for i, p in enumerate(prompts):
        base.submit(Request(p, _sp(temp), id=i))
    base.run(max_steps=500)
    ref = _outputs(base)
    assert all(len(v) == 24 for v in ref.values())

    s = _contended_run(engine, prompts, temp)
    assert sum(r.n_preemptions for r in s.finished) >= 1, \
        "scenario must actually preempt"
    assert _outputs(s) == ref
    rep = s.metrics.report()
    assert rep["finish_reasons"]["preempted_resumed"] >= 1
    assert rep["preempt_reasons"] == {"priority": rep["preemptions"]}
    if paged:
        # the resume re-admission adopted the victim's registered prompt
        # pages from the prefix cache instead of re-prefilling them
        assert rep["prefix_hits"] >= 1 and rep["prefix_hit_tokens"] >= 8


def test_preempted_request_keeps_id_and_output(setup):
    """The resume preserves identity: same request object, same id, the
    pre-preemption tokens never re-emitted (n_generated monotone)."""
    cfg, _ = setup
    engine = _engine(setup)
    prompts = _prompts(cfg, [16, 12, 9])
    s = _contended_run(engine, prompts, 0.0)
    victim = next(r for r in s.finished if r.n_preemptions > 0)
    assert victim.finish_reason == "length"
    assert len(victim.output_tokens) == 24
    assert victim.resume_prompt is None          # consumed by the replay
    assert victim.slot is None


def test_victim_selection_lowest_class_least_generated(setup):
    """Among DECODE slots, the victim is the lowest class; ties break to
    the least-generated (cheapest recompute).  Equal-class waiters never
    preempt (no livelock by slot trading)."""
    cfg, _ = setup
    engine = _engine(setup, n_slots=3)
    prompts = _prompts(cfg, [8, 8, 8, 8])
    s = Scheduler(engine, max_burst=1)     # step-granular n_generated
    s.submit(Request(prompts[0], _sp(max_new=24), id=0, priority=1))
    s.step(); s.step()                      # id 0 decodes first (oldest)
    s.submit(Request(prompts[1], _sp(max_new=24), id=1, priority=5))
    s.submit(Request(prompts[2], _sp(max_new=24), id=2, priority=5))
    for _ in range(6):
        s.step()
    gen = {r.id: r.n_generated for r in s.running.values()}
    assert set(gen) == {0, 1, 2}
    # equal-class arrival: nobody preempted
    s.submit(Request(prompts[3], _sp(max_new=4), id=3, priority=5))
    s.step()
    assert all(r.n_preemptions == 0 for r in s.running.values())
    assert len(s.waiting) == 1
    # higher-class arrival: evicts from class 5 (never the class-1 slot),
    # picking the least-generated of the two
    s.submit(Request(prompts[3], _sp(max_new=4), id=4, priority=0))
    s.step()
    preempted = [r for r in s.waiting if r.n_preemptions > 0]
    assert [r.id for r in preempted] == [2]     # class 5, least generated
    assert gen[2] <= gen[1]
    s.run(max_steps=500)
    assert all(r.finish_reason == "length" for r in s.finished)


@multi_device
def test_preempt_resume_bit_identical_dp2_tp4(setup):
    """The tentpole contract under the mesh: preempt-and-resume on a
    dp=2 x tp=4 mesh, quantized weights and int8 KV, emits exactly the
    unpreempted meshless tokens at the same kernel mode."""
    from repro.quant.policy import PrecisionPolicy
    cfg = get_config("granite-8b", smoke=True)
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))
    prompts = _prompts(cfg, [16, 12, 9], seed=5)

    def engine(mesh, kernel="auto"):
        return ServingEngine(cfg, params, ServeConfig(
            max_len=48, n_slots=2, prefill_chunk=8,
            policy=PrecisionPolicy(kv="int8", kernel=kernel), mesh=mesh))

    base = Scheduler(engine(None, "pallas"))
    for i, p in enumerate(prompts):
        base.submit(Request(p, _sp(0.0), id=i))
    base.run(max_steps=500)
    ref = _outputs(base)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    s = _contended_run(engine(mesh), prompts, 0.0)
    assert sum(r.n_preemptions for r in s.finished) >= 1
    assert _outputs(s) == ref
    assert s.metrics.report()["topology"] == \
        {"n_devices": 8, "dp": 2, "tp": 4}


# ---------------------------------------------------------------------------
# Deadlines (virtual clock; step-granular enforcement)
# ---------------------------------------------------------------------------
def test_ttft_deadline_sheds_waiting(setup):
    cfg, _ = setup
    t = [0.0]
    engine = _engine(setup, n_slots=1)
    s = Scheduler(engine, clock=lambda: t[0])
    s.submit(Request(_prompts(cfg, [8])[0], _sp(max_new=16), id=0))
    # Seat id 0 in the single slot before the deadline request arrives:
    # EDF admission would otherwise run the deadline-carrying request
    # first (finite key beats inf), and it would meet its deadline.
    s.step()
    s.submit(Request(_prompts(cfg, [8], 1)[0], _sp(max_new=4), id=1,
                     ttft_deadline_s=0.5))
    for _ in range(3):
        s.step()
        t[0] += 1.0
    s.run(max_steps=500)
    reasons = {r.id: r.finish_reason for r in s.finished}
    assert reasons == {0: "length", 1: "deadline_exceeded"}
    rep = s.metrics.report()
    assert rep["finish_reasons"]["deadline_exceeded"] == 1
    # the shed request pollutes no latency percentile
    assert len(s.metrics.ttft) == 1 and len(s.metrics.e2e) == 1


def test_e2e_deadline_retires_running(setup):
    cfg, _ = setup
    t = [0.0]
    engine = _engine(setup, n_slots=1)
    s = Scheduler(engine, clock=lambda: t[0])
    r = s.submit(Request(_prompts(cfg, [8])[0], _sp(max_new=32), id=0,
                         e2e_deadline_s=2.5))
    while not r.is_finished:
        s.step()
        t[0] += 1.0
    assert r.finish_reason == "deadline_exceeded"
    assert 0 < r.n_generated < 32                # partial output delivered
    assert r.slot is None                        # slot returned to the pool
    assert s.pool.n_free == s.pool.n_slots


# ---------------------------------------------------------------------------
# Admission control + graceful degradation (serve.slo)
# ---------------------------------------------------------------------------
def test_rejection_typed_and_protect_priority(setup):
    cfg, _ = setup
    engine = _engine(setup)
    s = Scheduler(engine, slo=SLOPolicy(max_waiting=2, protect_priority=0))
    rejected = 0
    for p in _prompts(cfg, [8] * 8):
        r = s.submit(Request(p, _sp(max_new=4), priority=2))
        if r.is_finished:
            rejected += 1
            assert r.finish_reason == "rejected"
            assert r.rejection.kind == "queue_full"
            assert r.rejection.to_dict()["kind"] == "queue_full"
    assert rejected > 0
    protected = s.submit(Request(_prompts(cfg, [8], 9)[0], _sp(max_new=4),
                                 priority=0))
    assert not protected.is_finished, "protected class is never rejected"
    s.run(max_steps=500)
    rep = s.metrics.report()
    assert rep["rejection_kinds"] == {"queue_full": rejected}
    assert rep["finish_reasons"]["rejected"] == rejected


def test_drain_time_and_deadline_unmeetable_rejections(setup):
    cfg, _ = setup
    engine = _engine(setup)
    s = Scheduler(engine, slo=SLOPolicy(max_queue_delay_s=1e-9))
    first = s.submit(Request(_prompts(cfg, [8])[0], _sp(max_new=4),
                             priority=1))
    assert not first.is_finished             # empty system: est 0 accepted
    second = s.submit(Request(_prompts(cfg, [8], 1)[0], _sp(max_new=4),
                              priority=1))
    assert second.is_finished and second.rejection.kind == "drain_time"
    assert second.rejection.estimate_s > 0
    s.run(max_steps=500)

    s2 = Scheduler(engine, slo=SLOPolicy())
    s2.submit(Request(_prompts(cfg, [8])[0], _sp(max_new=4), priority=1))
    doomed = s2.submit(Request(_prompts(cfg, [8], 1)[0], _sp(max_new=4),
                               priority=1, ttft_deadline_s=1e-12))
    assert doomed.is_finished
    assert doomed.rejection.kind == "deadline_unmeetable"
    s2.run(max_steps=500)


def test_downgrade_hysteresis_engage_hold_release(setup):
    cfg, _ = setup
    engine = _engine(setup)
    hi = 1e-6
    slo = SLOPolicy(downgrade_map={"bf16": "int8"},
                    downgrade_high_s=hi, downgrade_low_s=hi / 10)
    s = Scheduler(engine, tiers=["bf16", "int8"], slo=slo)
    r1 = s.submit(Request(_prompts(cfg, [8])[0], _sp(max_new=8)))
    assert r1.tier == "bf16" and not slo.degraded
    r2 = s.submit(Request(_prompts(cfg, [8], 1)[0], _sp(max_new=8)))
    assert slo.degraded and r2.tier == "int8" and r2.downgraded_from == "bf16"
    # hold: a request already downgraded is never re-downgraded, and the
    # flag holds while the estimate sits inside the band
    assert slo.downgrade_low_s < slo.last_estimate_s
    s.run(max_steps=500)
    r3 = s.submit(Request(_prompts(cfg, [8], 2)[0], _sp(max_new=8)))
    assert not slo.degraded and r3.tier == "bf16" \
        and r3.downgraded_from is None           # released below low water
    s.run(max_steps=500)
    assert s.metrics.report()["downgrades"] == 1
    # downgraded request still finished at the denser tier
    assert next(r for r in s.finished if r is r2).tier == "int8"


def test_slo_policy_validation():
    with pytest.raises(ValueError, match="both"):
        SLOPolicy(downgrade_high_s=1.0)
    with pytest.raises(ValueError, match="inverted"):
        SLOPolicy(downgrade_high_s=1.0, downgrade_low_s=2.0)
    with pytest.raises(ValueError, match="never fires"):
        SLOPolicy(downgrade_map={"bf16": "int8"})


def test_cost_model_planning(setup):
    """burst_cap / prefill_chunks_per_step size work from the analytical
    model: tiny budgets clamp to 1, generous budgets open up, and the
    scheduler's burst plan under a tiny budget stays K=1 end to end."""
    cfg, _ = setup
    engine = _engine(setup, n_slots=4)
    tight = SLOPolicy(max_step_s=1e-12)
    loose = SLOPolicy(max_step_s=10.0)
    s = Scheduler(engine, slo=tight)
    for i, p in enumerate(_prompts(cfg, [8, 8])):
        s.submit(Request(p, _sp(max_new=8), id=i))
    s.run(max_steps=500)
    rep = s.metrics.report()
    assert set(rep["burst_hist"]) == {"1"}       # cost cap forces K=1
    assert tight.prefill_chunks_per_step(s) == 1
    s2 = Scheduler(engine, slo=loose)
    for i, p in enumerate(_prompts(cfg, [8, 8])):
        s2.submit(Request(p, _sp(max_new=8), id=i))
    dec = list(s2.running.values())
    assert loose.burst_cap(s2, dec, s2.pool, 8) == 8
    assert loose.prefill_chunks_per_step(s2) >= 1
    s2.run(max_steps=500)
    assert tight.estimate_queue_delay_s(s2) == 0.0   # drained system


# ---------------------------------------------------------------------------
# Fault tolerance on the hot path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("paged", [False, True], ids=["slab", "paged"])
@pytest.mark.parametrize("mode", ["injected", "nan"])
def test_fault_recovery_bit_identical(setup, paged, mode):
    """One killed (or NaN-poisoned) decode dispatch: the cohort requeues
    through preempt-and-resume and the final outputs are bit-identical to
    the fault-free run."""
    cfg, _ = setup
    prompts = _prompts(cfg, [16, 12])

    def run(injector, paged_):
        eng = _engine(setup, paged=paged_, fault_injector=injector)
        s = Scheduler(eng)
        for i, p in enumerate(prompts):
            s.submit(Request(p, _sp(temp=0.8, max_new=12), id=i))
        s.run(max_steps=2000)
        return s

    ref = _outputs(run(None, paged))
    fired = []
    s = run(lambda kind, seq: (fired.append(seq) or mode)
            if seq == 5 and not fired else None, paged)
    assert fired == [5]
    assert _outputs(s) == ref
    rep = s.metrics.report()
    assert rep["faults"] == 1 and rep["fault_kinds"] == {mode: 1}
    assert rep["preempt_reasons"].get("fault", 0) >= 1


def test_fault_exhaustion_and_backoff(setup):
    """Every decode dispatch dies: each request burns max_fault_retries+1
    faults with exponentially-spaced holds, then retires with
    finish_reason='fault'; slots and pages all return to the pool."""
    cfg, _ = setup
    eng = _engine(setup, paged=True, max_fault_retries=2,
                  fault_injector=lambda kind, seq:
                  "injected" if kind != "prefill" else None)
    s = Scheduler(eng)
    reqs = [s.submit(Request(p, _sp(max_new=8), id=i))
            for i, p in enumerate(_prompts(cfg, [16, 12]))]
    holds = {}
    while s.has_work:
        s.step()
        for r in s.waiting:
            if r.n_faults:
                holds.setdefault(r.id, []).append(
                    r.hold_until_step - s.n_steps)
        assert s.n_steps < 2000
    for r in reqs:
        assert r.finish_reason == "fault"
        assert r.n_faults == 3                   # budget 2 -> 3rd exhausts
        assert r.slot is None
    # exponential backoff really spaced the retries: the second fault's
    # 2-step hold is still pending a full step after it was charged
    # (holds are sampled post-increment, so a value of k means k more
    # rounds before the request is eligible again)
    assert any(max(h) >= 1 for h in holds.values())
    from repro.serve import RetryBudget
    rb = RetryBudget(max_retries=3)
    assert [rb.record_fault("x") for _ in range(4)] == [1, 2, 4, None]
    rb.clear("x")
    assert rb.n_faults("x") == 0
    assert s.pool.n_free == s.pool.n_slots
    assert s.pool.check() if hasattr(s.pool, "check") else True
    rep = s.metrics.report()
    assert rep["finish_reasons"]["fault"] == 2
    assert rep["fault_requests"] >= 6


def test_injector_none_is_inert(setup):
    """No injector: the fault machinery adds nothing — outputs match a
    plain engine's and the poisoned-token guard never arms."""
    cfg, _ = setup
    prompts = _prompts(cfg, [16, 12])
    plain = Scheduler(_engine(setup))
    hooked = Scheduler(_engine(setup, fault_injector=None))
    for s in (plain, hooked):
        for i, p in enumerate(prompts):
            s.submit(Request(p, _sp(max_new=8), id=i))
        s.run(max_steps=500)
    assert _outputs(plain) == _outputs(hooked)
    assert not hooked._ft_check
    assert hooked.metrics.n_fault_events == 0


# ---------------------------------------------------------------------------
# Metrics accounting
# ---------------------------------------------------------------------------
def test_accounting_identity_and_json_clean(setup):
    """Every submitted request lands in exactly one disjoint finish
    reason; the report round-trips RFC JSON; per-priority percentiles
    appear when more than one class was served."""
    cfg, _ = setup
    t = [0.0]
    engine = _engine(setup)
    s = Scheduler(engine, clock=lambda: t[0],
                  slo=SLOPolicy(max_waiting=3, protect_priority=0))
    rng = np.random.default_rng(3)
    for i in range(12):
        s.submit(Request(
            rng.integers(1, cfg.vocab, (8,)).astype(np.int32),
            _sp(max_new=4), priority=int(i % 2) * 5,
            ttft_deadline_s=4.0 if i % 3 == 0 else None))
        t[0] += 0.1
    while s.has_work:
        s.step()
        t[0] += 1.0
        assert s.n_steps < 500
    rep = s.metrics.report()
    assert json.loads(json.dumps(rep, allow_nan=False)) == rep
    disjoint = sum(v for k, v in rep["finish_reasons"].items()
                   if k != "preempted_resumed")
    assert disjoint == rep["n_requests"] == s.metrics.n_arrived == 12
    assert set(rep["queue_wait_p50_s"]) <= {"0", "5"}
    assert "per_priority" in rep
    for cls in rep["per_priority"].values():
        for k in cls:
            assert cls[k] is not None
