"""Pallas kernel validation (interpret=True on CPU) vs pure-jnp oracles.

Per the harness contract: every kernel sweeps shapes/dtypes and asserts
allclose against the ref.py oracle; the virtual-DSP kernel is BIT-exact
against the int64 packing oracle.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import PAPER_PARALLELISM, solve_lane_plan
from repro.kernels import ref
from repro.kernels.ops import quantized_matmul
from repro.kernels.packed_matmul import (
    packed_block_plan, packed_gemv, packed_matmul, packed_shapes_legal,
    w8a8_matmul,
)
from repro.kernels.xtramac_mac import virtual_dsp_multiply
from repro.quant.schemes import (
    effective_group, get_scheme, quantize_activations_int8, quantize_weights,
)

RNG = np.random.default_rng(7)


def _qw(scheme_name, k, n, scale=1.0):
    w = (RNG.normal(size=(k, n)) * scale).astype(np.float32)
    return w, quantize_weights(get_scheme(scheme_name), w)


# ---------------------------------------------------------------------------
# packed matmul / GEMV: scheme x shape sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", ["awq_int4", "mxfp4", "fp8"])
@pytest.mark.parametrize("m,k,n", [(1, 256, 128), (4, 512, 256), (128, 1024, 384),
                                   (8, 128, 128)])
def test_packed_matmul_vs_ref(scheme, m, k, n):
    _, qw = _qw(scheme, k, n)
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.bfloat16)
    got = packed_matmul(x, qw, bm=min(m, 8), bn=128, bk=256, interpret=True)
    want = ref.packed_matmul_ref(x, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=1e-3)


@pytest.mark.parametrize("bk,bn", [(128, 128), (256, 384), (1024, 128)])
def test_packed_matmul_block_sweep(bk, bn):
    """Result is block-shape invariant (same math, different tiling)."""
    _, qw = _qw("awq_int4", 1024, 384)
    x = jnp.asarray(RNG.normal(size=(4, 1024)), jnp.bfloat16)
    got = packed_matmul(x, qw, bm=4, bn=bn, bk=bk, interpret=True)
    want = ref.packed_matmul_ref(x, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=1e-3)


def test_packed_matmul_accuracy_vs_float():
    """Dequantized INT4 matmul tracks the fp32 matmul within quant error."""
    w, qw = _qw("awq_int4", 2048, 256)
    x = RNG.normal(size=(2, 2048)).astype(np.float32)
    got = np.asarray(packed_matmul(jnp.asarray(x, jnp.bfloat16), qw, interpret=True))
    exact = x @ w
    rel = np.abs(got - exact).max() / np.abs(exact).max()
    # 4-bit group-128 envelope: per-weight err ~ scale/sqrt(12), accumulated
    # over K=2048 as sqrt(K); relative-to-max ~0.15 for Gaussian data
    assert rel < 0.25, rel


def test_w8a8_exact_int32():
    """INT8 kernel accumulation is exact (integer adder path of the paper)."""
    w, qw = _qw("w8a8", 512, 256)
    x = RNG.normal(size=(16, 512)).astype(np.float32)
    x_codes, x_scale = quantize_activations_int8(jnp.asarray(x))
    got = w8a8_matmul(x_codes, x_scale, qw.packed, qw.scales,
                      bm=16, bn=128, bk=256, interpret=True)
    want = ref.w8a8_matmul_ref(x_codes, x_scale, qw.packed, qw.scales)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@pytest.mark.parametrize("scheme", ["awq_int4", "mxfp4", "fp8", "w8a8", "bf16"])
def test_quantized_matmul_dispatch(scheme):
    """Public entry point: kernel path == jnp path for every scheme."""
    _, qw = _qw(scheme, 256, 128)
    x = jnp.asarray(RNG.normal(size=(4, 256)), jnp.bfloat16)
    out_k = quantized_matmul(x, qw, use_kernel=True, interpret=True,
                             out_dtype=jnp.float32)
    out_j = quantized_matmul(x, qw, use_kernel=False, out_dtype=jnp.float32)
    # kernel path accumulates in f32 (fused dequant); the jnp fallback
    # dequantizes INTO bf16 (the paper's Stage-1 mapping) and emits bf16
    # dots — tolerance covers bf16 rounding over K=256
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_j),
                               rtol=2e-2, atol=0.1)


def test_quantized_matmul_batched_shape():
    _, qw = _qw("awq_int4", 256, 128)
    x = jnp.asarray(RNG.normal(size=(2, 3, 256)), jnp.bfloat16)
    out = quantized_matmul(x, qw, use_kernel=False)
    assert out.shape == (2, 3, 128) and out.dtype == jnp.bfloat16
    assert not np.isnan(np.asarray(out, dtype=np.float32)).any()


# ---------------------------------------------------------------------------
# deterministic differential suite: kernel == tiled oracle BITWISE
#
# tests/test_kernel_properties.py carries the hypothesis generalisation of
# these contracts; this section is the always-on deterministic pin (the
# container may not ship hypothesis) over irregular shapes: K not a
# multiple of the default bk, N not a multiple of bn, single-group K.
# ---------------------------------------------------------------------------
def _irregular_shapes(scheme_name):
    """(m, k, n) triples legal for the scheme but hostile to the tiling."""
    s = get_scheme(scheme_name)
    per = 32 // s.weight_bits
    g = s.group_size
    if g == -1:   # per-channel: only word alignment constrains K
        ks = [per * 3, per * 37]
    else:         # group-aligned, plus a single-group K < group
        ks = [g, g * 3, per * max(1, g // per - 1)]
    return [(m, k, n) for k in ks for n in (16, 48, 384) for m in (1, 8, 9, 33)]


@pytest.mark.parametrize("scheme", ["awq_int4", "mxfp4", "fp8"])
def test_packed_kernels_bitexact_vs_tiled_ref(scheme):
    """packed_gemv/packed_matmul == ref.packed_matmul_tiled_ref bitwise on
    every packed scheme over irregular shapes, and allclose to the plain
    dequantize-then-dot LUT oracle."""
    for m, k, n in _irregular_shapes(scheme):
        assert packed_shapes_legal(m, k, n, get_scheme(scheme)), (m, k, n)
        _, qw = _qw(scheme, k, n)
        x = jnp.asarray(RNG.normal(size=(m, k)), jnp.bfloat16)
        if m <= 8:   # the GEMV dispatch predicate in kernels/ops.py
            got = packed_gemv(x, qw, interpret=True)
            want = ref.packed_matmul_tiled_ref(x, qw, bm=m, bn=256, bk=1024)
        else:
            got = packed_matmul(x, qw, interpret=True)
            want = ref.packed_matmul_tiled_ref(x, qw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"{scheme} m={m} k={k} n={n}")
        lut = np.asarray(ref.packed_matmul_ref(x, qw))
        np.testing.assert_allclose(np.asarray(got), lut, rtol=2e-3, atol=1e-3)


@pytest.mark.parametrize("scheme", ["awq_int4", "mxfp4", "fp8"])
@pytest.mark.parametrize("bm,bn,bk", [(8, 16, 64), (32, 128, 512),
                                      (128, 512, 4096)])
def test_packed_block_plan_bitexact(scheme, bm, bn, bk):
    """Any requested block shape fits to the same legal plan in kernel and
    oracle — bitwise equal even when bk must shrink to a group boundary."""
    s = get_scheme(scheme)
    k = s.group_size * 3 if s.group_size > 0 else 4 * 60
    _, qw = _qw(scheme, k, 96)
    x = jnp.asarray(RNG.normal(size=(16, k)), jnp.bfloat16)
    got = packed_matmul(x, qw, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.packed_matmul_tiled_ref(x, qw, bm=bm, bn=bn, bk=bk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    fbm, fbn, fbk = packed_block_plan(16, k, 96, s, bm=bm, bn=bn, bk=bk)
    g = effective_group(s.group_size, k)
    assert 16 % fbm == 0 and 96 % fbn == 0 and k % fbk == 0
    assert fbk % min(g, fbk) == 0


@pytest.mark.parametrize("m,k,n", [(1, 4, 8), (20, 400, 312), (7, 52, 8)])
def test_w8a8_bitexact_irregular(m, k, n):
    """INT32 accumulation is associative, so the INT8 kernel stays bitwise
    equal to its oracle even on shapes the tiling has to pad around."""
    _, qw = _qw("w8a8", k, n)
    x_codes, x_scale = quantize_activations_int8(
        jnp.asarray(RNG.normal(size=(m, k)), jnp.float32))
    got = w8a8_matmul(x_codes, x_scale, qw.packed, qw.scales, interpret=True)
    want = ref.w8a8_matmul_ref(x_codes, x_scale, qw.packed, qw.scales)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)


@pytest.mark.parametrize("scheme", ["awq_int4", "mxfp4", "fp8"])
@pytest.mark.parametrize("tp", [2, 4])
def test_sharded_oracle_decomposition(scheme, tp):
    """sharded_packed_matmul_ref degenerates to the tiled oracle at tp=1
    and its N-sharded decomposition is bitwise equal to the whole."""
    s = get_scheme(scheme)
    k = s.group_size * 2 if s.group_size > 0 else 4 * 32
    _, qw = _qw(scheme, k, 128 * tp)
    x = jnp.asarray(RNG.normal(size=(2, k)), jnp.bfloat16)
    whole = np.asarray(ref.packed_matmul_tiled_ref(x, qw))
    trivial = np.asarray(ref.sharded_packed_matmul_ref(x, qw, tp=1, shard_dim=1))
    np.testing.assert_array_equal(trivial, whole)
    nshard = np.asarray(ref.sharded_packed_matmul_ref(x, qw, tp=tp, shard_dim=1))
    np.testing.assert_array_equal(nshard, whole)


# ---------------------------------------------------------------------------
# virtual-DSP kernel: bit-exact vs the int64 packing oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pair", sorted(PAPER_PARALLELISM))
def test_virtual_dsp_bitexact(pair):
    plan = solve_lane_plan(*pair, max_parallelism=4)
    n_a, n_b = len(plan.offsets_a), len(plan.offsets_b)
    t = 2048
    a = RNG.integers(0, plan.w_a and (1 << plan.w_a), size=(t, n_a), dtype=np.int64)
    b = RNG.integers(0, 1 << plan.w_b, size=(t, n_b), dtype=np.int64)
    got = np.asarray(virtual_dsp_multiply(a, b, plan, bt=512, interpret=True))
    want = ref.virtual_dsp_ref(plan, a, b)
    np.testing.assert_array_equal(got, want)


def test_virtual_dsp_max_magnitudes():
    """Boundary case: all lanes at max magnitude (full 45-bit product)."""
    plan = solve_lane_plan("bf16", "bf16", max_parallelism=4)
    n_a, n_b = len(plan.offsets_a), len(plan.offsets_b)
    a = np.full((256, n_a), (1 << plan.w_a) - 1, dtype=np.int64)
    b = np.full((256, n_b), (1 << plan.w_b) - 1, dtype=np.int64)
    got = np.asarray(virtual_dsp_multiply(a, b, plan, bt=256, interpret=True))
    want = ref.virtual_dsp_ref(plan, a, b)
    np.testing.assert_array_equal(got, want)
