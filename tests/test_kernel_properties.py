"""Hypothesis differential tests: Pallas kernels vs their jnp oracles.

The kernels are the serving hot path under a mesh (DESIGN.md §14), so this
suite is the property-based pin behind the deterministic sweeps in
``test_kernels.py``:

  * ``packed_matmul`` / ``packed_gemv`` are BIT-exact against
    ``ref.packed_matmul_tiled_ref`` — the oracle that decodes with the
    kernel's own arithmetic path (``decode_codes_arith``) and replays the
    kernel's exact grid — across every packed scheme and adversarial
    shapes: K not a multiple of the default bk, N not a multiple of bn,
    K splitting into several scale groups or exactly one;
  * ``w8a8_matmul`` is BIT-exact against ``ref.w8a8_matmul_ref`` (INT32
    accumulation is associative — no tiling caveat needed);
  * the same runs stay allclose to the plain dequantize-then-dot LUT
    oracle (``ref.packed_matmul_ref``) — the tiled oracle must not drift
    from the mathematical definition.

Everything runs interpret=True on CPU (the conftest platform pin).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.kernels import ref  # noqa: E402
from repro.kernels.packed_matmul import (  # noqa: E402
    packed_block_plan, packed_gemv, packed_matmul, packed_shapes_legal,
    w8a8_matmul,
)
from repro.quant.schemes import (  # noqa: E402
    SCHEMES, effective_group, get_scheme, quantize_activations_int8,
    quantize_weights,
)

PACKED_SCHEMES = sorted(n for n, s in SCHEMES.items() if s.packed)


def _draw_k(data, scheme):
    """A legal-but-irregular K: multiple of the packing word and of the
    effective scale group, deliberately NOT a multiple of the default
    bk=512 most of the time, and sometimes a single-group (K < group)
    layer like the smoke configs."""
    per = 32 // scheme.weight_bits
    group = scheme.group_size
    if group == -1:   # per-channel: word-aligned is the only constraint
        return per * data.draw(st.integers(3, 40))
    if data.draw(st.booleans()):
        return group * data.draw(st.integers(1, 5))        # group-aligned
    return per * data.draw(st.integers(1, group // per - 1))  # single group


@pytest.mark.parametrize("scheme_name", PACKED_SCHEMES)
@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_packed_kernels_bitexact_vs_tiled_ref(scheme_name, data):
    """Kernel == tiled oracle bitwise, for GEMV and matmul block plans,
    on irregular (M, K, N)."""
    scheme = get_scheme(scheme_name)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    k = _draw_k(data, scheme)
    n = data.draw(st.integers(1, 24)) * 16      # not always bn-aligned
    m = data.draw(st.sampled_from([1, 2, 3, 5, 8, 9, 16, 33]))
    assert packed_shapes_legal(m, k, n, scheme), (m, k, n)
    qw = quantize_weights(scheme, rng.standard_normal((k, n)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)

    if m <= 8:   # the dispatch predicate in kernels/ops.py
        got = packed_gemv(x, qw, interpret=True)
        want = ref.packed_matmul_tiled_ref(x, qw, bm=m, bn=256, bk=1024)
    else:
        got = packed_matmul(x, qw, interpret=True)
        want = ref.packed_matmul_tiled_ref(x, qw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the tiled oracle has not drifted from the mathematical result
    lut = np.asarray(ref.packed_matmul_ref(x, qw))
    np.testing.assert_allclose(np.asarray(got), lut, rtol=2e-3, atol=1e-3)


@pytest.mark.parametrize("scheme_name", PACKED_SCHEMES)
@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_block_plan_invariance_bitexact(scheme_name, data):
    """Kernel and oracle agree bitwise for ANY requested block shape —
    both fit the request to the same legal plan (``packed_block_plan``),
    including K blocks that must shrink to a group boundary."""
    scheme = get_scheme(scheme_name)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    k = _draw_k(data, scheme)
    n = 32 * data.draw(st.integers(1, 6))
    m = data.draw(st.sampled_from([4, 16]))
    bm = data.draw(st.sampled_from([8, 32, 128]))
    bn = data.draw(st.sampled_from([16, 128, 512]))
    bk = data.draw(st.sampled_from([64, 512, 4096]))
    qw = quantize_weights(scheme, rng.standard_normal((k, n)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
    got = packed_matmul(x, qw, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.packed_matmul_tiled_ref(x, qw, bm=bm, bn=bn, bk=bk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the fitted plan really respected the group/word quantum
    fbm, fbn, fbk = packed_block_plan(m, k, n, scheme, bm=bm, bn=bn, bk=bk)
    g = effective_group(scheme.group_size, k)
    assert m % fbm == 0 and n % fbn == 0 and k % fbk == 0
    assert fbk % min(g, fbk) == 0


@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_w8a8_bitexact_vs_ref(data):
    """INT8 x INT8 kernel == oracle bitwise on irregular shapes: INT32
    accumulation is exact, so even the tiling is allowed to differ."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    k = 4 * data.draw(st.integers(1, 100))
    n = data.draw(st.integers(1, 40)) * 8
    m = data.draw(st.integers(1, 20))
    qw = quantize_weights(get_scheme("w8a8"),
                          rng.standard_normal((k, n)).astype(np.float32))
    x_codes, x_scale = quantize_activations_int8(
        jnp.asarray(rng.standard_normal((m, k)), jnp.float32))
    got = w8a8_matmul(x_codes, x_scale, qw.packed, qw.scales, interpret=True)
    want = ref.w8a8_matmul_ref(x_codes, x_scale, qw.packed, qw.scales)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)


@pytest.mark.parametrize("scheme_name", PACKED_SCHEMES)
@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_sharded_oracle_decomposition_consistent(scheme_name, data):
    """``sharded_packed_matmul_ref`` at tp=1 degenerates to the tiled
    oracle exactly, and the N-sharded decomposition is bitwise equal to
    the unsharded oracle whenever N splits at a block boundary (the K
    loop per output column is untouched by an N split)."""
    scheme = get_scheme(scheme_name)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    k = _draw_k(data, scheme)
    tp = data.draw(st.sampled_from([2, 4]))
    n = 128 * tp
    m = data.draw(st.sampled_from([2, 16]))
    qw = quantize_weights(scheme, rng.standard_normal((k, n)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
    whole = np.asarray(ref.packed_matmul_tiled_ref(x, qw))
    trivial = np.asarray(ref.sharded_packed_matmul_ref(
        x, qw, tp=1, shard_dim=1))
    np.testing.assert_array_equal(trivial, whole)
    nshard = np.asarray(ref.sharded_packed_matmul_ref(
        x, qw, tp=tp, shard_dim=1))
    np.testing.assert_array_equal(nshard, whole)
