"""Hardware profiles for the analytical performance model."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FPGAProfile:
    name: str
    luts: int
    ffs: int
    dsps: int
    freq_mhz: float
    hbm_gbps: float
    power_w: float
    usable_fraction: float = 0.8   # P&R headroom (routing, shell)


@dataclasses.dataclass(frozen=True)
class GPUProfile:
    name: str
    hbm_gbps: float
    power_w: float


@dataclasses.dataclass(frozen=True)
class TPUProfile:
    name: str
    peak_bf16_tflops: float
    hbm_gbps: float
    ici_gbps_per_link: float
    hbm_gib: int


# paper §VI-D: AMD Alveo V80 (2.6M LUTs, 10,848 DSPs, 300 MHz, 810 GB/s HBM)
V80 = FPGAProfile("V80", luts=2_600_000, ffs=5_200_000, dsps=10_848,
                  freq_mhz=300.0, hbm_gbps=810.0, power_w=190.0)
# paper §V / §VI-C: Alveo U55c (32 HBM channels, 460 GB/s)
U55C = FPGAProfile("U55c", luts=1_304_000, ffs=2_607_000, dsps=9_024,
                   freq_mhz=300.0, hbm_gbps=460.0, power_w=85.0)
H100 = GPUProfile("H100-PCIe", hbm_gbps=2000.0, power_w=135.0)
TPU_V5E = TPUProfile("TPUv5e", peak_bf16_tflops=197.0, hbm_gbps=819.0,
                     ici_gbps_per_link=50.0, hbm_gib=16)
