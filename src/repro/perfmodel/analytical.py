"""Analytical end-to-end LLM inference simulator (paper §VI-D, Figs. 1/14).

Follows the framework of Chen et al. [7]: transformer decode is alternating
memory phases (weight streaming from HBM) and compute phases (MAC-array
limited), with idealized streaming and on-chip activation reuse:

    t_layer = max( weight_bytes / BW,  batch * MACs / (units * freq) )

The MAC-unit count is the resource-budget quotient over the *per-operation*
LUT/FF/DSP cost of the arithmetic unit — which is exactly where XtraMAC's
density advantage (Table IV/V) enters: same fabric, more MAC lanes.  The
baseline instantiates the AMD FP-Operator profiles; XtraMAC swaps in its
per-lane costs.  Everything else (checkpoint MAC counts, datatype split,
tiling) is held fixed, so Fig. 14's deltas isolate arithmetic-unit density.

MAC counting per decode token (context L):
  projections/FFN (quantized):  2 * N_proj_params   MACs  (scheme datatype)
  attention QK^T + PV (BF16):   2 * 2 * L * H * dh * n_layers
  MoE: only top-k (+shared) expert params are active.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

from repro.core.gemv_engine import GemvEngineConfig
from repro.core.resource_model import Resources, TABLE_IV, TABLE_V
from repro.models.transformer import ModelConfig
from .hardware import FPGAProfile, V80


# Every deployment must execute BOTH the scheme's quantized MACs
# (projections/FFN) and BF16 MACs (attention) at runtime.  The vendor
# baseline does this by SPATIAL REPLICATION (both datapaths instantiated
# per slot — Fig. 2b); XtraMAC shares ONE runtime-switching instance
# (Table III) whose lanes serve both phases.
#
# scheme -> (vendor per-slot = quant IP + BF16 IP,
#            xtramac switching instance, quant lanes, bf16 lanes)
from repro.core.resource_model import TABLE_III

_VENDOR_BF16 = TABLE_V["vendor"]["bf16"]                      # 220/310.5/1

# vendor slot / (quant lanes, bf16 lanes) per slot:
#  * FP-accumulate schemes: ONE upcast FP datapath (Table IV vendor row,
#    conversion module included) serves both phases at 1 lane each.
#  * W8A8 (INT32 accumulate): the FP operator cannot absorb INT8 — the
#    vendor deploys spatial replication (2-lane INT8 MAC + BF16 MAC).
_DEPLOY = {
    "awq_int4": (TABLE_IV[("int8", "bf16")][0], (1, 1),
                 TABLE_III["I:int4xbf16+bf16"], (2, 2)),
    "w8a8": (TABLE_V["vendor"]["int8"].scale(2) + _VENDOR_BF16, (2, 1),
             TABLE_III["II:int8xint8+int32|bf16"], (2, 2)),
    "fp8": (TABLE_IV[("fp8_e4m3", "bf16")][0], (1, 1),
            TABLE_III["III:fp8xfp8+bf16|bf16"], (4, 2)),
    "mxfp4": (TABLE_IV[("fp4_e2m1", "bf16")][0], (1, 1),
              TABLE_III["IV:fp4xbf16+bf16|bf16"], (2, 2)),
}

_SCHEME_WEIGHT_BITS = {"awq_int4": 4, "mxfp4": 4, "fp8": 8, "w8a8": 8,
                       "bf16": 16}


def gemv_engine_for(scheme: str, fpga: FPGAProfile = V80) -> GemvEngineConfig:
    """Datatype-adaptive MAC engine for ``scheme`` on ``fpga``: the
    channel-streaming GEMV model of ``core/gemv_engine.py`` (paper §VI-C)
    with the lane count set by the scheme's weight precision —
    ``N_MAC = channel_bits / (w_bits * P)`` — and the profile's HBM
    bandwidth and power.  A 4-bit scheme packs 4x the MAC lanes of bf16
    into the same channels, so pricing through this engine makes compute
    cost *per-datatype* rather than a flat MAC count at a fixed rate.
    The channel geometry (30 active 512-bit channels) is the paper's
    U55c layout; only bandwidth/power scale with the profile."""
    return GemvEngineConfig(
        hbm_bw_gbps=fpga.hbm_gbps, power_w=fpga.power_w,
        weight_bits=min(_SCHEME_WEIGHT_BITS[scheme], 16))


@functools.lru_cache(maxsize=64)
def _param_split(cfg: ModelConfig) -> Dict[str, float]:
    """Active parameter counts by role: {'proj': N, 'head': N} per layer sum.
    Memoized (ModelConfig is frozen/hashable): the serving profiler calls
    ``decode_latency`` once per distinct step shape and the abstract
    param-tree walk is the dominant cost of each call."""
    from repro.launch.roofline import model_params
    p = model_params(cfg)
    # embedding + lm_head stream once per token too, in bf16
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return {"proj": p["active"] - emb, "emb": float(emb)}


def mac_distribution(cfg: ModelConfig, scheme: str, context: int
                     ) -> Dict[str, float]:
    """Fig. 1: fraction of decode MACs per datatype combination."""
    split = _param_split(cfg)
    proj_macs = split["proj"] + split["emb"] * 0  # embeddings: lookup, no MAC
    lm_head_macs = cfg.vocab * cfg.d_model
    attn_macs = 2.0 * context * cfg.n_heads * cfg.head_dim * cfg.n_layers
    total = proj_macs + lm_head_macs + attn_macs
    combos = {
        "awq_int4": "INT4xBF16", "mxfp4": "FP4xBF16",
        "fp8": "FP8xFP8", "w8a8": "INT8xINT8", "bf16": "BF16xBF16",
    }
    quant_name = combos[scheme]
    dist = {quant_name: proj_macs / total}
    dist["BF16xBF16"] = dist.get("BF16xBF16", 0.0) + \
        (attn_macs + lm_head_macs) / total
    return dist


def mac_unit_budget(per_op: Resources, fpga: FPGAProfile) -> int:
    """How many MAC lanes the fabric budget supports."""
    lut_lim = fpga.usable_fraction * fpga.luts / max(per_op.lut, 1e-9)
    ff_lim = fpga.usable_fraction * fpga.ffs / max(per_op.ff, 1e-9)
    dsp_lim = fpga.usable_fraction * fpga.dsps / max(per_op.dsp, 1e-9)
    return int(min(lut_lim, ff_lim, dsp_lim))


def decode_latency(cfg: ModelConfig, scheme: str, *, batch: int, context: int,
                   design: str, fpga: FPGAProfile = V80,
                   kv_bytes_per_token: float = None,
                   engine_model: Optional[GemvEngineConfig] = None
                   ) -> Dict[str, float]:
    """One decode step latency under the two-phase streaming model.

    ``kv_bytes_per_token`` overrides the default bf16 KV storage cost
    (2 slabs x 2 B x Hk x dh x L per cached position) — quantized KV
    tiers (DESIGN.md §9) stream fewer bytes per context position, which
    is how the serving profiler (obs/profiler.py) prices a pool tier
    into the prediction.

    ``engine_model`` routes the compute phase through the channel-
    streaming GEMV engine (``gemv_engine_for``) instead of the fabric
    unit-budget tables: the quantized projections run at the engine's
    lane count for the scheme's weight bits, attention at the (4x
    sparser) bf16 lane count, and the memory phase is derated by the
    engine's measured HBM utilization.  This is the per-datatype MAC
    pricing the serving profiler joins against measurements; the Fig. 14
    vendor-vs-XtraMAC comparison keeps the table-budget path
    (``engine_model=None``) so its density deltas stay isolated.
    """
    split = _param_split(cfg)
    w_bits = _SCHEME_WEIGHT_BITS[scheme]
    weight_bytes = split["proj"] * w_bits / 8.0 + split["emb"] * 2.0
    # KV read for attention, grows with context (default: bf16 storage)
    if kv_bytes_per_token is None:
        kv_bytes_per_token = \
            2.0 * 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers
    kv_bytes = context * float(kv_bytes_per_token)
    bw = fpga.hbm_gbps * 1e9
    if engine_model is not None:
        bw *= engine_model.hbm_utilization
    t_mem = (weight_bytes + batch * kv_bytes) / bw

    proj_macs = split["proj"] + cfg.vocab * cfg.d_model
    attn_macs = 2.0 * context * cfg.n_heads * cfg.head_dim * cfg.n_layers
    if engine_model is not None:
        eng_q = dataclasses.replace(engine_model,
                                    weight_bits=min(w_bits, 16))
        eng_b = dataclasses.replace(engine_model, weight_bits=16)
        units_q, units_b = eng_q.macs_per_cycle, eng_b.macs_per_cycle
        t_compute = batch * (
            proj_macs / (units_q * eng_q.freq_hz)
            + attn_macs / (units_b * eng_b.freq_hz))
    else:
        vendor_slot, (vq, vb), xtra_inst, (xq, xb) = _DEPLOY[scheme]
        if design == "vendor":
            slots = mac_unit_budget(vendor_slot, fpga)
            units_q, units_b = slots * vq, slots * vb
        else:
            slots = mac_unit_budget(xtra_inst, fpga)
            units_q, units_b = slots * xq, slots * xb
        freq = fpga.freq_mhz * 1e6
        t_compute = batch * (proj_macs / (units_q * freq)
                             + attn_macs / (units_b * freq))
    return {"t_mem_s": t_mem, "t_compute_s": t_compute,
            "t_total_s": max(t_mem, t_compute),
            "bound": "memory" if t_mem >= t_compute else "compute",
            "units_quant": units_q, "units_bf16": units_b}


def spec_round_latency(cfg: ModelConfig, *, k: int, batch: int, context: int,
                       design: str = "xtramac",
                       draft_scheme: str = "awq_int4",
                       target_scheme: str = "w8a8",
                       acceptance: float = 0.7,
                       kv_bytes_per_token: float = None,
                       draft_kv_bytes_per_token: float = None,
                       fpga: FPGAProfile = V80,
                       use_engine_model: bool = True) -> Dict[str, float]:
    """Price one speculative decode round (DESIGN.md §17): K draft steps
    at the aggressive scheme/KV tier plus ONE (K+1)-position verify
    dispatch at the target precision — the draft/verify pair the serving
    scheduler issues, so SLO admission can stay honest about speculative
    throughput.

    The verify dispatch streams the target weights and KV exactly ONCE
    (its memory phase equals a plain decode step's) while its compute
    phase covers K+1 positions per row — so the window rides along at
    ~one plain step's cost exactly where the MAC array has idle compute
    headroom (the Table-III/IV slot deployment at small batch), and
    costs linearly per position on the channel-streaming GEMV engine,
    whose lanes are throughput-matched to HBM by construction.  The
    model reports whichever bound holds; speculation wins wall clock
    only in the headroom regime.  (This prices the DEPLOYMENT's
    single-weight-stream verify; the host engine scores the window as
    chained exact decode steps inside the one dispatch for bit-identity
    — see ``serve/engine.py`` ``verify_slots``.)

    ``acceptance`` is the per-position draft acceptance rate a; expected
    emitted tokens per row per round is the geometric sum
    E = (1 - a^(K+1)) / (1 - a)  (every round emits at least the verify's
    own position-0 sample).  Returns the round wall, the effective
    per-token latency t_round / E, the plain-decode per-token latency at
    the target precision, and their ratio (> 1 = speculation wins)."""
    assert k >= 1 and 0.0 <= acceptance < 1.0
    eng_d = gemv_engine_for(draft_scheme, fpga) if use_engine_model else None
    eng_t = gemv_engine_for(target_scheme, fpga) if use_engine_model else None
    draft = decode_latency(
        cfg, draft_scheme, batch=batch, context=context, design=design,
        fpga=fpga, kv_bytes_per_token=draft_kv_bytes_per_token,
        engine_model=eng_d)
    target = decode_latency(
        cfg, target_scheme, batch=batch, context=context, design=design,
        fpga=fpga, kv_bytes_per_token=kv_bytes_per_token,
        engine_model=eng_t)
    t_draft = k * draft["t_total_s"]
    t_verify = max(target["t_mem_s"], (k + 1) * target["t_compute_s"])
    t_round = t_draft + t_verify
    a = acceptance
    e_tokens = (1.0 - a ** (k + 1)) / (1.0 - a) if a > 0 else 1.0
    t_plain = target["t_total_s"]
    return {
        "t_draft_s": t_draft, "t_verify_s": t_verify,
        "t_round_s": t_round,
        "expected_tokens_per_row": e_tokens,
        "t_per_token_s": t_round / e_tokens,
        "t_plain_per_token_s": t_plain,
        "speedup": t_plain / (t_round / e_tokens),
        "verify_bound": "memory"
        if target["t_mem_s"] >= (k + 1) * target["t_compute_s"]
        else "compute",
    }


def fig14_simulation(context: int = 512, batches=(1, 8, 32),
                     fpga: FPGAProfile = V80) -> Dict:
    """Reproduce Fig. 14: per-checkpoint decode latency, vendor vs XtraMAC."""
    from repro.configs.xtramac_paper import PAPER_CHECKPOINTS
    rows = {}
    for name, (cfg, scheme) in PAPER_CHECKPOINTS.items():
        per_batch = {}
        for b in batches:
            v = decode_latency(cfg, scheme, batch=b, context=context,
                               design="vendor", fpga=fpga)
            x = decode_latency(cfg, scheme, batch=b, context=context,
                               design="xtramac", fpga=fpga)
            per_batch[b] = {
                "vendor_ms": v["t_total_s"] * 1e3,
                "xtramac_ms": x["t_total_s"] * 1e3,
                "speedup": v["t_total_s"] / x["t_total_s"],
                "bound": x["bound"],
            }
        rows[name] = per_batch
    return rows
