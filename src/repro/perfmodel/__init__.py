from .hardware import FPGAProfile, GPUProfile, TPUProfile, U55C, V80, H100, TPU_V5E
from .analytical import (decode_latency, fig14_simulation, gemv_engine_for,
                         mac_distribution, mac_unit_budget)

__all__ = ["FPGAProfile", "GPUProfile", "TPUProfile", "U55C", "V80", "H100",
           "TPU_V5E", "decode_latency", "fig14_simulation", "gemv_engine_for",
           "mac_distribution", "mac_unit_budget"]
