"""Mixed-precision serving engine: step-level primitives over a KV pool.

This is the system-level consumer of the paper's technique: checkpoint
weights are stored in the per-layer mixed-precision plan (projections /
experts in INT4/FP8/FP4/INT8 packed codes -> the XtraMAC-style MACs;
attention in BF16), and the engine exposes jitted steps over a
persistent cache — the per-tile "datatype control signal" of the paper's
GEMV engine becomes the static per-layer scheme in the compiled program
(DESIGN.md §2: JAX traces static dtypes, so runtime switching is realized
at layer granularity, which is the granularity the paper's own workloads
switch at).

Step primitives (DESIGN.md §7, §11):
  * ``prefill_chunk_into_slot`` — write one fixed-size chunk of one
    request's prompt into its KV pool slot (compiles once; prompts of any
    length are a host-side loop of chunks over a once-padded prompt).
  * ``prefill_into_slots``     — convenience loop of the above over whole
    prompts; returns last-true-position logits per request.
  * ``decode_slots``           — one decode step for ALL pool slots at
    once, each row writing/attending at its own length (per-row
    ``cache_index``), with sampling FUSED into the jit: per-slot keys and
    temperatures go in, only [n_slots] int32 token ids come out — the
    [n_slots, vocab] logits never leave the device.  Inactive slots ride
    along and are masked host-side; their garbage write lands exactly
    where the slot's next real write goes, so it is always overwritten
    before it could be attended.  (``decode_slots_with_logits`` keeps the
    logits-returning variant for score / first-token / diagnostic paths.)
  * ``decode_burst``           — K consecutive decode steps as ONE jitted
    ``lax.scan``: cache (donated), tokens, lengths and per-slot stop masks
    are threaded through the scan carry, a precomputed [K, n_slots, 2] key
    schedule rides the scan xs, and rows that retire mid-burst (EOS /
    max-new-tokens / capacity) freeze in place.  One dispatch and ONE host
    sync amortize over K generated tokens (DESIGN.md §11) — the software
    analogue of the paper's II=1 pipeline: the decode loop streams without
    per-token host intervention.

Both the continuous-batching ``Scheduler`` and the one-shot ``generate()``
(kept as a thin wrapper: it submits every row to a private scheduler and
drains it) drive these same primitives, so the two paths cannot drift —
greedy one-shot output IS scheduler output by construction.  Families
without a sliceable KV cache (ssm / hybrid / audio / vlm) keep the legacy
static-batch loop.

**Sharded serving** (DESIGN.md §10): with ``ServeConfig.mesh`` set to a
``dp x tp`` device mesh (axes 'data' x 'model'), the pool step primitives
become mesh-aware jits with explicit in/out shardings — params via
``partitioning.param_specs`` (packed code words and group scales shard
along N on the model axis; K only where the split lands on word AND
scale-group boundaries), the pool cache via
``partitioning.serve_pool_pspec`` (slots on 'data', KV heads on 'model').
The scheduler stays host-side and byte-identical: it sees the same
alloc/free/lengths interface whether the slab under it lives on one chip
or thirty-two.  Buffer donation survives because the cache's in- and
out-shardings are pinned equal.

**Precision policy / runtime tiers** (DESIGN.md §12): the engine's whole
precision configuration is ONE ``quant.policy.PrecisionPolicy``
(``ServeConfig(policy=...)``; legacy ``kv_dtype=`` / ``plan=`` are thin
adapters emitting the equivalent policy, bit-identity pinned) — weight
schemes resolve the param shardings, ``policy.kv`` is the default KV
tier, ``policy.kernel`` drives kernel dispatch via
``kernels.ops.declare_execution``.  Every step primitive takes the pool
it operates on, and ``new_pool(kv_dtype=...)`` builds pools at any tier,
so one engine serves bf16/fp8/int8-KV traffic concurrently: compiled
steps are cached per ``(n_slots, capacity, tier)`` and the scheduler
cohorts decode batches per tier — the software analogue of XtraMAC's
runtime datatype switch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models.common import QLinear
from repro.quant.policy import PrecisionPolicy, validate_kv_tier
from repro.runtime.fault_tolerance import StepFault

from .kv_pool import (KVCachePool, PagedKVPool, POOLABLE_FAMILIES,
                      pages_for_budget, slots_for_budget)
from .sampling import sample_rows


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512        # per-slot KV capacity (prompt + new tokens)
    temperature: float = 0.0
    eos_id: int = -1          # -1: never stop early
    # LEGACY adapter for the pool storage dtype: 'bf16' (or jnp.bfloat16)
    # for plain slabs, 'int8' / 'fp8' for quantized packed-codes + scales
    # slabs (DESIGN.md §9).  The canonical spelling is ``policy=``; giving
    # kv_dtype emits the equivalent policy (bit-identity pinned), and
    # after construction this field always reads the policy's canonical
    # tier name.  Unknown names — and raw dtypes no tier can honor —
    # raise HERE, not at first pool build.
    kv_dtype: Any = None
    n_slots: int = 8          # KV pool width = decode batch (static shape)
    prefill_chunk: int = 16   # chunked-prefill granularity (static shape)
    # upper bound on the decode-burst length K (DESIGN.md §11): the
    # scheduler plans K per round (clamped to 1 while admission or a
    # prefill is pending) and rounds it down to a power of two, so at most
    # log2(max_burst) burst variants ever compile.  1 disables bursts.
    max_burst: int = 8
    # optional cache-memory budget: when set, ``new_pool()`` derives the
    # slot count from KV bytes/token at the pool's tier instead of taking
    # ``n_slots`` — the knob that turns cache quantization into served
    # concurrency
    cache_budget_bytes: Optional[int] = None
    # paged KV pool (DESIGN.md §15): ``new_pool()`` builds a PagedKVPool —
    # per-slot page tables over a shared refcounted page arena with
    # copy-on-write prefix sharing — instead of the fixed slab.  Output is
    # bit-identical; capacity accounting becomes page-granular.
    paged: bool = False
    # arena page size in cache positions; 0 = prefill_chunk (pages are
    # chunk-aligned by construction — any explicit value must be a
    # multiple of prefill_chunk)
    page_size: int = 0
    # optional jax.sharding.Mesh ('data' x 'model' axes): shard params and
    # the KV pool across it (DESIGN.md §10).  None = single-device jits.
    mesh: Any = None
    # the unified precision contract (DESIGN.md §12): weight schemes, the
    # default KV tier and kernel dispatch as ONE declarative object.  None
    # derives a policy from the legacy knobs above.
    policy: Optional[PrecisionPolicy] = None
    # fault-injection hook (DESIGN.md §16): a callable
    # ``(kind, seq) -> Optional[str]`` consulted once per engine dispatch
    # (kind in {'prefill', 'decode', 'burst', 'verify'}; ``seq`` is the
    # monotone dispatch counter, so a test or bench can kill step #7
    # deterministically).  Return None for no fault; 'nan' to poison the
    # dispatch's sampled tokens (exercises the scheduler's poisoned-output
    # detector); any other string to raise ``StepFault(tag)`` in place of
    # the dispatch (lost shard / failed launch).  None disables the hook
    # at zero cost.
    fault_injector: Any = None
    # bounded retry: how many step faults one request may survive (each
    # costs a preempt-and-requeue with exponential backoff) before the
    # scheduler retires it with finish_reason='fault'
    max_fault_retries: int = 3

    def __post_init__(self):
        pol = self.policy
        if isinstance(pol, dict):
            pol = PrecisionPolicy.from_dict(pol)
        if pol is None:
            # legacy adapter: kv_dtype -> the equivalent policy.  Eager:
            # an unknown tier name raises at ServeConfig construction.
            pol = PrecisionPolicy.from_legacy(kv_dtype=self.kv_dtype)
        elif self.kv_dtype is not None \
                and validate_kv_tier(self.kv_dtype) != pol.kv:
            raise ValueError(
                f"ServeConfig: kv_dtype={self.kv_dtype!r} contradicts "
                f"policy.kv={pol.kv!r} — drop kv_dtype (the policy is "
                "the single source of truth)")
        object.__setattr__(self, "policy", pol)
        object.__setattr__(self, "kv_dtype", pol.kv)


def _has_qlinear(params) -> bool:
    """Whether the parameter tree carries packed quantized leaves (decides
    the ``quantize=`` parameterization of the matching spec tree)."""
    found = []
    jax.tree_util.tree_map(
        lambda x: found.append(isinstance(x, QLinear)), params,
        is_leaf=lambda x: isinstance(x, QLinear))
    return any(found)


# Families served through the slot pool / scheduler; VLM is poolable but its
# per-request patch inputs are not threaded through Request yet.
SCHEDULABLE_FAMILIES = ("dense", "moe")


class ServingEngine:
    def __init__(self, cfg: T.ModelConfig, params, serve_cfg: ServeConfig, *,
                 plan: Optional[Dict[str, str]] = None):
        """``plan``: LEGACY adapter for the per-name scheme overrides the
        checkpoint was built with (QuantMaker plan) — folded into the
        serve config's ``PrecisionPolicy`` as exact-name patterns, so the
        sharding spec tree matches the parameter tree leaf for leaf.  The
        canonical spelling is ``ServeConfig(policy=...)``."""
        self.cfg = cfg
        self.scfg = serve_cfg
        self.mesh = serve_cfg.mesh
        # the engine's effective precision contract: serve-config policy
        # with any legacy plan folded in, validated EAGERLY against the
        # model config and mesh (unknown schemes, group/K mismatches,
        # quantized-KV-on-MLA all raise here — not at first pool build or
        # first trace)
        self.policy = serve_cfg.policy.with_plan(plan or {}) \
            .validate_for(cfg, self.mesh)
        self._plan = self.policy.resolved_plan(cfg)
        self._param_shardings = None
        self._sharded_steps: Dict = {}   # (n_slots, capacity, tier) -> jits
        # monotone dispatch counter consulted by the fault-injection hook
        # (DESIGN.md §16) — advances only when an injector is armed, so
        # the disabled path costs nothing and dispatch numbering is
        # deterministic for a given workload
        self._fault_seq = 0

        # The execution policy (kernel mode + mesh + per-leaf kernel
        # sharding specs) is declared before every step call (not just
        # here) so lazily-traced jits always see THIS engine's kernel mode
        # and mesh, regardless of what other engines were constructed in
        # between.  Under a multi-device mesh the Pallas kernels run
        # shard_map'd over it (DESIGN.md §14) — the weight-spec map tells
        # the dispatch where each packed leaf's codes and scales live.
        self._partitioned = self.mesh is not None and self.mesh.size > 1
        self._kernel_weight_specs = None
        if self.mesh is not None:
            from repro.runtime import partitioning as PT
            if self._partitioned and _has_qlinear(params):
                self._kernel_weight_specs = PT.serve_weight_kernel_specs(
                    cfg, self.mesh, plan=self._plan)
            self._declare_execution()
            pspec = PT.param_specs(cfg, self.mesh, train=False,
                                   quantize=_has_qlinear(params),
                                   plan=self._plan)
            if jax.tree_util.tree_structure(params) != \
                    jax.tree_util.tree_structure(
                        pspec, is_leaf=lambda x: isinstance(x, P)):
                raise ValueError(
                    "parameter tree does not match its sharding spec tree — "
                    "params built with a QuantMaker plan must pass the same "
                    "plan to ServingEngine(..., plan=...) or declare it in "
                    "ServeConfig(policy=...) weight patterns")
            self._param_shardings = PT.named(self.mesh, pspec)
            params = jax.device_put(params, self._param_shardings)
        self.params = params

        mcfg = cfg

        # ---- legacy one-shot steps (static batch, lockstep lengths) ----
        @jax.jit
        def prefill(params, batch, cache):
            logits, _, cache = T.forward(mcfg, params, batch, cache=cache,
                                         cache_index=0, mode="prefill")
            return logits[:, -1], cache

        @jax.jit
        def decode(params, tokens, cache, index):
            logits, _, cache = T.forward(mcfg, params, {"tokens": tokens},
                                         cache=cache, cache_index=index,
                                         mode="decode")
            return logits[:, -1], cache

        # ---- pool-based steps (continuous batching) --------------------
        # the pool cache is donated: the caller rebinds pool.cache to the
        # result immediately, and without donation every token step would
        # materialize a second copy of the whole [L, n_slots, capacity, ...]
        # tree (the dominant memory/memcpy cost of the serving loop).
        # Under a mesh the bare jits below are replaced per pool geometry by
        # ``_steps_for`` with explicit in/out shardings.
        def prefill_chunk(params, tokens, cache, slot, offset, with_logits):
            """tokens [1, C] into pool slot ``slot`` at position ``offset``;
            returns ([C, V] logits, updated pool cache).  ``with_logits=False``
            (non-final chunks, whose logits the caller discards) returns None
            logits — XLA dead-code-eliminates the whole lm-head matmul."""
            slot_cache = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
                cache)
            logits, _, slot_cache = T.forward(
                mcfg, params, {"tokens": tokens}, cache=slot_cache,
                cache_index=offset, mode="prefill_chunk")
            cache = jax.tree_util.tree_map(
                lambda pool, upd: jax.lax.dynamic_update_slice_in_dim(
                    pool, upd, slot, axis=1),
                cache, slot_cache)
            return (logits[0] if with_logits else None), cache

        def decode_slots_logits(params, tokens, cache, lengths):
            """tokens [n_slots, 1]; row i writes/attends at lengths[i].
            Returns the full [n_slots, V] logits — the diagnostic / scoring
            variant; the serving hot path uses the fused ``decode_slots``."""
            logits, _, cache = T.forward(mcfg, params, {"tokens": tokens},
                                         cache=cache, cache_index=lengths,
                                         mode="decode")
            return logits[:, -1], cache

        def decode_slots(params, tokens, cache, lengths, keys, temps):
            """Fused decode + sample: one step for all slots, sampling on
            device (keys [n_slots, 2], temps [n_slots]).  Only the
            [n_slots] int32 sampled ids cross to the host — the logits are
            dead past ``sample_rows`` and never materialize off-device."""
            logits, _, cache = T.forward(mcfg, params, {"tokens": tokens},
                                         cache=cache, cache_index=lengths,
                                         mode="decode")
            return sample_rows(logits[:, -1], keys, temps), cache

        def decode_burst(params, cache, tokens, lengths, active, rem, keys,
                         temps, eos_ids, max_len):
            """K consecutive fused decode steps as one ``lax.scan``
            (DESIGN.md §11).  K is the leading dim of ``keys``
            [K, n_slots, 2] — the per-(request, step) key schedule the host
            precomputed from each request's ``step_key`` sequence, which is
            what makes a burst bit-identical to K single steps.

            Carry: (cache, tokens, lengths, active, rem).  Per step, active
            rows commit their input token's KV at ``lengths`` (then advance
            it), sample the next token, and re-evaluate their stop mask:
              * EOS       — sampled id == eos_ids[row] (>= 0),
              * length    — rem (tokens the row may still emit) hits 0,
              * capacity  — the committed length would exceed the slot
                            (mirrors the scheduler's defensive retire).
            Frozen rows ride along exactly like inactive slots: their
            lengths stop advancing, so their garbage writes land where the
            slot's next real write goes.  ys = (sampled [K, n_slots],
            was-active [K, n_slots]) — the host emits token (t, i) iff
            valid[t, i], in step-major order, reproducing the single-step
            emission sequence."""
            def step(carry, step_keys):
                cache, tokens, lengths, active, rem = carry
                logits, _, cache = T.forward(
                    mcfg, params, {"tokens": tokens[:, None]}, cache=cache,
                    cache_index=lengths, mode="decode")
                sampled = sample_rows(logits[:, -1], step_keys, temps)
                act = active.astype(jnp.int32)
                lengths = lengths + act
                rem = rem - act
                stop_eos = (eos_ids >= 0) & (sampled == eos_ids)
                still = active & ~stop_eos & (rem > 0) \
                    & (lengths < max_len - 1)
                tokens = jnp.where(active, sampled, tokens)
                return (cache, tokens, lengths, still, rem), (sampled, active)
            (cache, _, _, _, _), (toks, valid) = jax.lax.scan(
                step, (cache, tokens, lengths, active, rem), keys)
            return cache, toks, valid

        def verify_slots(params, tokens, cache, lengths, key_schedule,
                         temps):
            """Speculative verify (DESIGN.md §17): score ALL K+1 window
            positions of every row in ONE dispatch.  ``tokens``
            [n_slots, S] is each row's [last_committed, d_1..d_K] window;
            position j's logits predict token n_generated+j and are
            sampled with ``key_schedule[j]`` — the SAME per-(id,
            n_generated) keys a plain decode step would use — so the
            target's own samples g_0..g_K come back [S, n_slots] and the
            host accepts the longest prefix with g_{j-1} == d_j.

            The window runs as a ``lax.scan`` of EXACT plain decode
            steps *inside* the dispatch: step j is byte-for-byte the
            ``decode_burst`` step body (same s==1 forward, same KV
            write, same ``sample_rows``), which is the bit-identity
            argument ON EVERY GEOMETRY — under a mesh the s==1 steps hit
            the same Pallas kernels (fused decode attention, packed
            matvec) as the non-speculative scheduler, whereas a parallel
            S-wide scoring pass routes to bitwise-DIFFERENT kernels
            (einsum attention, the matmul block plan) whose last-bit
            logit differences temperature sampling amplifies into token
            flips.  One dispatch either way: the measured economics
            (dispatches/host-syncs per token) are the scan's; the
            single-weight-stream verify is the *priced deployment model*
            (``perfmodel.spec_round_latency``), not the host execution.
            Length commit/rollback stays host-side (the engine wrapper
            does NOT advance ``pool.lengths``)."""
            def step(carry, xs):
                cache, idx = carry
                tok, keys = xs
                logits, _, cache = T.forward(
                    mcfg, params, {"tokens": tok[:, None]}, cache=cache,
                    cache_index=idx, mode="decode")
                return ((cache, idx + 1),
                        sample_rows(logits[:, -1], keys, temps))
            (cache, _), sampled = jax.lax.scan(
                step, (cache, lengths), (tokens.T, key_schedule))
            return sampled, cache

        # ---- paged-pool steps (DESIGN.md §15) --------------------------
        # Same step semantics over a PagedKVPool: ``cache`` is the page
        # arena [L, n_pages, page_size, ...] and each step additionally
        # takes the page table mapping slots to arena pages.  Inside the
        # step, every attention layer gathers its slots' virtual slabs
        # from the arena, runs the UNCHANGED slab attention math (einsum
        # oracle or Pallas decode kernel), and scatters the updated slab
        # back through the table — which is the paged pool's bit-identity
        # contract: identical bytes in the identical [slot, pos] layout at
        # every attended position.  The arena is donated exactly like the
        # slab; the table is a tiny int32 array rebuilt from host state
        # per dispatch (page mappings change between steps, not within).
        def prefill_chunk_paged(params, tokens, cache, table_row, offset,
                                with_logits):
            """tokens [1, C] through the single slot whose page-table row
            is ``table_row`` [1, pages_per_slot].  The whole arena rides
            through (pages of one slot are scattered across it — there is
            no contiguous sub-slab to slice out), but only this slot's
            virtual slab is gathered/computed/scattered inside."""
            logits, _, cache = T.forward(
                mcfg, params, {"tokens": tokens}, cache=cache,
                cache_index=offset, mode="prefill_chunk",
                page_table=table_row)
            return (logits[0] if with_logits else None), cache

        def decode_slots_logits_paged(params, tokens, cache, lengths, table):
            logits, _, cache = T.forward(mcfg, params, {"tokens": tokens},
                                         cache=cache, cache_index=lengths,
                                         mode="decode", page_table=table)
            return logits[:, -1], cache

        def decode_slots_paged(params, tokens, cache, lengths, keys, temps,
                               table):
            logits, _, cache = T.forward(mcfg, params, {"tokens": tokens},
                                         cache=cache, cache_index=lengths,
                                         mode="decode", page_table=table)
            return sample_rows(logits[:, -1], keys, temps), cache

        def decode_burst_paged(params, cache, tokens, lengths, active, rem,
                               keys, temps, eos_ids, max_len, table):
            """Paged twin of ``decode_burst``: the page table is loop-
            invariant across the K scanned steps (the scheduler pins every
            written page via ``ensure_decode`` BEFORE dispatch), so the
            scan body closes over it and the carry stays identical to the
            slab burst's."""
            def step(carry, step_keys):
                cache, tokens, lengths, active, rem = carry
                logits, _, cache = T.forward(
                    mcfg, params, {"tokens": tokens[:, None]}, cache=cache,
                    cache_index=lengths, mode="decode", page_table=table)
                sampled = sample_rows(logits[:, -1], step_keys, temps)
                act = active.astype(jnp.int32)
                lengths = lengths + act
                rem = rem - act
                stop_eos = (eos_ids >= 0) & (sampled == eos_ids)
                still = active & ~stop_eos & (rem > 0) \
                    & (lengths < max_len - 1)
                tokens = jnp.where(active, sampled, tokens)
                return (cache, tokens, lengths, still, rem), (sampled, active)
            (cache, _, _, _, _), (toks, valid) = jax.lax.scan(
                step, (cache, tokens, lengths, active, rem), keys)
            return cache, toks, valid

        def verify_slots_paged(params, tokens, cache, lengths, key_schedule,
                               temps, table):
            """Paged twin of ``verify_slots``: the caller pins the whole
            S-wide write window (``ensure_decode(slots, S, rems)``) before
            dispatch, so the table is invariant across the window's
            in-dispatch scan steps."""
            def step(carry, xs):
                cache, idx = carry
                tok, keys = xs
                logits, _, cache = T.forward(
                    mcfg, params, {"tokens": tok[:, None]}, cache=cache,
                    cache_index=idx, mode="decode", page_table=table)
                return ((cache, idx + 1),
                        sample_rows(logits[:, -1], keys, temps))
            (cache, _), sampled = jax.lax.scan(
                step, (cache, lengths), (tokens.T, key_schedule))
            return sampled, cache

        self._prefill = prefill
        self._decode = decode
        self._prefill_chunk_fn = prefill_chunk
        self._decode_slots_fn = decode_slots
        self._decode_slots_logits_fn = decode_slots_logits
        self._decode_burst_fn = decode_burst
        # single-device jits (mesh=None path; also the tracing baseline).
        # The burst jit re-lowers per distinct K (the scan length is part
        # of the traced shape); the scheduler's power-of-two K policy
        # bounds that to log2(max_burst) variants.
        self._prefill_chunk = jax.jit(prefill_chunk, donate_argnums=(2,),
                                      static_argnums=(5,))
        self._decode_slots = jax.jit(decode_slots, donate_argnums=(2,))
        self._decode_slots_logits = jax.jit(decode_slots_logits,
                                            donate_argnums=(2,))
        self._decode_burst = jax.jit(decode_burst, donate_argnums=(1,))
        self._verify_slots_fn = verify_slots
        # the verify jit re-lowers per distinct window width S = K+1 (the
        # planner's power-of-two K ladder bounds that to log2(max_burst)
        # variants, same argument as the burst jit)
        self._verify_slots = jax.jit(verify_slots, donate_argnums=(2,))
        self._prefill_chunk_paged_fn = prefill_chunk_paged
        self._decode_slots_paged_fn = decode_slots_paged
        self._decode_slots_logits_paged_fn = decode_slots_logits_paged
        self._decode_burst_paged_fn = decode_burst_paged
        self._prefill_chunk_paged = jax.jit(
            prefill_chunk_paged, donate_argnums=(2,), static_argnums=(5,))
        self._decode_slots_paged = jax.jit(decode_slots_paged,
                                           donate_argnums=(2,))
        self._decode_slots_logits_paged = jax.jit(decode_slots_logits_paged,
                                                  donate_argnums=(2,))
        self._decode_burst_paged = jax.jit(decode_burst_paged,
                                           donate_argnums=(1,))
        self._verify_slots_paged_fn = verify_slots_paged
        self._verify_slots_paged = jax.jit(verify_slots_paged,
                                           donate_argnums=(2,))

    # ------------------------------------------------------------------
    # Mesh-aware step construction (DESIGN.md §10)
    # ------------------------------------------------------------------
    def _declare_execution(self) -> None:
        """Declare this engine's execution policy (kernel mode + mesh +
        per-leaf kernel sharding specs) to ``kernels.ops``.  Called before
        every step invocation: jits trace on their first call, and the
        kernel dispatch is baked in at trace time.  ``kernel='auto'``
        leaves the process kernel mode untouched (backend default /
        whatever a driver pinned — under a mesh the default resolves to
        the shard_map'd pallas path); 'jnp' and 'pallas' pin it.  A
        single-device mesh declares as meshless (plain kernels; the
        shardings are trivial)."""
        from repro.kernels.ops import declare_execution
        declare_execution(
            kernel=None if self.policy.kernel == "auto" else self.policy.kernel,
            mesh=self.mesh if self._partitioned else None,
            weight_specs=self._kernel_weight_specs)

    @property
    def topology(self) -> Optional[Dict[str, int]]:
        """{'n_devices', 'dp', 'tp'} under a mesh, else None."""
        if self.mesh is None:
            return None
        tp = int(self.mesh.shape.get("model", 1))
        return {"n_devices": int(self.mesh.size),
                "dp": int(self.mesh.size) // tp, "tp": tp}

    def pool_shardings(self, pool: KVCachePool):
        """NamedSharding tree for ``pool``'s cache under this engine's
        mesh (None when meshless).  Derived from the pool's KV tier — the
        per-pool component of the precision policy."""
        if self.mesh is None:
            return None
        from repro.runtime import partitioning as PT
        # Paged pools shard the page arena: the page axis takes the slab's
        # slot (data) axis — pages ride where slots used to, so dp x tp
        # sharding and donation survive the paging indirection unchanged.
        rows = pool.n_pages if getattr(pool, "paged", False) else pool.n_slots
        spec = PT.serve_pool_pspec(self.cfg, self.mesh, rows,
                                   kv_dtype=pool.kv_dtype)
        return PT.named(self.mesh, spec)

    def _steps_for(self, pool: KVCachePool):
        """(prefill_chunk, decode_slots, decode_slots_logits, decode_burst)
        jits for ``pool``'s geometry.

        Meshless: the bare jits.  Under a mesh: jits carrying explicit
        in/out shardings — cache in-sharding == out-sharding keeps donation
        alive; tokens / lengths / stop masks / sampled ids ride the slot
        (data) axis; the [K, n_slots, 2] burst key schedule and the
        [K, n_slots] burst outputs carry the slot axis at position 1
        (``partitioning.serve_burst_pspec``); scalars and the [1, C] chunk
        tokens are replicated.  Cached per ``(n_slots, capacity, tier)`` —
        the pool-varying components of the precision policy — so ONE
        engine holds compiled step sets for several KV tiers at once and
        per-request tier switching never recompiles a tier it has already
        served (DESIGN.md §12).  (Meshless, the bare jits below do the
        same thing through jax.jit's own signature cache: a bf16 slab and
        a packed int8 slab are different pytree structures, hence
        different compiled specializations of one wrapper.)
        """
        self._declare_execution()
        paged = getattr(pool, "paged", False)
        if self.mesh is None:
            if paged:
                return (self._prefill_chunk_paged, self._decode_slots_paged,
                        self._decode_slots_logits_paged,
                        self._decode_burst_paged)
            return (self._prefill_chunk, self._decode_slots,
                    self._decode_slots_logits, self._decode_burst)
        key = (pool.n_slots, pool.capacity, pool.kv_dtype, paged,
               getattr(pool, "n_pages", 0), getattr(pool, "page_size", 0))
        steps = self._sharded_steps.get(key)
        if steps is None:
            from repro.runtime import partitioning as PT
            cache_sh = self.pool_shardings(pool)
            rep = NamedSharding(self.mesh, P())
            burst = PT.serve_burst_pspec(self.mesh, pool.n_slots)
            tok_sh = NamedSharding(self.mesh, P(burst["row"][0], None))
            len_sh = NamedSharding(self.mesh, burst["row"])
            keys_sh = NamedSharding(self.mesh, burst["row_keys"])
            sched_sh = NamedSharding(self.mesh, burst["key_schedule"])
            out_sh = NamedSharding(self.mesh, burst["burst_out"])
            if paged:
                # the page table rides the slot (data) axis like lengths;
                # the single-row prefill table is replicated like its chunk
                table_sh = NamedSharding(self.mesh, burst["row_keys"])
                pc = jax.jit(
                    self._prefill_chunk_paged_fn, donate_argnums=(2,),
                    static_argnums=(5,),
                    in_shardings=(self._param_shardings, rep, cache_sh,
                                  rep, rep),
                    out_shardings=(None, cache_sh))
                ds = jax.jit(
                    self._decode_slots_paged_fn, donate_argnums=(2,),
                    in_shardings=(self._param_shardings, tok_sh, cache_sh,
                                  len_sh, keys_sh, len_sh, table_sh),
                    out_shardings=(len_sh, cache_sh))
                dl = jax.jit(
                    self._decode_slots_logits_paged_fn, donate_argnums=(2,),
                    in_shardings=(self._param_shardings, tok_sh, cache_sh,
                                  len_sh, table_sh),
                    out_shardings=(None, cache_sh))
                db = jax.jit(
                    self._decode_burst_paged_fn, donate_argnums=(1,),
                    in_shardings=(self._param_shardings, cache_sh, len_sh,
                                  len_sh, len_sh, len_sh, sched_sh, len_sh,
                                  len_sh, rep, table_sh),
                    out_shardings=(cache_sh, out_sh, out_sh))
            else:
                pc = jax.jit(
                    self._prefill_chunk_fn, donate_argnums=(2,),
                    static_argnums=(5,),
                    in_shardings=(self._param_shardings, rep, cache_sh, rep,
                                  rep),
                    out_shardings=(None, cache_sh))
                ds = jax.jit(
                    self._decode_slots_fn, donate_argnums=(2,),
                    in_shardings=(self._param_shardings, tok_sh, cache_sh,
                                  len_sh, keys_sh, len_sh),
                    out_shardings=(len_sh, cache_sh))
                dl = jax.jit(
                    self._decode_slots_logits_fn, donate_argnums=(2,),
                    in_shardings=(self._param_shardings, tok_sh, cache_sh,
                                  len_sh),
                    out_shardings=(None, cache_sh))
                db = jax.jit(
                    self._decode_burst_fn, donate_argnums=(1,),
                    in_shardings=(self._param_shardings, cache_sh, len_sh,
                                  len_sh, len_sh, len_sh, sched_sh, len_sh,
                                  len_sh, rep),
                    out_shardings=(cache_sh, out_sh, out_sh))
            steps = self._sharded_steps[key] = (pc, ds, dl, db)
        return steps

    def _verify_for(self, pool: KVCachePool):
        """The speculative-verify jit for ``pool``'s geometry (DESIGN.md
        §17) — kept out of ``_steps_for``'s 4-tuple so the plain serving
        paths never pay for it.  Under a mesh the [n_slots, S] window
        tokens ride the slot (data) axis like decode tokens, the
        [S, n_slots, 2] key schedule and [S, n_slots] sampled output reuse
        the burst's schedule/output shardings (slot axis at position 1),
        and the cache in==out sharding keeps donation alive."""
        self._declare_execution()
        paged = getattr(pool, "paged", False)
        if self.mesh is None:
            return self._verify_slots_paged if paged else self._verify_slots
        key = (pool.n_slots, pool.capacity, pool.kv_dtype, paged,
               getattr(pool, "n_pages", 0), getattr(pool, "page_size", 0),
               "verify")
        vs = self._sharded_steps.get(key)
        if vs is None:
            from repro.runtime import partitioning as PT
            cache_sh = self.pool_shardings(pool)
            rep = NamedSharding(self.mesh, P())
            burst = PT.serve_burst_pspec(self.mesh, pool.n_slots)
            tok_sh = NamedSharding(self.mesh, P(burst["row"][0], None))
            len_sh = NamedSharding(self.mesh, burst["row"])
            sched_sh = NamedSharding(self.mesh, burst["key_schedule"])
            out_sh = NamedSharding(self.mesh, burst["burst_out"])
            if paged:
                table_sh = NamedSharding(self.mesh, burst["row_keys"])
                vs = jax.jit(
                    self._verify_slots_paged_fn, donate_argnums=(2,),
                    in_shardings=(self._param_shardings, tok_sh, cache_sh,
                                  len_sh, sched_sh, len_sh, table_sh),
                    out_shardings=(out_sh, cache_sh))
            else:
                vs = jax.jit(
                    self._verify_slots_fn, donate_argnums=(2,),
                    in_shardings=(self._param_shardings, tok_sh, cache_sh,
                                  len_sh, sched_sh, len_sh),
                    out_shardings=(out_sh, cache_sh))
            self._sharded_steps[key] = vs
        return vs

    # ------------------------------------------------------------------
    # Pool-based step primitives (the scheduler's interface)
    # ------------------------------------------------------------------
    def new_pool(self, n_slots: Optional[int] = None,
                 max_len: Optional[int] = None,
                 kv_dtype: Optional[str] = None) -> KVCachePool:
        """Build a slot pool at KV tier ``kv_dtype`` (default: the
        policy's tier).  With ``cache_budget_bytes`` set, the slot count
        is derived from KV bytes/token at the pool's tier — an int8/fp8
        pool fits ~2x the slots of bf16 in the same budget, which is what
        makes per-request tier switching a capacity lever (one engine can
        hold one pool per tier; see Scheduler ``tiers=``)."""
        tier = self.scfg.kv_dtype if kv_dtype is None \
            else validate_kv_tier(kv_dtype, self.cfg)
        max_len = max_len or self.scfg.max_len
        if self.scfg.paged:
            # page-granular budget accounting (DESIGN.md §15): the budget
            # buys an ARENA of pages, not worst-case max_len slots — slots
            # stay at the configured width (a slot is just a batch row; it
            # costs nothing until its request commits pages).
            page_size = self.scfg.page_size or self.scfg.prefill_chunk
            n_slots = n_slots or self.scfg.n_slots
            n_pages = None
            if self.scfg.cache_budget_bytes is not None:
                n_pages = pages_for_budget(
                    self.cfg, max_len, self.scfg.cache_budget_bytes,
                    kv_dtype=tier, page_size=page_size,
                    align=self.scfg.prefill_chunk)
            pool = PagedKVPool(self.cfg, n_slots, max_len, kv_dtype=tier,
                               align=self.scfg.prefill_chunk,
                               page_size=page_size, n_pages=n_pages)
            if self.mesh is not None:
                pool.place(self.pool_shardings(pool))
            return pool
        if n_slots is None:
            if self.scfg.cache_budget_bytes is not None:
                n_slots = slots_for_budget(
                    self.cfg, max_len, self.scfg.cache_budget_bytes,
                    kv_dtype=tier,
                    align=self.scfg.prefill_chunk)
            else:
                n_slots = self.scfg.n_slots
        pool = KVCachePool(self.cfg, n_slots, max_len,
                           kv_dtype=tier,
                           align=self.scfg.prefill_chunk)
        if self.mesh is not None:
            pool.place(self.pool_shardings(pool))
        return pool

    def _inject_fault(self, kind: str) -> Optional[str]:
        """Consult the fault-injection hook for one dispatch.  Returns
        'nan' when the dispatch's output should be poisoned (decode paths
        only — the caller corrupts the sampled ids so the scheduler's
        poisoned-output detector fires), raises ``StepFault`` for a
        killed dispatch, and returns None on the no-fault path."""
        fi = self.scfg.fault_injector
        if fi is None:
            return None
        self._fault_seq += 1
        mode = fi(kind, self._fault_seq)
        if not mode:
            return None
        if mode == "nan" and kind != "prefill":
            return "nan"
        raise StepFault(str(mode), f"{kind} dispatch #{self._fault_seq}")

    def pad_prompt(self, prompt: np.ndarray):
        """Prefill pre-pass: ONE int32 conversion + zero-pad to a whole
        number of prefill chunks.  Returns (padded [ceil(P/C)*C], P).  The
        per-chunk loop then slices views out of this buffer instead of
        allocating a fresh chunk per call (host allocation churn was the
        prefill path's per-chunk overhead)."""
        C = self.scfg.prefill_chunk
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        n = int(prompt.size)
        assert n > 0, "empty prompt"
        padded = np.zeros((-(-n // C) * C,), np.int32)
        padded[:n] = prompt
        return padded, n

    def prefill_chunk_into_slot(self, pool: KVCachePool, slot: int,
                                prompt: np.ndarray, offset: int, *,
                                prompt_len: Optional[int] = None,
                                need_logits: bool = True):
        """Write prompt[offset : offset+C] into ``slot``.  For the prompt's
        final chunk, returns the [C, V] chunk logits (pad positions carry
        garbage — callers index the true last position); earlier chunks
        return None and skip the lm-head compute entirely.  Advances
        ``pool.lengths[slot]``.

        With ``prompt_len`` given, ``prompt`` must already be the
        chunk-padded buffer from ``pad_prompt`` (the scheduler pads once at
        admission); without it, the legacy raw-prompt interface pads here.
        ``need_logits=False`` skips the lm-head even on the final chunk
        (the preempt-resume replay path: those tokens' next-token samples
        were already delivered, only their KV must be recomputed) — it
        reuses the non-final chunk's compiled variant, so no extra jit.
        """
        C = self.scfg.prefill_chunk
        if prompt_len is None:
            prompt, prompt_len = self.pad_prompt(prompt)
        n = min(C, prompt_len - offset)
        assert n > 0, (offset, prompt_len)
        assert offset + n <= pool.max_len, "prompt exceeds slot capacity"
        chunk = prompt[offset:offset + C][None]       # view, no allocation
        final = (offset + n >= prompt_len) and need_logits
        self._inject_fault("prefill")
        prefill_chunk = self._steps_for(pool)[0]
        if getattr(pool, "paged", False):
            # pin the chunk's write window (fresh pages / COW of a shared
            # page on a full-cover prefix hit) before the jitted write
            pool.ensure(slot, offset + C)
            logits, pool.cache = prefill_chunk(
                self.params, jnp.asarray(chunk), pool.cache,
                jnp.asarray(pool.page_table[slot:slot + 1]),
                jnp.int32(offset), final)
        else:
            logits, pool.cache = prefill_chunk(
                self.params, jnp.asarray(chunk), pool.cache,
                jnp.int32(slot), jnp.int32(offset), final)
        pool.lengths[slot] = offset + n
        return jax.block_until_ready(logits) if final else None

    def prefill_into_slots(self, pool: KVCachePool, slots: Sequence[int],
                           prompts: Sequence[np.ndarray]) -> List:
        """Full chunked prefill of each (slot, prompt); returns the [V]
        logits at each prompt's true last position."""
        C = self.scfg.prefill_chunk
        out = []
        for slot, prompt in zip(slots, prompts):
            padded, n = self.pad_prompt(prompt)
            logits = None
            for off in range(0, n, C):
                logits = self.prefill_chunk_into_slot(pool, slot, padded,
                                                      off, prompt_len=n)
            out.append(logits[(n - 1) % C])
        return out

    def decode_slots(self, pool: KVCachePool, tokens: np.ndarray,
                     keys: Optional[np.ndarray] = None,
                     temperatures: Optional[np.ndarray] = None) -> np.ndarray:
        """One fused decode+sample step over every pool slot.  ``tokens``
        [n_slots]; row i is written at pool.lengths[i].  Sampling happens
        ON DEVICE (``keys`` [n_slots, 2] uint32 / ``temperatures``
        [n_slots]; both default to zeros = greedy) and only the [n_slots]
        int32 sampled ids come back — the logits never leave the device.
        The caller commits the write by incrementing ``pool.lengths`` for
        the rows it considers active."""
        n = pool.n_slots
        tokens = np.asarray(tokens, np.int32).reshape(n, 1)
        if keys is None:
            keys = np.zeros((n, 2), np.uint32)
        if temperatures is None:
            temperatures = np.zeros((n,), np.float32)
        poison = self._inject_fault("decode")
        decode_slots = self._steps_for(pool)[1]
        step_args = (self.params, jnp.asarray(tokens), pool.cache,
                     jnp.asarray(pool.lengths), jnp.asarray(keys, jnp.uint32),
                     jnp.asarray(temperatures, jnp.float32))
        if getattr(pool, "paged", False):
            # paged pools: the caller (scheduler) must have pinned every
            # active row's write position via ``pool.ensure_decode`` —
            # inactive rows' garbage writes flow to the reserved garbage
            # page through their unmapped (entry-0) table slots.
            step_args += (jnp.asarray(pool.page_table),)
        toks, pool.cache = decode_slots(*step_args)
        toks = np.asarray(toks)
        if poison is not None:
            # poisoned-output simulation: out-of-vocab ids, as a NaN-
            # saturated sampler would produce — the scheduler's validity
            # guard (not this return path) is what must catch them
            toks = np.full_like(toks, -1)
        return toks

    def decode_slots_with_logits(self, pool: KVCachePool,
                                 tokens: np.ndarray) -> np.ndarray:
        """The logits-returning decode variant (score / diagnostic paths):
        same write semantics as ``decode_slots`` but returns the full
        [n_slots, V] logits — one host transfer of the whole logit block."""
        tokens = np.asarray(tokens, np.int32).reshape(pool.n_slots, 1)
        decode_logits = self._steps_for(pool)[2]
        step_args = (self.params, jnp.asarray(tokens), pool.cache,
                     jnp.asarray(pool.lengths))
        if getattr(pool, "paged", False):
            step_args += (jnp.asarray(pool.page_table),)
        logits, pool.cache = decode_logits(*step_args)
        return jax.block_until_ready(logits)

    def decode_burst(self, pool: KVCachePool, tokens: np.ndarray,
                     key_schedule: np.ndarray, temperatures: np.ndarray,
                     active: np.ndarray, remaining: np.ndarray,
                     eos_ids: np.ndarray):
        """K consecutive decode steps on device — ONE dispatch, ONE host
        sync (DESIGN.md §11).  K = key_schedule.shape[0]; row i of
        ``key_schedule[t]`` must be request i's ``step_key`` for its
        (n_generated + t)-th token so the burst is bit-identical to K
        single steps.  ``active`` [n_slots] bool marks live decode rows;
        ``remaining`` [n_slots] int32 is each row's max-new-tokens budget
        left; ``eos_ids`` [n_slots] int32 (-1 = never).  Rows that hit a
        stop condition freeze mid-burst (their lengths stop advancing).

        Returns (tokens [K, n_slots] int32, valid [K, n_slots] bool) as
        host arrays; token (t, i) was emitted iff valid[t, i].  Commits
        ``pool.lengths`` for every emitted token (unlike single-step
        ``decode_slots``, where the caller commits)."""
        K, n = key_schedule.shape[0], pool.n_slots
        assert key_schedule.shape == (K, n, 2), key_schedule.shape
        tokens = np.asarray(tokens, np.int32).reshape(n)
        poison = self._inject_fault("burst")
        decode_burst = self._steps_for(pool)[3]
        step_args = (
            self.params, pool.cache, jnp.asarray(tokens),
            jnp.asarray(pool.lengths), jnp.asarray(active, bool),
            jnp.asarray(remaining, jnp.int32),
            jnp.asarray(key_schedule, jnp.uint32),
            jnp.asarray(temperatures, jnp.float32),
            jnp.asarray(eos_ids, jnp.int32), jnp.int32(pool.max_len))
        if getattr(pool, "paged", False):
            # write windows for the whole K-step burst must be pinned
            # (``pool.ensure_decode(slots, K, rems)``) before this dispatch
            step_args += (jnp.asarray(pool.page_table),)
        pool.cache, toks, valid = decode_burst(*step_args)
        toks = np.asarray(toks)                       # the burst's one sync
        valid = np.asarray(valid)
        if poison is not None:
            toks = np.full_like(toks, -1)
        pool.lengths += valid.sum(axis=0).astype(np.int32)
        return toks, valid

    def verify_slots(self, pool: KVCachePool, tokens: np.ndarray,
                     key_schedule: np.ndarray,
                     temperatures: np.ndarray) -> np.ndarray:
        """Speculative verify over every pool slot (DESIGN.md §17).
        ``tokens`` [n_slots, S]: row i's window [last_committed, d_1..d_K]
        written at pool.lengths[i]..+S-1; ``key_schedule`` [S, n_slots, 2]
        carries each row's real step keys for tokens n_generated..+K.
        Returns the target's sampled ids [S, n_slots] int32 — g_j at
        position j.  Does NOT commit ``pool.lengths``: the caller accepts
        the longest agreeing prefix and sets lengths to the emitted count
        (which IS the rollback — positions past the committed length are
        garbage-but-masked, exactly like inactive-slot decode writes)."""
        n = pool.n_slots
        tokens = np.asarray(tokens, np.int32).reshape(n, -1)
        s = tokens.shape[1]
        assert key_schedule.shape == (s, n, 2), key_schedule.shape
        poison = self._inject_fault("verify")
        vs = self._verify_for(pool)
        step_args = (self.params, jnp.asarray(tokens), pool.cache,
                     jnp.asarray(pool.lengths),
                     jnp.asarray(key_schedule, jnp.uint32),
                     jnp.asarray(temperatures, jnp.float32))
        if getattr(pool, "paged", False):
            # the S-wide write window must be pinned by the caller
            # (``pool.ensure_decode(slots, S, rems)``) before dispatch
            step_args += (jnp.asarray(pool.page_table),)
        sampled, pool.cache = vs(*step_args)
        sampled = np.asarray(sampled)             # the round's verify sync
        if poison is not None:
            sampled = np.full_like(sampled, -1)
        return sampled

    # ------------------------------------------------------------------
    # One-shot generation (backwards-compatible wrapper)
    # ------------------------------------------------------------------
    def generate(self, batch: Dict, *, max_new_tokens: int,
                 seed: int = 0, obs=None) -> Dict:
        """batch: {'tokens': [B, S]} (+ stubs).  Returns generated ids
        [B, T] (post-EOS positions masked to 0), per-row lengths and finish
        reasons.  ``obs``: optional ``repro.obs.Observability`` bundle
        threaded into the internal Scheduler (DESIGN.md §13); the legacy
        static-batch families have no scheduler and ignore it."""
        if self.cfg.family in SCHEDULABLE_FAMILIES:
            return self._generate_scheduled(batch, max_new_tokens, seed,
                                            obs=obs)
        return self._generate_legacy(batch, max_new_tokens, seed)

    def _generate_scheduled(self, batch, max_new_tokens: int, seed: int,
                            obs=None):
        from .request import Request, SamplingParams
        from .scheduler import Scheduler

        tokens = np.asarray(batch["tokens"], np.int32)
        b, s = tokens.shape
        assert s + max_new_tokens <= self.scfg.max_len, \
            "grow ServeConfig.max_len"
        sched = Scheduler(self, obs=obs)
        reqs = [sched.submit(Request(
            prompt=tokens[i],
            sampling=SamplingParams(temperature=self.scfg.temperature,
                                    max_new_tokens=max_new_tokens,
                                    eos_id=self.scfg.eos_id, seed=seed)))
            for i in range(b)]
        sched.run()
        width = max(r.n_generated for r in reqs)
        gen = np.zeros((b, width), np.int32)
        lengths = np.zeros((b,), np.int32)
        for i, r in enumerate(reqs):
            gen[i, :r.n_generated] = r.output_tokens
            lengths[i] = r.n_generated
        m = sched.metrics
        return {"generated": gen, "prompt_len": s, "batch": b,
                "lengths": lengths,
                "finish_reasons": [r.finish_reason for r in reqs],
                # burst accounting (DESIGN.md §11): how amortized the
                # decode path actually ran for this generation
                "decode_dispatches": m.decode_dispatches,
                "decode_token_steps": m.decode_token_steps,
                "host_syncs": sched.n_host_syncs,
                "burst_hist": dict(m.burst_hist)}

    # ---- legacy static-batch loop (ssm / hybrid / audio / vlm) ---------
    def _sample(self, logits, key):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature).astype(jnp.int32)

    def _generate_legacy(self, batch, max_new_tokens: int, seed: int):
        self._declare_execution()
        cfg, scfg = self.cfg, self.scfg
        tokens = jnp.asarray(batch["tokens"], jnp.int32)
        b, s = tokens.shape
        prefix = cfg.n_patches if cfg.family == "vlm" else 0
        assert s + max_new_tokens <= scfg.max_len, "grow ServeConfig.max_len"

        cache = T.init_cache(cfg, b, prefix + s + max_new_tokens,
                             kv_dtype=scfg.kv_dtype)
        last_logits, cache = self._prefill(self.params, batch, cache)

        key = jax.random.PRNGKey(seed)
        out: List[np.ndarray] = []
        index = prefix + s
        tok = self._sample(last_logits, key)
        out.append(np.asarray(tok))
        finished = np.zeros((b,), bool)
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, tok[:, None], cache,
                                         jnp.int32(index + i))
            tok = self._sample(logits, sub)
            out.append(np.asarray(tok))
            if scfg.eos_id >= 0:
                finished |= np.asarray(tok) == scfg.eos_id
                if finished.all():   # whole batch retired: stop burning steps
                    break
        gen = np.stack(out, axis=1)
        lengths = np.full((b,), gen.shape[1], np.int32)
        reasons = ["length"] * b
        if scfg.eos_id >= 0:
            # mask everything after each row's first EOS (a static batch
            # cannot retire rows early, but their post-EOS garbage must not
            # leak into the output)
            eos = gen == scfg.eos_id
            seen_before = np.cumsum(eos, axis=1) - eos
            keep = seen_before == 0
            gen = np.where(keep, gen, 0)
            lengths = keep.sum(1).astype(np.int32)
            reasons = ["eos" if eos[i].any() else "length" for i in range(b)]
        return {"generated": gen, "prompt_len": s, "batch": b,
                "lengths": lengths, "finish_reasons": reasons}

    def score(self, batch: Dict) -> np.ndarray:
        """Teacher-forced mean NLL per row (serving-quality check)."""
        self._declare_execution()
        logits, _, _ = T.forward(self.cfg, self.params, batch, mode="train")
        if self.cfg.family == "vlm":
            logits = logits[:, self.cfg.n_patches:]
        lf = jnp.asarray(logits, jnp.float32)
        labels = batch["labels"]
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, labels[..., None].clip(0), -1)[..., 0]
        mask = (labels >= 0)
        nll = jnp.where(mask, lse - gold, 0.0).sum(-1) / mask.sum(-1)
        return np.asarray(nll)
