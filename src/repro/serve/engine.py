"""Mixed-precision serving engine: batched prefill + decode with KV cache.

This is the system-level consumer of the paper's technique: checkpoint
weights are stored in the per-layer mixed-precision plan (projections /
experts in INT4/FP8/FP4/INT8 packed codes -> the XtraMAC-style MACs;
attention in BF16), and the engine runs one jitted prefill and one jitted
decode step over a persistent cache — the per-tile "datatype control
signal" of the paper's GEMV engine becomes the static per-layer scheme in
the compiled program (DESIGN.md §2: JAX traces static dtypes, so runtime
switching is realized at layer granularity, which is the granularity the
paper's own workloads switch at).

Greedy sampling by default; temperature optional.  Designed so the same
class drives the CPU smoke tests and (via pjit shardings from
launch/steps.py) the production mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0
    eos_id: int = -1          # -1: never stop early
    kv_dtype: jnp.dtype = jnp.bfloat16


class ServingEngine:
    def __init__(self, cfg: T.ModelConfig, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg

        mcfg = cfg

        @jax.jit
        def prefill(params, batch, cache):
            logits, _, cache = T.forward(mcfg, params, batch, cache=cache,
                                         cache_index=0, mode="prefill")
            return logits[:, -1], cache

        @jax.jit
        def decode(params, tokens, cache, index):
            logits, _, cache = T.forward(mcfg, params, {"tokens": tokens},
                                         cache=cache, cache_index=index,
                                         mode="decode")
            return logits[:, -1], cache

        self._prefill = prefill
        self._decode = decode

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature).astype(jnp.int32)

    def generate(self, batch: Dict, *, max_new_tokens: int,
                 seed: int = 0) -> Dict:
        """batch: {'tokens': [B, S]} (+ stubs).  Returns generated ids and
        per-step logits summaries."""
        cfg, scfg = self.cfg, self.scfg
        tokens = jnp.asarray(batch["tokens"], jnp.int32)
        b, s = tokens.shape
        prefix = cfg.n_patches if cfg.family == "vlm" else 0
        max_len = prefix + s + max_new_tokens
        assert max_len <= scfg.max_len + prefix + s, "grow ServeConfig.max_len"

        cache = T.init_cache(cfg, b, prefix + s + max_new_tokens,
                             kv_dtype=scfg.kv_dtype)
        last_logits, cache = self._prefill(self.params, batch, cache)

        key = jax.random.PRNGKey(seed)
        out: List[np.ndarray] = []
        index = prefix + s
        tok = self._sample(last_logits, key)
        out.append(np.asarray(tok))
        finished = np.zeros((b,), bool)
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, tok[:, None], cache,
                                         jnp.int32(index + i))
            tok = self._sample(logits, sub)
            out.append(np.asarray(tok))
            if scfg.eos_id >= 0:
                finished |= np.asarray(tok) == scfg.eos_id
                if finished.all():
                    break
        gen = np.stack(out, axis=1)
        return {"generated": gen, "prompt_len": s, "batch": b}

    def score(self, batch: Dict) -> np.ndarray:
        """Teacher-forced mean NLL per row (serving-quality check)."""
        logits, _, _ = T.forward(self.cfg, self.params, batch, mode="train")
        if self.cfg.family == "vlm":
            logits = logits[:, self.cfg.n_patches:]
        lf = jnp.asarray(logits, jnp.float32)
        labels = batch["labels"]
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, labels[..., None].clip(0), -1)[..., 0]
        mask = (labels >= 0)
        nll = jnp.where(mask, lse - gold, 0.0).sum(-1) / mask.sum(-1)
        return np.asarray(nll)
