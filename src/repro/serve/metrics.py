"""Serving-level metrics: the quantities the paper's end-to-end workloads
(Table VII) are judged by, surfaced from the continuous-batching scheduler.

  * TTFT   — time to first token: arrival -> first sampled token (includes
             queueing while WAITING plus chunked prefill).
  * ITL    — inter-token latency: gaps between a request's decode tokens.
  * tok/s  — generated-token throughput over the busy window.
  * slot occupancy — time-weighted fraction of KV pool slots in use: the
             serving-level analogue of the paper's sustained-II=1 claim
             (a MAC array only hits its rated throughput if the scheduler
             keeps it fed; so for the pool).  Multi-tier schedulers also
             get a per-tier occupancy (each tier's pool weighted by its
             own slot count) — a tier can starve while the total looks
             healthy.
  * burst accounting (DESIGN.md §11) — decode dispatches, token-steps and
             a burst-length histogram: ``decode_dispatches_per_token`` is
             the direct measure of how amortized the decode hot path ran
             (1.0 = one jit entry per token; 1/K at steady bursts of K).

All timestamps come from the scheduler's injectable clock, so tests can
drive a virtual clock and assert on exact values.

**Burst-granularity ITL caveat**: all K tokens of a decode burst surface
at burst end (the whole point is that nothing crosses the host mid-burst),
so their timestamps cluster there — intra-burst ITL gaps are near zero and
the burst's wall time lands on the gap *between* bursts.  Mean ITL and
tok/s are unaffected (same tokens, same wall clock); percentiles are
burst-granular.  ``report()`` flags this via ``itl_granularity`` and
additionally reports ``itl_burst_spread_*``: an estimate that spreads
each burst's wall time uniformly across the tokens it emitted (grouped by
the per-token dispatch ids the scheduler records), which is the
defensible per-token percentile when bursts ran.

**SLO accounting** (DESIGN.md §16): every submitted request retires with
exactly one finish reason — the generation reasons (eos / length /
capacity) plus the shed reasons (rejected / deadline_exceeded / fault) —
so ``finish_reasons`` sums to ``n_requests``: nothing disappears under
overload (``preempted_resumed`` in the same dict is an *overlay*: finished
requests that survived >= 1 preemption; it is not part of the sum).
TTFT / ITL / e2e samples come only from requests that actually delivered
a first token — shed requests never pollute the latency percentiles and
are visible in the reasons map and the rejection/preemption/fault
counters instead.  Queue waits are ``admit - last enqueue`` per priority
class (a preempted request's second wait is charged to its requeue), and
per-priority TTFT/e2e percentiles appear whenever more than one class
was served — the quantity the SLO bench's bounded-p99 claim is made on.

**Registry consumption** (DESIGN.md §13): with a
``repro.obs.MetricsRegistry`` attached, every event hook additionally
publishes into shared counter/histogram families — ``ServeMetrics`` is a
*consumer* of the registry, not a parallel bookkeeping system; the
scheduler publishes its own gauges (queue depth, per-tier slots) into
the same registry.  ``registry=None`` (default) changes nothing.

``report()`` is RFC-JSON clean: fields whose denominator is empty are
``None`` (-> ``null``), never ``float("nan")`` — ``json.dumps(report,
allow_nan=False)`` must always succeed (round-trip pinned in tests).
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Union

import numpy as np


def _pct(values: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(values, np.float64), q))


def burst_spread_itl(token_times: List[float],
                     token_dispatches: List[int]) -> List[float]:
    """Per-token ITL estimate with each dispatch's wall time spread
    uniformly across the tokens it emitted.

    Tokens sharing a dispatch id surfaced from one burst at (nearly) one
    timestamp; the raw gap sequence therefore puts the whole burst wall
    on its first token and ~0 on the rest.  Here a group of m tokens
    emitted by one dispatch, following a previous token at t_prev,
    contributes m samples of (t_group_end - t_prev) / m.  Sample count
    equals the raw gap count (len - 1); with K=1 everywhere the estimate
    IS the raw diff sequence.
    """
    n = len(token_times)
    if n < 2 or len(token_dispatches) != n:
        return list(np.diff(np.asarray(token_times))) if n > 1 else []
    out: List[float] = []
    i = 0
    while i < n:
        j = i
        while j + 1 < n and token_dispatches[j + 1] == token_dispatches[i]:
            j += 1
        if i == 0:
            if j > 0:                       # gaps inside the first group
                out.extend([(token_times[j] - token_times[0]) / j] * j)
        else:
            m = j - i + 1
            out.extend([(token_times[j] - token_times[i - 1]) / m] * m)
        i = j + 1
    return out


class ServeMetrics:
    def __init__(self, n_slots: int, registry=None):
        self.n_slots = n_slots
        # {'n_devices', 'dp', 'tp'} when serving under a mesh (set by the
        # scheduler from engine.topology); None for single-device serving
        self.topology: Optional[Dict] = None
        # {tier: n_slots} when the scheduler serves multiple KV precision
        # tiers from one engine (DESIGN.md §12); None for single-tier —
        # ``n_slots`` above is always the total across tiers
        self.tiers: Optional[Dict[str, int]] = None
        self.ttft: List[float] = []
        # TTFT split by prefix-cache outcome (paged pools, DESIGN.md §15):
        # a hit adopts cached prompt pages and skips their prefill chunks,
        # so hit TTFT should sit measurably below miss TTFT — the split is
        # the direct evidence.  Slab pools only ever fill ttft_miss.
        self.ttft_hit: List[float] = []
        self.ttft_miss: List[float] = []
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_hit_tokens = 0
        self.itl: List[float] = []
        self.itl_spread: List[float] = []     # burst-spread ITL estimate
        self.e2e: List[float] = []            # per-request total latency
        self.n_requests = 0
        self.n_arrived = 0
        self.total_new_tokens = 0
        # --- SLO accounting (DESIGN.md §16) ---
        self.finish_reasons: Dict[str, int] = {}   # disjoint; sums to n_requests
        self.n_resumed = 0            # finished after >= 1 preemption
        self.n_preemptions = 0
        self.preempt_reasons: Dict[str, int] = {}  # 'priority' | 'fault'
        self.n_rejections = 0
        self.rejection_kinds: Dict[str, int] = {}
        self.n_downgrades = 0
        self.n_fault_events = 0       # faulted dispatches
        self.n_fault_requests = 0     # request-slots those dispatches hit
        self.fault_kinds: Dict[str, int] = {}
        # queue wait (admit - last enqueue) and TTFT/e2e, per priority class
        self.queue_wait: Dict[int, List[float]] = {}
        self._prio_ttft: Dict[int, List[float]] = {}
        self._prio_e2e: Dict[int, List[float]] = {}
        self.first_arrival: Optional[float] = None
        self.last_finish: Optional[float] = None
        # time-weighted occupancy integrals (total, and per tier when the
        # scheduler passes per-tier samples)
        self._occ_integral = 0.0
        self._occ_time = 0.0
        self._tier_occ: Dict[str, float] = {}
        self._last_sample: Optional[float] = None
        # decode-burst accounting (DESIGN.md §11)
        self.decode_dispatches = 0      # jitted decode/burst entries
        self.decode_token_steps = 0     # token-steps those entries covered
        self.decode_tokens_emitted = 0  # tokens that actually surfaced
        self.burst_hist: Dict[int, int] = {}   # planned K -> count
        # speculative-decoding accounting (DESIGN.md §17).  Identities,
        # pinned by tests and the bench's inline check:
        #   tokens_drafted  == tokens_accepted + tokens_rejected
        #   tokens_emitted  == tokens_accepted + bonus_tokens
        # and at drain every generated token was emitted exactly once:
        #   total_new_tokens == first tokens (len(ttft))
        #                       + decode_tokens_emitted (plain rounds)
        #                       + spec_tokens_emitted   (spec rounds)
        self.spec_rounds = 0
        self.spec_draft_dispatches = 0
        self.spec_verify_dispatches = 0
        self.spec_catchup_dispatches = 0   # draft-KV replay prefill chunks
        self.spec_tokens_drafted = 0
        self.spec_tokens_accepted = 0      # emitted tokens matching drafts
        self.spec_tokens_rejected = 0
        self.spec_bonus_tokens = 0         # verify's own (non-draft) samples
        self.spec_tokens_emitted = 0
        self.spec_accept_hist: Dict[int, int] = {}  # accepted/verify -> n
        # optional shared registry (repro.obs) this consumer publishes to
        self._reg = registry
        if registry is not None:
            self._r_arrived = registry.counter(
                "serve_requests_arrived_total", "requests submitted")
            self._r_finished = registry.counter(
                "serve_requests_finished_total",
                "requests retired, by finish reason and KV tier")
            self._r_tokens = registry.counter(
                "serve_new_tokens_total", "generated tokens, by KV tier")
            self._r_dispatch = registry.counter(
                "serve_decode_dispatches_total",
                "jitted decode/burst entries, by KV tier")
            self._r_steps = registry.counter(
                "serve_decode_token_steps_total",
                "planned decode token-steps, by KV tier")
            self._r_burst = registry.histogram(
                "serve_burst_k", "planned burst length per decode dispatch",
                buckets=(1, 2, 4, 8, 16, 32, 64))
            self._r_ttft = registry.histogram(
                "serve_ttft_seconds", "time to first token")
            self._r_e2e = registry.histogram(
                "serve_e2e_seconds", "request arrival -> retirement")
            self._r_preempt = registry.counter(
                "serve_preemptions_total",
                "decode slots evicted and requeued, by reason and KV tier")
            self._r_reject = registry.counter(
                "serve_rejections_total",
                "requests shed at admission, by verdict kind")
            self._r_downgrade = registry.counter(
                "serve_downgrades_total",
                "KV-tier downgrades under pressure, by from/to tier")
            self._r_fault = registry.counter(
                "serve_faults_total", "faulted dispatches, by fault kind")
            self._r_qwait = registry.histogram(
                "serve_queue_wait_seconds",
                "enqueue -> admission wait, by priority class")
            self._r_spec_rounds = registry.counter(
                "serve_spec_rounds_total",
                "speculative draft/verify rounds, by KV tier")
            self._r_spec_disp = registry.counter(
                "serve_spec_dispatches_total",
                "speculation dispatches, by kind "
                "(draft / verify / catchup) and KV tier")
            self._r_spec_tok = registry.counter(
                "serve_spec_tokens_total",
                "draft-window token outcomes, by result "
                "(accepted / rejected / bonus) and KV tier")
            self._r_spec_acc = registry.histogram(
                "serve_spec_accepted_per_verify",
                "draft tokens accepted per verify dispatch",
                buckets=(0, 1, 2, 4, 8, 16, 32))

    # -- event hooks (called by the scheduler) -----------------------------
    def on_arrival(self, now: float) -> None:
        self.n_arrived += 1
        if self.first_arrival is None:
            self.first_arrival = now
        if self._reg is not None:
            self._r_arrived.inc()

    def on_admit(self, req) -> None:
        """WAITING -> PREFILL: record the queue wait this admission ended,
        charged to the request's most recent enqueue (submit or a
        preemption requeue) and its priority class."""
        if req.admit_time is None:
            return
        t0 = req.last_enqueue_time if req.last_enqueue_time is not None \
            else req.arrival_time
        if t0 is None:
            return
        wait = max(req.admit_time - t0, 0.0)
        prio = getattr(req, "priority", 0)
        self.queue_wait.setdefault(prio, []).append(wait)
        if self._reg is not None:
            self._r_qwait.observe(wait, priority=str(prio))

    def on_preempt(self, req, reason: str = "priority") -> None:
        """A DECODE (or mid-prefill) slot was evicted and requeued —
        either for a higher-priority waiter ('priority') or because a
        faulted dispatch invalidated it ('fault')."""
        self.n_preemptions += 1
        self.preempt_reasons[reason] = \
            self.preempt_reasons.get(reason, 0) + 1
        if self._reg is not None:
            self._r_preempt.inc(reason=reason,
                                tier=getattr(req, "tier", None) or "")

    def on_reject(self, req) -> None:
        """Admission control shed the request at submit (typed verdict in
        ``req.rejection``); it retires with finish_reason='rejected'."""
        kind = getattr(req.rejection, "kind", None) or "unknown"
        self.n_rejections += 1
        self.rejection_kinds[kind] = self.rejection_kinds.get(kind, 0) + 1
        if self._reg is not None:
            self._r_reject.inc(kind=kind)

    def on_downgrade(self, req) -> None:
        """The SLO policy served the request at a denser KV tier than it
        asked for (``req.downgraded_from`` -> ``req.tier``)."""
        self.n_downgrades += 1
        if self._reg is not None:
            self._r_downgrade.inc(
                src=getattr(req, "downgraded_from", None) or "",
                dst=getattr(req, "tier", None) or "")

    def on_fault(self, fault, n_requests: int) -> None:
        """One engine dispatch faulted (raised or returned poisoned
        output), invalidating ``n_requests`` slots."""
        self.n_fault_events += 1
        self.n_fault_requests += n_requests
        kind = getattr(fault, "kind", None) or "unknown"
        self.fault_kinds[kind] = self.fault_kinds.get(kind, 0) + 1
        if self._reg is not None:
            self._r_fault.inc(kind=kind)

    def on_step(self, now: float,
                used_slots: Union[int, Mapping[str, int]]) -> None:
        """Sample occupancy; weight = wall time since the previous sample.
        ``used_slots`` is either the total used count (legacy) or a
        {tier: used} mapping — the mapping form also feeds the per-tier
        occupancy integrals when ``self.tiers`` is set."""
        per_tier = None
        if isinstance(used_slots, Mapping):
            per_tier = used_slots
            used_slots = sum(used_slots.values())
        if self._last_sample is not None:
            dt = max(now - self._last_sample, 0.0)
            self._occ_integral += dt * (used_slots / self.n_slots)
            self._occ_time += dt
            if per_tier is not None and self.tiers:
                for tier, used in per_tier.items():
                    cap = self.tiers.get(tier)
                    if cap:
                        self._tier_occ[tier] = (
                            self._tier_occ.get(tier, 0.0)
                            + dt * (used / cap))
        self._last_sample = now

    def on_decode_burst(self, k: int, tokens_emitted: int,
                        tier: Optional[str] = None) -> None:
        """One decode dispatch covering ``k`` planned token-steps (k = 1
        for the fused single step).  ``tokens_emitted`` counts the tokens
        that actually surfaced across all rows (rows frozen mid-burst emit
        fewer than k) — its running total vs the dispatch count gives the
        emitted-per-dispatch amortization in ``report()``."""
        self.decode_dispatches += 1
        self.decode_token_steps += k
        self.decode_tokens_emitted += tokens_emitted
        self.burst_hist[k] = self.burst_hist.get(k, 0) + 1
        if self._reg is not None:
            t = tier or ""
            self._r_dispatch.inc(tier=t)
            self._r_steps.inc(k, tier=t)
            self._r_burst.observe(k, tier=t)

    def on_spec_round(self, k: int, rows: int, drafted: int, accepted: int,
                      emitted: int, catchup_dispatches: int = 0,
                      tier: Optional[str] = None) -> None:
        """One speculative round (DESIGN.md §17): a K-step draft burst
        plus ONE target verify dispatch covering ``rows`` cohort rows.
        ``drafted`` counts proposed draft tokens (K per row),
        ``accepted`` the emitted tokens that matched drafts, ``emitted``
        every token that surfaced (accepted + at most one bonus/
        correction sample per row, EOS/budget truncation included).
        ``catchup_dispatches``: draft-KV replay prefill chunks issued
        before the round's draft burst."""
        bonus = emitted - accepted
        assert 0 <= accepted <= drafted and 0 <= bonus <= rows, \
            (drafted, accepted, emitted, rows)
        self.spec_rounds += 1
        self.spec_draft_dispatches += 1
        self.spec_verify_dispatches += 1
        self.spec_catchup_dispatches += catchup_dispatches
        self.spec_tokens_drafted += drafted
        self.spec_tokens_accepted += accepted
        self.spec_tokens_rejected += drafted - accepted
        self.spec_bonus_tokens += bonus
        self.spec_tokens_emitted += emitted
        self.spec_accept_hist[accepted] = \
            self.spec_accept_hist.get(accepted, 0) + 1
        if self._reg is not None:
            t = tier or ""
            self._r_spec_rounds.inc(tier=t)
            self._r_spec_disp.inc(kind="draft", tier=t)
            self._r_spec_disp.inc(kind="verify", tier=t)
            if catchup_dispatches:
                self._r_spec_disp.inc(catchup_dispatches, kind="catchup",
                                      tier=t)
            if accepted:
                self._r_spec_tok.inc(accepted, result="accepted", tier=t)
            if drafted - accepted:
                self._r_spec_tok.inc(drafted - accepted, result="rejected",
                                     tier=t)
            if bonus:
                self._r_spec_tok.inc(bonus, result="bonus", tier=t)
            self._r_spec_acc.observe(accepted, tier=t)

    def on_finish(self, req) -> None:
        self.n_requests += 1
        self.total_new_tokens += req.n_generated
        self.last_finish = req.finish_time
        reason = req.finish_reason or "unknown"
        self.finish_reasons[reason] = self.finish_reasons.get(reason, 0) + 1
        if getattr(req, "n_preemptions", 0) > 0:
            self.n_resumed += 1
        prio = getattr(req, "priority", 0)
        ttft = e2e = None
        hit_tokens = getattr(req, "prefix_hit_tokens", 0)
        # latency/prefix samples only from requests that DELIVERED — a
        # request shed before its first token (rejected, deadline, fault
        # during prefill) is visible in finish_reasons and the shed
        # counters, never in the percentiles it would drag to zero
        if req.first_token_time is not None and req.arrival_time is not None:
            if hit_tokens > 0:
                self.prefix_hits += 1
                self.prefix_hit_tokens += hit_tokens
            else:
                self.prefix_misses += 1
            ttft = req.first_token_time - req.arrival_time
            self.ttft.append(ttft)
            (self.ttft_hit if hit_tokens > 0 else self.ttft_miss).append(ttft)
            self._prio_ttft.setdefault(prio, []).append(ttft)
            if req.finish_time is not None:
                e2e = req.finish_time - req.arrival_time
                self.e2e.append(e2e)
                self._prio_e2e.setdefault(prio, []).append(e2e)
        if len(req.token_times) > 1:
            self.itl.extend(np.diff(np.asarray(req.token_times)).tolist())
            self.itl_spread.extend(burst_spread_itl(
                req.token_times, getattr(req, "token_dispatches", [])))
        if self._reg is not None:
            tier = getattr(req, "tier", None) or ""
            self._r_finished.inc(tier=tier,
                                 reason=req.finish_reason or "unknown")
            self._r_tokens.inc(req.n_generated, tier=tier)
            if ttft is not None:
                self._r_ttft.observe(ttft)
            if e2e is not None:
                self._r_e2e.observe(e2e)

    # -- report ------------------------------------------------------------
    @property
    def occupancy_mean(self) -> float:
        return self._occ_integral / self._occ_time if self._occ_time else 0.0

    def report(self) -> Dict:
        wall = ((self.last_finish - self.first_arrival)
                if self.first_arrival is not None
                and self.last_finish is not None else 0.0)
        out = {
            "n_requests": self.n_requests,
            "total_new_tokens": self.total_new_tokens,
            "wall_s": round(wall, 4),
            # None (-> JSON null) when the busy window is empty: NaN is
            # not RFC JSON and poisons every downstream json.loads
            "tokens_per_s": round(self.total_new_tokens / wall, 2)
            if wall > 0 else None,
            "slot_occupancy_mean": round(self.occupancy_mean, 4),
        }
        if self.topology is not None:
            out["topology"] = dict(self.topology)
        if self.tiers is not None:
            out["tiers"] = dict(self.tiers)
            if self._occ_time:
                out["tier_occupancy_mean"] = {
                    t: round(v / self._occ_time, 4)
                    for t, v in sorted(self._tier_occ.items())}
        if self.decode_dispatches:
            out["decode_dispatches"] = self.decode_dispatches
            out["decode_token_steps"] = self.decode_token_steps
            out["decode_tokens_emitted"] = self.decode_tokens_emitted
            # per token-step: the literal "jit entries <= 1/K amortized"
            # measure — 1.0 on the K=1 path, 1/K at steady bursts of K,
            # independent of how many rows shared each step
            out["decode_dispatches_per_step"] = round(
                self.decode_dispatches / self.decode_token_steps, 4)
            if self.total_new_tokens:
                out["decode_dispatches_per_token"] = round(
                    self.decode_dispatches / self.total_new_tokens, 4)
            out["burst_hist"] = {str(k): v for k, v
                                 in sorted(self.burst_hist.items())}
            # ITL timestamps are burst-granular once any K > 1 ran
            out["itl_granularity"] = ("burst" if any(
                k > 1 for k in self.burst_hist) else "token")
        if self.spec_rounds:
            # speculation accounting (DESIGN.md §17): the headline wins
            # are acceptance_rate (drafts the target agreed with) and
            # emitted_per_verify_dispatch (> 1 means one target dispatch
            # delivered more than one token — the whole point)
            out["spec"] = {
                "rounds": self.spec_rounds,
                "draft_dispatches": self.spec_draft_dispatches,
                "verify_dispatches": self.spec_verify_dispatches,
                "catchup_dispatches": self.spec_catchup_dispatches,
                "tokens_drafted": self.spec_tokens_drafted,
                "tokens_accepted": self.spec_tokens_accepted,
                "tokens_rejected": self.spec_tokens_rejected,
                "bonus_tokens": self.spec_bonus_tokens,
                "tokens_emitted": self.spec_tokens_emitted,
                "acceptance_rate": round(
                    self.spec_tokens_accepted / self.spec_tokens_drafted, 4)
                if self.spec_tokens_drafted else None,
                "accepted_per_verify_dispatch": round(
                    self.spec_tokens_accepted
                    / self.spec_verify_dispatches, 4),
                "emitted_per_verify_dispatch": round(
                    self.spec_tokens_emitted
                    / self.spec_verify_dispatches, 4),
                "accept_hist": {str(a): c for a, c in
                                sorted(self.spec_accept_hist.items())},
                "plain_tokens_emitted": self.decode_tokens_emitted,
            }
        if (self.spec_rounds or self.decode_dispatches) \
                and self.total_new_tokens:
            # spec-aware amortization across BOTH decode paths: every
            # dispatch that advanced decode state (plain decode/burst
            # entries + spec draft + verify + draft-KV catch-up chunks)
            # over every generated token.  With spec off this is exactly
            # decode_dispatches_per_token.
            out["dispatches_per_token"] = round(
                (self.decode_dispatches + self.spec_draft_dispatches
                 + self.spec_verify_dispatches
                 + self.spec_catchup_dispatches)
                / self.total_new_tokens, 4)
        if self.prefix_hits:
            out["prefix_hits"] = self.prefix_hits
            out["prefix_misses"] = self.prefix_misses
            out["prefix_hit_rate"] = round(
                self.prefix_hits / (self.prefix_hits + self.prefix_misses), 4)
            out["prefix_hit_tokens"] = self.prefix_hit_tokens
            for name, xs in (("ttft_hit", self.ttft_hit),
                             ("ttft_miss", self.ttft_miss)):
                if xs:
                    out[f"{name}_mean_s"] = round(float(np.mean(xs)), 4)
                    out[f"{name}_p50_s"] = round(_pct(xs, 50), 4)
        for name, xs in (("ttft", self.ttft), ("itl", self.itl),
                         ("e2e_latency", self.e2e)):
            if xs:
                out[f"{name}_mean_s"] = round(float(np.mean(xs)), 4)
                out[f"{name}_p50_s"] = round(_pct(xs, 50), 4)
                out[f"{name}_p95_s"] = round(_pct(xs, 95), 4)
        if self.itl_spread:
            # burst-spread estimate alongside the raw percentiles
            # (identical to itl_* when every dispatch was K=1)
            xs = self.itl_spread
            out["itl_burst_spread_mean_s"] = round(float(np.mean(xs)), 4)
            out["itl_burst_spread_p50_s"] = round(_pct(xs, 50), 4)
            out["itl_burst_spread_p95_s"] = round(_pct(xs, 95), 4)
        # --- SLO accounting (DESIGN.md §16) ---
        if self.finish_reasons:
            # disjoint reasons sum to n_requests; 'preempted_resumed' is
            # an overlay (finished after >= 1 preemption), not a term
            fr = dict(sorted(self.finish_reasons.items()))
            if self.n_resumed:
                fr["preempted_resumed"] = self.n_resumed
            out["finish_reasons"] = fr
        if self.queue_wait:
            out["queue_wait_p50_s"] = {
                str(p): round(_pct(xs, 50), 4)
                for p, xs in sorted(self.queue_wait.items())}
            out["queue_wait_p95_s"] = {
                str(p): round(_pct(xs, 95), 4)
                for p, xs in sorted(self.queue_wait.items())}
        if self.n_preemptions:
            out["preemptions"] = self.n_preemptions
            out["preempt_reasons"] = dict(sorted(
                self.preempt_reasons.items()))
        if self.n_rejections:
            out["rejections"] = self.n_rejections
            out["rejection_kinds"] = dict(sorted(
                self.rejection_kinds.items()))
        if self.n_downgrades:
            out["downgrades"] = self.n_downgrades
        if self.n_fault_events:
            out["faults"] = self.n_fault_events
            out["fault_requests"] = self.n_fault_requests
            out["fault_kinds"] = dict(sorted(self.fault_kinds.items()))
        classes = set(self._prio_ttft) | set(self._prio_e2e)
        if len(classes) > 1:
            # the bounded-p99 claim is per class — one overloaded run's
            # aggregate percentiles hide exactly the split that matters
            per: Dict[str, Dict] = {}
            for p in sorted(classes):
                d: Dict = {}
                for name, xs in (("ttft", self._prio_ttft.get(p)),
                                 ("e2e", self._prio_e2e.get(p))):
                    if xs:
                        d[f"{name}_p50_s"] = round(_pct(xs, 50), 4)
                        d[f"{name}_p95_s"] = round(_pct(xs, 95), 4)
                        d[f"{name}_p99_s"] = round(_pct(xs, 99), 4)
                        d[f"n_{name}"] = len(xs)
                per[str(p)] = d
            out["per_priority"] = per
        return out
