"""Serving-level metrics: the quantities the paper's end-to-end workloads
(Table VII) are judged by, surfaced from the continuous-batching scheduler.

  * TTFT   — time to first token: arrival -> first sampled token (includes
             queueing while WAITING plus chunked prefill).
  * ITL    — inter-token latency: gaps between a request's decode tokens.
  * tok/s  — generated-token throughput over the busy window.
  * slot occupancy — time-weighted fraction of KV pool slots in use: the
             serving-level analogue of the paper's sustained-II=1 claim
             (a MAC array only hits its rated throughput if the scheduler
             keeps it fed; so for the pool).
  * burst accounting (DESIGN.md §11) — decode dispatches, token-steps and
             a burst-length histogram: ``decode_dispatches_per_token`` is
             the direct measure of how amortized the decode hot path ran
             (1.0 = one jit entry per token; 1/K at steady bursts of K).

All timestamps come from the scheduler's injectable clock, so tests can
drive a virtual clock and assert on exact values.

**Burst-granularity ITL caveat**: all K tokens of a decode burst surface
at burst end (the whole point is that nothing crosses the host mid-burst),
so their timestamps cluster there — intra-burst ITL gaps are near zero and
the burst's wall time lands on the gap *between* bursts.  Mean ITL and
tok/s are unaffected (same tokens, same wall clock); percentiles are
burst-granular.  ``report()`` flags this via ``itl_granularity``.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


def _pct(values: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(values, np.float64), q))


class ServeMetrics:
    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        # {'n_devices', 'dp', 'tp'} when serving under a mesh (set by the
        # scheduler from engine.topology); None for single-device serving
        self.topology: Optional[Dict] = None
        # {tier: n_slots} when the scheduler serves multiple KV precision
        # tiers from one engine (DESIGN.md §12); None for single-tier —
        # ``n_slots`` above is always the total across tiers
        self.tiers: Optional[Dict[str, int]] = None
        self.ttft: List[float] = []
        self.itl: List[float] = []
        self.e2e: List[float] = []            # per-request total latency
        self.n_requests = 0
        self.total_new_tokens = 0
        self.first_arrival: Optional[float] = None
        self.last_finish: Optional[float] = None
        # time-weighted occupancy integral
        self._occ_integral = 0.0
        self._occ_time = 0.0
        self._last_sample: Optional[float] = None
        # decode-burst accounting (DESIGN.md §11)
        self.decode_dispatches = 0      # jitted decode/burst entries
        self.decode_token_steps = 0     # token-steps those entries covered
        self.decode_tokens_emitted = 0  # tokens that actually surfaced
        self.burst_hist: Dict[int, int] = {}   # planned K -> count

    # -- event hooks (called by the scheduler) -----------------------------
    def on_arrival(self, now: float) -> None:
        if self.first_arrival is None:
            self.first_arrival = now

    def on_step(self, now: float, used_slots: int) -> None:
        """Sample occupancy; weight = wall time since the previous sample."""
        if self._last_sample is not None:
            dt = max(now - self._last_sample, 0.0)
            self._occ_integral += dt * (used_slots / self.n_slots)
            self._occ_time += dt
        self._last_sample = now

    def on_decode_burst(self, k: int, tokens_emitted: int) -> None:
        """One decode dispatch covering ``k`` planned token-steps (k = 1
        for the fused single step).  ``tokens_emitted`` counts the tokens
        that actually surfaced across all rows (rows frozen mid-burst emit
        fewer than k) — its running total vs the dispatch count gives the
        emitted-per-dispatch amortization in ``report()``."""
        self.decode_dispatches += 1
        self.decode_token_steps += k
        self.decode_tokens_emitted += tokens_emitted
        self.burst_hist[k] = self.burst_hist.get(k, 0) + 1

    def on_finish(self, req) -> None:
        self.n_requests += 1
        self.total_new_tokens += req.n_generated
        self.last_finish = req.finish_time
        if req.first_token_time is not None and req.arrival_time is not None:
            self.ttft.append(req.first_token_time - req.arrival_time)
        if req.finish_time is not None and req.arrival_time is not None:
            self.e2e.append(req.finish_time - req.arrival_time)
        if len(req.token_times) > 1:
            self.itl.extend(np.diff(np.asarray(req.token_times)).tolist())

    # -- report ------------------------------------------------------------
    @property
    def occupancy_mean(self) -> float:
        return self._occ_integral / self._occ_time if self._occ_time else 0.0

    def report(self) -> Dict:
        wall = ((self.last_finish - self.first_arrival)
                if self.first_arrival is not None
                and self.last_finish is not None else 0.0)
        out = {
            "n_requests": self.n_requests,
            "total_new_tokens": self.total_new_tokens,
            "wall_s": round(wall, 4),
            "tokens_per_s": round(self.total_new_tokens / wall, 2)
            if wall > 0 else float("nan"),
            "slot_occupancy_mean": round(self.occupancy_mean, 4),
        }
        if self.topology is not None:
            out["topology"] = dict(self.topology)
        if self.tiers is not None:
            out["tiers"] = dict(self.tiers)
        if self.decode_dispatches:
            out["decode_dispatches"] = self.decode_dispatches
            out["decode_token_steps"] = self.decode_token_steps
            out["decode_tokens_emitted"] = self.decode_tokens_emitted
            # per token-step: the literal "jit entries <= 1/K amortized"
            # measure — 1.0 on the K=1 path, 1/K at steady bursts of K,
            # independent of how many rows shared each step
            out["decode_dispatches_per_step"] = round(
                self.decode_dispatches / self.decode_token_steps, 4)
            if self.total_new_tokens:
                out["decode_dispatches_per_token"] = round(
                    self.decode_dispatches / self.total_new_tokens, 4)
            out["burst_hist"] = {str(k): v for k, v
                                 in sorted(self.burst_hist.items())}
            # ITL timestamps are burst-granular once any K > 1 ran
            out["itl_granularity"] = ("burst" if any(
                k > 1 for k in self.burst_hist) else "token")
        for name, xs in (("ttft", self.ttft), ("itl", self.itl),
                         ("e2e_latency", self.e2e)):
            if xs:
                out[f"{name}_mean_s"] = round(float(np.mean(xs)), 4)
                out[f"{name}_p50_s"] = round(_pct(xs, 50), 4)
                out[f"{name}_p95_s"] = round(_pct(xs, 95), 4)
        return out
