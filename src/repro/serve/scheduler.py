"""Continuous-batching scheduler: FCFS admission over slot-based KV pools.

Each ``step()`` does up to three things, all against statically-shaped
jitted engine primitives (DESIGN.md §7, §11):

  1. **Admission** — FCFS per tier: the waiting queue is scanned in
     arrival order and a request is admitted as soon as a KV slot is free
     in *its tier's* pool.  Requests can join at any time, including
     mid-flight between decode steps.  With one tier (the default) this is
     exactly head-of-queue FCFS.
  2. **One prefill chunk** — the oldest PREFILL request advances by one
     fixed-size chunk (chunked prefill *interleaved* with decode, so a long
     prompt never stalls in-flight decodes for more than a chunk).  When
     the prompt completes, its first token is sampled from the chunk
     logits — that token is the request's TTFT event.
  3. **One decode round per tier** — every DECODE-state slot advances.
     Rows are cohorted by KV tier (each tier owns one pool, and a decode
     dispatch operates on one pool), so a mixed bf16/int8/fp8 workload
     issues one dispatch per active tier per round.  Each cohort's round
     is a planned **burst** of K token-steps executed as one jitted
     ``lax.scan`` on device (K = 1 falls back to the fused single step):
     one dispatch and one host sync per K generated tokens instead of per
     token.  K is the min over the cohort's slots of tokens-until-that-
     slot's next scheduling event (length/capacity retirement), clamped to
     1 whenever the waiting queue is non-empty or a prefill is mid-flight —
     so admission latency and chunked-prefill interleaving are byte-
     identical to a burst-free scheduler — and rounded down to a power of
     two so at most log2(max_burst) burst lengths ever compile.  EOS cannot
     be planned for; rows that sample it freeze mid-burst on device.

Retirement (EOS / max-new-tokens / slot capacity) frees the slot
immediately, so the next ``step()`` can admit a waiting request into it —
finished rows never burn decode steps, which is precisely what the old
static-batch ``generate()`` got wrong.

**Precision tiers** (DESIGN.md §12): ``Scheduler(engine, tiers=...)``
builds one pool per KV tier ('bf16' / 'int8' / 'fp8') and requests pick
theirs via ``Request.kv_policy`` — per-request runtime precision
switching inside one engine.  Concurrency is capped per pool, and each
pool is capped by KV bytes per token at ITS tier: with a
``cache_budget_bytes`` the int8/fp8 tiers admit roughly twice the slots
of the bf16 tier from the same budget (DESIGN.md §9), so the tier knob
is a per-request quality/capacity trade served from one engine.  The
scheduling logic itself is storage-agnostic — it sees alloc/free/lengths
per pool, and a request's computation touches only its own tier's slab,
so traffic at other tiers cannot perturb its tokens.

**Observability** (DESIGN.md §13): ``Scheduler(engine, obs=...)`` attaches
a ``repro.obs.Observability`` bundle — a Chrome-trace tracer (per-request
lifecycle spans, per-dispatch prefill/burst events, queue/slot counter
tracks), a metrics registry (the scheduler publishes gauges and counters;
``ServeMetrics`` consumes the same registry), and a model-vs-measured
step profiler.  All trace timestamps come from the scheduler's injectable
clock, so two virtual-clock runs produce byte-identical trace files.
``obs=None`` (default) is a strict no-op: zero extra clock calls, zero
extra host syncs, zero extra dispatches (pinned by tests/test_obs.py).

**SLO-aware scheduling** (DESIGN.md §16): requests carry a priority class
(smaller = more important) and optional TTFT / e2e deadlines.  Admission
scans the queue in priority-then-arrival order (stable: with one class it
IS the FCFS scan), and when a waiter cannot be admitted the scheduler may
**preempt** the lowest-priority DECODE slot of its tier: the victim's
slot is freed (on a paged pool its registered prompt pages stay alive in
the prefix cache), and the victim is requeued with a ``resume_prompt`` —
prompt + all generated tokens but the last.  Re-admission re-prefills
only the tail past the prefix hit, emits nothing for the replayed tokens,
and decode continues at the preserved ``n_generated`` — so, with the
per-(request, step) key schedule, a preempted-then-resumed request's
output is bit-identical to an unpreempted run (pinned in
tests/test_slo_serving.py for slab and paged pools, single-device and
dp x tp).  ``Scheduler(engine, slo=...)`` attaches a ``serve.slo.SLOPolicy``
for admission control (typed rejections), KV-tier downgrade with
hysteresis, and cost-model burst/chunk planning.  Deadlines are enforced
step-granularly from the clock sample each round already takes.

**Fault tolerance** (DESIGN.md §16): every engine dispatch is fenced — a
``StepFault`` (killed dispatch, lost shard, or the ``ServeConfig
(fault_injector=...)`` test hook) or poisoned decode output (sampled ids
outside the vocabulary) invalidates the affected slots and requeues their
requests through the same preempt-and-resume path, with bounded
retry-and-backoff (``ServeConfig.max_fault_retries``, exponential hold in
scheduler steps) instead of process death.  A request that exhausts its
budget retires with ``finish_reason='fault'``.  Because a faulted
dispatch's outputs are dropped whole and recovery replays from the KV
recompute, fault recovery preserves the bit-identity contract.

Determinism: sampling keys are per (request, step) — see request.py — and
row computations are independent of batch composition (dense ops are
row-wise; MoE decode routes each row as its own drop-free single-token
group), so a request's greedy output is identical whether it was served
alone, in a full one-shot batch, admitted mid-flight next to strangers,
advanced K tokens at a time inside a burst, cohorted beside other tiers,
or preempted and resumed.  The clock is injectable for metric tests.
Burst timing caveat: all K tokens of a burst surface at burst end, so
their ``token_times`` are burst-granular (see metrics.py).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Deque, Dict, List, Mapping, Optional, Sequence, \
    Tuple, Union

import numpy as np

from repro.obs.trace import PID_REQUESTS, PID_SCHEDULER
from repro.runtime.fault_tolerance import RetryBudget, StepFault

from .kv_pool import KVCachePool
from .metrics import ServeMetrics
from .request import Request, RequestState, SamplingParams  # noqa: F401
from .sampling import (batched_step_keys, sample_one,  # noqa: F401
                       sample_tokens)
from .spec import DraftEngine, SpecConfig, SpecPlanner, accept_longest_prefix


class Scheduler:
    def __init__(self, engine, *, pool: Optional[KVCachePool] = None,
                 clock: Callable[[], float] = time.monotonic,
                 max_burst: Optional[int] = None,
                 tiers: Union[None, Sequence[str],
                              Mapping[str, Optional[int]]] = None,
                 obs=None, slo=None,
                 spec: Optional[SpecConfig] = None):
        """``tiers``: KV tiers this scheduler serves — a sequence of tier
        names (each pool sized by the engine's ServeConfig: explicit
        ``n_slots`` or budget-derived per tier) or a {tier: n_slots}
        mapping (None values fall back to the config sizing).  Default:
        one pool at the engine policy's tier.  ``pool`` injects a single
        pre-built pool instead (mutually exclusive with ``tiers``).
        ``obs``: a ``repro.obs.Observability`` bundle (tracer / registry /
        profiler / snapshot writer, each optional); None disables all of
        it at zero cost.  ``slo``: a ``serve.slo.SLOPolicy`` — admission
        control, KV-tier downgrade with hysteresis, and cost-model burst/
        chunk planning (DESIGN.md §16); None keeps the policy-free
        admit-everything scheduler.  ``spec``: a ``serve.spec.SpecConfig``
        — speculative decoding with low-precision drafts (DESIGN.md §17):
        eligible decode rounds draft K tokens per row on a cheap twin of
        the engine and verify the whole window in one target dispatch;
        accepted tokens stay bit-identical to non-speculative decode.
        None (default) changes nothing."""
        self.engine = engine
        if pool is not None and tiers is not None:
            raise ValueError("give either pool= or tiers=, not both")
        if pool is not None:
            # an injected pool must be chunk-aligned, or a final-chunk write
            # window past ``capacity`` gets clamp-shifted by
            # dynamic_update_slice onto committed positions (silent KV
            # corruption) — engine.new_pool() aligns automatically
            C = engine.scfg.prefill_chunk
            need = -(-pool.max_len // C) * C
            if pool.capacity < need:
                raise ValueError(
                    f"pool capacity {pool.capacity} not aligned to prefill "
                    f"chunk {C} (need >= {need}); build it with "
                    f"engine.new_pool() or KVCachePool(..., align={C})")
            self.pools: Dict[str, KVCachePool] = {pool.kv_dtype: pool}
        elif tiers is not None:
            items = list(tiers.items()) if isinstance(tiers, Mapping) \
                else [(t, None) for t in tiers]
            if not items:
                raise ValueError("tiers= must name at least one KV tier")
            self.pools = {}
            for tier, n in items:
                p = engine.new_pool(n_slots=n, kv_dtype=tier)
                if p.kv_dtype in self.pools:
                    raise ValueError(f"duplicate KV tier {p.kv_dtype!r}")
                self.pools[p.kv_dtype] = p
        else:
            p = engine.new_pool()
            self.pools = {p.kv_dtype: p}
        # requests that don't ask for a tier (kv_policy=None) land here:
        # the engine policy's tier when served, else the first tier listed
        default = engine.scfg.kv_dtype
        self.default_tier = default if default in self.pools \
            else next(iter(self.pools))
        # burst cap: ServeConfig.max_burst unless overridden per scheduler
        self.max_burst = int(getattr(engine.scfg, "max_burst", 1)
                             if max_burst is None else max_burst)
        assert self.max_burst >= 1
        self.waiting: Deque[Request] = deque()
        self.running: Dict[Tuple[str, int], Request] = {}  # (tier, slot)
        self.finished: List[Request] = []
        self.slo = slo
        # fault tolerance (DESIGN.md §16): bounded per-request retry with
        # exponential backoff; the poisoned-output guard (sampled ids in
        # [0, vocab)) is armed only when a fault injector is — a real
        # deployment would arm an isfinite guard the same way
        self._retry = RetryBudget(
            getattr(engine.scfg, "max_fault_retries", 3))
        self._ft_check = getattr(engine.scfg, "fault_injector",
                                 None) is not None
        # freshest known clock sample (stamped once per step and at every
        # submit) — deadline shedding reads THIS instead of taking extra
        # clock calls, keeping the obs-disabled zero-extra-calls contract
        self._last_now: Optional[float] = None
        self.obs = obs
        self.tracer = obs.tracer if obs is not None else None
        self.profiler = obs.profiler if obs is not None else None
        # timing (clock pair around each engine dispatch) is needed iff
        # someone consumes it; the disabled path takes neither clock call
        self._timed = self.tracer is not None or self.profiler is not None
        # speculative decoding (DESIGN.md §17): the draft twin and its
        # K-controller exist only when asked for — spec=None adds zero
        # state, zero dispatches, zero trace events
        self.spec_cfg = spec
        self.draft = DraftEngine(engine, spec) if spec is not None else None
        self.spec_planner = SpecPlanner(spec) if spec is not None else None
        # stable Perfetto lane per tier on the scheduler process: tid 0 is
        # the prefill lane, decode tiers get 1.. in sorted order; with
        # speculation enabled each tier additionally gets a draft and a
        # verify lane past the decode block (registered ONLY then, so
        # spec-off trace files stay byte-identical)
        self._tier_tid = {t: 1 + i for i, t in enumerate(sorted(self.pools))}
        base = 1 + len(self.pools)
        self._spec_tid = {t: (base + 2 * i, base + 2 * i + 1)
                          for i, t in enumerate(sorted(self.pools))}
        if self.tracer is not None:
            self.tracer.process_name(PID_REQUESTS, "requests")
            self.tracer.process_name(PID_SCHEDULER, "scheduler")
            self.tracer.thread_name(PID_SCHEDULER, 0, "prefill")
            for t, tid in sorted(self._tier_tid.items()):
                self.tracer.thread_name(PID_SCHEDULER, tid, f"decode:{t}")
            if spec is not None:
                for t, (dtid, vtid) in sorted(self._spec_tid.items()):
                    self.tracer.thread_name(PID_SCHEDULER, dtid, f"draft:{t}")
                    self.tracer.thread_name(PID_SCHEDULER, vtid,
                                            f"verify:{t}")
        registry = obs.registry if obs is not None else None
        self._r_steps = self._r_queue = self._r_used = None
        self._r_adm = self._r_chunks = self._r_syncs = None
        self._r_hits = self._r_hit_tokens = self._r_pages = None
        self._syncs_published = 0
        self._any_paged = any(getattr(p, "paged", False)
                              for p in self.pools.values())
        if registry is not None:
            self._r_steps = registry.counter(
                "serve_scheduler_steps_total", "scheduling rounds")
            self._r_queue = registry.gauge(
                "serve_queue_depth", "requests WAITING for a KV slot")
            self._r_used = registry.gauge(
                "serve_slots_used", "occupied KV slots, by tier")
            slots_total = registry.gauge(
                "serve_slots_total", "provisioned KV slots, by tier")
            for t, p in sorted(self.pools.items()):
                slots_total.set(p.n_slots, tier=t)
            self._r_adm = registry.counter(
                "serve_admissions_total",
                "WAITING -> PREFILL transitions, by tier")
            self._r_chunks = registry.counter(
                "serve_prefill_chunks_total",
                "prefill chunk dispatches, by tier")
            self._r_syncs = registry.counter(
                "serve_host_syncs_total",
                "blocking device->host transfers on the serving hot path")
            if self._any_paged:
                self._r_hits = registry.counter(
                    "serve_prefix_hits_total",
                    "admissions that adopted cached prefix pages, by tier")
                self._r_hit_tokens = registry.counter(
                    "serve_prefix_hit_tokens_total",
                    "prompt tokens served from the prefix cache, by tier")
                self._r_pages = registry.gauge(
                    "serve_pages",
                    "page-arena occupancy, by tier and state "
                    "(used / cached / free)")
        self.metrics = ServeMetrics(
            sum(p.n_slots for p in self.pools.values()), registry=registry)
        if len(self.pools) > 1:
            self.metrics.tiers = {t: p.n_slots
                                  for t, p in self.pools.items()}
        # sharded serving is invisible to the scheduling logic (the pool
        # interface is identical), but the mesh shape belongs in reports
        self.metrics.topology = getattr(engine, "topology", None)
        self._clock = clock
        self._next_id = 0
        self.n_steps = 0
        # monotone engine-dispatch id (prefill chunks and decode rounds
        # share the sequence); stamped on every emitted token so the
        # burst-spread ITL estimate and the tracer can attribute tokens
        # to the dispatch that surfaced them.  Advances identically with
        # obs on or off.
        self._dispatch_seq = 0
        # device->host blocking transfers on the serving hot path: final
        # prefill-chunk logits, the first-token sample, one per decode
        # dispatch, and one per key-schedule build (temperature rows,
        # batched across rows)
        self.n_host_syncs = 0

    @property
    def pool(self) -> KVCachePool:
        """The default tier's pool (single-tier callers' interface)."""
        return self.pools[self.default_tier]

    @property
    def n_decode_steps(self) -> int:
        """Decode TOKEN-steps executed (a burst adds its planned K)."""
        return self.metrics.decode_token_steps

    @property
    def n_decode_dispatches(self) -> int:
        """Jitted decode/burst entries (one per tier cohort per round)."""
        return self.metrics.decode_dispatches

    # ------------------------------------------------------------------
    def _resolve_tier(self, req: Request) -> KVCachePool:
        """Resolve and validate the request's KV tier (eagerly, with an
        actionable message) and its end-to-end slot fit."""
        tier = self.default_tier if req.kv_policy is None else req.kv_policy
        if tier not in self.pools:
            raise ValueError(
                f"request kv_policy={req.kv_policy!r}: no pool at that "
                f"tier; this scheduler serves {sorted(self.pools)} "
                "(build it with Scheduler(engine, tiers=[...]))")
        req.tier = tier
        pool = self.pools[tier]
        need = req.prompt_len + req.sampling.max_new_tokens
        if need > pool.max_len:
            raise ValueError(
                f"request needs {need} cache positions "
                f"(prompt {req.prompt_len} + max_new "
                f"{req.sampling.max_new_tokens}) > slot capacity "
                f"{pool.max_len}")
        return pool

    def submit(self, req: Request) -> Request:
        """Enqueue (priority-then-arrival order is applied at admission;
        with one priority class this is exactly FCFS).  With an SLO
        policy attached, the request may be DOWNGRADED to a denser KV
        tier (``req.downgraded_from`` records the original) or shed with
        a typed verdict: it comes back FINISHED with
        ``finish_reason='rejected'`` and ``req.rejection`` set, and is
        never enqueued — callers must check ``is_finished`` when serving
        under a policy."""
        self._resolve_tier(req)
        if req.id is None:
            req.id = self._next_id
        self._next_id = max(self._next_id, req.id) + 1
        req.state = RequestState.WAITING
        req.arrival_time = self._clock()
        self._last_now = req.arrival_time
        req.last_enqueue_time = req.arrival_time
        self.metrics.on_arrival(req.arrival_time)
        if self.slo is not None:
            verdict = self.slo.admit(req, self)
            if req.downgraded_from is not None \
                    and req.tier != req.kv_policy:
                # the policy downgraded the tier in place — re-resolve
                # (and re-validate the fit at the denser tier)
                self._resolve_tier(req)
                self.metrics.on_downgrade(req)
            if verdict is not None:
                req.rejection = verdict
                self.metrics.on_reject(req)
                self._finish_unadmitted(req, "rejected",
                                        req.arrival_time, None)
                return req
        self.waiting.append(req)
        return req

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    @property
    def kv_bytes_per_token(self) -> int:
        """Cache bytes one committed position costs at the default tier
        (pool storage dtype included) — the denominator of the
        slots-per-budget trade."""
        return self.pool.bytes_per_token

    # ------------------------------------------------------------------
    def _plan_burst(self, dec: List[Request], pool: KVCachePool) -> int:
        """Burst length K for one tier cohort's round (DESIGN.md §11).

        K = min over the cohort's rows of the tokens that row can still
        emit before a *predictable* scheduling event — its max-new-tokens
        budget or its slot-capacity horizon — capped by ``max_burst`` and
        rounded DOWN to a power of two (bounds compiled burst variants;
        correctness never depends on the plan, only efficiency).  Clamped
        to 1 whenever admission could happen next round (waiting queue
        non-empty, any tier) or a prefill is mid-flight, so burst mode
        changes neither admission latency nor prefill/decode interleaving.
        EOS is unplannable and is handled by the on-device stop masks
        instead."""
        if self.max_burst <= 1 or self.waiting:
            return 1
        if any(r.state is RequestState.PREFILL
               for r in self.running.values()):
            return 1
        k = self.max_burst
        if self.slo is not None:
            # cost-model cap: largest K whose modeled wall fits the
            # policy's per-round latency budget (DESIGN.md §16)
            k = self.slo.burst_cap(self, dec, pool, k)
        for r in dec:
            budget = r.sampling.max_new_tokens - r.n_generated
            capacity = pool.max_len - int(pool.lengths[r.slot]) - 1
            k = min(k, max(1, min(budget, capacity)))
        return 1 << (k.bit_length() - 1)   # largest power of two <= k

    def step(self) -> Dict[str, List]:
        """One scheduling round.  Returns the tokens emitted this round
        (``emitted``: list of (request, slot, token)) and requests retired
        (``finished``)."""
        emitted: List = []
        finished_now: List[Request] = []

        # 0. deadline shedding (step-granular, from the freshest clock
        # sample already taken): WAITING requests whose TTFT or e2e
        # deadline has already passed can no longer meet their SLO — shed
        # them before they cost a slot
        self._shed_expired_waiting(finished_now)

        # 1. admission: priority-then-deadline-then-arrival scan (EDF
        # within a priority class: requests carrying a TTFT deadline sort
        # by its absolute wall time, deadline-free requests after them;
        # the sort is stable, so with no deadlines set one class is
        # exactly the FCFS scan); a request is admitted when its tier's
        # pool has a free slot (paged: slot AND pages).  When it cannot
        # be admitted and a strictly lower-priority DECODE slot exists in
        # its tier, that victim is PREEMPTED: slot freed (registered
        # prompt pages stay in the prefix cache), request requeued with a
        # resume buffer (DESIGN.md §16).  The scan early-exits once no
        # waiter could be admitted even by preemption: the scan order is
        # priority-sorted, so the first hopeless waiter proves the rest
        # hopeless too — a backlogged queue stays O(sort) per step.
        admitted: List[Request] = []
        if self.waiting:
            free_total = sum(p.n_free for p in self.pools.values())
            order = sorted(self.waiting, key=self._admit_order_key)
            run_prios = [r.priority for r in self.running.values()
                         if r.state is RequestState.DECODE]
            max_run_prio = max(run_prios) if run_prios else None
            for req in order:
                if req.hold_until_step > self.n_steps:
                    continue           # fault backoff: not yet retryable
                if free_total == 0 and (max_run_prio is None
                                        or req.priority >= max_run_prio):
                    break              # neither a slot nor a victim
                if self._try_admit(req):
                    admitted.append(req)
                    free_total = sum(p.n_free
                                     for p in self.pools.values())
                    run_prios = [r.priority
                                 for r in self.running.values()
                                 if r.state is RequestState.DECODE]
                    max_run_prio = max(run_prios) if run_prios else None
            if admitted:
                gone = {id(r) for r in admitted}
                self.waiting = deque(r for r in self.waiting
                                     if id(r) not in gone)

        # 2. prefill chunks for the oldest mid-prefill request (one per
        # round unless the SLO policy budgets more from the cost model)
        n_chunks = 1 if self.slo is None \
            else self.slo.prefill_chunks_per_step(self)
        for _ in range(n_chunks):
            if not self._prefill_one_chunk(emitted, finished_now):
                break

        # 3. one decode round (burst of K token-steps) per tier cohort —
        # or, with speculation enabled and the same conditions under
        # which bursts plan K > 1 (nothing waiting, no prefill
        # mid-flight), a speculative draft/verify round (DESIGN.md §17)
        dec = sorted((r for r in self.running.values()
                      if r.state is RequestState.DECODE), key=lambda r: r.id)
        spec_ok = (self.spec_planner is not None and not self.waiting
                   and not any(r.state is RequestState.PREFILL
                               for r in self.running.values()))
        for tier in sorted({r.tier for r in dec}):
            cohort = [r for r in dec if r.tier == tier]
            pool = self.pools[tier]
            if spec_ok:
                ks = self.spec_planner.plan([(r, r.slot) for r in cohort],
                                            pool)
                if ks >= 1:
                    self._decode_spec(cohort, pool, ks, emitted,
                                      finished_now)
                    continue
            k = self._plan_burst(cohort, pool)
            if k <= 1:
                self._decode_single(cohort, pool, emitted, finished_now)
            else:
                self._decode_burst(cohort, pool, k, emitted, finished_now)

        self.n_steps += 1
        now = self._clock()
        self._last_now = now
        # queue-wait stamps for this round's admissions (the tracer path
        # stamped precisely at admission; everyone else gets the round's
        # clock sample — zero extra clock calls either way)
        for req in admitted:
            if req.admit_time is None:
                req.admit_time = now
            self.metrics.on_admit(req)
        # e2e deadline enforcement for running requests (step-granular)
        for req in [r for r in self.running.values()
                    if r.e2e_deadline_s is not None
                    and r.arrival_time is not None
                    and now - r.arrival_time > r.e2e_deadline_s]:
            self._retire(req, "deadline_exceeded", now, finished_now)
        self.metrics.on_step(
            now, {t: p.n_used for t, p in self.pools.items()})
        if self.obs is not None:
            self._obs_step(now)
        return {"emitted": emitted, "finished": finished_now}

    # ------------------------------------------------------------------
    # Admission, preemption, deadline shedding (DESIGN.md §16)
    # ------------------------------------------------------------------
    @staticmethod
    def _admit_order_key(r: Request) -> Tuple[int, float]:
        """Admission scan order: priority class first, then EDF within
        the class — the ABSOLUTE TTFT deadline (arrival + relative
        deadline), with deadline-free requests after every deadline
        carrier.  The sort is stable, so arrival order breaks ties and a
        deadline-free single-class queue is exactly FCFS."""
        if r.ttft_deadline_s is None:
            return (r.priority, float("inf"))
        return (r.priority, (r.arrival_time or 0.0) + r.ttft_deadline_s)

    def _try_admit(self, req: Request) -> bool:
        """Admit ``req`` into its tier's pool, preempting lower-priority
        DECODE slots of that tier if needed (and possible).  On success
        the request is PREFILL-state and registered in ``running``."""
        pool = self.pools[req.tier]
        max_new = req.sampling.max_new_tokens - max(req.n_generated - 1, 0)
        paged = getattr(pool, "paged", False)
        while True:
            if paged:
                # paged admission (DESIGN.md §15): a slot AND enough
                # arena pages for the request's worst-case growth; a
                # prefix-cache hit adopts shared pages and resumes
                # prefill past them — which is what makes a preempted
                # request's resume re-prefill only its generated tail
                adm = pool.admit(req.prefill_tokens, max_new)
                if adm is not None:
                    req.slot, req.prefill_pos, req.prefix_hit_tokens = adm
                    break
            elif pool.n_free:
                req.slot = pool.alloc()
                req.prefill_pos = 0
                break
            victim = self._pick_victim(req.tier, req.priority)
            if victim is None:
                return False
            self._preempt(victim, reason="priority")
        if paged and self._r_hits is not None and req.prefix_hit_tokens > 0:
            self._r_hits.inc(tier=req.tier)
            self._r_hit_tokens.inc(req.prefix_hit_tokens, tier=req.tier)
        req.state = RequestState.PREFILL
        # one-time prompt pre-pass: int32 + chunk padding hoisted out of
        # the per-chunk loop (engine slices views from it); rebuilt after
        # a preemption because the resume buffer replaced the prompt
        if req.prompt_padded is None:
            req.prompt_padded, _ = self.engine.pad_prompt(
                req.prefill_tokens)
        self.running[(req.tier, req.slot)] = req
        # admit stamp feeds the WAITING span; gated so the disabled path
        # makes zero extra clock calls
        if self.tracer is not None:
            req.admit_time = self._clock()
        if self._r_adm is not None:
            self._r_adm.inc(tier=req.tier)
        return True

    def _pick_victim(self, tier: str,
                     priority: int) -> Optional[Request]:
        """The DECODE request of ``tier`` to evict for a priority-
        ``priority`` waiter: strictly lower class only (never preempt an
        equal — that would livelock two requests trading one slot), the
        lowest class first, and among equals the one with the least
        generated output (cheapest KV recompute), then the youngest."""
        cands = [r for r in self.running.values()
                 if r.tier == tier and r.state is RequestState.DECODE
                 and r.priority > priority]
        if not cands:
            return None
        return max(cands,
                   key=lambda r: (r.priority, -r.n_generated, r.id))

    def _preempt(self, req: Request, reason: str = "priority") -> None:
        """Evict ``req`` from its slot and requeue it WAITING with a
        resume buffer: the original prompt plus every generated token but
        the last (the last token is the next decode INPUT — its KV was
        never written).  The slot's pages are freed; on a paged pool the
        registered prompt pages stay alive in the prefix cache, so
        re-admission prefix-hits them and re-prefills only the generated
        tail.  ``n_generated`` and the output are preserved, which (with
        per-(request, step) keys) makes the resumed continuation
        bit-identical to an unpreempted run."""
        assert req.state in (RequestState.PREFILL, RequestState.DECODE)
        del self.running[(req.tier, req.slot)]
        if self.draft is not None:
            # mirrored draft-KV state is slot-keyed: stale the moment the
            # target slot is freed (re-admission catches up from the
            # request's own committed tokens)
            self.draft.release(req.tier, req.slot)
        self.pools[req.tier].free(req.slot)
        req.slot = None
        req.state = RequestState.WAITING
        if req.output_tokens:
            req.resume_prompt = np.concatenate(
                [req.prompt,
                 np.asarray(req.output_tokens[:-1], np.int32)]) \
                if req.n_generated > 1 else req.prompt
        req.prompt_padded = None
        req.prefill_pos = 0
        req.prefix_hit_tokens = 0
        req.n_preemptions += 1
        req.last_enqueue_time = self._last_now
        req.admit_time = None
        self.waiting.append(req)
        self.metrics.on_preempt(req, reason=reason)
        if self.tracer is not None and self._last_now is not None:
            self.tracer.instant(
                "preempted", self._last_now, pid=PID_REQUESTS,
                tid=req.id or 0,
                args={"reason": reason, "n_generated": req.n_generated,
                      "n_preemptions": req.n_preemptions})

    def _shed_expired_waiting(self, finished_now: List[Request]) -> None:
        """Retire WAITING requests whose TTFT or e2e deadline already
        passed (they can no longer meet their SLO; holding them only
        starves feasible work).  Uses the freshest existing clock sample
        — no extra clock calls on the disabled-obs path."""
        now = self._last_now
        if now is None or not self.waiting:
            return
        expired = [
            r for r in self.waiting
            if r.arrival_time is not None
            and ((r.ttft_deadline_s is not None
                  and r.first_token_time is None
                  and now - r.arrival_time > r.ttft_deadline_s)
                 or (r.e2e_deadline_s is not None
                     and now - r.arrival_time > r.e2e_deadline_s))]
        if not expired:
            return
        gone = {id(r) for r in expired}
        self.waiting = deque(r for r in self.waiting
                             if id(r) not in gone)
        for r in expired:
            self._finish_unadmitted(r, "deadline_exceeded", now,
                                    finished_now)

    def _finish_unadmitted(self, req: Request, reason: str,
                           now: float,
                           finished_now: Optional[List[Request]]) -> None:
        """Retire a request that holds no slot (rejected at submit, or
        shed from the WAITING queue)."""
        req.state = RequestState.FINISHED
        req.finish_reason = reason
        req.finish_time = now
        self.finished.append(req)
        if finished_now is not None:
            finished_now.append(req)
        self.metrics.on_finish(req)
        if self.tracer is not None:
            self._trace_request(req)

    # ------------------------------------------------------------------
    # Fault recovery (DESIGN.md §16)
    # ------------------------------------------------------------------
    def _on_fault(self, cohort: List[Request], fault: StepFault,
                  finished_now: List[Request]) -> None:
        """One engine dispatch died or returned poisoned output: drop the
        dispatch's outputs whole, invalidate every affected slot, and
        requeue the requests through the preempt-and-resume path (their
        KV is recomputed, so the recovery is bit-identical).  Each
        request's retry budget is charged; exhausted requests retire with
        ``finish_reason='fault'``; survivors are held back
        exponentially-longer each time (backoff in scheduler steps)."""
        self.metrics.on_fault(fault, len(cohort))
        for r in list(cohort):
            backoff = self._retry.record_fault(r.id)
            r.n_faults += 1
            if backoff is None:
                # budget exhausted: permanent retirement.  The slot is
                # still owned here — _retire frees it.
                now = self._last_now if self._last_now is not None \
                    else r.arrival_time
                self._retire(r, "fault", now, finished_now)
            else:
                r.hold_until_step = self.n_steps + backoff
                self._preempt(r, reason="fault")

    def _tokens_poisoned(self, toks: np.ndarray) -> bool:
        """Poisoned-output guard (armed only with a fault injector, like
        a deployment's isfinite guard): sampled ids must be valid vocab
        entries."""
        return bool(np.any((toks < 0) | (toks >= self.engine.cfg.vocab)))

    def _prefill_one_chunk(self, emitted: List,
                           finished_now: List[Request]) -> bool:
        """One prefill-chunk dispatch for the oldest mid-prefill request;
        returns False when there was nothing to prefill or the served
        request finished its prompt (callers budgeting several chunks per
        round stop there).  Serves the resume buffer after a preemption —
        the final chunk of a resume emits NOTHING (those tokens were
        already delivered; only their KV needed recomputing)."""
        pre = [r for r in self.running.values()
               if r.state is RequestState.PREFILL]
        if not pre:
            return False
        req = min(pre, key=lambda r: r.id)
        pool = self.pools[req.tier]
        self._dispatch_seq += 1
        start = req.prefill_pos
        plen = req.prefill_len
        t0 = self._clock() if self._timed else 0.0
        try:
            chunk_logits = self.engine.prefill_chunk_into_slot(
                pool, req.slot, req.prompt_padded, start,
                prompt_len=plen, need_logits=not req.is_resuming)
        except StepFault as f:
            self._on_fault([req], f, finished_now)
            return False
        C = self.engine.scfg.prefill_chunk
        req.prefill_pos = min(start + C, plen)
        final = req.prefill_pos >= plen
        resumed = req.is_resuming
        if final:
            req.state = RequestState.DECODE
            if getattr(pool, "paged", False):
                # publish the committed whole pages to the prefix cache —
                # later requests (or this one, preempted again) with the
                # same token prefix adopt them instead of re-prefilling
                pool.register_prefix(req.slot, req.prefill_tokens)
            if resumed:
                # replay complete: KV now covers prompt + generated[:-1];
                # decode continues at the preserved n_generated with
                # output_tokens[-1] as the next input.  No logits were
                # computed, nothing crosses the host, nothing is emitted.
                req.resume_prompt = None
            else:
                # two blocking transfers: the final-chunk logits and the
                # sampled first token
                self.n_host_syncs += 2
                tok = sample_one(chunk_logits[(plen - 1) % C],
                                 req.step_key(), req.sampling.temperature)
        if self._timed:
            t1 = self._clock()
            n_tok = req.prefill_pos - start
            if self.tracer is not None:
                self.tracer.complete(
                    "prefill_chunk", t0, t1, pid=PID_SCHEDULER, tid=0,
                    args={"req": req.id, "tier": req.tier, "pos": start,
                          "tokens": n_tok, "final": final,
                          "dispatch": self._dispatch_seq})
            if self.profiler is not None:
                self.profiler.record_prefill(
                    tier=req.tier, n_tokens=n_tok, wall_s=t1 - t0)
        if self._r_chunks is not None:
            self._r_chunks.inc(tier=req.tier)
        if final and not resumed:
            self._emit(req, tok, emitted, finished_now,
                       dispatch=self._dispatch_seq)
        return not final

    def _obs_step(self, now: float) -> None:
        """Post-round observability publication (obs-enabled path only):
        scheduler gauges/counters into the registry, queue/slot counter
        tracks into the trace, and the periodic snapshot tick."""
        if self._r_steps is not None:
            self._r_steps.inc()
            self._r_queue.set(len(self.waiting))
            for t, p in sorted(self.pools.items()):
                self._r_used.set(p.n_used, tier=t)
            # publish by delta so the counter stays monotone while
            # n_host_syncs remains the raw baseline-pinned tally
            self._r_syncs.inc(self.n_host_syncs - self._syncs_published)
            self._syncs_published = self.n_host_syncs
            if self._r_pages is not None:
                for t, p in sorted(self.pools.items()):
                    if getattr(p, "paged", False):
                        self._r_pages.set(p.pages_in_use, tier=t,
                                          state="used")
                        self._r_pages.set(p.pages_cached, tier=t,
                                          state="cached")
                        self._r_pages.set(p.pages_free, tier=t,
                                          state="free")
        if self.tracer is not None:
            self.tracer.counter("queue_depth", now,
                                {"waiting": len(self.waiting)})
            self.tracer.counter(
                "slots_used", now,
                {t: self.pools[t].n_used for t in sorted(self.pools)})
        self.obs.on_step(now)

    def _key_schedule(self, dec: List[Request], k: int,
                      keys: np.ndarray, temps: np.ndarray) -> None:
        """Fill the [k, n_slots, 2] ``keys`` schedule and [n_slots]
        ``temps`` for the temperature rows of ``dec`` — ONE batched
        computation and ONE blocking transfer for the whole round
        (greedy rows keep key 0; their key is never consumed)."""
        trows = [r for r in dec if r.sampling.temperature > 0]
        if not trows:
            return
        sched = batched_step_keys(
            [r.sampling.seed for r in trows], [r.id or 0 for r in trows],
            [r.n_generated for r in trows], k)          # [R, k, 2]
        self.n_host_syncs += 1
        for r, row in zip(trows, sched):
            temps[r.slot] = r.sampling.temperature
            keys[:, r.slot] = row

    def _decode_single(self, dec: List[Request], pool: KVCachePool,
                       emitted: List, finished_now: List[Request]) -> None:
        """K = 1: one fused decode+sample step for one tier cohort
        (sampling still on device — only [n_slots] token ids cross to the
        host)."""
        n = pool.n_slots
        tokens = np.zeros((n,), np.int32)
        keys = np.zeros((1, n, 2), np.uint32)    # inactive rows: key 0
        temps = np.zeros((n,), np.float32)
        for r in dec:
            tokens[r.slot] = r.last_token
        self._key_schedule(dec, 1, keys, temps)
        if getattr(pool, "paged", False):
            # pin every active row's write position (fresh page at a page
            # boundary) before the dispatch writes there
            pool.ensure_decode([r.slot for r in dec], 1)
        self._dispatch_seq += 1
        ctx = self._cohort_context(dec, pool)
        t0 = self._clock() if self._timed else 0.0
        try:
            toks = self.engine.decode_slots(pool, tokens, keys[0], temps)
        except StepFault as f:
            self._on_fault(dec, f, finished_now)
            return
        self.n_host_syncs += 1
        if self._ft_check \
                and self._tokens_poisoned(toks[[r.slot for r in dec]]):
            self._on_fault(dec, StepFault("nan", "decode ids out of vocab"),
                           finished_now)
            return
        if self._timed:
            self._obs_decode(dec, pool, 1, len(dec), ctx, t0, self._clock())
        self.metrics.on_decode_burst(1, len(dec), tier=pool.kv_dtype)
        for r in dec:
            # the input token's KV was just written at lengths[slot]
            pool.lengths[r.slot] += 1
            self._emit(r, int(toks[r.slot]), emitted, finished_now,
                       dispatch=self._dispatch_seq)

    def _decode_burst(self, dec: List[Request], pool: KVCachePool, k: int,
                      emitted: List, finished_now: List[Request]) -> None:
        """K > 1: one device-resident burst for one tier cohort.  Emission
        replays the device's step-major order host-side, so `_emit`
        bookkeeping (retirement, slot free, metrics) sees exactly the
        sequence K single steps would have produced."""
        n = pool.n_slots
        tokens = np.zeros((n,), np.int32)
        temps = np.zeros((n,), np.float32)
        eos = np.full((n,), -1, np.int32)
        active = np.zeros((n,), bool)
        rem = np.zeros((n,), np.int32)
        keys = np.zeros((k, n, 2), np.uint32)
        for r in dec:
            tokens[r.slot] = r.last_token
            eos[r.slot] = r.sampling.eos_id
            active[r.slot] = True
            rem[r.slot] = r.sampling.max_new_tokens - r.n_generated
        self._key_schedule(dec, k, keys, temps)
        if getattr(pool, "paged", False):
            # pin the whole K-step write window per row, capped at each
            # row's remaining budget — overshoot writes from rows that
            # freeze mid-burst land in the garbage page via their
            # unmapped table entries, not in allocated pages
            pool.ensure_decode([r.slot for r in dec], k,
                               [int(rem[r.slot]) for r in dec])
        self._dispatch_seq += 1
        ctx = self._cohort_context(dec, pool)
        t0 = self._clock() if self._timed else 0.0
        try:
            toks, valid = self.engine.decode_burst(
                pool, tokens, keys, temps, active, rem, eos)
        except StepFault as f:
            self._on_fault(dec, f, finished_now)
            return
        self.n_host_syncs += 1
        if self._ft_check and self._tokens_poisoned(toks[valid]):
            # the burst committed pool.lengths before the guard tripped;
            # preempt-and-requeue frees the slot (and its pages), so the
            # poisoned commits never reach a served token
            self._on_fault(dec, StepFault("nan", "burst ids out of vocab"),
                           finished_now)
            return
        n_emit = int(valid.sum())
        if self._timed:
            self._obs_decode(dec, pool, k, n_emit, ctx, t0, self._clock())
        self.metrics.on_decode_burst(k, n_emit, tier=pool.kv_dtype)
        # slots are captured before emission: _emit may retire a request
        # mid-replay (clearing req.slot), but its already-emitted burst
        # tokens are still addressed by the slot it occupied on device
        rows = [(r, r.slot) for r in dec]
        for t in range(k):
            for r, slot in rows:
                if valid[t, slot]:
                    # engine.decode_burst already committed pool.lengths
                    self._emit(r, int(toks[t, slot]), emitted, finished_now,
                               dispatch=self._dispatch_seq)

    def _decode_spec(self, dec: List[Request], pool: KVCachePool, k: int,
                     emitted: List, finished_now: List[Request]) -> None:
        """One speculative round for one tier cohort (DESIGN.md §17):
        draft K tokens per row on the DraftEngine's mirrored low-precision
        pool, verify the whole [last, d_1..d_K] window in ONE target
        dispatch, and emit the longest agreeing prefix plus the target's
        own next sample.  Every emitted token was sampled by the TARGET
        model with the request's real per-(id, n_generated) step key, so
        the output is bit-identical to non-speculative decode at any
        acceptance rate; a fully-rejected round still emits the verify's
        position-0 sample (exactly the plain step's token).  Three host
        syncs per round (key schedule, draft burst, verify) cover up to
        K+1 tokens per row."""
        tier = pool.kv_dtype
        n = pool.n_slots
        s = k + 1
        rows = [(r, r.slot) for r in dec]
        # draft catch-up: replay committed-token suffixes the draft pool
        # missed (first spec round in a slot, the bonus position after a
        # fully-accepted round, plain/faulted rounds while speculation
        # cooled down) — KV-only prefill chunks, no host sync
        n_catchup = self.draft.catch_up(tier, pool, rows)
        # ONE key schedule serves the whole round: draft step t consumes
        # keys[t] (token n_generated + t) and verify position j consumes
        # keys[j] — the shared Gumbel draw that makes temperature-row
        # drafts line up with the target's own samples
        keys = np.zeros((s, n, 2), np.uint32)
        temps = np.zeros((n,), np.float32)
        self._key_schedule(dec, s, keys, temps)
        self._dispatch_seq += 1
        t0 = self._clock() if self._timed else 0.0
        drafts = self.draft.draft_burst(tier, pool, rows, k, keys[:k],
                                        temps)
        self.n_host_syncs += 1
        t1 = self._clock() if self._timed else 0.0
        if self.tracer is not None:
            self.tracer.complete(
                "spec_draft", t0, t1, pid=PID_SCHEDULER,
                tid=self._spec_tid[tier][0],
                args={"tier": tier, "k": k, "rows": len(dec),
                      "catchup_chunks": n_catchup,
                      "dispatch": self._dispatch_seq})
        window = np.zeros((n, s), np.int32)
        rems = np.zeros((n,), np.int32)
        for r, slot in rows:
            window[slot, 0] = r.last_token
            window[slot, 1:] = drafts[:, slot]
            rems[slot] = r.sampling.max_new_tokens - r.n_generated
        if getattr(pool, "paged", False):
            # pin the S-wide verify window per row (planner capped K by
            # each row's budget, so rem >= S and nothing lands in the
            # garbage page on the accepted path)
            pool.ensure_decode([slot for _, slot in rows], s,
                               [int(rems[slot]) for _, slot in rows])
        self._dispatch_seq += 1
        verify_dispatch = self._dispatch_seq
        t2 = self._clock() if self._timed else 0.0
        try:
            verified = self.engine.verify_slots(pool, window, keys, temps)
        except StepFault as f:
            self._on_fault(dec, f, finished_now)
            return
        self.n_host_syncs += 1
        if self._ft_check and self._tokens_poisoned(
                verified[:, [slot for _, slot in rows]]):
            # the verify's outputs are dropped whole; target lengths were
            # never committed, so the poisoned KV writes stay masked and
            # the preempt-recompute recovery is bit-identical
            self._on_fault(dec, StepFault("nan", "verify ids out of vocab"),
                           finished_now)
            return
        # host acceptance: longest agreeing prefix + the target's own
        # bonus/correction sample, truncated by first-EOS and budget
        plan: List[Tuple[Request, int, int]] = []
        drafted = accepted = emitted_total = 0
        for r, slot in rows:
            n_emit, n_acc = accept_longest_prefix(
                drafts[:, slot], verified[:, slot], r.sampling.eos_id,
                int(rems[slot]))
            plan.append((r, slot, n_emit))
            drafted += k
            accepted += n_acc
            emitted_total += n_emit
        # commit target lengths FIRST (the verify wrote all S positions;
        # committing only n_emit IS the rejection rollback — everything
        # past the committed length is garbage-but-masked), then sync the
        # draft pool to the committed state in one length assignment
        for r, slot, n_emit in plan:
            pool.lengths[slot] += n_emit
        self.draft.sync_lengths(tier, pool, rows)
        self.spec_planner.observe(drafted, accepted)
        if self._timed:
            t3 = self._clock()
            if self.tracer is not None:
                self.tracer.complete(
                    "spec_verify", t2, t3, pid=PID_SCHEDULER,
                    tid=self._spec_tid[tier][1],
                    args={"tier": tier, "k": k, "rows": len(dec),
                          "accepted": accepted, "emitted": emitted_total,
                          "dispatch": verify_dispatch})
        self.metrics.on_spec_round(
            k=k, rows=len(dec), drafted=drafted, accepted=accepted,
            emitted=emitted_total, catchup_dispatches=n_catchup, tier=tier)
        # step-major emission replay — the exact sequence K+1 single
        # steps would have produced; slots captured pre-emission because
        # _emit may retire a request mid-replay
        for t in range(s):
            for r, slot, n_emit in plan:
                if t < n_emit:
                    self._emit(r, int(verified[t, slot]), emitted,
                               finished_now, dispatch=verify_dispatch)

    def _cohort_context(self, dec: List[Request], pool: KVCachePool) -> int:
        """Mean committed context across a cohort BEFORE its dispatch —
        what the analytical model prices the round's KV streaming at.
        Host-side numpy only; called on the obs-enabled path."""
        if self.profiler is None:
            return 0
        return int(round(float(
            np.mean([pool.lengths[r.slot] for r in dec]))))

    def _obs_decode(self, dec: List[Request], pool: KVCachePool, k: int,
                    n_emit: int, ctx: int, t0: float, t1: float) -> None:
        """Per-dispatch observability for one tier cohort's decode round:
        a trace slice on the tier's lane and a profiler record (t1 - t0
        spans the jitted dispatch INCLUDING its blocking device->host
        transfer — the burst's true host-visible wall)."""
        tier = pool.kv_dtype
        if self.profiler is not None:
            self.profiler.record_decode(
                tier=tier, k=k, rows=len(dec), context=ctx,
                kv_bytes_per_token=pool.bytes_per_token, wall_s=t1 - t0)
        if self.tracer is not None:
            self.tracer.complete(
                "decode_burst", t0, t1, pid=PID_SCHEDULER,
                tid=self._tier_tid[tier],
                args={"tier": tier, "k": k, "rows": len(dec),
                      "emitted": n_emit,
                      "slots": sorted(r.slot for r in dec),
                      "dispatch": self._dispatch_seq})

    def run(self, max_steps: Optional[int] = None) -> None:
        """Step until every submitted request is FINISHED."""
        steps = 0
        while self.has_work:
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(f"scheduler did not drain in {steps} steps")
            self.step()
            steps += 1

    # ------------------------------------------------------------------
    def _emit(self, req: Request, tok: int, emitted: List,
              finished_now: List[Request], dispatch: int = -1) -> None:
        now = self._clock()
        req.output_tokens.append(tok)
        req.token_times.append(now)
        req.token_dispatches.append(dispatch)
        if req.first_token_time is None:
            req.first_token_time = now
        emitted.append((req, req.slot, tok))
        sp = req.sampling
        if sp.eos_id >= 0 and tok == sp.eos_id:
            self._retire(req, "eos", now, finished_now)
        elif req.n_generated >= sp.max_new_tokens:
            self._retire(req, "length", now, finished_now)
        elif req.prompt_len + req.n_generated >= \
                self.pools[req.tier].max_len:
            # defensive: submit() bounds prompt+max_new, so this only fires
            # for requests constructed around the validation.  The device
            # burst mirrors this exact condition in its stop mask.
            self._retire(req, "capacity", now, finished_now)

    def _retire(self, req: Request, reason: str, now: float,
                finished_now: List[Request]) -> None:
        req.state = RequestState.FINISHED
        req.finish_reason = reason
        req.finish_time = now
        del self.running[(req.tier, req.slot)]
        if self.draft is not None:
            self.draft.release(req.tier, req.slot)
        self.pools[req.tier].free(req.slot)
        req.slot = None
        self._retry.clear(req.id)
        self.finished.append(req)
        finished_now.append(req)
        self.metrics.on_finish(req)
        if self.tracer is not None:
            self._trace_request(req)

    def _trace_request(self, req: Request) -> None:
        """Emit the request's lifecycle spans at retirement — the spans
        are reconstructed from the timestamps the hot path already
        stamped, so tracing adds nothing per token."""
        tr = self.tracer
        tid = req.id or 0
        tr.thread_name(PID_REQUESTS, tid, f"req {tid}")
        a, ad = req.arrival_time, req.admit_time
        ft, fin = req.first_token_time, req.finish_time
        if a is not None and ad is not None:
            tr.complete("WAITING", a, ad, pid=PID_REQUESTS, tid=tid,
                        args={"tier": req.tier})
        if ad is not None and ft is not None:
            tr.complete("PREFILL", ad, ft, pid=PID_REQUESTS, tid=tid,
                        args={"prompt_len": req.prompt_len})
        if ft is not None and fin is not None:
            tr.complete("DECODE", ft, fin, pid=PID_REQUESTS, tid=tid,
                        args={"n_generated": req.n_generated})
        if ft is not None:
            tr.instant("first_token", ft, pid=PID_REQUESTS, tid=tid)
        if fin is not None:
            tr.instant("finished", fin, pid=PID_REQUESTS, tid=tid,
                       args={"reason": req.finish_reason,
                             "n_generated": req.n_generated})
