"""Continuous-batching scheduler: FCFS admission over a slot-based KV pool.

Each ``step()`` does up to three things, all against statically-shaped
jitted engine primitives (DESIGN.md §7):

  1. **Admission** — FCFS: while a KV slot is free, the oldest WAITING
     request checks one out and enters PREFILL.  Requests can join at any
     time, including mid-flight between decode steps.
  2. **One prefill chunk** — the oldest PREFILL request advances by one
     fixed-size chunk (chunked prefill *interleaved* with decode, so a long
     prompt never stalls in-flight decodes for more than a chunk).  When
     the prompt completes, its first token is sampled from the chunk
     logits — that token is the request's TTFT event.
  3. **One decode batch** — every DECODE-state slot advances one token in
     a single [n_slots] batched step.  Inactive slots ride along (static
     shapes) and are ignored host-side.

Retirement (EOS / max-new-tokens / slot capacity) frees the slot
immediately, so the next ``step()`` can admit a waiting request into it —
finished rows never burn decode steps, which is precisely what the old
static-batch ``generate()`` got wrong.

Concurrency is capped by the pool, and the pool is capped by KV bytes per
token: with a quantized pool (``ServeConfig.kv_dtype`` = 'int8'/'fp8' and a
``cache_budget_bytes``) the same cache memory admits roughly twice the
slots, which is the whole point of extending the mixed-precision plan to
the KV side (DESIGN.md §9).  The scheduler itself is storage-agnostic — it
sees alloc/free/lengths, and quantization is per (position, head), so a
request's committed cache bytes never depend on what shared its batches.

Determinism: sampling keys are per (request, step) — see request.py — and
row computations are independent of batch composition (dense ops are
row-wise; MoE decode routes each row as its own drop-free single-token
group), so a request's greedy output is identical whether it was served
alone, in a full one-shot batch, or admitted mid-flight next to strangers.
The clock is injectable for metric tests.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kv_pool import KVCachePool
from .metrics import ServeMetrics
from .request import Request, RequestState, SamplingParams  # noqa: F401


@jax.jit
def _sample_tokens(logits, keys, temperatures):
    """Batched per-row sampling: logits [N, V], keys [N, 2], temps [N].
    Greedy when a row's temperature <= 0, else temperature-scaled
    categorical.  One dispatch + one host transfer for the whole decode
    batch instead of N round-trips on the serving hot path (the single
    first-token sample reuses this with N=1 so there is exactly one
    sampling rule)."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperatures, jnp.float32(1e-6))[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, logits / t)
    return jnp.where(temperatures <= 0, greedy, sampled.astype(jnp.int32))


def _sample_one(logits, key, temperature) -> int:
    return int(_sample_tokens(
        logits[None], jnp.asarray(key)[None],
        jnp.asarray([temperature], jnp.float32))[0])


class Scheduler:
    def __init__(self, engine, *, pool: Optional[KVCachePool] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.engine = engine
        if pool is None:
            pool = engine.new_pool()
        else:
            # an injected pool must be chunk-aligned, or a final-chunk write
            # window past ``capacity`` gets clamp-shifted by
            # dynamic_update_slice onto committed positions (silent KV
            # corruption) — engine.new_pool() aligns automatically
            C = engine.scfg.prefill_chunk
            need = -(-pool.max_len // C) * C
            if pool.capacity < need:
                raise ValueError(
                    f"pool capacity {pool.capacity} not aligned to prefill "
                    f"chunk {C} (need >= {need}); build it with "
                    f"engine.new_pool() or KVCachePool(..., align={C})")
        self.pool = pool
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}      # slot -> Request
        self.finished: List[Request] = []
        self.metrics = ServeMetrics(self.pool.n_slots)
        # sharded serving is invisible to the scheduling logic (the pool
        # interface is identical), but the mesh shape belongs in reports
        self.metrics.topology = getattr(engine, "topology", None)
        self._clock = clock
        self._next_id = 0
        self.n_steps = 0
        self.n_decode_steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> Request:
        """FCFS enqueue.  Validates the request fits a slot end-to-end."""
        need = req.prompt_len + req.sampling.max_new_tokens
        if need > self.pool.max_len:
            raise ValueError(
                f"request needs {need} cache positions "
                f"(prompt {req.prompt_len} + max_new "
                f"{req.sampling.max_new_tokens}) > slot capacity "
                f"{self.pool.max_len}")
        if req.id is None:
            req.id = self._next_id
        self._next_id = max(self._next_id, req.id) + 1
        req.state = RequestState.WAITING
        req.arrival_time = self._clock()
        self.waiting.append(req)
        self.metrics.on_arrival(req.arrival_time)
        return req

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    @property
    def kv_bytes_per_token(self) -> int:
        """Cache bytes one committed position costs (pool storage dtype
        included) — the denominator of the slots-per-budget trade."""
        return self.pool.bytes_per_token

    # ------------------------------------------------------------------
    def step(self) -> Dict[str, List]:
        """One scheduling round.  Returns the tokens emitted this round
        (``emitted``: list of (request, slot, token)) and requests retired
        (``finished``)."""
        emitted: List = []
        finished_now: List[Request] = []

        # 1. admission: free slots go to the oldest waiting requests
        while self.waiting and self.pool.n_free:
            req = self.waiting.popleft()
            req.slot = self.pool.alloc()
            req.state = RequestState.PREFILL
            req.prefill_pos = 0
            self.running[req.slot] = req

        # 2. one prefill chunk for the oldest mid-prefill request
        pre = [r for r in self.running.values()
               if r.state is RequestState.PREFILL]
        if pre:
            req = min(pre, key=lambda r: r.id)
            chunk_logits = self.engine.prefill_chunk_into_slot(
                self.pool, req.slot, req.prompt, req.prefill_pos)
            C = self.engine.scfg.prefill_chunk
            req.prefill_pos = min(req.prefill_pos + C, req.prompt_len)
            if req.prefill_pos >= req.prompt_len:
                req.state = RequestState.DECODE
                tok = _sample_one(chunk_logits[(req.prompt_len - 1) % C],
                                  req.step_key(), req.sampling.temperature)
                self._emit(req, tok, emitted, finished_now)

        # 3. one decode batch over every DECODE slot
        dec = sorted((r for r in self.running.values()
                      if r.state is RequestState.DECODE), key=lambda r: r.id)
        if dec:
            n = self.pool.n_slots
            tokens = np.zeros((n,), np.int32)
            keys = np.zeros((n, 2), np.uint32)       # inactive rows: key 0
            temps = np.zeros((n,), np.float32)
            for r in dec:
                tokens[r.slot] = r.last_token
                keys[r.slot] = np.asarray(r.step_key())
                temps[r.slot] = r.sampling.temperature
            logits = self.engine.decode_slots(self.pool, tokens)
            self.n_decode_steps += 1
            toks = np.asarray(_sample_tokens(logits, jnp.asarray(keys),
                                             jnp.asarray(temps)))
            for r in dec:
                # the input token's KV was just written at lengths[slot]
                self.pool.lengths[r.slot] += 1
                self._emit(r, int(toks[r.slot]), emitted, finished_now)

        self.n_steps += 1
        self.metrics.on_step(self._clock(), self.pool.n_used)
        return {"emitted": emitted, "finished": finished_now}

    def run(self, max_steps: Optional[int] = None) -> None:
        """Step until every submitted request is FINISHED."""
        steps = 0
        while self.has_work:
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(f"scheduler did not drain in {steps} steps")
            self.step()
            steps += 1

    # ------------------------------------------------------------------
    def _emit(self, req: Request, tok: int, emitted: List,
              finished_now: List[Request]) -> None:
        now = self._clock()
        req.output_tokens.append(tok)
        req.token_times.append(now)
        if req.first_token_time is None:
            req.first_token_time = now
        emitted.append((req, req.slot, tok))
        sp = req.sampling
        if sp.eos_id >= 0 and tok == sp.eos_id:
            self._retire(req, "eos", now, finished_now)
        elif req.n_generated >= sp.max_new_tokens:
            self._retire(req, "length", now, finished_now)
        elif req.prompt_len + req.n_generated >= self.pool.max_len:
            # defensive: submit() bounds prompt+max_new, so this only fires
            # for requests constructed around the validation
            self._retire(req, "capacity", now, finished_now)

    def _retire(self, req: Request, reason: str, now: float,
                finished_now: List[Request]) -> None:
        req.state = RequestState.FINISHED
        req.finish_reason = reason
        req.finish_time = now
        del self.running[req.slot]
        self.pool.free(req.slot)
        req.slot = None
        self.finished.append(req)
        finished_now.append(req)
        self.metrics.on_finish(req)
