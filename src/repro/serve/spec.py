"""Speculative decoding with low-precision drafts (DESIGN.md §17).

XtraMAC's thesis — runtime datatype switching as a *speed* mechanism, not
only a memory one — applied at the serving layer (ROADMAP item 3): drive
K draft tokens through the SAME weights under an aggressive low-precision
policy (the draft engine's KV tier, e.g. int8, plus whatever packed
weight schemes the checkpoint carries), then verify the whole window in
ONE target-precision dispatch and accept the longest agreeing prefix.
Two dispatches replace up to K+1 — the precision ladder PR 5 built
becomes wall-clock speedup whenever the cheap model agrees with the
expensive one.

**The acceptance contract** (the §11/§15/§16 bit-identity contract,
extended): every emitted token is bit-identical to non-speculative
decode — greedy AND seeded temperature, slab AND paged pools,
single-device AND dp x tp.  The mechanism is *exact-match* acceptance:

  * The draft proposes d_1..d_K by sampling ITS OWN logits with the
    request's REAL per-(id, n_generated) key schedule (request.py) — for
    temperature rows this maximizes agreement, because categorical
    sampling with a shared key is a shared Gumbel draw: nearby logits
    give the same argmax.
  * The verify dispatch feeds [last_token, d_1..d_K] (S = K+1 positions)
    at each row's committed length and samples the target's own token
    g_j at every position j with key(n_generated + j), through the one
    ``sample_rows`` rule.
  * The host emits g_0..g_m where m is the longest prefix with
    g_{j-1} == d_j.  Every emitted g_j was sampled by the TARGET model
    from a context of previously-emitted tokens (all prior d's matched),
    with the exact key a plain decode step would have used — so accepted
    output equals non-speculative output *by construction*, at ANY
    acceptance rate.  Full rejection still emits g_0 (exactly the plain
    decode step's token): a speculative round never stalls and never
    wastes the verify.

**Rollback invariant**: the verify writes S positions of target KV, but
the host commits ``lengths[slot] += n_emit`` only.  Positions
L..L+n_emit-1 hold inputs [last, g_0..g_{n_emit-2}] — exactly the
committed state of a never-drafted run (d_j == g_{j-1} on the accepted
prefix) — and positions beyond are garbage-but-uncommitted: masked by
``kv_valid_len`` at every later attend and overwritten before the slot's
next real write lands there, the same argument that already covers
inactive-slot and frozen-burst-row writes (§11).  Rollback is therefore
length-only, for slab and paged pools alike (the paged write window is
pinned via ``ensure_decode(slots, K+1, rems)`` — uncommitted overshoot
flows to garbage/unpinned pages exactly like burst overshoot).

**Draft KV state**: the draft engine keeps one slab pool per target
tier, slot ids mirrored.  The draft burst writes draft-KV for inputs
[last, d_1..d_{K-1}]; on the accepted prefix those EQUAL the committed
tokens, so after syncing ``draft.lengths = target.lengths`` the draft is
rolled back and caught up in one assignment.  Only two cases leave a
deficit the next round must catch up (``_catch_up``): a fully-accepted
round (the bonus token's input position was never drafted) and plain /
prefill activity while the draft sat idle — both are closed by replaying
the committed token suffix through the draft's prefill-chunk path
(``need_logits=False``: KV only, no host sync).

**K-controller** (``SpecPlanner``): a rolling acceptance EMA walks K up
and down a power-of-two ladder; when acceptance collapses at K=1 the
planner falls back to PLAIN bursts for an exponentially-growing cooldown
(probe rounds re-test speculation, backoff bounds their cost) — so a
workload the draft cannot predict degrades to the §11 burst path instead
of paying 2x dispatches per token.  Speculation runs only when no
request is WAITING and no prefill is mid-flight — the same conditions
under which the scheduler plans K > 1 bursts, so admission latency and
chunk interleaving are untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.quant.policy import PrecisionPolicy, validate_kv_tier


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs (``Scheduler(engine, spec=...)``).

    ``draft_kv``: the draft engine's aggressive KV tier — the runtime
    datatype switch that makes drafting cheap (weights are shared with
    the target, so the checkpoint's packed schemes ride along).
    ``draft_policy`` overrides the whole draft ``PrecisionPolicy``
    instead (mutually exclusive with draft_kv).
    ``k_max``: draft-length ceiling (power-of-two ladder, like
    ``max_burst``).  ``k_init``: the ladder rung speculation starts at.
    ``accept_floor``: EMA acceptance below this at K=1 collapses to
    plain bursts; ``accept_raise``: EMA above this doubles K.
    ``ema_alpha``: acceptance EMA weight on the newest round.
    ``cooldown_rounds`` / ``cooldown_backoff``: plain-burst rounds after
    a collapse, growing by the backoff factor each consecutive collapse.
    ``max_collapses``: consecutive collapses (probe rounds that failed
    straight back into cooldown, with no healthy round between) after
    which speculation switches off for good — a workload the draft can
    NEVER predict pays O(1) total probe cost instead of a constant
    fraction (each probe's draft-KV catch-up costs ~cooldown/C chunks,
    so probing forever costs ~1/C of all dispatches forever).
    ``corrupt_drafts``: adversarial test/bench harness — garbles every
    draft token so acceptance is exactly 0 (like the fault injector, a
    seeded way to exercise the fallback path; accepted output must STILL
    be bit-identical, because correctness never depends on the draft).
    """
    draft_kv: str = "int8"
    draft_policy: Optional[PrecisionPolicy] = None
    k_max: int = 4
    k_init: int = 2
    accept_floor: float = 0.2
    accept_raise: float = 0.8
    ema_alpha: float = 0.5
    cooldown_rounds: int = 4
    cooldown_backoff: int = 2
    max_cooldown_rounds: int = 64
    max_collapses: int = 3
    corrupt_drafts: bool = False

    def __post_init__(self):
        if self.draft_policy is None:
            validate_kv_tier(self.draft_kv)
        if self.k_max < 1 or self.k_init < 1 or self.k_init > self.k_max:
            raise ValueError(
                f"need 1 <= k_init <= k_max, got k_init={self.k_init} "
                f"k_max={self.k_max}")
        if not 0.0 <= self.accept_floor <= self.accept_raise <= 1.0:
            raise ValueError("need 0 <= accept_floor <= accept_raise <= 1")


class DraftEngine:
    """The target engine's cheap twin: SAME weights, aggressive policy.

    Wraps a second ``ServingEngine`` over the target's parameter tree
    with the draft ``PrecisionPolicy`` (default: the target policy at
    the aggressive KV tier) — sharing params means zero extra weight
    memory and, under a mesh, the already-placed sharded arrays.  Keeps
    one slab draft pool per target tier with mirrored slot ids and
    tracks each slot's committed draft length (``-1`` = stale: the slot
    was freed/preempted or never drafted; re-entry replays the committed
    tokens through the draft prefill path).
    """

    def __init__(self, engine, cfg: SpecConfig):
        from .engine import ServeConfig, ServingEngine
        self.cfg = cfg
        self.target = engine
        policy = cfg.draft_policy
        if policy is None:
            policy = dataclasses.replace(engine.policy,
                                         kv=validate_kv_tier(cfg.draft_kv))
        scfg = dataclasses.replace(
            engine.scfg, policy=policy, kv_dtype=None,
            # draft pools are always slabs: their state is disposable
            # (length-synced to the target every round) and never shared,
            # so paging buys nothing and rollback stays a pure length
            # assignment
            paged=False, cache_budget_bytes=None,
            # draft dispatches are fenced by the SCHEDULER's fault
            # handling via the target engine's injector; a second armed
            # injector would double-count dispatch seq numbers
            fault_injector=None)
        # one inner engine per (target engine, draft policy): jitted
        # draft closures live on the ServingEngine, so sharing it across
        # DraftEngine instances (warmup scheduler, timed scheduler,
        # corrupt/clean variants) reuses every compile.  Pool state stays
        # per-DraftEngine — only the stateless compute twin is cached.
        cache = engine.__dict__.setdefault("_draft_engine_cache", {})
        key = policy.to_json()
        inner = cache.get(key)
        if inner is None:
            inner = ServingEngine(engine.cfg, engine.params, scfg)
            cache[key] = inner
        self.engine = inner
        self.pools: Dict[str, object] = {}          # target tier -> pool
        self.draft_len: Dict[str, np.ndarray] = {}  # target tier -> [n_slots]

    def pool_for(self, tier: str, target_pool):
        """The draft pool mirroring ``target_pool`` (built lazily)."""
        pool = self.pools.get(tier)
        if pool is None:
            pool = self.engine.new_pool(n_slots=target_pool.n_slots,
                                        max_len=target_pool.max_len)
            self.pools[tier] = pool
            self.draft_len[tier] = np.full((target_pool.n_slots,), -1,
                                           np.int64)
        return pool

    def release(self, tier: str, slot: int) -> None:
        """Target slot freed (retire / preempt / fault): the mirrored
        draft state is stale.  O(1) — the next request in this slot
        catches up from its own committed tokens."""
        lens = self.draft_len.get(tier)
        if lens is not None:
            lens[slot] = -1

    def catch_up(self, tier: str, target_pool, rows: List[Tuple]) -> int:
        """Bring each (request, slot) row's draft KV up to the target's
        committed length by replaying the committed token suffix
        (prompt + outputs[:-1]) through the draft prefill-chunk path —
        KV only (``need_logits=False``), so no logits and no host sync.
        Chunks re-start at the aligned offset below the deficit;
        rewriting already-correct positions recomputes identical bytes
        (deterministic forward over an identical prefix).  Returns the
        number of draft prefill dispatches issued."""
        pool = self.pool_for(tier, target_pool)
        lens = self.draft_len[tier]
        C = self.engine.scfg.prefill_chunk
        dispatches = 0
        for req, slot in rows:
            want = int(target_pool.lengths[slot])
            have = int(lens[slot])
            if have >= want:
                pool.lengths[slot] = want
                lens[slot] = want
                continue
            committed = np.concatenate(
                [req.prompt, np.asarray(req.output_tokens[:-1], np.int32)]) \
                if req.n_generated > 1 else req.prompt
            assert committed.size == want, (committed.size, want)
            padded, n = self.engine.pad_prompt(committed)
            start = max(0, have) // C * C
            pool.lengths[slot] = start
            for off in range(start, n, C):
                self.engine.prefill_chunk_into_slot(
                    pool, slot, padded, off, prompt_len=n,
                    need_logits=False)
                dispatches += 1
            lens[slot] = want
        return dispatches

    def draft_burst(self, tier: str, target_pool, rows: List[Tuple],
                    k: int, key_schedule: np.ndarray,
                    temps: np.ndarray) -> np.ndarray:
        """K draft steps on the draft pool — PR 4's ``lax.scan`` burst,
        unchanged, at the aggressive tier.  ``key_schedule`` [K, n, 2]
        carries each row's REAL step keys for tokens
        n_generated..n_generated+K-1 (the same keys verify position
        j < K uses), which is what makes temperature-row drafts line up
        with the target's Gumbel draws.  EOS is disabled (-1) — the
        draft never freezes; real EOS is enforced on the accepted
        tokens.  Returns the proposals d_1..d_K as [K, n_slots] int32
        (inactive slots carry garbage the caller ignores)."""
        pool = self.pool_for(tier, target_pool)
        n = pool.n_slots
        tokens = np.zeros((n,), np.int32)
        active = np.zeros((n,), bool)
        rem = np.zeros((n,), np.int32)
        eos = np.full((n,), -1, np.int32)
        for req, slot in rows:
            tokens[slot] = req.last_token
            active[slot] = True
            rem[slot] = k
        toks, valid = self.engine.decode_burst(
            pool, tokens, key_schedule, temps, active, rem, eos)
        if self.cfg.corrupt_drafts:
            # adversarial collapse harness: guarantee 0 acceptance while
            # staying in-vocab (the contract says output is STILL
            # bit-identical — the verify's own samples carry the round)
            toks = (toks + 1) % self.target.cfg.vocab
        return toks

    def sync_lengths(self, tier: str, target_pool,
                     rows: List[Tuple]) -> None:
        """Post-round rollback/commit in one assignment: on the accepted
        prefix the draft's written inputs EQUAL the committed tokens
        (d_j == g_{j-1}), so draft state up to the target's new length
        is already correct — and everything past it is garbage the next
        write overwrites, exactly like the target's own rollback."""
        pool = self.pools[tier]
        lens = self.draft_len[tier]
        for req, slot in rows:
            want = int(target_pool.lengths[slot])
            # a fully-accepted round emits K+1 tokens but drafts only K
            # input positions — the deficit (at most 1 here) is closed by
            # next round's catch_up
            got = min(int(pool.lengths[slot]), want)
            pool.lengths[slot] = got
            lens[slot] = got


class SpecPlanner:
    """Rolling-acceptance K controller + plain-burst fallback.

    State machine per scheduler: an acceptance-rate EMA drives K along
    the power-of-two ladder [1, k_max]; a collapse at K=1 (EMA below
    ``accept_floor``) switches to plain bursts for ``cooldown`` rounds,
    with the cooldown growing by ``cooldown_backoff`` on every
    consecutive collapsed probe (and resetting on a healthy round).
    Probes re-enter at K=1 — the cheapest round that still measures the
    workload — and after ``max_collapses`` consecutive failed probes
    speculation switches off permanently, so a draft-hostile workload
    pays O(1) total probe cost and dispatches-per-token converges to the
    plain-burst rate exactly."""

    def __init__(self, cfg: SpecConfig):
        self.cfg = cfg
        self.k = cfg.k_init
        self.ema: Optional[float] = None     # None until the first round
        self.cooldown = 0                    # plain rounds left
        self.off = False                     # permanent fallback
        self._next_cooldown = cfg.cooldown_rounds
        self._consecutive_collapses = 0
        self.n_spec_rounds = 0
        self.n_plain_fallbacks = 0
        self.n_collapses = 0

    @property
    def active(self) -> bool:
        """Whether the next eligible round would speculate."""
        return not self.off and self.cooldown == 0

    def plan(self, rows, pool) -> int:
        """Draft length K for this round, or 0 = run the plain path.
        Caps mirror ``_plan_burst``: each row's verify window must fit
        its slot (lengths + K + 1 <= max_len) and its budget must cover
        more than one token (a 1-token budget gains nothing over a plain
        step), and K rounds down to a power of two so at most
        log2(k_max) verify widths ever compile."""
        if self.off:
            self.n_plain_fallbacks += 1
            return 0
        if self.cooldown > 0:
            self.cooldown -= 1
            self.n_plain_fallbacks += 1
            return 0
        k = self.k
        for req, slot in rows:
            budget = req.sampling.max_new_tokens - req.n_generated
            if budget < 2:
                return 0
            capacity = pool.max_len - int(pool.lengths[slot]) - 1
            k = min(k, budget - 1, capacity)
        if k < 1:
            return 0
        return 1 << (k.bit_length() - 1)

    def expected_tokens_per_round(self) -> float:
        """E[emitted per row per spec round] under the current EMA and K
        (geometric acceptance): sum_{j=0..K} a^j = (1 - a^{K+1})/(1 - a).
        Feeds the SLO drain estimate so admission prices speculative
        throughput honestly."""
        a = min(max(self.ema if self.ema is not None else 0.5, 0.0), 0.999)
        return float((1.0 - a ** (self.k + 1)) / (1.0 - a))

    def observe(self, drafted: int, accepted: int) -> None:
        """Fold one spec round's outcome into the controller."""
        self.n_spec_rounds += 1
        rate = accepted / drafted if drafted else 0.0
        self.ema = rate if self.ema is None else (
            self.cfg.ema_alpha * rate
            + (1.0 - self.cfg.ema_alpha) * self.ema)
        if rate >= self.cfg.accept_floor:
            # any decent round (probe or steady-state) clears the
            # consecutive-collapse streak: the workload is predictable
            # again, so future collapses restart the backoff ladder
            self._consecutive_collapses = 0
        if self.ema >= self.cfg.accept_raise:
            self.k = min(self.k * 2, self.cfg.k_max)
            self._next_cooldown = self.cfg.cooldown_rounds
        elif self.ema < self.cfg.accept_floor:
            if self.k > 1:
                self.k = max(1, self.k // 2)
            else:
                # collapsed at the bottom rung: fall back to plain
                # bursts, backoff the next probe, reset the EMA so the
                # probe round judges the workload fresh.  Probes restart
                # at K=1 (one cheap draft step) and climb the ladder on
                # success; too many consecutive failed probes switch
                # speculation off for good.
                self.n_collapses += 1
                self._consecutive_collapses += 1
                if self._consecutive_collapses >= self.cfg.max_collapses:
                    self.off = True
                self.cooldown = self._next_cooldown
                self._next_cooldown = min(
                    self._next_cooldown * self.cfg.cooldown_backoff,
                    self.cfg.max_cooldown_rounds)
                self.ema = None
                self.k = 1

    def snapshot(self) -> Dict:
        return {"k": self.k,
                "acceptance_ema": None if self.ema is None
                else round(self.ema, 4),
                "cooldown": self.cooldown,
                "off": self.off,
                "spec_rounds": self.n_spec_rounds,
                "plain_fallbacks": self.n_plain_fallbacks,
                "collapses": self.n_collapses}


def accept_longest_prefix(draft: np.ndarray, verified: np.ndarray,
                          eos_id: int, rem: int) -> Tuple[int, int]:
    """Host-side acceptance for ONE row: ``draft`` [K] proposals d_1..d_K,
    ``verified`` [K+1] target samples g_0..g_K.  Returns (n_emit,
    n_accepted): emit g_0..g_{n_emit-1} where the window runs through the
    longest prefix with g_{j-1} == d_j plus the bonus/correction sample,
    truncated at the first emitted EOS and the row's remaining budget.
    n_accepted counts the emitted tokens that were draft matches — the
    speculation-win numerator (n_emit - n_accepted is 0 or 1: the bonus)."""
    k = int(draft.shape[0])
    m = 0
    while m < k and int(verified[m]) == int(draft[m]):
        m += 1
    n_emit = min(m + 1, rem)
    if eos_id >= 0:
        for j in range(n_emit):
            if int(verified[j]) == eos_id:
                n_emit = j + 1
                break
    n_accepted = min(m, n_emit)
    return n_emit, n_accepted
