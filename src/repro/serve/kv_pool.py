"""Slot-based KV-cache pool for continuous batching.

One fixed cache tree of shape ``[n_layers, n_slots, max_len, ...]`` is
allocated once (per-layer K/V slabs for GQA, compressed latents for MLA)
and shared by every request the engine ever serves: a request checks a
*slot* (one batch row) out of the pool for its lifetime and the slot is
returned on retirement.  Because the tree's shapes never change, the jitted
prefill-chunk and decode steps compile exactly once — admission, retirement
and slot reuse are pure host-side bookkeeping plus in-place
``dynamic_update_slice`` / scatter writes (DESIGN.md §7).

This is paging at slot granularity: the unit of allocation is a whole
``max_len`` row rather than a fixed-size token block.  That forgoes
vLLM-style fine-grained page sharing but needs no gather indirection inside
the kernels — the right trade at the current scale, and the pool interface
(alloc/free/lengths) is what a block-paged backend would slot in behind.

Slot hygiene: freed slots are NOT zeroed.  Every read is masked by the
explicit per-row valid length the scheduler passes to the model
(``kv_valid_len``), so stale bytes from a previous tenant are never
attended; the next tenant's prefill overwrites positions [0, P) before any
read of them.  ``lengths[slot]`` is the single source of truth for how many
positions of a slot are committed.

**Capacity is a function of KV bytes per token** (DESIGN.md §9): the pool
dtype knob (``kv_dtype`` = 'bf16' | 'int8' | 'fp8') sets how many bytes one
cached position costs, and ``slots_for_budget`` turns a cache-memory budget
into a slot count — quantizing the cache is how the same budget serves
roughly twice the concurrent requests.
"""
from __future__ import annotations

import heapq
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.quant.kv_cache import kv_dtype_name

# Families whose cache tree is stacked per-layer KV slabs with a batch
# (= slot) axis at position 1.  SSM/hybrid state pools would be a different
# (cheaper) layout; audio additionally caches the encoder output.
POOLABLE_FAMILIES = ("dense", "moe", "vlm")


def _spec_bytes(tree) -> int:
    """Total bytes of a cache tree (arrays or ShapeDtypeStructs)."""
    return sum(int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
               for leaf in jax.tree_util.tree_leaves(tree))


def bytes_per_slot(cfg: T.ModelConfig, max_len: int, *, kv_dtype="bf16",
                   align: int = 1) -> int:
    """Allocated cache bytes one pool slot costs (all layers, K+V, scales
    included for quantized dtypes; computed from the abstract cache spec so
    it can never drift from what ``init_cache`` actually allocates)."""
    capacity = -(-max_len // align) * align
    spec = T.init_cache(cfg, 1, capacity, abstract=True, kv_dtype=kv_dtype)
    return _spec_bytes(spec)


def slots_for_budget(cfg: T.ModelConfig, max_len: int, budget_bytes: int, *,
                     kv_dtype="bf16", align: int = 1) -> int:
    """How many ``max_len`` slots fit a cache-memory budget at ``kv_dtype``."""
    per = bytes_per_slot(cfg, max_len, kv_dtype=kv_dtype, align=align)
    n = int(budget_bytes) // per
    if n < 1:
        raise ValueError(
            f"cache budget {budget_bytes} B < one {max_len}-position slot "
            f"({per} B at kv_dtype={kv_dtype_name(kv_dtype)!r})")
    return n


class KVCachePool:
    paged = False       # fixed-slab layout: no page indirection on the slot

    def __init__(self, cfg: T.ModelConfig, n_slots: int, max_len: int, *,
                 kv_dtype=jnp.bfloat16, align: int = 1):
        """``align``: allocation granularity of the sequence axis.  The
        engine passes its prefill chunk size so every chunk's write window
        [k*C, (k+1)*C) fits the slab even when ``max_len`` is not
        chunk-aligned — ``dynamic_update_slice`` clamps out-of-range
        starts, which would silently shift the write otherwise.  Reads are
        bounded by per-row valid lengths, so the pad tail is never
        attended."""
        if cfg.family not in POOLABLE_FAMILIES:
            raise ValueError(
                f"KVCachePool supports {POOLABLE_FAMILIES} families, "
                f"not {cfg.family!r} (recurrent/enc-dec state pooling is a "
                f"separate layout)")
        assert n_slots >= 1 and max_len >= 1 and align >= 1
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len                            # logical capacity
        self.capacity = -(-max_len // align) * align      # allocated positions
        self.kv_dtype = kv_dtype_name(kv_dtype)
        self.cache = T.init_cache(cfg, n_slots, self.capacity,
                                  kv_dtype=kv_dtype)
        self.shardings = None           # set by place() under a device mesh
        self.lengths = np.zeros((n_slots,), np.int32)   # committed positions
        self._free: List[int] = list(range(n_slots))    # min-heap of slot ids
        heapq.heapify(self._free)

    def place(self, shardings) -> "KVCachePool":
        """Commit the cache tree to a device mesh: one NamedSharding per
        slab (``partitioning.serve_pool_pspec``: slots on the data axis,
        heads on 'model').  The engine's mesh-aware jits pin the same
        shardings on their cache in/outputs, so the slabs never migrate
        after this one placement and buffer donation stays in-place
        (DESIGN.md §10).  Host-side bookkeeping (lengths / free heap) is
        untouched — the scheduler cannot tell a sharded pool from a local
        one."""
        self.shardings = shardings
        self.cache = jax.device_put(self.cache, shardings)
        return self

    # -- memory accounting -------------------------------------------------
    @property
    def cache_bytes(self) -> int:
        """Allocated bytes of the whole cache tree (codes + scales)."""
        return _spec_bytes(self.cache)

    @property
    def bytes_per_token(self) -> int:
        """Cache bytes one committed position costs across all layers."""
        return self.cache_bytes // (self.n_slots * self.capacity)

    # -- allocation --------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_slots - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.n_used / self.n_slots

    def alloc(self) -> Optional[int]:
        """Check out the lowest free slot id (deterministic placement), or
        None when the pool is full."""
        if not self._free:
            return None
        slot = heapq.heappop(self._free)
        self.lengths[slot] = 0
        return slot

    def free(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots
        assert slot not in self._free, f"double free of slot {slot}"
        self.lengths[slot] = 0
        heapq.heappush(self._free, slot)

    def room(self, slot: int) -> int:
        """Cache positions still writable in ``slot``."""
        return self.max_len - int(self.lengths[slot])


# ===========================================================================
# Paged pool: shared page arena + per-slot page tables (DESIGN.md §15)
# ===========================================================================
#
# The slab pool above reserves worst-case ``capacity`` positions per slot.
# The paged pool instead stores every layer's cache as a page *arena*
# ``[L, n_pages, page_size, ...]`` and gives each slot a page table
# ``page_table[slot] -> [pages_per_slot] int32``; pages are allocated as a
# request's committed length grows, refcounted, shared copy-on-write across
# requests whose token prefixes match page-by-page, and evicted LRU when the
# arena runs dry.  Page 0 is a reserved garbage page: unmapped table entries
# point at it, so the jitted gather/scatter (quant/kv_cache.gather_pages /
# scatter_pages) needs no masking — page 0's bytes are only ever gathered
# into positions >= kv_valid_len, which the attention mask zeroes exactly.
#
# The load-bearing invariant (documented and enforced here, relied on by
# scatter_pages): **no shared page ever sits at any slot's write position.**
# Decode/burst steps write a KV row at ``lengths[slot]`` for *every* slot —
# including inactive and mid-prefill ones (the write is unconditional inside
# the jitted step; slab semantics made that harmless because each slot owned
# its row).  ``ensure()`` keeps it harmless here: before any step may write
# positions [lengths, upto) of a slot, every page covering that range is made
# privately owned — entry 0 gets a fresh page, a shared (refcount > 1) entry
# is copy-on-write duplicated.  Everything else a write can touch is either
# already private or the garbage page.

_ROOT_KEY = ("kv-prefix-root",)


def _copy_page_fn(cache, src, dst):
    """arena[:, dst] <- arena[:, src] on every leaf (COW page duplication).
    Donated + jitted once per cache structure; src/dst are traced scalars so
    repeated COWs reuse one executable."""
    return jax.tree_util.tree_map(lambda a: a.at[:, dst].set(a[:, src]),
                                  cache)


_copy_page = jax.jit(_copy_page_fn, donate_argnums=(0,))


def bytes_per_page(cfg: T.ModelConfig, page_size: int, *,
                   kv_dtype="bf16") -> int:
    """Allocated cache bytes one arena page costs (all layers, K+V,
    scales included for quantized dtypes)."""
    spec = T.init_cache(cfg, 1, page_size, abstract=True, kv_dtype=kv_dtype)
    return _spec_bytes(spec)


def pages_for_budget(cfg: T.ModelConfig, max_len: int, budget_bytes: int, *,
                     kv_dtype="bf16", page_size: int, align: int = 1) -> int:
    """How many arena pages fit a cache-memory budget at ``kv_dtype``.

    The page-granular replacement for ``slots_for_budget``: the budget buys
    ``budget // bytes_per_page`` pages outright — no worst-case ``max_len``
    rounding per request.  Requires room for the reserved garbage page plus
    one worst-case request (so admission can always make progress)."""
    assert page_size >= 1 and page_size % align == 0
    per = bytes_per_page(cfg, page_size, kv_dtype=kv_dtype)
    n = int(budget_bytes) // per
    capacity = -(-max_len // page_size) * page_size
    floor = 1 + capacity // page_size           # garbage page + one full slot
    if n < floor:
        raise ValueError(
            f"cache budget {budget_bytes} B < {floor} pages of {page_size} "
            f"positions ({per} B/page at kv_dtype={kv_dtype_name(kv_dtype)!r})"
            f" — too small for one {max_len}-position request")
    return n


class PageAllocator:
    """Host-side bookkeeping of the page arena: free list, refcounts, page
    tables, content-keyed prefix cache and LRU eviction.  Pure python over
    numpy tables — no device arrays — so the whole state machine is
    property-testable (tests/test_paged_properties.py).  The only device
    effect it ever *requests* is a page copy: mutating calls return a list
    of ``(src, dst)`` page copies for the owner to execute on the arena.

    Refcount accounting: ``refcounts[p]`` = number of slot table entries
    equal to ``p``, plus 1 if the prefix cache holds ``p`` (a *cache ref*).
    A page at refcount 0 is free; a registered page at refcount 1 is held
    only by the cache and sits in the LRU ``evictable`` queue — eviction
    unregisters it and hands it out as a fresh page.

    Prefix keys are nested content tuples: ``key_i = (key_{i-1},
    tuple(tokens[i*ps:(i+1)*ps]))``.  Exact token-chain equality — a "hash
    match" with no collisions — so adopting a cached page is always sound.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 pages_per_slot: int, *, align: int = 1):
        assert n_pages >= 1 + pages_per_slot, \
            f"arena of {n_pages} pages cannot hold garbage page + one slot " \
            f"({pages_per_slot} pages)"
        assert page_size % align == 0, \
            f"page_size {page_size} must be a multiple of the prefill " \
            f"chunk {align} (pages are chunk-aligned by construction)"
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_slots = n_slots
        self.pages_per_slot = pages_per_slot
        self.align = align
        self.capacity = pages_per_slot * page_size
        # page 0 reserved as the garbage page — never allocated.
        self._free_pages: List[int] = list(range(1, n_pages))
        heapq.heapify(self._free_pages)
        self.refcounts = np.zeros((n_pages,), np.int32)
        self.table = np.zeros((n_slots, pages_per_slot), np.int32)
        self._free_slots: List[int] = list(range(n_slots))
        heapq.heapify(self._free_slots)
        self.prefix_map: Dict[tuple, int] = {}   # chain key -> page id
        self.page_key: Dict[int, tuple] = {}     # page id -> chain key
        self.evictable: "OrderedDict[int, None]" = OrderedDict()  # LRU
        # pages promised to admitted-but-not-yet-allocated growth, so a
        # later admission can't strand an in-flight request mid-decode.
        self._slot_reserve = np.zeros((n_slots,), np.int32)
        self._reserved = 0
        # counters (read by pool/scheduler metrics)
        self.n_evictions = 0
        self.n_cow_copies = 0

    # -- internal page lifecycle -------------------------------------------
    def _evict_lru(self) -> int:
        page, _ = self.evictable.popitem(last=False)     # least recently used
        key = self.page_key.pop(page)
        del self.prefix_map[key]
        self.refcounts[page] -= 1                        # drop the cache ref
        assert self.refcounts[page] == 0
        self.n_evictions += 1
        return page

    def _alloc_page(self, slot: int) -> int:
        if self._free_pages:
            page = heapq.heappop(self._free_pages)
        elif self.evictable:
            page = self._evict_lru()
        else:
            raise RuntimeError(
                "page arena exhausted: admission reservations should make "
                "this unreachable — allocator invariant violated")
        self.refcounts[page] = 1
        if self._slot_reserve[slot] > 0:
            self._slot_reserve[slot] -= 1
            self._reserved -= 1
        return page

    def _deref(self, page: int) -> None:
        self.refcounts[page] -= 1
        rc = int(self.refcounts[page])
        assert rc >= 0, f"refcount underflow on page {page}"
        if page in self.page_key:
            if rc == 1:      # cache-only now: eligible for eviction (MRU end)
                self.evictable[page] = None
                self.evictable.move_to_end(page)
            assert rc >= 1, f"registered page {page} lost its cache ref"
        elif rc == 0:
            heapq.heappush(self._free_pages, page)

    def _ref(self, page: int) -> None:
        self.refcounts[page] += 1
        if page in self.evictable:       # back in active use: not evictable
            del self.evictable[page]

    # -- prefix cache ------------------------------------------------------
    def match(self, tokens: Sequence[int]) -> List[int]:
        """Longest page-aligned cached prefix of ``tokens``: the list of
        cached page ids covering tokens[0 : len(pages)*page_size]."""
        key = _ROOT_KEY
        pages: List[int] = []
        limit = min(len(tokens) // self.page_size, self.pages_per_slot)
        for i in range(limit):
            key = (key, tuple(
                int(t) for t in
                tokens[i * self.page_size:(i + 1) * self.page_size]))
            page = self.prefix_map.get(key)
            if page is None:
                break
            pages.append(page)
        return pages

    def _admission_plan(self, tokens: Sequence[int], max_new: int):
        P = len(tokens)
        ps = self.page_size
        need_total = min(-(-(P + max_new) // ps), self.pages_per_slot)
        pages = self.match(tokens)
        hit_tokens = len(pages) * ps
        full_cover = hit_tokens >= P
        if full_cover:
            # Re-prefill only the final chunk so the engine still produces
            # the first-token logits; its page is COW'd by ensure().
            prefill_pos = ((P - 1) // self.align) * self.align
        else:
            prefill_pos = hit_tokens
        need_new = need_total - len(pages) + (1 if full_cover else 0)
        return pages, hit_tokens, prefill_pos, need_new

    def can_admit(self, tokens: Sequence[int], max_new: int) -> bool:
        if not self._free_slots:
            return False
        pages, _, _, need_new = self._admission_plan(tokens, max_new)
        adopted_evictable = sum(1 for p in pages if p in self.evictable)
        avail = (len(self._free_pages) + len(self.evictable)
                 - adopted_evictable - self._reserved)
        return avail >= need_new

    def admit(self, tokens: Sequence[int], max_new: int
              ) -> Optional[Tuple[int, int, int, List[Tuple[int, int]]]]:
        """Admit a request: adopt its cached prefix pages and reserve arena
        room for its worst-case growth.  Returns ``(slot, prefill_pos,
        hit_tokens, copies)`` — prefill resumes at ``prefill_pos`` (0 on a
        full miss; the prompt tail past the cached pages otherwise) — or
        None when no slot or not enough pages are available.  ``copies``
        are ``(src, dst)`` arena page copies the caller must execute."""
        if not self.can_admit(tokens, max_new):
            return None
        pages, hit_tokens, prefill_pos, need_new = \
            self._admission_plan(tokens, max_new)
        slot = heapq.heappop(self._free_slots)
        for i, page in enumerate(pages):
            self.table[slot, i] = page
            self._ref(page)
        self._slot_reserve[slot] = need_new
        self._reserved += need_new
        # The write-position invariant: the page under prefill_pos (where
        # the next dispatch writes) must be privately owned NOW — in the
        # full-cover case it is an adopted shared page and gets COW'd here.
        copies = self.ensure(slot, prefill_pos, prefill_pos + 1)
        return slot, prefill_pos, hit_tokens, copies

    def ensure(self, slot: int, committed: int, upto: int
               ) -> List[Tuple[int, int]]:
        """Make every page covering positions [committed, upto) of ``slot``
        privately writable: entry 0 -> fresh page; shared (refcount > 1)
        entry -> copy-on-write duplicate.  Returns the ``(src, dst)`` page
        copies to execute.  Idempotent; must run before any jitted step may
        write those positions."""
        upto = min(upto, self.capacity)
        copies: List[Tuple[int, int]] = []
        for idx in range(committed // self.page_size,
                         -(-upto // self.page_size)):
            entry = int(self.table[slot, idx])
            if entry == 0:
                self.table[slot, idx] = self._alloc_page(slot)
            elif int(self.refcounts[entry]) > 1:
                fresh = self._alloc_page(slot)
                copies.append((entry, fresh))
                self.table[slot, idx] = fresh
                self._deref(entry)
                self.n_cow_copies += 1
        return copies

    def register_prefix(self, slot: int, tokens: Sequence[int]) -> int:
        """Publish ``slot``'s fully-prefilled prompt pages into the prefix
        cache (called once, when prefill completes).  Only whole pages are
        cacheable; already-cached chains are deduped (the slot keeps its
        private copy unregistered).  Returns pages newly registered."""
        key = _ROOT_KEY
        registered = 0
        ps = self.page_size
        for i in range(min(len(tokens) // ps, self.pages_per_slot)):
            key = (key, tuple(int(t) for t in tokens[i * ps:(i + 1) * ps]))
            if key in self.prefix_map:
                continue
            page = int(self.table[slot, i])
            assert page != 0, "registering an unmapped prompt page"
            self.prefix_map[key] = page
            self.page_key[page] = key
            self.refcounts[page] += 1        # the cache ref
            registered += 1
        return registered

    def free_slot(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots
        assert slot not in self._free_slots, f"double free of slot {slot}"
        for idx in range(self.pages_per_slot):
            entry = int(self.table[slot, idx])
            if entry != 0:
                self._deref(entry)
        self.table[slot, :] = 0
        self._reserved -= int(self._slot_reserve[slot])
        self._slot_reserve[slot] = 0
        heapq.heappush(self._free_slots, slot)

    # -- accounting --------------------------------------------------------
    @property
    def n_free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def pages_free(self) -> int:
        return len(self._free_pages)

    @property
    def pages_cached(self) -> int:
        """Cache-only (refcount-1 registered) pages, evictable LRU."""
        return len(self.evictable)

    @property
    def pages_in_use(self) -> int:
        """Pages held by at least one slot table (excludes cache-only)."""
        return self.n_pages - 1 - self.pages_free - self.pages_cached

    def check(self) -> None:
        """Assert every allocator invariant (the property-test oracle)."""
        table_refs = np.bincount(self.table.reshape(-1),
                                 minlength=self.n_pages)
        table_refs[0] = 0
        cache_refs = np.zeros((self.n_pages,), np.int64)
        for page in self.page_key:
            cache_refs[page] += 1
        expect = table_refs + cache_refs
        assert (self.refcounts == expect).all(), \
            f"refcount drift: {self.refcounts.tolist()} != {expect.tolist()}"
        free = set(self._free_pages)
        assert len(free) == len(self._free_pages), "duplicate free pages"
        assert 0 not in free and 0 not in self.page_key \
            and 0 not in self.evictable, "garbage page 0 leaked into lists"
        assert all(self.refcounts[p] == 0 for p in free), \
            "free page with live refs"
        assert free.isdisjoint(self.evictable), "page both free and evictable"
        assert all(p in self.page_key and self.refcounts[p] == 1
                   for p in self.evictable), "evictable page not cache-only"
        assert all(self.refcounts[p] >= 1 for p in self.page_key), \
            "registered page with no refs"
        assert {self.prefix_map[k] for k in self.prefix_map} \
            == set(self.page_key), "prefix_map / page_key out of sync"
        assert self._reserved == int(self._slot_reserve.sum())
        for slot in self._free_slots:
            assert (self.table[slot] == 0).all(), "freed slot keeps pages"
        # no leaks: every non-garbage page is free, cached-only or in a table
        accounted = len(free) + int((table_refs > 0).sum()) \
            + sum(1 for p in self.page_key if table_refs[p] == 0)
        assert accounted == self.n_pages - 1, \
            f"page leak: {accounted} accounted of {self.n_pages - 1}"


class PagedKVPool:
    """Paged drop-in for ``KVCachePool``: same scheduler-facing surface
    (lengths / free / room / occupancy / place), plus page-aware admission
    (``admit`` instead of bare ``alloc``), write-window pinning
    (``ensure`` / ``ensure_decode``) and prefix publication
    (``register_prefix``).  Device state is the per-layer page arena
    ``[L, n_pages, page_size, ...]`` and the host-side ``page_table`` that
    the engine ships to its jitted steps; all paging policy lives in the
    ``PageAllocator``."""

    paged = True

    def __init__(self, cfg: T.ModelConfig, n_slots: int, max_len: int, *,
                 kv_dtype=jnp.bfloat16, align: int = 1,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None):
        if cfg.family not in POOLABLE_FAMILIES:
            raise ValueError(
                f"PagedKVPool supports {POOLABLE_FAMILIES} families, "
                f"not {cfg.family!r}")
        assert n_slots >= 1 and max_len >= 1 and align >= 1
        page_size = align if page_size is None else page_size
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        self.capacity = -(-max_len // page_size) * page_size
        self.pages_per_slot = self.capacity // page_size
        if n_pages is None:       # full provisioning: slab parity + garbage
            n_pages = 1 + n_slots * self.pages_per_slot
        self.n_pages = n_pages
        self.kv_dtype = kv_dtype_name(kv_dtype)
        self.allocator = PageAllocator(n_pages, page_size, n_slots,
                                       self.pages_per_slot, align=align)
        self.cache = T.init_cache(cfg, n_pages, page_size, kv_dtype=kv_dtype)
        self.shardings = None
        self.lengths = np.zeros((n_slots,), np.int32)
        # prefix-cache effectiveness counters (metrics / bench)
        self.n_prefix_hits = 0
        self.n_prefix_misses = 0
        self.prefix_hit_tokens_total = 0

    def place(self, shardings) -> "PagedKVPool":
        """Commit the arena to a device mesh (pages ride the slot axis of
        ``serve_pool_pspec``, heads on 'model' — see engine.pool_shardings).
        Page-table/bookkeeping stays host-side, exactly like slab lengths."""
        self.shardings = shardings
        self.cache = jax.device_put(self.cache, shardings)
        return self

    @property
    def page_table(self) -> np.ndarray:
        return self.allocator.table

    # -- memory accounting -------------------------------------------------
    @property
    def cache_bytes(self) -> int:
        return _spec_bytes(self.cache)

    @property
    def bytes_per_token(self) -> int:
        return self.cache_bytes // (self.n_pages * self.page_size)

    # -- slot / page availability ------------------------------------------
    @property
    def n_free(self) -> int:
        return self.allocator.n_free_slots

    @property
    def n_used(self) -> int:
        return self.n_slots - self.n_free

    @property
    def occupancy(self) -> float:
        return self.n_used / self.n_slots

    @property
    def pages_in_use(self) -> int:
        return self.allocator.pages_in_use

    @property
    def pages_cached(self) -> int:
        return self.allocator.pages_cached

    @property
    def pages_free(self) -> int:
        return self.allocator.pages_free

    def room(self, slot: int) -> int:
        return self.max_len - int(self.lengths[slot])

    # -- request lifecycle -------------------------------------------------
    def can_admit(self, tokens: Sequence[int], max_new: int) -> bool:
        return self.allocator.can_admit(tokens, max_new)

    def admit(self, tokens: Sequence[int], max_new: int
              ) -> Optional[Tuple[int, int, int]]:
        """Admit on pages available: returns ``(slot, prefill_pos,
        hit_tokens)`` or None.  ``prefill_pos > 0`` means the prompt's
        first ``hit_tokens`` positions were adopted from the prefix cache
        and prefill resumes mid-prompt (or, on a full-cover hit, re-runs
        only the final chunk for its logits)."""
        out = self.allocator.admit(tokens, max_new)
        if out is None:
            return None
        slot, prefill_pos, hit_tokens, copies = out
        self.lengths[slot] = prefill_pos
        self._run_copies(copies)
        if hit_tokens > 0:
            self.n_prefix_hits += 1
            self.prefix_hit_tokens_total += hit_tokens
        else:
            self.n_prefix_misses += 1
        return slot, prefill_pos, hit_tokens

    def ensure(self, slot: int, upto: int) -> None:
        """Pin the write window [lengths[slot], upto): allocate/COW pages so
        the jitted steps may write there without touching shared state."""
        self._run_copies(self.allocator.ensure(
            slot, int(self.lengths[slot]), upto))

    def ensure_decode(self, slots: Sequence[int], k: int = 1,
                      rems: Optional[Sequence[int]] = None) -> None:
        """Pin every decoding slot's write window for a ``k``-step
        decode/burst dispatch (the scheduler calls this each step).

        ``rems`` (remaining new tokens per slot) caps the pinned window:
        a row that finishes mid-burst keeps issuing writes at its frozen
        length, but those are garbage rows that flow through unmapped
        (entry-0) table slots into the reserved garbage page — only
        positions that will actually be *committed* (at most
        ``min(k, rem)`` of them) need privately mapped pages.  This keeps
        page allocation within the admission-time reservation."""
        for i, slot in enumerate(slots):
            kk = k if rems is None else min(k, int(rems[i]))
            self.ensure(slot, int(self.lengths[slot]) + kk)

    def register_prefix(self, slot: int, tokens: Sequence[int]) -> int:
        """Publish the prompt's whole pages to the prefix cache once
        prefill completes (content-keyed; deduped against existing chains)."""
        return self.allocator.register_prefix(slot, tokens)

    def free(self, slot: int) -> None:
        """Retire a request: drop its page refs (shared pages survive for
        other holders; cache-only pages become evictable; private pages
        return to the free list) and release the slot."""
        self.allocator.free_slot(slot)
        self.lengths[slot] = 0

    def _run_copies(self, copies: List[Tuple[int, int]]) -> None:
        for src, dst in copies:
            self.cache = _copy_page(self.cache, jnp.int32(src),
                                    jnp.int32(dst))
