"""Slot-based KV-cache pool for continuous batching.

One fixed cache tree of shape ``[n_layers, n_slots, max_len, ...]`` is
allocated once (per-layer K/V slabs for GQA, compressed latents for MLA)
and shared by every request the engine ever serves: a request checks a
*slot* (one batch row) out of the pool for its lifetime and the slot is
returned on retirement.  Because the tree's shapes never change, the jitted
prefill-chunk and decode steps compile exactly once — admission, retirement
and slot reuse are pure host-side bookkeeping plus in-place
``dynamic_update_slice`` / scatter writes (DESIGN.md §7).

This is paging at slot granularity: the unit of allocation is a whole
``max_len`` row rather than a fixed-size token block.  That forgoes
vLLM-style fine-grained page sharing but needs no gather indirection inside
the kernels — the right trade at the current scale, and the pool interface
(alloc/free/lengths) is what a block-paged backend would slot in behind.

Slot hygiene: freed slots are NOT zeroed.  Every read is masked by the
explicit per-row valid length the scheduler passes to the model
(``kv_valid_len``), so stale bytes from a previous tenant are never
attended; the next tenant's prefill overwrites positions [0, P) before any
read of them.  ``lengths[slot]`` is the single source of truth for how many
positions of a slot are committed.

**Capacity is a function of KV bytes per token** (DESIGN.md §9): the pool
dtype knob (``kv_dtype`` = 'bf16' | 'int8' | 'fp8') sets how many bytes one
cached position costs, and ``slots_for_budget`` turns a cache-memory budget
into a slot count — quantizing the cache is how the same budget serves
roughly twice the concurrent requests.
"""
from __future__ import annotations

import heapq
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.quant.kv_cache import kv_dtype_name

# Families whose cache tree is stacked per-layer KV slabs with a batch
# (= slot) axis at position 1.  SSM/hybrid state pools would be a different
# (cheaper) layout; audio additionally caches the encoder output.
POOLABLE_FAMILIES = ("dense", "moe", "vlm")


def _spec_bytes(tree) -> int:
    """Total bytes of a cache tree (arrays or ShapeDtypeStructs)."""
    return sum(int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
               for leaf in jax.tree_util.tree_leaves(tree))


def bytes_per_slot(cfg: T.ModelConfig, max_len: int, *, kv_dtype="bf16",
                   align: int = 1) -> int:
    """Allocated cache bytes one pool slot costs (all layers, K+V, scales
    included for quantized dtypes; computed from the abstract cache spec so
    it can never drift from what ``init_cache`` actually allocates)."""
    capacity = -(-max_len // align) * align
    spec = T.init_cache(cfg, 1, capacity, abstract=True, kv_dtype=kv_dtype)
    return _spec_bytes(spec)


def slots_for_budget(cfg: T.ModelConfig, max_len: int, budget_bytes: int, *,
                     kv_dtype="bf16", align: int = 1) -> int:
    """How many ``max_len`` slots fit a cache-memory budget at ``kv_dtype``."""
    per = bytes_per_slot(cfg, max_len, kv_dtype=kv_dtype, align=align)
    n = int(budget_bytes) // per
    if n < 1:
        raise ValueError(
            f"cache budget {budget_bytes} B < one {max_len}-position slot "
            f"({per} B at kv_dtype={kv_dtype_name(kv_dtype)!r})")
    return n


class KVCachePool:
    def __init__(self, cfg: T.ModelConfig, n_slots: int, max_len: int, *,
                 kv_dtype=jnp.bfloat16, align: int = 1):
        """``align``: allocation granularity of the sequence axis.  The
        engine passes its prefill chunk size so every chunk's write window
        [k*C, (k+1)*C) fits the slab even when ``max_len`` is not
        chunk-aligned — ``dynamic_update_slice`` clamps out-of-range
        starts, which would silently shift the write otherwise.  Reads are
        bounded by per-row valid lengths, so the pad tail is never
        attended."""
        if cfg.family not in POOLABLE_FAMILIES:
            raise ValueError(
                f"KVCachePool supports {POOLABLE_FAMILIES} families, "
                f"not {cfg.family!r} (recurrent/enc-dec state pooling is a "
                f"separate layout)")
        assert n_slots >= 1 and max_len >= 1 and align >= 1
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len                            # logical capacity
        self.capacity = -(-max_len // align) * align      # allocated positions
        self.kv_dtype = kv_dtype_name(kv_dtype)
        self.cache = T.init_cache(cfg, n_slots, self.capacity,
                                  kv_dtype=kv_dtype)
        self.shardings = None           # set by place() under a device mesh
        self.lengths = np.zeros((n_slots,), np.int32)   # committed positions
        self._free: List[int] = list(range(n_slots))    # min-heap of slot ids
        heapq.heapify(self._free)

    def place(self, shardings) -> "KVCachePool":
        """Commit the cache tree to a device mesh: one NamedSharding per
        slab (``partitioning.serve_pool_pspec``: slots on the data axis,
        heads on 'model').  The engine's mesh-aware jits pin the same
        shardings on their cache in/outputs, so the slabs never migrate
        after this one placement and buffer donation stays in-place
        (DESIGN.md §10).  Host-side bookkeeping (lengths / free heap) is
        untouched — the scheduler cannot tell a sharded pool from a local
        one."""
        self.shardings = shardings
        self.cache = jax.device_put(self.cache, shardings)
        return self

    # -- memory accounting -------------------------------------------------
    @property
    def cache_bytes(self) -> int:
        """Allocated bytes of the whole cache tree (codes + scales)."""
        return _spec_bytes(self.cache)

    @property
    def bytes_per_token(self) -> int:
        """Cache bytes one committed position costs across all layers."""
        return self.cache_bytes // (self.n_slots * self.capacity)

    # -- allocation --------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_slots - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.n_used / self.n_slots

    def alloc(self) -> Optional[int]:
        """Check out the lowest free slot id (deterministic placement), or
        None when the pool is full."""
        if not self._free:
            return None
        slot = heapq.heappop(self._free)
        self.lengths[slot] = 0
        return slot

    def free(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots
        assert slot not in self._free, f"double free of slot {slot}"
        self.lengths[slot] = 0
        heapq.heappush(self._free, slot)

    def room(self, slot: int) -> int:
        """Cache positions still writable in ``slot``."""
        return self.max_len - int(self.lengths[slot])
