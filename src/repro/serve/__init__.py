from repro.quant.policy import PrecisionPolicy

from .engine import SCHEDULABLE_FAMILIES, ServeConfig, ServingEngine
from .kv_pool import KVCachePool, bytes_per_slot, slots_for_budget
from .metrics import ServeMetrics
from .request import Request, RequestState, SamplingParams
from .scheduler import Scheduler

__all__ = [
    "KVCachePool", "PrecisionPolicy", "Request", "RequestState",
    "SamplingParams", "SCHEDULABLE_FAMILIES", "Scheduler", "ServeConfig",
    "ServeMetrics", "ServingEngine", "bytes_per_slot", "slots_for_budget",
]
