from repro.quant.policy import PrecisionPolicy

from .engine import SCHEDULABLE_FAMILIES, ServeConfig, ServingEngine
from .kv_pool import (KVCachePool, PageAllocator, PagedKVPool,
                      bytes_per_page, bytes_per_slot, pages_for_budget,
                      slots_for_budget)
from .metrics import ServeMetrics
from .request import Request, RequestState, SamplingParams
from .scheduler import Scheduler

__all__ = [
    "KVCachePool", "PageAllocator", "PagedKVPool", "PrecisionPolicy",
    "Request", "RequestState", "SamplingParams", "SCHEDULABLE_FAMILIES",
    "Scheduler", "ServeConfig", "ServeMetrics", "ServingEngine",
    "bytes_per_page", "bytes_per_slot", "pages_for_budget",
    "slots_for_budget",
]
