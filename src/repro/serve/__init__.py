from repro.quant.policy import PrecisionPolicy
from repro.runtime.fault_tolerance import RetryBudget, StepFault

from .engine import SCHEDULABLE_FAMILIES, ServeConfig, ServingEngine
from .kv_pool import (KVCachePool, PageAllocator, PagedKVPool,
                      bytes_per_page, bytes_per_slot, pages_for_budget,
                      slots_for_budget)
from .metrics import ServeMetrics
from .request import Request, RequestState, SamplingParams
from .scheduler import Scheduler
from .slo import Rejection, SLOPolicy
from .spec import DraftEngine, SpecConfig, SpecPlanner

__all__ = [
    "DraftEngine", "KVCachePool", "PageAllocator", "PagedKVPool",
    "PrecisionPolicy", "Rejection", "Request", "RequestState",
    "RetryBudget", "SamplingParams", "SCHEDULABLE_FAMILIES", "Scheduler",
    "ServeConfig", "ServeMetrics", "ServingEngine", "SLOPolicy",
    "SpecConfig", "SpecPlanner", "StepFault",
    "bytes_per_page", "bytes_per_slot", "pages_for_budget",
    "slots_for_budget",
]
