"""SLO policy: admission control, graceful degradation, cost-model planning.

The scheduler (PRs 1-8) is work-conserving but policy-free: at sustained
overload every request's TTFT grows without bound, because nothing ever
says no.  ``SLOPolicy`` is the piece that says no — three levers, all
driven by the SAME analytical cost model that prices dispatches for the
serving profiler (``perfmodel.analytical.decode_latency``, the paper's
two-phase streaming model):

  * **Admission control** — ``admit()`` estimates the queue's drain time
    and sheds work with a typed ``Rejection`` (queue_full / drain_time /
    deadline_unmeetable) instead of letting it rot in the queue.
    Requests at or above ``protect_priority`` (class numbers <= it) are
    never rejected — overload sheds best-effort traffic so the protected
    classes' TTFT stays bounded (the scheduler's preemption handles the
    slots those classes need).
  * **Graceful degradation** — under pressure, ``admit()`` downgrades
    ``Request.kv_policy`` along ``downgrade_map`` (e.g. bf16 -> int8):
    per-request KV tiers (DESIGN.md §12) make precision a *runtime*
    capacity lever, which is exactly the XtraMAC/MixPE/FlexiBit
    mixed-precision-as-mechanism thesis lifted to the scheduler.  The
    downgrade engages above ``downgrade_high_s`` estimated drain and
    disengages below ``downgrade_low_s`` — hysteresis, so a workload
    sitting at the threshold doesn't flap between tiers.
  * **Cost-model planning** — ``burst_cap()`` and
    ``prefill_chunks_per_step()`` size the decode burst K and the
    prefill share of each round from modeled step latency against
    ``max_step_s``, instead of the fixed ``max_burst`` / one-chunk caps.

All time thresholds are in COST-MODEL seconds (the analytical FPGA
pricing), not host wall seconds — on a CPU smoke host the two differ by
orders of magnitude, but the model is monotone in backlog, which is what
admission control needs: thresholds calibrate once per deployment.
Estimates are pure functions of scheduler state; the policy adds no
clock calls and no host syncs (DESIGN.md §16).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .request import RequestState


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Typed admission verdict for a shed request (``Request.rejection``).

    ``kind``: 'queue_full' (waiting depth cap), 'drain_time' (estimated
    queue drain beyond the policy cap), or 'deadline_unmeetable' (the
    request's own TTFT deadline is provably beyond the estimated drain).
    ``estimate_s`` is the cost-model drain estimate the verdict was based
    on, for post-hoc audit in bench reports."""
    kind: str
    detail: str
    estimate_s: Optional[float] = None

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "detail": self.detail,
                "estimate_s": self.estimate_s}


class SLOPolicy:
    def __init__(self, *,
                 max_queue_delay_s: Optional[float] = None,
                 max_waiting: Optional[int] = None,
                 protect_priority: int = 0,
                 downgrade_map: Optional[Dict[str, str]] = None,
                 downgrade_high_s: Optional[float] = None,
                 downgrade_low_s: Optional[float] = None,
                 max_step_s: Optional[float] = None,
                 design: str = "xtramac",
                 scheme: Optional[str] = None):
        """``max_queue_delay_s``: reject unprotected arrivals once the
        estimated drain exceeds this (None = never reject on drain).
        ``max_waiting``: hard waiting-queue depth cap for unprotected
        arrivals.  ``protect_priority``: requests with
        ``priority <= protect_priority`` are never rejected.
        ``downgrade_map``: {from_tier: to_tier} applied while degraded;
        degradation engages at ``downgrade_high_s`` estimated drain and
        releases at ``downgrade_low_s`` (must be < high — the hysteresis
        band).  ``max_step_s``: modeled per-round latency budget that
        sizes decode bursts and prefill chunks per step (None = keep the
        scheduler's fixed caps).  ``design`` / ``scheme`` pick the
        analytical deployment priced (see obs/profiler.py)."""
        if (downgrade_high_s is None) != (downgrade_low_s is None):
            raise ValueError("give both downgrade_high_s and "
                             "downgrade_low_s, or neither")
        if downgrade_high_s is not None \
                and not downgrade_low_s < downgrade_high_s:
            raise ValueError(
                f"hysteresis band inverted: downgrade_low_s "
                f"{downgrade_low_s} must be < downgrade_high_s "
                f"{downgrade_high_s}")
        if downgrade_map and downgrade_high_s is None:
            raise ValueError("downgrade_map without downgrade_high_s/"
                             "downgrade_low_s thresholds never fires")
        self.max_queue_delay_s = max_queue_delay_s
        self.max_waiting = max_waiting
        self.protect_priority = protect_priority
        self.downgrade_map = dict(downgrade_map or {})
        self.downgrade_high_s = downgrade_high_s
        self.downgrade_low_s = downgrade_low_s
        self.max_step_s = max_step_s
        self.design = design
        self.scheme = scheme
        self.degraded = False           # hysteresis state
        self.last_estimate_s: Optional[float] = None
        self._step_memo: Dict = {}

    # ------------------------------------------------------------------
    # Cost model: one decode token-step at a given shape (memoized; the
    # context is bucketed to a power of two so the memo stays small)
    # ------------------------------------------------------------------
    def _model_step_s(self, engine, batch: int, context: int,
                      kv_bytes_per_token: int) -> float:
        batch = max(int(batch), 1)
        context = max(int(context), 1)
        ctx_bucket = 1 << (context - 1).bit_length()
        key = (batch, ctx_bucket, kv_bytes_per_token)
        t = self._step_memo.get(key)
        if t is None:
            from repro.perfmodel.analytical import (_DEPLOY, decode_latency,
                                                    gemv_engine_for)
            want = self.scheme or engine.cfg.scheme_proj or "w8a8"
            scheme = want if want in _DEPLOY else "w8a8"
            t = decode_latency(
                engine.cfg, scheme, batch=batch, context=ctx_bucket,
                design=self.design,
                kv_bytes_per_token=kv_bytes_per_token,
                engine_model=gemv_engine_for(scheme))["t_total_s"]
            self._step_memo[key] = t
        return t

    def estimate_queue_delay_s(self, sched) -> float:
        """Cost-model estimate of the time to drain everything currently
        in the system: outstanding decode tokens amortize over the total
        slot width; outstanding prefill tokens serialize one chunk per
        round on top (the scheduler's interleaving policy).  Monotone in
        backlog — the property admission control keys on.

        With speculative decoding active (DESIGN.md §17) the decode
        backlog drains in draft/verify rounds instead of single steps:
        each round costs K draft steps at the draft tier's KV bytes plus
        one verify priced as a plain target step — the optimistic bound
        where the K+1-wide verify compute rides the same weight/KV
        stream (idle-headroom regime; see spec_round_latency) — and
        delivers E = (1 - a^(K+1)) / (1 - a) tokens per row at the
        controller's acceptance EMA — so admission prices speculative
        throughput instead of assuming one token per dispatch."""
        engine = sched.engine
        pool = sched.pool
        n_slots = sum(p.n_slots for p in sched.pools.values())
        dec_toks = 0
        pre_toks = 0
        ctx_sum, ctx_n = 0, 0
        for r in sched.running.values():
            dec_toks += max(r.sampling.max_new_tokens - r.n_generated, 0)
            if r.state is RequestState.PREFILL:
                pre_toks += max(r.prefill_len - r.prefill_pos, 0)
            if r.slot is not None:
                ctx_sum += int(sched.pools[r.tier].lengths[r.slot])
                ctx_n += 1
        for r in sched.waiting:
            dec_toks += r.sampling.max_new_tokens
            pre_toks += r.prefill_len
        context = ctx_sum // ctx_n if ctx_n else pool.max_len // 2
        t_tok = self._model_step_s(engine, n_slots, context,
                                   pool.bytes_per_token)
        C = engine.scfg.prefill_chunk
        planner = getattr(sched, "spec_planner", None)
        if planner is not None and planner.active:
            draft = getattr(sched, "draft", None)
            dpool = draft.pools.get(sched.default_tier) \
                if draft is not None else None
            draft_bpt = dpool.bytes_per_token if dpool is not None \
                else pool.bytes_per_token
            t_draft = self._model_step_s(engine, n_slots, context,
                                         draft_bpt)
            t_round = planner.k * t_draft + t_tok
            e_tokens = max(planner.expected_tokens_per_round(), 1.0)
            est = (dec_toks / max(n_slots, 1)) / e_tokens * t_round \
                + (pre_toks / C) * t_tok
        else:
            rounds = dec_toks / max(n_slots, 1) + pre_toks / C
            est = rounds * t_tok
        self.last_estimate_s = est
        return est

    # ------------------------------------------------------------------
    # Admission (called by Scheduler.submit)
    # ------------------------------------------------------------------
    def admit(self, req, sched) -> Optional[Rejection]:
        """Admission verdict for ``req`` against the scheduler's current
        backlog.  Returns None to accept (possibly after downgrading the
        request's KV tier in place — the scheduler re-resolves the tier
        and records the downgrade), or a typed ``Rejection`` to shed."""
        est = self.estimate_queue_delay_s(sched)
        # hysteresis: engage above high, release below low, hold between
        if self.downgrade_high_s is not None:
            if not self.degraded and est > self.downgrade_high_s:
                self.degraded = True
            elif self.degraded and est < self.downgrade_low_s:
                self.degraded = False
        if self.degraded and self.downgrade_map:
            cur = req.kv_policy if req.kv_policy is not None \
                else sched.default_tier
            target = self.downgrade_map.get(cur)
            if target is not None and target in sched.pools \
                    and req.downgraded_from is None:
                req.downgraded_from = cur
                req.kv_policy = target
        if req.priority <= self.protect_priority:
            return None
        if self.max_waiting is not None \
                and len(sched.waiting) >= self.max_waiting:
            return Rejection(
                "queue_full",
                f"{len(sched.waiting)} waiting >= cap {self.max_waiting}",
                est)
        if self.max_queue_delay_s is not None \
                and est > self.max_queue_delay_s:
            return Rejection(
                "drain_time",
                f"estimated drain {est:.3g}s > cap "
                f"{self.max_queue_delay_s:.3g}s", est)
        if req.ttft_deadline_s is not None and est > req.ttft_deadline_s:
            return Rejection(
                "deadline_unmeetable",
                f"estimated drain {est:.3g}s > ttft deadline "
                f"{req.ttft_deadline_s:.3g}s", est)
        return None

    # ------------------------------------------------------------------
    # Cost-model planning (called by Scheduler per round)
    # ------------------------------------------------------------------
    def burst_cap(self, sched, cohort: List, pool, max_burst: int) -> int:
        """Largest decode-burst K whose modeled wall fits ``max_step_s``
        (the scheduler still applies its own event-horizon and power-of-
        two policies on top, so the cap only ever shrinks a burst)."""
        if self.max_step_s is None or not cohort:
            return max_burst
        ctx = sum(int(pool.lengths[r.slot]) for r in cohort) // len(cohort)
        t = self._model_step_s(sched.engine, len(cohort), ctx,
                               pool.bytes_per_token)
        if t <= 0:
            return max_burst
        return max(1, min(max_burst, int(self.max_step_s / t)))

    def prefill_chunks_per_step(self, sched) -> int:
        """How many prefill-chunk dispatches one scheduling round may
        issue: enough to fill ``max_step_s`` of modeled latency (a chunk
        of C tokens is priced as C single-row token-steps — the model
        covers decode; prefill reuses it as a proxy), at least 1, capped
        at 8 so a pathological budget cannot starve decode."""
        if self.max_step_s is None:
            return 1
        engine = sched.engine
        pool = sched.pool
        C = engine.scfg.prefill_chunk
        t_chunk = C * self._model_step_s(engine, 1, pool.max_len // 2,
                                         pool.bytes_per_token)
        if t_chunk <= 0:
            return 1
        return max(1, min(8, int(self.max_step_s / t_chunk)))

    def snapshot(self) -> Dict:
        """Policy state for reports (bench / obs)."""
        return {
            "degraded": self.degraded,
            "last_estimate_s": self.last_estimate_s,
            "max_queue_delay_s": self.max_queue_delay_s,
            "max_waiting": self.max_waiting,
            "protect_priority": self.protect_priority,
            "downgrade_map": dict(self.downgrade_map),
            "downgrade_high_s": self.downgrade_high_s,
            "downgrade_low_s": self.downgrade_low_s,
            "max_step_s": self.max_step_s,
        }
