"""Request / sequence state machine for the continuous-batching scheduler.

A ``Request`` is one user generation: a prompt, per-request sampling
parameters, and the lifecycle

    WAITING -> PREFILL -> DECODE -> FINISHED

WAITING:  submitted, no KV slot yet (FCFS admission queue).
PREFILL:  owns a KV slot; the prompt is being written cache-chunk by
          cache-chunk (``prefill_pos`` tracks committed positions).
DECODE:   prompt fully in cache; one token per engine decode step.
FINISHED: retired (EOS, length limit, or slot-capacity limit); the KV slot
          has been returned to the pool.

Randomness is *per request and per step*: the sampling key is
``fold_in(fold_in(PRNGKey(seed), request_id), n_generated)``, so a
request's sampled continuation is a pure function of (seed, id, prompt,
weights) — independent of which slot it landed in, what else shared its
decode batches, or when it was admitted.  That is what makes continuous
batching testable against one-shot generation (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import jax
import numpy as np


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # <= 0: greedy
    max_new_tokens: int = 16
    eos_id: int = -1              # -1: never stop on a token
    seed: int = 0


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                       # [P] int32 token ids
    sampling: SamplingParams = SamplingParams()
    id: Optional[int] = None                 # assigned by the scheduler
    state: RequestState = RequestState.WAITING
    # requested KV-cache precision tier ('bf16' | 'int8' | 'fp8'; None =
    # the engine's default tier).  The scheduler routes the request to its
    # tier's pool and cohorts decode batches per tier (DESIGN.md §12) —
    # per-request runtime precision switching.  A request's tokens are a
    # pure function of (seed, id, prompt, weights, tier): tiers share
    # weights but never a cache slab, so traffic at other tiers cannot
    # perturb this request's continuation.
    kv_policy: Optional[str] = None
    tier: Optional[str] = None               # resolved at submit()
    # scheduling class (DESIGN.md §16): smaller = more important; 0 is the
    # highest class.  Admission scans priority-then-arrival order, and
    # under slot/page pressure the scheduler may preempt the lowest-
    # priority DECODE slot to admit a higher-priority waiter.  Priority
    # never changes a request's tokens — only when they are produced.
    priority: int = 0
    # optional SLO deadlines, in scheduler-clock seconds from arrival.  A
    # request still WAITING past its TTFT deadline, or still running past
    # its e2e deadline, is shed with finish_reason='deadline_exceeded'
    # (step-granular: enforced from the scheduler's once-per-step clock
    # sample, so the disabled-obs zero-extra-clock-calls contract holds).
    ttft_deadline_s: Optional[float] = None
    e2e_deadline_s: Optional[float] = None
    slot: Optional[int] = None               # KV pool slot while admitted
    prefill_pos: int = 0                     # prompt positions in cache
    # prompt tokens adopted from the paged pool's prefix cache at
    # admission (0 on a slab pool or a prefix miss) — prefill resumes
    # past them, which is the TTFT win metrics split hit/miss on
    prefix_hit_tokens: int = 0
    # chunk-padded prompt buffer (engine.pad_prompt), built once at
    # admission so the per-chunk prefill loop slices views instead of
    # allocating per chunk
    prompt_padded: Optional[np.ndarray] = None
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    # --- preemption / fault-recovery state (DESIGN.md §16) ---
    # set when the request lost its slot mid-decode (preempted for a
    # higher-priority waiter, or invalidated by a step fault): the tokens
    # whose KV must be recomputed on re-admission — the original prompt
    # plus every generated token except the last (the last emitted token
    # is the next decode INPUT; its KV has not been written yet).  The
    # prefill loop serves ``resume_prompt`` instead of ``prompt``, emits
    # nothing at its final chunk (those tokens were already delivered),
    # and decode continues at the preserved ``n_generated`` — which, with
    # the per-(request, step) key schedule, makes the resumed output
    # bit-identical to an unpreempted run.
    resume_prompt: Optional[np.ndarray] = None
    n_preemptions: int = 0                   # scheduler preempt-and-requeues
    n_faults: int = 0                        # step faults charged to this req
    # earliest scheduler step() index at which a fault-requeued request may
    # be re-admitted (exponential backoff; 0 = immediately)
    hold_until_step: int = 0
    # most recent WAITING-queue entry (submit or requeue) — queue-wait
    # samples are admit - last_enqueue, so a preempted request's second
    # wait is charged to the requeue, not its original arrival
    last_enqueue_time: Optional[float] = None
    # typed admission-control verdict when the SLO policy sheds the
    # request at submit (serve.slo.Rejection); finish_reason='rejected'
    rejection: Optional[object] = None
    # KV tier the SLO policy downgraded this request from (None = served
    # at the tier it asked for)
    downgraded_from: Optional[str] = None
    finish_reason: Optional[str] = None
    # ^ eos | length | capacity | rejected | deadline_exceeded | fault
    # --- timing (scheduler clock; see metrics.py) ---
    arrival_time: Optional[float] = None
    # when the request left WAITING (KV slot allocated).  Only stamped
    # when the scheduler runs with observability attached (DESIGN.md §13)
    # — the disabled path makes zero extra clock calls
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    # engine-dispatch id that emitted each token (parallel to
    # token_times): tokens sharing an id surfaced from ONE decode burst,
    # which is what the burst-spread ITL estimate and the tracer's
    # per-dispatch attribution key on (metrics.py, obs/trace.py)
    token_dispatches: List[int] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size > 0, "empty prompt"

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def prefill_tokens(self) -> np.ndarray:
        """What the prefill loop must commit to the cache: the original
        prompt, or the resume buffer (prompt + replayed generated tokens)
        after a preemption."""
        return self.prompt if self.resume_prompt is None else \
            self.resume_prompt

    @property
    def prefill_len(self) -> int:
        return int(self.prefill_tokens.size)

    @property
    def is_resuming(self) -> bool:
        return self.resume_prompt is not None

    @property
    def n_generated(self) -> int:
        return len(self.output_tokens)

    @property
    def last_token(self) -> int:
        return self.output_tokens[-1]

    @property
    def is_finished(self) -> bool:
        return self.state == RequestState.FINISHED

    def step_key(self):
        """PRNG key for sampling generated token #``n_generated``."""
        base = jax.random.fold_in(jax.random.PRNGKey(self.sampling.seed),
                                  self.id or 0)
        return jax.random.fold_in(base, self.n_generated)

    def step_keys(self, n: int) -> np.ndarray:
        """[n, 2] uint32 key schedule for generated tokens
        ``n_generated .. n_generated + n - 1`` — row t is bit-identical to
        what ``step_key()`` would return at that step, which is the
        on-device key-schedule contract that makes a K-step decode burst
        reproduce K single steps exactly (DESIGN.md §11).  One vmapped
        dispatch per (request, burst) instead of one fold_in per token."""
        base = jax.random.fold_in(jax.random.PRNGKey(self.sampling.seed),
                                  self.id or 0)
        steps = jax.numpy.arange(self.n_generated, self.n_generated + n)
        return np.asarray(
            jax.vmap(lambda s: jax.random.fold_in(base, s))(steps))
