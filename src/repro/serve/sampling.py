"""The serving sampling rule — ONE definition, shared by every path.

``sample_rows`` is the pure math: per-row greedy / temperature-scaled
categorical over [N, V] logits with per-row [N, 2] PRNG keys.  It is called
from three places that must agree bit-for-bit (DESIGN.md §11):

  * inside the fused ``decode_slots`` jit (engine.py) — sampling happens on
    device and only [n_slots] int32 token ids cross to the host;
  * inside every step of the ``decode_burst`` ``lax.scan`` (engine.py);
  * host-side for the first token sampled off a prompt's final prefill
    chunk (scheduler.py, via the jitted ``sample_tokens`` wrapper).

Greedy rows (temperature <= 0) never consume their key; temperature rows
use ``jax.random.categorical`` on ``logits / t``, which is a pure function
of (key, logits) — so a token sampled inside a K-step burst is bit-identical
to the same step run alone, as long as the same per-(request, step) key is
supplied (request.py's ``step_key`` / ``step_keys`` schedule).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample_rows(logits, keys, temperatures):
    """Batched per-row sampling: logits [N, V], keys [N, 2], temps [N].
    Greedy when a row's temperature <= 0, else temperature-scaled
    categorical.  Pure — safe to call inside any jit."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperatures, jnp.float32(1e-6))[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, logits / t)
    return jnp.where(temperatures <= 0, greedy, sampled.astype(jnp.int32))


# host-side entry point (first-token sampling off prefill logits): one
# dispatch for a whole batch of rows
sample_tokens = jax.jit(sample_rows)


def sample_one(logits, key, temperature) -> int:
    """Single-row convenience over ``sample_tokens`` (N=1), so there is
    exactly one sampling rule in the system."""
    return int(sample_tokens(
        logits[None], jnp.asarray(key)[None],
        jnp.asarray([temperature], jnp.float32))[0])


def batched_step_keys(seeds, ids, starts, k: int) -> np.ndarray:
    """[R, k, 2] uint32 key schedules for R requests in ONE computation and
    ONE blocking transfer: row r, step t is
    ``fold_in(fold_in(PRNGKey(seeds[r]), ids[r]), starts[r] + t)`` —
    bit-identical to ``Request.step_keys`` / ``step_key``, which define the
    contract (DESIGN.md §11).  The scheduler uses this for every decode
    round with temperature rows so key-schedule construction costs one
    host sync per round, not one per row."""
    seeds = jnp.asarray(seeds, jnp.int32)
    ids = jnp.asarray(ids, jnp.int32)
    starts = jnp.asarray(starts, jnp.int32)

    def one(seed, rid, n0):
        base = jax.random.fold_in(jax.random.PRNGKey(seed), rid)
        return jax.vmap(lambda s: jax.random.fold_in(base, s))(
            n0 + jnp.arange(k))

    return np.asarray(jax.vmap(one)(seeds, ids, starts))
