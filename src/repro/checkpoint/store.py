"""Atomic, async, elastic checkpointing.

Layout (one directory per step):
  <dir>/step_000123/
    manifest.json     tree structure, per-leaf dtype/shape, extra metadata
    arr_000.npy ...   one .npy per leaf (row-major, logical/global values)
  <dir>/LATEST        atomic pointer file (written last)

Properties required at 1000+-node scale:
  * **atomic**  — a step directory becomes visible only via the LATEST
    pointer, renamed after fsync; partial writes never load.
  * **async**   — ``CheckpointManager.save_async`` snapshots to host memory
    synchronously (cheap) and writes in a background thread, overlapping
    the next training steps.
  * **elastic** — leaves are stored as *logical* (unsharded) arrays plus
    the partition-spec names; ``load_checkpoint`` re-shards onto whatever
    mesh the restarted job has (different pod count / axis sizes), which is
    what lets a 512-chip job resume on 256 chips.

A real deployment writes per-host shard files (ocdbt-style); the logical
format here keeps the semantics while staying dependency-free.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy can't natively save/cast the ML dtypes; round-trip through
# same-width integer views, recording the logical dtype in the manifest.
_ML_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory, step: int, tree, *, extra: Optional[Dict] = None):
    """Synchronous atomic save of a pytree of arrays."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f".tmp_step_{step:09d}_{os.getpid()}"
    final = directory / f"step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "n_leaves": len(leaves), "extra": extra or {},
                "time": time.time(), "dtypes": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        name = arr.dtype.name
        manifest["dtypes"].append(name)
        if name in _ML_DTYPES:
            arr = arr.view(_ML_DTYPES[name][1])
        np.save(tmp / f"arr_{i:05d}.npy", arr)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                          # atomic on POSIX
    latest = directory / "LATEST"
    tmp_latest = directory / ".LATEST.tmp"
    tmp_latest.write_text(str(step))
    tmp_latest.rename(latest)                  # pointer last
    return final


def latest_step(directory) -> Optional[int]:
    latest = pathlib.Path(directory) / "LATEST"
    if not latest.exists():
        return None
    try:
        return int(latest.read_text().strip())
    except ValueError:
        return None


def load_checkpoint(directory, step: int, like, *, shardings=None):
    """Load into the structure of ``like``; re-shard with ``shardings``.

    ``like`` supplies the treedef (and optionally dtypes); ``shardings`` is
    an equally-structured tree of jax.sharding.Sharding for elastic
    restore onto a (possibly different) mesh — leaves are device_put with
    the new sharding, so a checkpoint from a 512-chip run restores onto
    256 chips (or a single CPU) unchanged.
    """
    directory = pathlib.Path(directory) / f"step_{step:09d}"
    manifest = json.loads((directory / "manifest.json").read_text())
    leaves_like, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), \
        f"checkpoint has {manifest['n_leaves']} leaves, model expects {len(leaves_like)}"
    shard_leaves = (treedef.flatten_up_to(shardings) if shardings is not None
                    else [None] * len(leaves_like))
    dtypes = manifest.get("dtypes") or [None] * len(leaves_like)
    out = []
    for i, (ref, sh, dt) in enumerate(zip(leaves_like, shard_leaves, dtypes)):
        arr = np.load(directory / f"arr_{i:05d}.npy")
        if dt in _ML_DTYPES:
            arr = arr.view(_ML_DTYPES[dt][0])
        if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
            arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


class CheckpointManager:
    """Async writer + retention. ``save_async`` returns immediately."""

    def __init__(self, directory, *, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree, *, extra=None):
        self.wait()       # one in flight at a time
        # snapshot to host memory synchronously (device buffers may be
        # donated/overwritten by the next step)
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra=extra)
                self._gc()
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:09d}", ignore_errors=True)
