"""Synthetic sharded token pipeline with prefetch and checkpointable state.

Deterministic: batch ``i`` on host ``h`` of ``H`` is a pure function of
(seed, i, h) via a counter-mode PRNG — so a restarted/elastically-rescaled
job replays the exact global token stream from the recorded step, with no
data files needed (the dry-run container has no corpus; a real deployment
swaps ``_gen_batch`` for an array-record reader with the same interface).

Prefetch: a daemon thread keeps ``prefetch`` batches ahead; ``state()`` /
``restore()`` round-trips the cursor for checkpointing.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticTokenPipeline:
    """Iterator of {'tokens': [B_host, S] i32, 'labels': ...} numpy batches."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(1, cfg.prefetch))
        self._cursor = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # -- deterministic batch generation (counter-mode PRNG) -----------------
    def _gen_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        ss = np.random.SeedSequence(
            entropy=cfg.seed, spawn_key=(step, cfg.host_id))
        rng = np.random.Generator(np.random.Philox(ss))
        # zipf-ish marginal over the vocab (more realistic than uniform)
        z = rng.zipf(1.3, size=(cfg.host_batch, cfg.seq_len))
        tokens = (z % (cfg.vocab - 2)).astype(np.int32) + 1
        labels = np.concatenate(
            [tokens[:, 1:], np.full((cfg.host_batch, 1), -1, np.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}

    def _producer(self):
        while not self._stop.is_set():
            batch = self._gen_batch(self._cursor)
            while not self._stop.is_set():
                try:
                    self._q.put((self._cursor, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            self._cursor += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self._step = step + 1
        return batch

    # -- checkpointable cursor ----------------------------------------------
    def state(self) -> Dict[str, int]:
        return {"step": self._step}

    @classmethod
    def restore(cls, cfg: DataConfig, state: Dict[str, int]
                ) -> "SyntheticTokenPipeline":
        return cls(cfg, start_step=int(state["step"]))

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
