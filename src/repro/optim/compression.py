"""INT8 error-feedback gradient compression for the cross-pod all-reduce.

At 2+ pods the gradient all-reduce is the only traffic crossing the slower
pod-to-pod links (DESIGN.md §5).  Compressing it 4x (f32 -> int8 with a
per-tensor scale) cuts that term proportionally; the quantization error is
carried in an error-feedback buffer (Seide et al. / PowerSGD-style EF) so
the *accumulated* update stays unbiased — convergence is preserved.

Two entry points:
  * ``compress_decompress`` — the quantize/EF math alone (unit-testable,
    deterministic); also what the train loop applies when simulating the
    compression on a single-axis mesh.
  * ``compressed_psum``    — the shard_map'd cross-'pod' all-reduce: int8
    codes are summed in int32 over the pod axis, then de-scaled.  Used
    inside train_step when the mesh has a 'pod' axis and compression is on.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any   # params-shaped error-feedback buffers (f32)


def init_compression(grads) -> CompressionState:
    return CompressionState(jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32)
        if jnp.issubdtype(g.dtype, jnp.floating) else jnp.zeros((), jnp.int8),
        grads))


def _quant_one(g, err):
    """g + err -> (codes int8, scale f32, new_err f32)."""
    v = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / 127.0
    codes = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    deq = codes.astype(jnp.float32) * scale
    return codes, scale, v - deq


def compress_decompress(grads, state: CompressionState
                        ) -> Tuple[Any, CompressionState]:
    """Pure quantize->dequantize with error feedback (no collective)."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    outs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            outs.append(g)
            errs.append(e)
            continue
        codes, scale, new_err = _quant_one(g, e)
        outs.append((codes.astype(jnp.float32) * scale).astype(g.dtype))
        errs.append(new_err)
    return tdef.unflatten(outs), CompressionState(tdef.unflatten(errs))


def compressed_psum(grads, state: CompressionState, axis_name: str
                    ) -> Tuple[Any, CompressionState]:
    """INT8-compressed mean over ``axis_name`` (call inside shard_map).

    Each participant quantizes (with its local error feedback), the int8
    codes are summed exactly in int32, and each participant de-scales with
    its own scale contribution summed alongside — an unbiased compressed
    mean.  Bytes on the wire: 1/4 of f32.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            return g, e
        codes, scale, new_err = _quant_one(g, e)
        total = jax.lax.psum(codes.astype(jnp.int32) * 1, axis_name)
        # scales differ per pod: sum of per-pod dequantized tensors needs the
        # per-pod scale applied before the reduce; approximate with the mean
        # scale (error absorbed by EF next step)
        mean_scale = jax.lax.psum(scale, axis_name) / n
        deq = total.astype(jnp.float32) * mean_scale / n
        return deq.astype(g.dtype), new_err

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            CompressionState(tdef.unflatten([o[1] for o in out])))
