"""AdamW with configurable moment dtype, global-norm clipping, cosine LR.

``moment_dtype=bf16`` is the 8-bit-Adam-style memory posture required for
nemotron-4-340b on 256 x 16 GB chips (DESIGN.md §6): fp32 moments would
need 18.6 GB/chip.  Moments are stored in ``moment_dtype`` but the update
math runs in fp32 (cast up, update, cast down).

Optimizer state is a pytree with the same structure as params, so the
FSDP partition specs apply verbatim (ZeRO-3: state sharded like weights).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32     # jnp.bfloat16 for the 340B posture
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray    # scalar int32
    mu: Any              # first moments  (params-shaped)
    nu: Any              # second moments (params-shaped)


def _float_leaves(tree):
    return jax.tree_util.tree_map(
        lambda p: jnp.issubdtype(p.dtype, jnp.floating), tree)


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    def zeros():
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating)
            else jnp.zeros((), jnp.int8),
            params)
    # mu and nu must be DISTINCT buffers (donation aliases by buffer)
    return OptState(jnp.zeros((), jnp.int32), zeros(), zeros())


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(grads):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(grads)
              if jnp.issubdtype(g.dtype, jnp.floating)]
    return jnp.sqrt(sum(leaves))


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1t = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p, mu, nu
        g = g.astype(jnp.float32) * scale
        mu_f = mu.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        nu_f = nu.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        upd = (mu_f / b1t) / (jnp.sqrt(nu_f / b2t) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, mu_f.astype(cfg.moment_dtype), nu_f.astype(cfg.moment_dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state.mu)
    flat_nu = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_mu, new_nu), {"grad_norm": gnorm, "lr": lr}
