from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from .compression import (CompressionState, compress_decompress,
                          compressed_psum, init_compression)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
    "CompressionState", "compress_decompress", "compressed_psum",
    "init_compression",
]
