"""granite-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152.  Llama-architecture code model.  [arXiv:2405.04324]

Quantization plan: AWQ INT4 -> INT4xBF16+BF16 MACs (weight-only quant).
"""
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14_336, vocab=49_152,
    activation="silu", gated_ffn=True, tie_embeddings=False,
    scheme_proj="awq_int4", scheme_ffn="awq_int4",
    microbatches=2,
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512,
    activation="silu", gated_ffn=True, tie_embeddings=False,
    scheme_proj="awq_int4", scheme_ffn="awq_int4",
    kv_chunk=64,
)
