"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000.  Squared-ReLU, non-gated FFN.  [arXiv:2402.16819]

Memory posture (DESIGN.md §6): fp32 Adam moments do NOT fit 256 x 16 GB
(340e9 x 14 B / 256 = 18.6 GB/chip); the training config therefore uses
bf16 params + bf16 moments (~8 B/param -> 10.6 GB/chip) with full remat.
Quantization plan: MXFP4 (FP4xBF16+BF16 MACs) for serving.
"""
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18_432, n_heads=96, n_kv_heads=8, d_head=192,
    d_ff=73_728, vocab=256_000,
    activation="relu2", gated_ffn=False, tie_embeddings=False,
    scheme_proj="mxfp4", scheme_ffn="mxfp4",
    microbatches=8,
)

SMOKE = ModelConfig(
    name="nemotron-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=256, vocab=512,
    activation="relu2", gated_ffn=False, tie_embeddings=False,
    scheme_proj="mxfp4", scheme_ffn="mxfp4",
    kv_chunk=64,
)
