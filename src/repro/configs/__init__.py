"""Architecture registry: ``--arch <id>`` resolves here.

Each module exposes FULL (the exact assigned config) and SMOKE (a reduced
same-family config for CPU tests).  ``get_config(name, smoke=...)``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.transformer import ModelConfig

from .shapes import SHAPES, ShapeSpec, input_specs, shape_applicable  # noqa: F401

_ARCH_MODULES: Dict[str, str] = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "xlstm-350m": "xlstm_350m",
    "zamba2-7b": "zamba2_7b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "minitron-8b": "minitron_8b",
    "granite-8b": "granite_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "starcoder2-15b": "starcoder2_15b",
    "whisper-medium": "whisper_medium",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.SMOKE if smoke else mod.FULL


def all_cells():
    """Every (arch, shape) pair with its skip reason (None = runs)."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            cells.append((arch, shape.name, shape_applicable(cfg, shape)))
    return cells
