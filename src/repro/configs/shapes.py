"""Assigned input shapes and abstract input specs for the dry-run.

Four shapes per LM architecture (seq_len x global_batch):
  train_4k     4,096 x 256    training       -> lowers train_step
  prefill_32k  32,768 x 32    inference      -> lowers serve prefill
  decode_32k   32,768 x 128   inference      -> lowers serve_step (1 token,
                                               KV cache of seq_len)
  long_500k    524,288 x 1    long-context   -> serve_step; ONLY for the
                                               sub-quadratic families
                                               (ssm/hybrid) — full-attention
                                               archs skip it (DESIGN.md §4)

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, zero allocation) — the dry-run lowers
against these.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, init_cache


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str   # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the reason it is skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full softmax attention over a 524k KV would be a pure "
                "KV-memory exercise; skipped per DESIGN.md §4 (runs for "
                "ssm/hybrid families)")
    return None


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _bf16(shape):
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """Abstract model inputs for one (arch x shape) cell.

    train:   {'tokens', 'labels'} (+ 'patches' / 'frames' stubs)
    prefill: {'tokens'} (+ stubs) + zeroed cache of size seq_len
    decode:  {'tokens' [B,1]} + cache of size seq_len + index scalar
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": _i32((b, s)), "labels": _i32((b, s))}
        if cfg.family == "vlm":
            s_text = s - cfg.n_patches
            batch = {"tokens": _i32((b, s_text)), "labels": _i32((b, s_text)),
                     "patches": _bf16((b, cfg.n_patches, cfg.d_model))}
        elif cfg.family == "audio":
            batch["frames"] = _bf16((b, cfg.n_frames, cfg.d_model))
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": _i32((b, s))}
        if cfg.family == "vlm":
            batch = {"tokens": _i32((b, s - cfg.n_patches)),
                     "patches": _bf16((b, cfg.n_patches, cfg.d_model))}
        elif cfg.family == "audio":
            batch["frames"] = _bf16((b, cfg.n_frames, cfg.d_model))
        cache = init_cache(cfg, b, s, abstract=True)
        return {"batch": batch, "cache": cache}

    # decode: one new token against a cache of seq_len
    batch = {"tokens": _i32((b, 1))}
    if cfg.family == "audio":
        batch["frames"] = _bf16((b, cfg.n_frames, cfg.d_model))  # enc cached
    cache = init_cache(cfg, b, s, abstract=True)
    return {"batch": batch, "cache": cache,
            "index": jax.ShapeDtypeStruct((), jnp.int32)}
