"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]

Quantization plan (paper Fig. 1, Qwen3-AWQ): expert/projection weights
AWQ-style INT4 -> INT4xBF16+BF16 MACs; attention MACs BF16xBF16+BF16.
"""
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=64,
    d_ff=768, vocab=151_936,
    n_experts=128, top_k=8, moe_d_ff=768,
    activation="silu", gated_ffn=True, tie_embeddings=False,
    scheme_proj="awq_int4", scheme_ffn="awq_int4",
    microbatches=2,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=96, vocab=512,
    n_experts=8, top_k=2, moe_d_ff=96,
    activation="silu", gated_ffn=True, tie_embeddings=False,
    scheme_proj="awq_int4", scheme_ffn="awq_int4",
    ssm_chunk=16, kv_chunk=64,
)
