"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304.
sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM ratio — every 8th block is sLSTM).
[arXiv:2405.04517]

d_ff=0: xLSTM blocks carry their own up-projection (no separate FFN).
Runs long_500k (recurrent state decode).  Quantization plan: W8A8
(INT8xINT8+INT32 MACs) on the block projections.
"""
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50_304,
    slstm_every=8, ssm_expand=2, ssm_chunk=128,
    use_rope=False, tie_embeddings=True,
    scheme_proj="w8a8", scheme_ffn="w8a8",
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=512,
    slstm_every=2, ssm_expand=2, ssm_chunk=16,
    use_rope=False, tie_embeddings=True,
    scheme_proj="w8a8", scheme_ffn="w8a8",
)
