"""deepseek-v2-236b [moe] — 60L d_model=5120 128H MLA (kv_lora=512)
d_ff=1536 (per routed expert) vocab=102400, 2 shared + 160 routed top-6.
[arXiv:2405.04434]

MLA: q_lora=1536, kv_lora=512, d_head 128 (nope) + 64 (rope), d_v=128.
Decode uses the absorbed-latent formulation (cache = kv_lora + rope dims).
Quantization plan: FP8 (E4M3) weights -> FP8xFP8+BF16 MACs on projections
and experts; attention MACs BF16.
"""
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102_400,
    n_experts=160, top_k=6, n_shared_experts=2, moe_d_ff=1536,
    use_mla=True, q_lora=1536, kv_lora=512,
    d_head_nope=128, d_head_rope=64, d_head_v=128,
    activation="silu", gated_ffn=True, tie_embeddings=False,
    scheme_proj="fp8", scheme_ffn="fp8",
    microbatches=8,
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=512,
    n_experts=8, top_k=2, n_shared_experts=2, moe_d_ff=96,
    use_mla=True, q_lora=48, kv_lora=32,
    d_head_nope=16, d_head_rope=8, d_head_v=16,
    activation="silu", gated_ffn=True, tie_embeddings=False,
    scheme_proj="fp8", scheme_ffn="fp8",
    kv_chunk=64,
)
