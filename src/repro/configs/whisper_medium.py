"""whisper-medium [audio] — enc-dec, 24 encoder + 24 decoder layers,
d_model=1024 16H d_ff=4096 vocab=51865.  Conv frontend is a STUB:
input_specs provides precomputed frame embeddings [B, 1500, d_model].
[arXiv:2212.04356]

Decode shapes exercise the DECODER (self-attn KV cache + cross-attention
over the cached encoder output).  The 32k decode length far exceeds the
released model's 448 decoder positions — the config is a shape/sharding
exercise, noted in DESIGN.md §6.  Quantization plan: W8A8.
"""
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=48, encoder_layers=24,    # 24 enc + 24 dec
    d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=4096, vocab=51_865,
    n_frames=1500,
    activation="gelu", gated_ffn=False, norm="layer",
    use_rope=False, tie_embeddings=True,
    scheme_proj="w8a8", scheme_ffn="w8a8",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=4, encoder_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=512,
    n_frames=8,
    activation="gelu", gated_ffn=False, norm="layer",
    use_rope=False, tie_embeddings=True,
    scheme_proj="w8a8", scheme_ffn="w8a8",
    kv_chunk=64,
)
