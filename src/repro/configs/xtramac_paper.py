"""The paper's own evaluation workloads (Tables VI/VII, Figs. 1 & 14).

Model profiles for the five quantized checkpoints the paper simulates
end-to-end (Table VI), expressed as ModelConfigs plus the paper's GEMV
kernel shapes (Table VII).  These drive benchmarks/paper_tables.py and the
perfmodel analytical simulator.
"""
from repro.models.transformer import ModelConfig

# Table VII GEMV workloads: (m, k, n) with INT4/FP4 x BF16 MACs
GEMV_SHAPES = [(1, 4096, 4096), (1, 4096, 12288)]

# Table VI checkpoints -> (config, quant scheme per component)
QWEN3_8B = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=12_288, vocab=151_936,
    activation="silu", gated_ffn=True, tie_embeddings=False,
    scheme_proj="awq_int4", scheme_ffn="awq_int4",
)

LLAMA31_8B = ModelConfig(
    name="llama-3.1-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14_336, vocab=128_256,
    activation="silu", gated_ffn=True, tie_embeddings=False,
    scheme_proj="w8a8", scheme_ffn="w8a8",
)

GPT_OSS_20B = ModelConfig(
    name="gpt-oss-20b", family="moe",
    n_layers=24, d_model=2880, n_heads=64, n_kv_heads=8, d_head=64,
    d_ff=2880, vocab=201_088,
    n_experts=32, top_k=4, moe_d_ff=2880,
    activation="silu", gated_ffn=True, tie_embeddings=False,
    scheme_proj="bf16", scheme_ffn="mxfp4",   # MoE blocks MXFP4, rest BF16
)

# checkpoint name -> (config, scheme label used in Fig. 1 / Fig. 14)
PAPER_CHECKPOINTS = {
    "Qwen-3-8B-AWQ": (QWEN3_8B, "awq_int4"),
    "Llama-3.1-8B-W8A8": (LLAMA31_8B, "w8a8"),
    "Qwen-3-8B-FP8": (QWEN3_8B, "fp8"),
    "Llama-3.1-8B-FP8": (LLAMA31_8B, "fp8"),
    "GPT-oss-20B": (GPT_OSS_20B, "mxfp4"),
}
