"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152.  GQA + RoPE, GELU non-gated FFN.  [arXiv:2402.19173]

Quantization plan: MXFP4 (FP4xBF16+BF16 MACs, UE8M0 scales).
"""
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_head=128,
    d_ff=24_576, vocab=49_152,
    activation="gelu", gated_ffn=False, tie_embeddings=False,
    scheme_proj="mxfp4", scheme_ffn="mxfp4",
    microbatches=2,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512,
    activation="gelu", gated_ffn=False, tie_embeddings=False,
    scheme_proj="mxfp4", scheme_ffn="mxfp4",
    kv_chunk=64,
)
