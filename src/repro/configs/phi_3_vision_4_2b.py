"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H d_ff=8192 vocab=32064.
phi3-mini backbone + CLIP frontend (STUB: input_specs provides precomputed
patch embeddings [B, 256, d_model]).  [hf:microsoft/Phi-3-vision-128k]

Quantization plan: FP8 weights (FP8xFP8+BF16 MACs) on projections/FFN.
"""
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_head=96,
    d_ff=8192, vocab=32_064,
    n_patches=256,
    activation="silu", gated_ffn=True, tie_embeddings=True,
    scheme_proj="fp8", scheme_ffn="fp8",
    microbatches=2,
)

SMOKE = ModelConfig(
    name="phi3v-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=512,
    n_patches=4,
    activation="silu", gated_ffn=True, tie_embeddings=True,
    scheme_proj="fp8", scheme_ffn="fp8",
    kv_chunk=64,
)
