"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000.  Width/depth-pruned Nemotron-4: squared-ReLU, non-gated FFN.
[arXiv:2407.14679]

Quantization plan: W8A8 (SmoothQuant-style) -> INT8xINT8+INT32 MACs.
"""
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=16_384, vocab=256_000,
    activation="relu2", gated_ffn=False, tie_embeddings=False,
    scheme_proj="w8a8", scheme_ffn="w8a8",
    microbatches=2,
)

SMOKE = ModelConfig(
    name="minitron-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512,
    activation="relu2", gated_ffn=False, tie_embeddings=False,
    scheme_proj="w8a8", scheme_ffn="w8a8",
    kv_chunk=64,
)
