"""zamba2-7b [hybrid] — 81L d_model=3584 32H d_ff=14336 vocab=32000,
ssm_state=64.  Mamba2 backbone + ONE shared attention block applied every
6 Mamba blocks (weights reused; each application keeps its own KV cache).
[arXiv:2411.15242]

Runs long_500k: Mamba states are O(1) in sequence length; the shared
attention KV (13 applications x 500k) is sharded over ('data','model').
Quantization plan: AWQ INT4 on Mamba projections and the shared block.
"""
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_head=112,
    d_ff=14_336, vocab=32_000,
    ssm_state=64, ssm_d_head=64, ssm_expand=2, ssm_chunk=128, attn_every=6,
    activation="silu", gated_ffn=True, tie_embeddings=True,
    scheme_proj="awq_int4", scheme_ffn="awq_int4",
    microbatches=8,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=512,
    ssm_state=16, ssm_d_head=16, ssm_expand=2, ssm_chunk=16, attn_every=2,
    activation="silu", gated_ffn=True, tie_embeddings=True,
    scheme_proj="awq_int4", scheme_ffn="awq_int4",
    kv_chunk=64,
)
