"""Sub-byte code packing — the TPU analogue of the paper's DSP bit-space.

On the U55c, XtraMAC packs multiple low-precision operands into each
512-bit HBM channel word (Section VI-C).  On TPU the same insight applies
to HBM words: INT4/FP4 codes are packed 8-per-int32 (FP8/INT8: 4-per-int32)
along the reduction (K) dimension, so decode-GEMV streams 4x fewer bytes
than BF16 weights.  Kernels unpack in VMEM right before the MXU.

Layout: ``packed[k // per_word, n]`` holds codes ``k .. k+per_word-1`` of
column ``n`` in little-endian bit order (code i at bits [i*bits, (i+1)*bits)).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def codes_per_word(bits: int) -> int:
    assert 32 % bits == 0, f"bits={bits} must divide 32"
    return 32 // bits


def pack_codes(codes, bits: int):
    """codes: uint values < 2^bits, shape [K, ...] -> int32 [K/per_word, ...]."""
    per = codes_per_word(bits)
    k = codes.shape[0]
    assert k % per == 0, f"K={k} not divisible by {per}"
    c = jnp.asarray(codes, jnp.int32).reshape((k // per, per) + codes.shape[1:])
    word = jnp.zeros((k // per,) + codes.shape[1:], jnp.int32)
    for i in range(per):
        word = word | (c[:, i] << (i * bits))
    return word


def unpack_codes(words, bits: int):
    """int32 [Kw, ...] -> uint codes [Kw*per_word, ...] (jnp; kernel-safe)."""
    per = codes_per_word(bits)
    mask = (1 << bits) - 1
    parts = [(words >> (i * bits)) & mask for i in range(per)]
    stacked = jnp.stack(parts, axis=1)  # [Kw, per, ...]
    return stacked.reshape((words.shape[0] * per,) + words.shape[1:])


def pack_codes_np(codes: np.ndarray, bits: int) -> np.ndarray:
    """Numpy twin of ``pack_codes`` (used off-trace, e.g. checkpoint import)."""
    per = codes_per_word(bits)
    k = codes.shape[0]
    assert k % per == 0
    c = codes.astype(np.int64).reshape((k // per, per) + codes.shape[1:])
    word = np.zeros((k // per,) + codes.shape[1:], np.int64)
    for i in range(per):
        word |= c[:, i] << (i * bits)
    return word.astype(np.uint32).view(np.int32)  # values < 2^32: reinterpret
