"""Quantized KV-cache storage — the serving pool's side of the paper's
mixed-precision plan.

PR 1 quantized the *weight* operand stream; this module extends the plan to
the KV cache (the operand stream that actually caps continuous-batching
throughput: slots = cache bytes / bytes-per-token).  A bf16 KV slab
``[..., S, H, D]`` becomes

  packed  [..., S, H, D/4]  int32  — 4 8-bit codes per word, little-endian
                                     (quant/pack.py's HBM-word layout,
                                     applied along ``d_head``)
  scales  [..., S, H]       f32    — one absmax scale per (position, head)
                                     group (DESIGN.md §9)

``QuantizedKV`` carries the pair as one pytree node (scheme name as static
aux data), so the pool cache tree flows through ``jax.lax.scan`` layer
stacks, ``tree_map`` slot slicing and buffer donation exactly like a plain
array slab.  Quantize-on-write happens inside the jitted prefill/decode
steps via ``cache_write_slice`` / ``cache_write_rows``; ``cache_read`` is
the dequantized dense view (the einsum-oracle read path — the Pallas
decode kernel instead streams ``packed``/``scales`` directly and
dequantizes in-kernel, see ``kernels/decode_attention.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .schemes import get_kv_scheme, kv_dequantize, kv_quantize


@jax.tree_util.register_pytree_node_class
class QuantizedKV:
    """One quantized KV slab as a pytree node: children = (packed, scales),
    static aux = scheme name — jit/scan/donation-safe (mirrors QLinear)."""

    def __init__(self, packed, scales, scheme_name: str):
        self.packed = packed
        self.scales = scales
        self.scheme_name = scheme_name

    def tree_flatten(self):
        return (self.packed, self.scales), (self.scheme_name,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    def __repr__(self):
        shape = getattr(self.packed, "shape", None)
        return f"QuantizedKV({self.scheme_name}, packed{shape})"


def kv_dtype_name(kv_dtype) -> str:
    """Canonical string name of the pool dtype knob ('bf16'|'int8'|'fp8')."""
    scheme = get_kv_scheme(kv_dtype)
    return scheme.name if scheme is not None else "bf16"


def kv_slab_pspec(axes, kv_dtype):
    """PartitionSpec twin of ``kv_slab_spec``: same tree shape (a
    ``QuantizedKV`` node for quantized dtypes, a bare spec otherwise), so
    sharding specs can never drift structurally from the slab they annotate.

    ``axes``: one mesh axis (or None) per *logical* slab dim
    [..., S, H, D].  For quantized slabs the trailing ``d_head`` dim packs
    4 codes per int32 word, so sharding it would split inside code words —
    it must be None; the scales twin simply drops that dim.
    """
    from jax.sharding import PartitionSpec as P
    scheme = get_kv_scheme(kv_dtype)
    if scheme is None:
        return P(*axes)
    assert axes[-1] is None, \
        "quantized KV packs codes along d_head: that dim cannot shard"
    return QuantizedKV(P(*axes), P(*axes[:-1]), scheme.name)


def kv_slab_spec(shape, kv_dtype):
    """ShapeDtypeStruct spec(s) for one KV slab of logical ``shape``
    [..., S, H, D] stored as ``kv_dtype`` ('bf16' / legacy jnp dtype / a
    KV scheme name).  Quantized slabs require ``D % 4 == 0`` (packing)."""
    scheme = get_kv_scheme(kv_dtype)
    if scheme is None:
        dt = kv_dtype if not isinstance(kv_dtype, str) and kv_dtype is not None \
            else jnp.bfloat16
        return jax.ShapeDtypeStruct(shape, dt)
    d = shape[-1]
    assert d % 4 == 0, f"d_head {d} not divisible by 4 (KV code packing)"
    return QuantizedKV(
        jax.ShapeDtypeStruct(shape[:-1] + (d // 4,), jnp.int32),
        jax.ShapeDtypeStruct(shape[:-1], jnp.float32),
        scheme.name,
    )


# ---------------------------------------------------------------------------
# Write / read paths (jnp; used inside the jitted engine steps)
# ---------------------------------------------------------------------------
def cache_write_slice(slab, vals, offset):
    """Write ``vals`` [B, S, ...] into ``slab`` at sequence position
    ``offset`` (axis 1) — the prefill/prefill-chunk write.  Quantized slabs
    quantize-on-write (per-position scales make the result independent of
    what else shares the write, so chunked and whole-prompt prefill commit
    identical bytes)."""
    if isinstance(slab, QuantizedKV):
        packed, scales = kv_quantize(get_kv_scheme(slab.scheme_name), vals)
        return QuantizedKV(
            jax.lax.dynamic_update_slice_in_dim(slab.packed, packed, offset,
                                                axis=1),
            jax.lax.dynamic_update_slice_in_dim(slab.scales, scales, offset,
                                                axis=1),
            slab.scheme_name)
    return jax.lax.dynamic_update_slice_in_dim(
        slab, vals.astype(slab.dtype), offset, axis=1)


def cache_write_rows(slab, vals, rows, offsets):
    """Per-row scatter (decode): row i of ``vals`` [B, 1, ...] lands at
    ``slab[i, offsets[i]]`` — every pool slot writes at its own length."""
    if isinstance(slab, QuantizedKV):
        packed, scales = kv_quantize(get_kv_scheme(slab.scheme_name), vals)
        return QuantizedKV(
            slab.packed.at[rows, offsets].set(packed[:, 0]),
            slab.scales.at[rows, offsets].set(scales[:, 0]),
            slab.scheme_name)
    return slab.at[rows, offsets].set(vals[:, 0].astype(slab.dtype))


def cache_read(slab, dtype=jnp.bfloat16):
    """Dense view of a slab: dequantize QuantizedKV (the einsum-oracle read
    path — one materialized [B, S, H, D] per layer), pass bf16 through."""
    if isinstance(slab, QuantizedKV):
        return kv_dequantize(get_kv_scheme(slab.scheme_name),
                             slab.packed, slab.scales, dtype)
    return slab


# ---------------------------------------------------------------------------
# Paged indirection (serve/kv_pool.PagedKVPool; DESIGN.md §15)
# ---------------------------------------------------------------------------
def gather_pages(arena, table):
    """Materialize the virtual KV slab of every slot from a page arena.

    ``arena``: one layer's page arena [n_pages, page_size, ...] (bare array
    or ``QuantizedKV`` — codes and scales gather in lockstep).  ``table``:
    [n_slots, pages_per_slot] int32 page ids.  Returns the *virtual slab*
    [n_slots, pages_per_slot * page_size, ...] — exactly the layout the
    slab pool stores directly, so every downstream consumer (the einsum
    attention paths, the Pallas decode kernel, the write primitives above)
    runs UNCHANGED on identical bytes.  That is the paged pool's
    bit-identity argument: same committed bytes in the same [slot, pos]
    layout, garbage pages only ever gathered into positions masked by
    ``kv_valid_len``.
    """
    n_slots, pp = table.shape

    def g(a):
        v = a[table]                         # [n_slots, pp, page_size, ...]
        return v.reshape((n_slots, pp * a.shape[1]) + a.shape[2:])

    if isinstance(arena, QuantizedKV):
        return QuantizedKV(g(arena.packed), g(arena.scales),
                           arena.scheme_name)
    return g(arena)


def scatter_pages(arena, table, virt):
    """Write a (possibly updated) virtual slab back through the page table.

    Inverse of ``gather_pages``: virtual position [slot, i*ps + j] lands at
    ``arena[table[slot, i], j]``.  Duplicate table entries are allowed and
    safe by the pool's invariants (DESIGN.md §15): a page shared
    copy-on-write between slots is never written through (writes hit
    private pages only, so every duplicate scatters the page's own
    unchanged bytes), and the reserved garbage page 0 — the target of every
    unmapped entry — may receive differing garbage rows, but its content is
    never gathered into an attended (< ``kv_valid_len``) position.
    """
    n_slots, pp = table.shape
    flat = table.reshape(-1)

    def s(a, v):
        return a.at[flat].set(
            v.reshape((n_slots * pp, a.shape[1]) + a.shape[2:]))

    if isinstance(arena, QuantizedKV):
        return QuantizedKV(s(arena.packed, virt.packed),
                           s(arena.scales, virt.scales), arena.scheme_name)
    return s(arena, virt)
