"""Quantization substrate: schemes (Table I), sub-byte packing, calibration."""
from .pack import codes_per_word, pack_codes, pack_codes_np, unpack_codes  # noqa: F401
from .schemes import (  # noqa: F401
    SCHEMES, QuantScheme, QuantizedLinearWeights, decode_codes, dequant_lut,
    dequantize, get_scheme, quantize_activations_fp8,
    quantize_activations_int8, quantize_weights,
)
