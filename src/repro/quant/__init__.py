"""Quantization substrate: schemes (Table I), sub-byte packing, calibration,
quantized KV-cache storage (DESIGN.md §9), and the unified PrecisionPolicy
contract over all of them (DESIGN.md §12)."""
from .kv_cache import (  # noqa: F401
    QuantizedKV, cache_read, cache_write_rows, cache_write_slice,
    kv_dtype_name, kv_slab_spec,
)
from .pack import codes_per_word, pack_codes, pack_codes_np, unpack_codes  # noqa: F401
from .policy import (  # noqa: F401
    KERNEL_MODES, KV_TIERS, PrecisionPolicy, leaf_dims, leaf_info,
    leaf_schemes, validate_kv_tier,
)
from .schemes import (  # noqa: F401
    KV_SCHEMES, SCHEMES, KVQuantScheme, QuantScheme, QuantizedLinearWeights,
    decode_codes, dequant_lut, dequantize, get_kv_scheme, get_scheme,
    kv_dequantize, kv_quantize, quantize_activations_fp8,
    quantize_activations_int8, quantize_weights,
)
