"""Quantization schemes for mixed-precision LLM inference (paper Table I).

Each scheme describes one MAC datatype combination of the paper and how
weights are quantized into it:

  awq_int4   weight-only INT4 (group-wise, symmetric)  -> INT4 x BF16 + BF16
  w8a8       SmoothQuant-style INT8 weights+acts       -> INT8 x INT8 + INT32
  fp8        E4M3 weights+acts (per-channel scale)     -> FP8 x FP8 + BF16
  mxfp4      MXFP4: FP4 E2M1 + UE8M0 power-of-2 scale  -> FP4 x BF16 + BF16
  bf16       no quantization (attention MACs)          -> BF16 x BF16 + BF16

The dequant LUTs are generated from core.formats codecs, so kernel-side
decode is bit-identical to the XtraMAC Stage-1 mapping semantics (DAZ,
implicit-one restore).  quantize() lives in numpy (offline, checkpoint
prep); dequantize() has a jnp path used inside models and kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from .pack import codes_per_word, pack_codes_np, unpack_codes


@dataclasses.dataclass(frozen=True)
class QuantScheme:
    name: str
    weight_format: str         # core.formats name
    act_format: str            # 'bf16' | 'int8' | 'fp8_e4m3'
    acc_format: str            # 'bf16' | 'fp32' | 'int32'
    group_size: int            # scale granularity along K; -1 = per-channel
    weight_bits: int
    scale_pow2: bool = False   # UE8M0-style power-of-two scales (MXFP4)
    pack_in_words: bool = True  # sub-byte/byte codes packed into int32 words

    @property
    def packed(self) -> bool:
        return self.pack_in_words and self.weight_bits <= 8

    @property
    def mac_combo(self) -> str:
        """The XtraMAC datatype combination this scheme executes as."""
        return f"{self.weight_format}x{self.act_format}"


SCHEMES: Dict[str, QuantScheme] = {
    "awq_int4": QuantScheme("awq_int4", "int4", "bf16", "bf16", 128, 4),
    # w8a8 keeps raw int8 [K, N] so the MXU INT8 x INT8 -> INT32 path applies
    "w8a8": QuantScheme("w8a8", "int8", "int8", "int32", -1, 8, pack_in_words=False),
    "fp8": QuantScheme("fp8", "fp8_e4m3", "fp8_e4m3", "bf16", -1, 8),
    "mxfp4": QuantScheme("mxfp4", "fp4_e2m1", "bf16", "bf16", 32, 4, scale_pow2=True),
    "bf16": QuantScheme("bf16", "bf16", "bf16", "bf16", -1, 16, pack_in_words=False),
}


def get_scheme(name: str) -> QuantScheme:
    return SCHEMES[name]


@dataclasses.dataclass
class QuantizedLinearWeights:
    """Packed weights + scales for one linear layer (K in-features x N out)."""
    scheme: QuantScheme
    packed: np.ndarray | jnp.ndarray     # int32 [K/per_word, N] (or bf16 [K,N])
    scales: Optional[np.ndarray | jnp.ndarray]  # f32 [K/G, N] or [1, N] or None
    shape: Tuple[int, int]               # (K, N) logical
    # logical leaf name ("ffn.w_up", ...) when applied from a model tree —
    # the mesh kernel dispatch keys its sharding-spec lookup on it
    name: Optional[str] = None


# ---------------------------------------------------------------------------
# Dequant lookup tables (exact codec values, from core.formats)
# ---------------------------------------------------------------------------
def dequant_lut(fmt_name: str) -> np.ndarray:
    """code -> float32 value table for a <=8-bit float format (DAZ applied)."""
    fmt = F.get_format(fmt_name)
    assert fmt.bits <= 8
    vals = fmt.decode_to_f64(np.arange(1 << fmt.bits))
    return np.nan_to_num(vals, nan=0.0).astype(np.float32)


FP4_LUT = dequant_lut("fp4_e2m1")
FP8_LUT = dequant_lut("fp8_e4m3")


def _int_decode(codes, bits: int):
    """Unsigned codes -> signed two's-complement values (jnp)."""
    half = 1 << (bits - 1)
    return jnp.where(codes >= half, codes - (1 << bits), codes)


def decode_codes(scheme: QuantScheme, codes):
    """jnp: unsigned codes -> float32 format values (pre-scale)."""
    if scheme.weight_format.startswith("int"):
        return _int_decode(codes, scheme.weight_bits).astype(jnp.float32)
    if scheme.weight_format == "fp4_e2m1":
        return jnp.asarray(FP4_LUT)[codes]
    if scheme.weight_format == "fp8_e4m3":
        return jnp.asarray(FP8_LUT)[codes]
    raise ValueError(scheme.weight_format)


# ---------------------------------------------------------------------------
# Quantize (offline / checkpoint preparation; numpy)
# ---------------------------------------------------------------------------
def effective_group(group: int, k: int) -> int:
    """Group size along K (clamped: small test layers use one group)."""
    return k if (group == -1 or group > k) else group


def _group_absmax(w: np.ndarray, group: int) -> np.ndarray:
    k, n = w.shape
    g = effective_group(group, k)
    assert k % g == 0
    return np.abs(w.reshape(k // g, g, n)).max(axis=1)  # [K/G, N]


def quantize_weights(scheme: QuantScheme, w: np.ndarray) -> QuantizedLinearWeights:
    """Quantize a float weight matrix [K, N] into packed codes + scales."""
    w = np.asarray(w, np.float32)
    k, n = w.shape
    if scheme.name == "bf16":
        return QuantizedLinearWeights(scheme, jnp.asarray(w, jnp.bfloat16), None, (k, n))

    g = effective_group(scheme.group_size, k)
    absmax = np.maximum(_group_absmax(w, g), 1e-12)          # [K/G, N]

    if scheme.weight_format.startswith("int"):
        qmax = (1 << (scheme.weight_bits - 1)) - 1           # symmetric
        scales = absmax / qmax
        wg = w.reshape(k // g, g, n)
        q = np.rint(wg / scales[:, None, :]).clip(-qmax - 1, qmax)
        codes = (q.astype(np.int64) & ((1 << scheme.weight_bits) - 1)).reshape(k, n)
    else:
        fmt = F.get_format(scheme.weight_format)
        if scheme.scale_pow2:  # UE8M0: scale = 2^ceil(log2(absmax / max_finite))
            scales = np.exp2(np.ceil(np.log2(absmax / fmt.max_finite)))
        else:
            scales = absmax / fmt.max_finite
        wg = w.reshape(k // g, g, n) / scales[:, None, :]
        codes = F.quantize_f64(fmt, wg.astype(np.float64)).reshape(k, n)

    if scheme.packed:
        packed = pack_codes_np(codes.astype(np.int64), scheme.weight_bits)
    else:
        packed = codes.astype(np.int8) if scheme.weight_format.startswith("int") \
            else codes.astype(np.uint8)
    return QuantizedLinearWeights(
        scheme, jnp.asarray(packed), jnp.asarray(scales, jnp.float32), (k, n)
    )


# ---------------------------------------------------------------------------
# Dequantize (jnp; reference path — kernels fuse this into the matmul)
# ---------------------------------------------------------------------------
def dequantize(qw: QuantizedLinearWeights, dtype=jnp.bfloat16):
    """Packed codes + scales -> dense weights [K, N].

    dtype=bf16 is the 'upcast' baseline materialization; dtype=f32 matches
    the fused kernels (which never round the dequantized value).
    """
    scheme = qw.scheme
    if scheme.name == "bf16":
        return qw.packed.astype(dtype)
    k, n = qw.shape
    if scheme.packed:
        codes = unpack_codes(qw.packed, scheme.weight_bits)     # [K, N] uint
    else:
        codes = qw.packed.astype(jnp.int32) & ((1 << scheme.weight_bits) - 1)
    vals = decode_codes(scheme, codes)                          # f32 [K, N]
    g = effective_group(scheme.group_size, k)
    vals = vals.reshape(k // g, g, n) * qw.scales[:, None, :]
    return vals.reshape(k, n).astype(dtype)


# ---------------------------------------------------------------------------
# KV-cache quantization (per-head-group; the serving pool's mixed-precision
# side — DESIGN.md §9)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KVQuantScheme:
    """8-bit KV-cache quantization with per-(position, head) group scales.

    The scale group is one head's ``d_head`` channel vector — the finest
    granularity that adds no inner-loop rescaling to the decode kernel
    (scores/values for a head consume exactly one K scale and one V scale
    per cached position).  Codes are packed 4-per-int32 word along
    ``d_head`` (quant/pack.py's HBM-word insight applied to the cache) and
    decode through the ``core.formats`` codec semantics: DAZ + implicit-one
    restore, bit-identical to the XtraMAC Stage-1 mapping.
    """
    name: str            # 'int8' | 'fp8'
    fmt_name: str        # backing core.formats codec
    bits: int = 8


KV_SCHEMES: Dict[str, KVQuantScheme] = {
    "int8": KVQuantScheme("int8", "int8"),
    "fp8": KVQuantScheme("fp8", "fp8_e4m3"),
}


def get_kv_scheme(kv_dtype) -> Optional[KVQuantScheme]:
    """Normalize the ``kv_dtype`` knob: None for bf16 storage (including the
    legacy jnp-dtype spelling), a ``KVQuantScheme`` for 'int8' / 'fp8'."""
    if kv_dtype is None or not isinstance(kv_dtype, str):
        return None                    # jnp dtype: plain (unquantized) cache
    if kv_dtype == "bf16":
        return None
    try:
        return KV_SCHEMES[kv_dtype]
    except KeyError as exc:
        raise KeyError(
            f"unknown kv_dtype {kv_dtype!r}; have 'bf16' + {sorted(KV_SCHEMES)}"
        ) from exc


def kv_pack_codes(codes):
    """8-bit codes [..., D] -> int32 words [..., D/4] (little-endian), jnp."""
    d = codes.shape[-1]
    assert d % 4 == 0, f"trailing dim {d} not divisible by 4 (KV packing)"
    c = (codes.astype(jnp.int32) & 0xFF).reshape(codes.shape[:-1] + (d // 4, 4))
    return c[..., 0] | (c[..., 1] << 8) | (c[..., 2] << 16) | (c[..., 3] << 24)


def kv_unpack_codes(words):
    """int32 words [..., Dw] -> unsigned 8-bit codes [..., Dw*4], jnp."""
    parts = [(words >> (8 * i)) & 0xFF for i in range(4)]
    return jnp.stack(parts, axis=-1).reshape(words.shape[:-1] + (-1,))


def _encode_fp8_e4m3(x):
    """jnp RN-even E4M3 encode of f32 (already clipped to max finite) ->
    uint codes, FTZ on underflow — bit-identical to
    ``core.formats.quantize_f64`` on this domain.  NOT the XLA float8 cast:
    that double-rounds through f16 on CPU, flipping round-to-nearest-even
    ties (e.g. 61.99 -> 64 instead of 60)."""
    fmt = F.FP8_E4M3
    xf = x.astype(jnp.float32)
    sign = jnp.signbit(xf).astype(jnp.int32)
    mag = jnp.abs(xf)
    _, e2 = jnp.frexp(mag)                    # mag = frac * 2^e2, frac [.5,1)
    e_unb = e2 - 1
    # integer mantissa with man_bits fractional bits; the 2^k scaling is
    # exact in f32, so jnp.round is a true RN-even on the real quotient
    m = jnp.round(jnp.ldexp(mag, fmt.man_bits - e_unb)).astype(jnp.int32)
    carry = m >= (1 << (fmt.man_bits + 1))
    m = jnp.where(carry, m >> 1, m)
    e_unb = e_unb + carry
    underflow = (e_unb < fmt.min_unbiased_exp) | (mag == 0)
    code = (sign << 7) | ((e_unb + fmt.bias) << fmt.man_bits) \
        | (m & ((1 << fmt.man_bits) - 1))
    return jnp.where(underflow, sign << 7, code)    # FTZ: signed zero


def kv_quantize(scheme: KVQuantScheme, x):
    """jnp (runs inside the jitted prefill/decode steps): quantize-on-write.

    x [..., D] float -> (packed int32 [..., D/4], scales f32 [...]) with one
    symmetric absmax scale per trailing-D group.  int8 is round-to-nearest
    two's complement; fp8 is an RN-even E4M3 encode clipped to the codec's
    max finite, bit-identical to the ``core.formats`` codec.
    """
    xf = x.astype(jnp.float32)
    absmax = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-12)
    if scheme.name == "int8":
        scales = absmax / 127.0
        q = jnp.clip(jnp.round(xf / scales[..., None]), -128, 127)
        codes = q.astype(jnp.int32)
    else:                                   # fp8_e4m3
        fmt = F.FP8_E4M3
        scales = absmax / jnp.float32(fmt.max_finite)
        scaled = jnp.clip(xf / scales[..., None],
                          -fmt.max_finite, fmt.max_finite)
        codes = _encode_fp8_e4m3(scaled)
    return kv_pack_codes(codes), scales


def kv_decode_codes(scheme: KVQuantScheme, codes):
    """jnp: unsigned 8-bit codes -> f32 pre-scale values (codec semantics:
    two's complement for int8, DAZ LUT for fp8 — NaN/subnormals read as 0)."""
    if scheme.name == "int8":
        return _int_decode(codes, 8).astype(jnp.float32)
    return jnp.asarray(FP8_LUT)[codes]


def kv_dequantize(scheme: KVQuantScheme, packed, scales, dtype=jnp.bfloat16):
    """jnp: packed words + group scales -> dense KV slab [..., D]."""
    codes = kv_unpack_codes(packed)
    return (kv_decode_codes(scheme, codes) * scales[..., None]).astype(dtype)


def quantize_activations_int8(x):
    """Per-tensor symmetric INT8 activation quant (SmoothQuant-style); jnp."""
    absmax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12)
    scale = absmax / 127.0
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -128, 127).astype(jnp.int8)
    return codes, scale


def quantize_activations_fp8(x):
    """Per-tensor E4M3 activation quant; returns codes (uint8) + scale; jnp."""
    fmt = F.FP8_E4M3
    absmax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12)
    scale = absmax / fmt.max_finite
    scaled = x.astype(jnp.float32) / scale
    # jnp-native E4M3 cast (XLA float8 support), then reinterpret as codes
    codes = scaled.astype(jnp.float8_e4m3fn)
    return codes, scale
