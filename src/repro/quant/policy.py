"""PrecisionPolicy — the one datatype-adaptive contract (DESIGN.md §12).

XtraMAC's core claim is a *single* datatype-adaptive interface: int, float
and mixed formats behind one MAC contract, with runtime datatype switching.
Before this module the repro scattered that contract across four knobs —
per-leaf scheme strings (``get_scheme``), ad-hoc ``QuantMaker`` plan dicts,
``ServeConfig.kv_dtype``, and the process-global kernel toggles in
``kernels/ops.py``.  ``PrecisionPolicy`` consolidates them into one frozen,
JSON-serializable object:

  * ``weights`` — ordered (layer-name pattern, scheme) pairs, first match
    wins; patterns are ``fnmatch`` globs over the logical leaf names the
    Maker walk and the partitioning rules already share ("attn.wq",
    "ffn.*", "moe.w_up", ...).  An unmatched name keeps its config default.
  * ``kv``      — KV-cache storage tier: 'bf16' | 'int8' | 'fp8'.
  * ``kernel``  — execution dispatch: 'auto' (backend decides; today the
    jnp reference path unless a driver opted into Pallas), 'jnp' (force
    the reference path), 'pallas' (force the fused kernels; invalid under
    a multi-device mesh — they are not GSPMD-partitionable).

Everything downstream derives from the policy instead of carrying its own
knob: ``QuantMaker`` consumes ``resolved_plan(cfg)``,
``runtime/partitioning.param_specs`` derives shardings from the same plan,
``ServeConfig(policy=...)`` carries it into the serving engine (legacy
``kv_dtype=`` / ``plan=`` arguments are thin adapters emitting the
equivalent policy, bit-identity pinned), and ``kernels/ops`` dispatches on
``kernel``.  Validation is EAGER: unknown scheme/kv/kernel names raise at
construction, and ``validate_for(cfg, mesh)`` raises config- and
mesh-incompatibilities (group sizes that do not divide a leaf's K,
quantized KV on MLA or a non-packable d_head, Pallas under partitioning,
and — with ``strict_tp=True`` — packed-K groupings the tp split would
force to replicate) at policy-resolution time instead of at first pool
build or first trace.

Per-request runtime switching: the ``kv`` field is the *tier* a request
may override (``Request.kv_policy``) — the serving engine keys its jitted
steps by ``(n_slots, capacity, tier)`` and the scheduler cohorts decode
batches per tier, so one engine serves bf16/fp8/int8-KV traffic
concurrently (the software analogue of the paper's runtime datatype
switch, at the granularity JAX can retrace: per cache tree, not per MAC).
"""
from __future__ import annotations

import dataclasses
import functools
import json
from fnmatch import fnmatchcase
from typing import Any, Dict, Mapping, Optional, Tuple

from .schemes import KV_SCHEMES, SCHEMES

KERNEL_MODES = ("auto", "jnp", "pallas")
KV_TIERS = ("bf16",) + tuple(sorted(KV_SCHEMES))


def _kv_tier_name(kv_dtype) -> str:
    """Canonical tier name for any legacy ``kv_dtype`` spelling (string
    name or the jnp.bfloat16 dtype), raising a ``ValueError`` with the
    valid tiers — the eager twin of ``quant.kv_cache.kv_dtype_name``.
    A non-bf16 raw dtype is rejected rather than silently coerced: tiers
    name the three supported storage formats, and an f32 pool (say) has
    different bytes and numerics than anything a tier could honor."""
    if kv_dtype is None:
        return "bf16"
    if not isinstance(kv_dtype, str):
        import jax.numpy as jnp
        if jnp.dtype(kv_dtype) == jnp.dtype(jnp.bfloat16):
            return "bf16"               # legacy jnp-dtype spelling
        raise ValueError(
            f"KV pool dtype {kv_dtype!r} is not expressible as a "
            f"precision tier; valid tiers: {list(KV_TIERS)} (raw-dtype "
            "slabs remain available via KVCachePool directly)")
    if kv_dtype not in KV_TIERS:
        raise ValueError(
            f"unknown KV tier {kv_dtype!r}; valid tiers: {list(KV_TIERS)}")
    return kv_dtype


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """One declarative precision configuration, eagerly validated."""
    weights: Tuple[Tuple[str, str], ...] = ()
    kv: str = "bf16"
    kernel: str = "auto"

    def __post_init__(self):
        # accept a mapping or any iterable of pairs; store as tuple-of-
        # tuples so the policy is hashable (jit-cache keys) and frozen
        w = self.weights
        if isinstance(w, Mapping):
            w = tuple(w.items())
        w = tuple((str(p), str(s)) for p, s in w)
        object.__setattr__(self, "weights", w)
        for pat, scheme in w:
            if scheme not in SCHEMES:
                raise ValueError(
                    f"policy weights[{pat!r}]: unknown scheme {scheme!r}; "
                    f"valid schemes: {sorted(SCHEMES)}")
        object.__setattr__(self, "kv", _kv_tier_name(self.kv))
        if self.kernel not in KERNEL_MODES:
            raise ValueError(
                f"policy kernel={self.kernel!r}; valid modes: "
                f"{list(KERNEL_MODES)}")

    # ------------------------------------------------------------------
    # Resolution
    # ------------------------------------------------------------------
    def resolve(self, name: str, default: Optional[str] = None) -> str:
        """Scheme for logical leaf ``name``: first matching pattern wins,
        else the config default (None reads as dense 'bf16')."""
        for pat, scheme in self.weights:
            if fnmatchcase(name, pat):
                return scheme
        return default if default is not None else "bf16"

    def resolved_plan(self, cfg) -> Dict[str, str]:
        """The policy applied to ``cfg``: a concrete {leaf name -> scheme}
        map over every dense leaf of the model — the ``plan`` dict
        ``QuantMaker`` and ``partitioning.param_specs`` consume.  Leaves
        the policy does not match keep their config-default scheme."""
        return {name: self.resolve(name, default)
                for name, default in leaf_schemes(cfg).items()}

    # ------------------------------------------------------------------
    # Serialization (the policy is a deployment artifact).  The frozen
    # dataclass is itself hashable — jit-cache / cohort keys use the
    # policy's components directly (the serving engine keys steps by
    # (n_slots, capacity, tier)).
    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"weights": [list(p) for p in self.weights],
                "kv": self.kv, "kernel": self.kernel}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "PrecisionPolicy":
        unknown = set(d) - {"weights", "kv", "kernel"}
        if unknown:
            raise ValueError(
                f"policy dict has unknown keys {sorted(unknown)}; "
                "expected {'weights', 'kv', 'kernel'}")
        return cls(weights=tuple(tuple(p) for p in d.get("weights", ())),
                   kv=d.get("kv", "bf16"), kernel=d.get("kernel", "auto"))

    @classmethod
    def from_json(cls, s: str) -> "PrecisionPolicy":
        return cls.from_dict(json.loads(s))

    # ------------------------------------------------------------------
    # Legacy adapters (bit-identity pinned: the emitted policy resolves to
    # exactly the configuration the legacy knobs produced)
    # ------------------------------------------------------------------
    @classmethod
    def from_legacy(cls, *, kv_dtype=None,
                    plan: Optional[Mapping[str, str]] = None,
                    kernel: str = "auto") -> "PrecisionPolicy":
        """Adapter for the pre-policy knobs: a ``QuantMaker`` plan dict
        becomes exact-name weight patterns (a name with no glob characters
        only matches itself), ``kv_dtype`` becomes the tier."""
        return cls(weights=tuple((plan or {}).items()),
                   kv=_kv_tier_name(kv_dtype), kernel=kernel)

    def with_plan(self, plan: Mapping[str, str]) -> "PrecisionPolicy":
        """This policy with exact-name ``plan`` entries prepended (they
        win over the policy's own patterns, mirroring plan-over-config
        precedence of the legacy path)."""
        if not plan:
            return self
        return dataclasses.replace(
            self, weights=tuple(plan.items()) + self.weights)

    # ------------------------------------------------------------------
    # Eager validation against a model config (and optionally a mesh)
    # ------------------------------------------------------------------
    def validate_for(self, cfg, mesh=None, *,
                     strict_tp: bool = False) -> "PrecisionPolicy":
        """Raise every config/mesh incompatibility NOW, with an actionable
        message — not at first pool build or first trace.

        Checks: every weight pattern matches at least one leaf; every
        resolved quantized leaf's K is divisible by the scheme's packing
        word and scale group; a quantized KV tier needs a GQA cache with
        ``d_head % 4 == 0`` (MLA latents stay bf16, DESIGN.md §9);
        ``kernel='pallas'`` is rejected under a multi-device mesh (the
        kernels are not GSPMD-partitionable — 'auto' downgrades instead).
        ``strict_tp=True`` additionally rejects policies whose packed-K
        grouping FORCES replication of a leaf the name rules would
        otherwise K-shard over the model axis (word/scale-group
        boundaries not aligned with the tp split) — useful when sharded
        memory capacity is part of the deployment contract.  By default
        such leaves replicate silently instead: the ``param_specs`` rules
        guarantee codes/scales shard in lockstep by construction
        (DESIGN.md §10), so misalignment costs memory, never
        correctness.  Returns self for chaining."""
        from .schemes import effective_group, get_scheme
        from .pack import codes_per_word

        info = leaf_info(cfg)
        for pat, _ in self.weights:
            if not any(fnmatchcase(n, pat) for n in info):
                raise ValueError(
                    f"policy weights pattern {pat!r} matches no leaf of "
                    f"{cfg.name!r}; leaves: {sorted(info)}")

        tp = int(mesh.shape.get("model", 1)) if mesh is not None else 1
        for name, (k, _, default) in info.items():
            scheme_name = self.resolve(name, default)
            if scheme_name == "bf16":
                continue
            s = get_scheme(scheme_name)
            group = effective_group(s.group_size, k)
            if k % group != 0:
                raise ValueError(
                    f"policy: leaf {name!r} has K={k}, not divisible by "
                    f"{scheme_name!r}'s scale group {s.group_size} — pick "
                    "a scheme whose group divides K (or keep the leaf "
                    "dense with 'bf16')")
            if s.packed and k % codes_per_word(s.weight_bits) != 0:
                raise ValueError(
                    f"policy: leaf {name!r} has K={k}, not packable "
                    f"{codes_per_word(s.weight_bits)}-per-int32-word for "
                    f"{scheme_name!r}")
            if strict_tp and tp > 1 and k % tp == 0 and k >= tp:
                shard = k // tp
                per_word = codes_per_word(s.weight_bits) if s.packed else 1
                if shard % group != 0 or shard % per_word != 0:
                    raise ValueError(
                        f"policy: leaf {name!r} K={k} at tp={tp} gives "
                        f"per-shard K={shard}, which splits "
                        f"{scheme_name!r}'s "
                        + (f"scale group {group}" if shard % group else
                           f"{per_word}-code packing word")
                        + " — the leaf would silently replicate; lower tp,"
                        " change the group size, or drop strict_tp")

        validate_kv_tier(self.kv, cfg)

        # kernel='pallas' is valid under a multi-device mesh: the kernels
        # run shard_map'd over it (DESIGN.md §14), with per-site fallback
        # where shard-local shapes cannot tile — no eager rejection.
        return self


def validate_kv_tier(tier, cfg=None) -> str:
    """Canonical tier name, eagerly validated (optionally against a model
    config) with an actionable message — the check the serving engine runs
    for every pool tier, including per-request overrides."""
    name = _kv_tier_name(tier)
    if cfg is not None and name != "bf16":
        if getattr(cfg, "use_mla", False):
            raise ValueError(
                f"kv tier {name!r}: KV quantization covers the GQA "
                "per-head cache; the MLA latent cache is already "
                "compressed and stays bf16 (DESIGN.md §9) — use 'bf16' "
                "for MLA models")
        if cfg.head_dim % 4 != 0:
            raise ValueError(
                f"kv tier {name!r}: d_head={cfg.head_dim} is not "
                "divisible by 4 (quantized KV packs 4 codes per int32 "
                "word along d_head) — use 'bf16'")
    return name


# ---------------------------------------------------------------------------
# Config walk (lazy model imports: quant is imported by the model layer)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def leaf_info(cfg) -> Dict[str, Tuple[int, int, str]]:
    """{logical dense-leaf name -> (K, N, config-default scheme)} for
    ``cfg`` — the name universe policies resolve against.  ONE abstract
    Maker walk (the same walk parameters and sharding rules use, so the
    three can't drift), cached per config: engine construction validates
    AND resolves against it without re-walking, and repeated engine
    builds over one config (tier pools, tests) pay nothing."""
    from repro.models.common import AbstractMaker
    from repro.models.transformer import build_params

    found: Dict[str, Tuple[int, int, str]] = {}

    class Probe(AbstractMaker):
        def __init__(self):
            super().__init__(quantize=False)

        def dense(self, name, stack, k, n, scheme=None):
            found[name] = (k, n, scheme if scheme is not None else "bf16")
            return super().dense(name, stack, k, n, scheme)

    build_params(cfg, Probe())
    return found


def leaf_schemes(cfg) -> Dict[str, str]:
    """{logical dense-leaf name -> config-default scheme} for ``cfg``."""
    return {name: s for name, (_, _, s) in leaf_info(cfg).items()}


def leaf_dims(cfg) -> Dict[str, Tuple[int, int]]:
    """{logical dense-leaf name -> (K, N)} for ``cfg``."""
    return {name: (k, n) for name, (k, n, _) in leaf_info(cfg).items()}
