"""Attention layers: GQA (+RoPE), MLA (DeepSeek-V2), cross-attention.

Two execution modes, chosen by query length:
  * **Chunked online-softmax** (train / prefill): ``lax.scan`` over KV chunks
    with running (max, sum, acc) — flash-attention recurrence in pure jnp.
    Keeps peak memory at one [.., Sq, chunk] score block and keeps the HLO
    small for 512-device compiles.
  * **Dense split-KV** (decode, Sq == 1): one einsum over the full KV length
    so the KV sequence axis can be sharded (flash-decode style); GSPMD turns
    the softmax/contraction over the sharded axis into the partial-softmax +
    all-reduce combine pattern.  With the execution policy pinned to
    ``kernel='pallas'`` (``PrecisionPolicy`` / ``ops.declare_execution``)
    the GQA decode branch instead runs the fused Pallas flash-decode kernel
    (``kernels/decode_attention.py``): packed KV blocks stream out of the
    pool and dequantize in-kernel; the einsum path here is kept as the
    interpret-mode oracle (DESIGN.md §9).

Projection weights go through ``apply_linear`` and may be quantized
(the paper's technique applies to projection MACs); the attention MACs
themselves (QK^T, PV) stay BF16xBF16 — exactly the paper's Table I split.
The KV *cache* may additionally be stored quantized (``kv_dtype`` = 'int8'
/ 'fp8'): writes quantize per (position, head) group inside the jitted
steps, reads dequantize (einsum path) or stream packed codes (kernel path).

Shapes: x [B, S, D]; heads layout [B, S, H, Dh].
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant.kv_cache import (cache_read, cache_write_rows,
                                  cache_write_slice, gather_pages,
                                  kv_slab_pspec, kv_slab_spec, scatter_pages)
from repro.quant.schemes import get_kv_scheme

from .common import (Maker, apply_linear, apply_rope, rms_norm,
                     shard_act)

_NEG = -1e30  # -inf stand-in that keeps exp() NaN-free on fully-masked rows


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    use_rope: bool = True
    causal: bool = True
    qkv_scheme: Optional[str] = None    # quantization scheme for projections
    kv_chunk: int = 512


# ---------------------------------------------------------------------------
# Parameter construction (Maker-driven; see common.py)
# ---------------------------------------------------------------------------
def attn_params(mk: Maker, cfg: AttnConfig, stack: Tuple[int, ...]) -> Dict[str, Any]:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s = cfg.qkv_scheme
    return {
        "wq": mk.dense("attn.wq", stack, d, h * dh, scheme=s),
        "wk": mk.dense("attn.wk", stack, d, hk * dh, scheme=s),
        "wv": mk.dense("attn.wv", stack, d, hk * dh, scheme=s),
        "wo": mk.dense("attn.wo", stack, h * dh, d, scheme=s),
    }


def cross_attn_params(mk: Maker, cfg: AttnConfig, stack) -> Dict[str, Any]:
    return attn_params(mk, cfg, stack)


# ---------------------------------------------------------------------------
# Core softmax attention (both execution modes)
# ---------------------------------------------------------------------------
def _repeat_kv(x, rep: int):
    if rep == 1:
        return x
    b, s, hk, dh = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, hk, rep, dh)).reshape(
        b, s, hk * rep, dh
    )


def attend(q, k, v, *, causal: bool, q_offset=0, kv_chunk: int = 512,
           kv_valid_len=None):
    """Softmax attention.  q [B,Sq,H,Dh]; k,v [B,Sk,Hk,Dh] -> [B,Sq,H,Dh].

    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``kv_valid_len``: optional [B] count of valid KV positions (ragged cache).
    """
    b, sq, h, dh = q.shape
    sk, hk = k.shape[1], k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    if sq == 1 or sk <= kv_chunk or sk % kv_chunk != 0:
        return _attend_dense(q, k, v, causal=causal, q_offset=q_offset,
                             kv_valid_len=kv_valid_len, scale=scale)
    return _attend_chunked(q, k, v, causal=causal, q_offset=q_offset,
                           kv_chunk=kv_chunk, kv_valid_len=kv_valid_len,
                           scale=scale)


def _mask_bias(causal, q_offset, sq, sk, k_offset, kv_valid_len, b):
    """[B or 1, Sq, Sk_chunk] additive f32 bias (0 or _NEG).

    ``q_offset`` may be a scalar (whole batch at one position — the static
    one-shot path) or a [B] vector (continuous batching: every cache slot
    sits at its own length).
    """
    q_off = jnp.reshape(jnp.asarray(q_offset), (-1, 1, 1))   # [B or 1, 1, 1]
    qpos = q_off + jnp.arange(sq)[None, :, None]             # [B or 1, Sq, 1]
    kpos = k_offset + jnp.arange(sk)[None, None, :]          # [1, 1, Sk]
    ok = jnp.broadcast_to(kpos <= qpos if causal else
                          jnp.ones((1, 1, sk), bool),
                          (qpos.shape[0], sq, sk))
    bias = jnp.where(ok, 0.0, _NEG)                          # [B or 1, Sq, Sk]
    if kv_valid_len is not None:
        valid = kpos < kv_valid_len[:, None, None]           # [B, 1, Sk]
        bias = jnp.where(valid, bias, _NEG)
    return bias


def _attend_dense(q, k, v, *, causal, q_offset, kv_valid_len, scale):
    """Grouped-GQA attention: K/V are NEVER materialized per query head —
    the einsums carry an explicit (group, rep) split; inputs stay bf16 with
    f32 accumulation (preferred_element_type), so no f32 copy of the KV
    cache is created either (decisive for 32k-cache decode).  Scores are
    kept in FLAT-head layout [b, h, sq, sk] so the full 16-way 'model' axis
    shards them (the grouped dims hk < 16 could not)."""
    b, sq, h, dh = q.shape
    sk, hk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    rep = h // hk
    qg = (q * scale.astype(q.dtype)).reshape(b, sq, hk, rep, dh)
    # bf16-storage dots: on TPU the MXU accumulates in f32 natively; asking
    # for an f32 result here makes the CPU backend hoist an f32 COPY of the
    # whole KV cache into the decode loop carry (verified in the dry-run
    # HLO), so the f32 upcast happens after the contraction instead.
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32)
    s = shard_act(s.reshape(b, h, sq, sk), "bhqk")
    bias = _mask_bias(causal, q_offset, sq, sk, 0, kv_valid_len, b)
    s = s + bias[:, None]
    p = jax.nn.softmax(s, axis=-1)
    pg = p.reshape(b, hk, rep, sq, sk).astype(v.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", pg, v)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def _attend_chunked(q, k, v, *, causal, q_offset, kv_chunk, kv_valid_len, scale):
    b, sq, h, dh = q.shape
    sk, hk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    rep = h // hk
    assert sk % kv_chunk == 0, (sk, kv_chunk)
    n_chunks = sk // kv_chunk
    qg = (q * scale.astype(q.dtype)).reshape(b, sq, hk, rep, dh)

    kc = jnp.moveaxis(k.reshape(b, n_chunks, kv_chunk, hk, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, kv_chunk, hk, dv), 1, 0)

    def body(carry, inp):
        m, l, acc = carry                      # flat-h: [b,h,sq], [...,dv]
        kci, vci, idx = inp
        # bf16-storage dots (see _attend_dense) — accumulation across
        # chunks stays f32 in the carry
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, kci).astype(jnp.float32)
        s = shard_act(s.reshape(b, h, sq, kv_chunk), "bhqk")
        bias = _mask_bias(causal, q_offset, sq, kv_chunk, idx * kv_chunk,
                          kv_valid_len, b)
        s = s + bias[:, None]
        m_new = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(-1)
        pg = p.reshape(b, hk, rep, sq, kv_chunk).astype(vci.dtype)
        pv = jnp.einsum("bhrqk,bkhd->bhrqd", pg, vci).astype(jnp.float32)
        pv = shard_act(pv.reshape(b, h, sq, dv), "bhqd")
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = shard_act(jnp.zeros((b, h, sq, dv), jnp.float32), "bhqd")
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]      # [b,h,sq,dv]
    out = jnp.moveaxis(out, 2, 1)                      # [b,sq,h,dv]
    return out.reshape(b, sq, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA self-attention layer (with optional KV cache for serving)
# ---------------------------------------------------------------------------
def gqa_forward(params, cfg: AttnConfig, x, *, positions=None,
                cache: Optional[Tuple] = None, cache_index=None,
                attend_local: bool = False, page_table=None):
    """x [B, S, D] -> (out [B, S, D], new_cache).

    cache = (k_cache [B, Smax, Hk, Dh], v_cache ...) with ``cache_index`` the
    write offset (prefill: 0; decode: current length).  ``cache_index`` may
    be a [B] vector (decode only, s == 1): row i writes at its own slot
    length — the continuous-batching path where every sequence in the batch
    is at a different position.  No cache: plain causal self-attention over
    x itself.  ``attend_local``: write the cache but attend over the
    freshly-computed k/v (prefill-from-empty: identical math, and keeps the
    chunked scan off the sharded cache sequence axis).

    ``page_table`` (paged serving, DESIGN.md §15): when given, ``cache`` is
    a page *arena* [n_pages, page_size, Hk, Dh] per slab and ``page_table``
    [B, pages_per_slot] maps each batch row to its pages.  The arena is
    gathered into the per-row virtual slab up front, ALL write/attend logic
    below runs unchanged on that slab (identical bytes, identical shapes —
    the bit-identity contract with the slab pool), and the updated slab is
    scattered back through the table on the way out.
    """
    b, s, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    arena = None
    if cache is not None and page_table is not None:
        arena = cache
        cache = tuple(gather_pages(a, page_table) for a in arena)
    q = shard_act(apply_linear(params["wq"], x).reshape(b, s, h, dh), "bthd")
    k = shard_act(apply_linear(params["wk"], x).reshape(b, s, hk, dh), "bthd")
    v = shard_act(apply_linear(params["wv"], x).reshape(b, s, hk, dh), "bthd")

    per_row = cache_index is not None and jnp.ndim(cache_index) == 1
    if positions is None:
        base = jnp.asarray(0 if cache_index is None else cache_index)
        positions = (base[:, None] if per_row else base) \
            + jnp.arange(s)[None, :]                         # [B or 1, S]
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        k_cache, v_cache = cache
        if per_row:
            assert s == 1, "per-row cache_index is a decode-only path"
            rows = jnp.arange(b)
            k_cache = cache_write_rows(k_cache, k, rows, cache_index)
            v_cache = cache_write_rows(v_cache, v, rows, cache_index)
        else:
            k_cache = cache_write_slice(k_cache, k, cache_index)
            v_cache = cache_write_slice(v_cache, v, cache_index)
        new_cache = (k_cache, v_cache)

    if cache is None or attend_local:
        out = attend(q, k, v, causal=cfg.causal, q_offset=0,
                     kv_chunk=cfg.kv_chunk)
    else:
        k_cache, v_cache = new_cache
        valid = jnp.broadcast_to(
            jnp.asarray(cache_index + s, jnp.int32), (b,))
        out = None
        if s == 1 and cfg.causal:
            # fused flash-decode when the execution policy selects it:
            # streams (packed) KV blocks straight from the pool slab,
            # dequantizes in-kernel, no [B,S,H,D] copy — shard_map'd over
            # a declared mesh (slots on 'data', KV heads on 'model')
            from repro.kernels.ops import fused_decode_attention
            out = fused_decode_attention(q, k_cache, v_cache, valid)
        if out is None:
            out = attend(q, cache_read(k_cache), cache_read(v_cache),
                         causal=cfg.causal, q_offset=cache_index,
                         kv_chunk=cfg.kv_chunk, kv_valid_len=valid)

    if arena is not None and new_cache is not None:
        new_cache = tuple(scatter_pages(a, page_table, v)
                          for a, v in zip(arena, new_cache))
    out = out.reshape(b, s, h * dh)
    return apply_linear(params["wo"], out), new_cache


def gqa_cache_spec(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """``dtype`` is the pool knob: a jnp dtype / 'bf16' for plain slabs, or
    a KV scheme name ('int8'/'fp8') for packed-codes + scales slabs."""
    shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return (kv_slab_spec(shape, dtype), kv_slab_spec(shape, dtype))


def gqa_cache_pspec(cfg: AttnConfig, kv_dtype, slot_ax, head_ax):
    """PartitionSpec twin of ``gqa_cache_spec`` for one pool layer
    [slots, S, H, D]: slots on ``slot_ax`` (DP), heads on ``head_ax`` (TP),
    sequence and d_head local (per-slot writes land at traced offsets;
    packed codes cannot split along d_head)."""
    s = kv_slab_pspec((slot_ax, None, head_ax, None), kv_dtype)
    return (s, s)


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder): KV from encoder output, no mask, no rope
# ---------------------------------------------------------------------------
def cross_attn_forward(params, cfg: AttnConfig, x, enc):
    """x [B, Sq, D] attends over enc [B, Sk, D]."""
    b, sq, d = x.shape
    sk = enc.shape[1]
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = shard_act(apply_linear(params["wq"], x).reshape(b, sq, h, dh), "bthd")
    k = shard_act(apply_linear(params["wk"], enc).reshape(b, sk, hk, dh), "bthd")
    v = shard_act(apply_linear(params["wv"], enc).reshape(b, sk, hk, dh), "bthd")
    out = attend(q, k, v, causal=False, kv_chunk=cfg.kv_chunk)
    return apply_linear(params["wo"], out.reshape(b, sq, h * dh))


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora: int          # query low-rank dim (0 = dense q projection)
    kv_lora: int         # compressed KV latent dim (the cached quantity)
    d_head_nope: int     # per-head non-rope dim
    d_head_rope: int     # shared rope dim
    d_head_v: int        # per-head value dim
    rope_theta: float = 10000.0
    qkv_scheme: Optional[str] = None
    kv_chunk: int = 512


def mla_params(mk: Maker, cfg: MLAConfig, stack) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.n_heads
    s = cfg.qkv_scheme
    p: Dict[str, Any] = {
        # KV compression: latent + shared rope key
        "w_dkv": mk.dense("attn.w_dkv", stack, d, cfg.kv_lora + cfg.d_head_rope, scheme=s),
        "kv_norm": mk.norm("attn.kv_norm", stack, cfg.kv_lora),
        # per-head expansions out of the latent
        "w_uk": mk.dense("attn.w_uk", stack, cfg.kv_lora, h * cfg.d_head_nope, scheme=s),
        "w_uv": mk.dense("attn.w_uv", stack, cfg.kv_lora, h * cfg.d_head_v, scheme=s),
        "wo": mk.dense("attn.wo", stack, h * cfg.d_head_v, d, scheme=s),
    }
    if cfg.q_lora:
        p["w_dq"] = mk.dense("attn.w_dq", stack, d, cfg.q_lora, scheme=s)
        p["q_norm"] = mk.norm("attn.q_norm", stack, cfg.q_lora)
        p["w_uq"] = mk.dense("attn.w_uq", stack, cfg.q_lora,
                             h * (cfg.d_head_nope + cfg.d_head_rope), scheme=s)
    else:
        p["w_uq"] = mk.dense("attn.w_uq", stack, d,
                             h * (cfg.d_head_nope + cfg.d_head_rope), scheme=s)
    return p


def _mla_queries(params, cfg: MLAConfig, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    if cfg.q_lora:
        cq = rms_norm(apply_linear(params["w_dq"], x), params["q_norm"])
        q = apply_linear(params["w_uq"], cq)
    else:
        q = apply_linear(params["w_uq"], x)
    q = shard_act(q.reshape(b, s, h, cfg.d_head_nope + cfg.d_head_rope),
                  "bthd")
    q_nope, q_rope = jnp.split(q, [cfg.d_head_nope], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(params, cfg: MLAConfig, x, *, cache=None, cache_index=None,
                positions=None, attend_local: bool = False, page_table=None):
    """MLA attention.  cache = (c_kv [B,Smax,kv_lora], k_rope [B,Smax,Dr]).

    Prefill/train path expands K/V per position; the decode path (Sq==1)
    uses the *absorbed* formulation — scores and values computed directly in
    the compressed latent space (the MLA serving trick), so cached bytes are
    kv_lora + d_head_rope per token regardless of head count.  As in
    ``gqa_forward``, ``cache_index`` may be a [B] vector for per-slot decode.
    ``page_table`` gathers/scatters the latent + rope arenas exactly as in
    ``gqa_forward`` — the offsets differ (no head axis) but the pages are
    the same [page, position, ...] layout (DESIGN.md §15).
    """
    b, s, d = x.shape
    h = cfg.n_heads
    arena = None
    if cache is not None and page_table is not None:
        arena = cache
        cache = tuple(gather_pages(a, page_table) for a in arena)
    per_row = cache_index is not None and jnp.ndim(cache_index) == 1
    if positions is None:
        base = jnp.asarray(0 if cache_index is None else cache_index)
        positions = (base[:, None] if per_row else base) \
            + jnp.arange(s)[None, :]
    q_nope, q_rope = _mla_queries(params, cfg, x, positions)

    ckr = apply_linear(params["w_dkv"], x)
    c_kv, k_rope = jnp.split(ckr, [cfg.kv_lora], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    new_cache = None
    valid = None
    q_off = 0
    if cache is not None:
        c_cache, r_cache = cache
        if per_row:
            assert s == 1, "per-row cache_index is a decode-only path"
            rows = jnp.arange(b)
            new_cache = (cache_write_rows(c_cache, c_kv, rows, cache_index),
                         cache_write_rows(r_cache, k_rope, rows, cache_index))
        else:
            new_cache = (cache_write_slice(c_cache, c_kv, cache_index),
                         cache_write_slice(r_cache, k_rope, cache_index))
        if not attend_local:   # attend over the cache (decode / chunked fill)
            c_kv, k_rope = new_cache
            valid = jnp.broadcast_to(
                jnp.asarray(cache_index + s, jnp.int32), (b,))
            q_off = cache_index

    if s == 1 and cache is not None:
        out = _mla_decode_absorbed(params, cfg, q_nope, q_rope, c_kv, k_rope,
                                   valid, q_off)
    else:
        out = _mla_expanded(params, cfg, q_nope, q_rope, c_kv, k_rope, valid,
                            q_off, s)
    if arena is not None and new_cache is not None:
        new_cache = tuple(scatter_pages(a, page_table, v)
                          for a, v in zip(arena, new_cache))
    return apply_linear(params["wo"], out.reshape(b, s, h * cfg.d_head_v)), new_cache


def _mla_expanded(params, cfg, q_nope, q_rope, c_kv, k_rope, valid, q_off, sq):
    b, sk = c_kv.shape[0], c_kv.shape[1]
    h = cfg.n_heads
    k_nope = shard_act(apply_linear(params["w_uk"], c_kv)
                       .reshape(b, sk, h, cfg.d_head_nope), "bthd")
    v = shard_act(apply_linear(params["w_uv"], c_kv)
                  .reshape(b, sk, h, cfg.d_head_v), "bthd")
    # fold the shared rope key in as extra head dims (standard MLA trick)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, sk, h, cfg.d_head_rope))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    return attend(q_full, k_full, v, causal=True, q_offset=q_off,
                  kv_chunk=cfg.kv_chunk, kv_valid_len=valid)


def _mla_decode_absorbed(params, cfg, q_nope, q_rope, c_kv, k_rope, valid, q_off):
    """Absorbed decode: scores/values in latent space; never expand K/V."""
    b, sk = c_kv.shape[0], c_kv.shape[1]
    h = cfg.n_heads
    # absorb W_uk into the query:  q_lat [B,1,H,kv_lora]
    w_uk = params["w_uk"]
    from .common import QLinear
    if isinstance(w_uk, QLinear):  # dequantize for the absorbed contraction
        from repro.quant.schemes import QuantizedLinearWeights, get_scheme, dequantize
        w_uk_d = dequantize(QuantizedLinearWeights(
            get_scheme(w_uk.scheme_name), w_uk.packed, w_uk.scales, w_uk.shape),
            dtype=jnp.bfloat16)
    else:
        w_uk_d = w_uk
    w_uk_h = w_uk_d.reshape(cfg.kv_lora, h, cfg.d_head_nope)
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk_h.astype(q_nope.dtype))
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.d_head_nope + cfg.d_head_rope))
    # latent cache stays bf16 in the einsums (no f32 copy of the 32k cache);
    # scores upcast to f32 AFTER the contraction (MXU accumulates f32
    # internally on TPU — bf16 here is the storage type of the result)
    s_lat = jnp.einsum("bqhl,bkl->bhqk", q_lat.astype(c_kv.dtype), c_kv)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope.astype(q_rope.dtype))
    s = (s_lat.astype(jnp.float32) + s_rope.astype(jnp.float32)) * scale
    kpos = jnp.arange(sk)[None, None, None, :]
    if valid is not None:
        s = jnp.where(kpos < valid[:, None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqk,bkl->bqhl", p.astype(c_kv.dtype), c_kv)
    # absorb W_uv on the way out
    w_uv = params["w_uv"]
    if isinstance(w_uv, QLinear):
        from repro.quant.schemes import QuantizedLinearWeights, get_scheme, dequantize
        w_uv_d = dequantize(QuantizedLinearWeights(
            get_scheme(w_uv.scheme_name), w_uv.packed, w_uv.scales, w_uv.shape),
            dtype=jnp.bfloat16)
    else:
        w_uv_d = w_uv
    w_uv_h = w_uv_d.reshape(cfg.kv_lora, h, cfg.d_head_v)
    out = jnp.einsum("bqhl,lhd->bqhd", o_lat, w_uv_h.astype(o_lat.dtype))
    return out.astype(q_nope.dtype)


def mla_cache_spec(cfg: MLAConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    if get_kv_scheme(dtype) is not None:
        raise ValueError(
            f"kv_dtype={dtype!r}: KV quantization covers the GQA per-head "
            "cache; the MLA latent cache is already compressed (kv_lora per "
            "token) and stays bf16 — see DESIGN.md §9")
    return (jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora), dtype),
            jax.ShapeDtypeStruct((batch, max_len, cfg.d_head_rope), dtype))


def mla_cache_pspec(cfg: MLAConfig, slot_ax):
    """PartitionSpec twin of ``mla_cache_spec`` for one pool layer: the
    compressed latent and shared rope key have no head axis — only the slot
    dim shards (the latent is consumed whole by every head's absorbed
    contraction, so splitting it would shard a contraction dim)."""
    from jax.sharding import PartitionSpec as P
    return (P(slot_ax, None, None), P(slot_ax, None, None))
