"""Unified, config-driven model stack for every assigned architecture.

One ``ModelConfig`` describes dense / MoE (incl. MLA) / SSM (xLSTM) /
hybrid (Mamba2+shared-attention) / VLM (patch-stub) / audio (enc-dec,
frame-stub) families.  Parameters are built by the Maker walk in
``common.py`` — the same walk yields real weights, quantized weights,
ShapeDtypeStructs (dry-run) and PartitionSpecs (pjit), so structure,
quantization plan and sharding cannot drift.

Homogeneous layer stacks run under ``lax.scan`` with optional
``jax.checkpoint`` (remat) — keeping the HLO small enough to compile the
512-device production mesh and bounding activation memory.  Heterogeneous
stacks (xLSTM's 7:1 mLSTM:sLSTM pattern, Zamba2's shared attention every 6
Mamba blocks) scan over *groups* with the special block unrolled inside the
group body.

Caches: every family exposes ``init_cache`` (zeros or abstract specs) and
the same forward entry point serves train (cache=None), prefill (cache +
index 0) and decode (cache + running index) — the serving engine in
``serve/`` builds on exactly this.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import moe as MOE
from . import ssm as S
from .common import (AbstractMaker, InitMaker, Maker, PspecMaker, QuantMaker,
                     activate, apply_linear, layer_norm, rms_norm, shard_act,
                     sinusoidal_positions)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 -> d_model // n_heads
    activation: str = "silu"
    gated_ffn: bool = True
    norm: str = "rms"           # rms | layer
    rope_theta: float = 10000.0
    use_rope: bool = True
    tie_embeddings: bool = True
    # --- quantization plan (the paper's technique) ---
    scheme_proj: Optional[str] = None    # attention/ssm projection weights
    scheme_ffn: Optional[str] = None     # FFN / expert weights
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    d_head_nope: int = 128
    d_head_rope: int = 64
    d_head_v: int = 128
    # --- SSM / hybrid ---
    ssm_state: int = 64
    ssm_d_head: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    slstm_every: int = 8        # xlstm: every 8th block is sLSTM
    attn_every: int = 6         # zamba2: shared attn block every 6 mamba
    # --- frontends (stubs: precomputed embeddings arrive as inputs) ---
    n_patches: int = 0          # vlm: patch embeddings [B, n_patches, d]
    n_frames: int = 0           # audio: encoder frames [B, n_frames, d]
    encoder_layers: int = 0     # audio enc-dec split
    # --- execution ---
    remat: bool = True
    kv_chunk: int = 512
    logit_softcap: float = 0.0
    microbatches: int = 1   # gradient-accumulation splits of the train batch

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def supports_long_context(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_encoder_decoder(self) -> bool:
        return self.family == "audio"

    def attn_cfg(self, causal=True, use_rope=None) -> A.AttnConfig:
        return A.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, d_head=self.head_dim,
            rope_theta=self.rope_theta,
            use_rope=self.use_rope if use_rope is None else use_rope,
            causal=causal, qkv_scheme=self.scheme_proj, kv_chunk=self.kv_chunk)

    def mla_cfg(self) -> A.MLAConfig:
        return A.MLAConfig(
            d_model=self.d_model, n_heads=self.n_heads, q_lora=self.q_lora,
            kv_lora=self.kv_lora, d_head_nope=self.d_head_nope,
            d_head_rope=self.d_head_rope, d_head_v=self.d_head_v,
            rope_theta=self.rope_theta, qkv_scheme=self.scheme_proj,
            kv_chunk=self.kv_chunk)

    def moe_cfg(self) -> MOE.MoEConfig:
        return MOE.MoEConfig(
            d_model=self.d_model, d_ff=self.moe_d_ff or self.d_ff,
            n_experts=self.n_experts, top_k=self.top_k,
            n_shared_experts=self.n_shared_experts,
            shared_d_ff=0,  # default: n_shared * expert d_ff (DeepSeek-V2)
            capacity_factor=self.capacity_factor, activation=self.activation,
            scheme=self.scheme_ffn)

    def mamba_cfg(self) -> S.Mamba2Config:
        return S.Mamba2Config(
            d_model=self.d_model, d_state=self.ssm_state,
            d_head=self.ssm_d_head, expand=self.ssm_expand,
            chunk=self.ssm_chunk, scheme=self.scheme_proj)

    def mlstm_cfg(self) -> S.MLSTMConfig:
        return S.MLSTMConfig(d_model=self.d_model, n_heads=self.n_heads,
                             expand=self.ssm_expand, chunk=self.ssm_chunk,
                             scheme=self.scheme_proj)

    def slstm_cfg(self) -> S.SLSTMConfig:
        return S.SLSTMConfig(d_model=self.d_model, n_heads=self.n_heads,
                             scheme=self.scheme_proj)


# ---------------------------------------------------------------------------
# Norm helper (gamma-only RMS or gamma+beta LayerNorm)
# ---------------------------------------------------------------------------
def _norm_params(mk: Maker, cfg: ModelConfig, name: str, stack, dim=None):
    d = dim or cfg.d_model
    p = {"g": mk.norm(name, stack, d)}
    if cfg.norm == "layer":
        p["b"] = mk.vector(name + ".b", stack, d)
    return p


def _apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "layer":
        return layer_norm(x, p["g"], p["b"])
    return rms_norm(x, p["g"])


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------
def _ffn_params(mk: Maker, cfg: ModelConfig, stack):
    d, f, s = cfg.d_model, cfg.d_ff, cfg.scheme_ffn
    if cfg.gated_ffn:
        return {"w_gate": mk.dense("ffn.w_gate", stack, d, f, scheme=s),
                "w_up": mk.dense("ffn.w_up", stack, d, f, scheme=s),
                "w_down": mk.dense("ffn.w_down", stack, f, d, scheme=s)}
    return {"w_in": mk.dense("ffn.w_in", stack, d, f, scheme=s),
            "w_out": mk.dense("ffn.w_out", stack, f, d, scheme=s)}


def _ffn_apply(cfg: ModelConfig, p, x):
    if cfg.gated_ffn:
        g = shard_act(apply_linear(p["w_gate"], x), "btf")
        u = shard_act(apply_linear(p["w_up"], x), "btf")
        h = (activate(cfg.activation, g.astype(jnp.float32))
             * u.astype(jnp.float32)).astype(jnp.bfloat16)
        return apply_linear(p["w_down"], h)
    # non-gated path: activation math stays bf16 — relu^2/gelu are stable in
    # bf16 and the f32 cast otherwise stacks f32 saved-residuals per layer
    h = activate(cfg.activation,
                 shard_act(apply_linear(p["w_in"], x), "btf"))
    return apply_linear(p["w_out"], h.astype(jnp.bfloat16))


# ---------------------------------------------------------------------------
# Transformer blocks (attention + FFN/MoE)
# ---------------------------------------------------------------------------
def _tf_block_params(mk: Maker, cfg: ModelConfig, stack, *, causal=True,
                     cross=False):
    p = {"ln1": _norm_params(mk, cfg, "ln1", stack)}
    if cfg.use_mla:
        p["attn"] = A.mla_params(mk, cfg.mla_cfg(), stack)
    else:
        p["attn"] = A.attn_params(mk, cfg.attn_cfg(causal), stack)
    if cross:
        p["ln_x"] = _norm_params(mk, cfg, "ln_x", stack)
        p["xattn"] = A.cross_attn_params(mk, cfg.attn_cfg(False), stack)
    p["ln2"] = _norm_params(mk, cfg, "ln2", stack)
    if cfg.n_experts and not cross:          # decoder MoE only in LM families
        p["moe"] = MOE.moe_params(mk, cfg.moe_cfg(), stack)
    else:
        p["ffn"] = _ffn_params(mk, cfg, stack)
    return p


def _tf_block_apply(cfg: ModelConfig, p, x, *, cache=None, cache_index=None,
                    positions=None, enc=None, causal=True, moe_groups=None,
                    attend_local=False, page_table=None):
    """One transformer block.  Returns (x, new_cache, aux)."""
    h = _apply_norm(cfg, p["ln1"], x)
    if cfg.use_mla:
        attn_out, new_cache = A.mla_forward(p["attn"], cfg.mla_cfg(), h,
                                            cache=cache, cache_index=cache_index,
                                            positions=positions,
                                            attend_local=attend_local,
                                            page_table=page_table)
    else:
        attn_out, new_cache = A.gqa_forward(p["attn"], cfg.attn_cfg(causal), h,
                                            cache=cache, cache_index=cache_index,
                                            positions=positions,
                                            attend_local=attend_local,
                                            page_table=page_table)
    x = x + attn_out
    if enc is not None and "xattn" in p:
        hx = _apply_norm(cfg, p["ln_x"], x)
        x = x + A.cross_attn_forward(p["xattn"], cfg.attn_cfg(False), hx, enc)
    h2 = _apply_norm(cfg, p["ln2"], x)
    aux = jnp.float32(0.0)
    if "moe" in p:
        out, aux = MOE.moe_forward(p["moe"], cfg.moe_cfg(), h2,
                                   n_groups=moe_groups)
    else:
        out = _ffn_apply(cfg, p["ffn"], h2)
    return x + out, new_cache, aux


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------
def build_params(cfg: ModelConfig, mk: Maker) -> Dict[str, Any]:
    p: Dict[str, Any] = {
        "embed": mk.table("embed", (), cfg.vocab, cfg.d_model),
        "ln_f": _norm_params(mk, cfg, "ln_f", ()),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = mk.dense("lm_head", (), cfg.d_model, cfg.vocab, scheme=None)

    L = cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm"):
        p["layers"] = _tf_block_params(mk, cfg, (L,))
    elif cfg.family == "ssm":              # xLSTM: groups of (k-1) mLSTM + 1 sLSTM
        per = cfg.slstm_every
        assert L % per == 0, (L, per)
        g = L // per
        p["mlstm"] = S.mlstm_params(mk, cfg.mlstm_cfg(), (g, per - 1))
        p["mlstm_ln"] = _norm_params(mk, cfg, "ln1", (g, per - 1))
        p["slstm"] = S.slstm_params(mk, cfg.slstm_cfg(), (g,))
        p["slstm_ln"] = _norm_params(mk, cfg, "ln1", (g,))
        if cfg.d_ff:   # xlstm-350m has d_ff=0: FFN is folded into the blocks
            p["ffn"] = _ffn_params(mk, cfg, (g, per))
            p["ffn_ln"] = _norm_params(mk, cfg, "ln2", (g, per))
    elif cfg.family == "hybrid":           # Zamba2: shared attn every k mamba
        per = cfg.attn_every
        g, rem = divmod(L, per)
        p["mamba"] = S.mamba2_params(mk, cfg.mamba_cfg(), (L,))
        p["mamba_ln"] = _norm_params(mk, cfg, "ln1", (L,))
        p["shared_attn"] = _tf_block_params(mk, cfg, ())   # ONE shared block
    elif cfg.family == "audio":            # whisper enc-dec
        Le, Ld = cfg.encoder_layers, L - cfg.encoder_layers
        p["enc_layers"] = _tf_block_params(mk, cfg, (Le,), causal=False)
        p["enc_pos"] = mk.table("enc_pos", (), cfg.n_frames, cfg.d_model)
        p["enc_ln_f"] = _norm_params(mk, cfg, "enc_ln_f", ())
        p["dec_layers"] = _tf_block_params(mk, cfg, (Ld,), cross=True)
        p["dec_pos"] = mk.table("dec_pos", (), 32768, cfg.d_model)
    else:
        raise ValueError(cfg.family)
    return p


def _maybe_remat(fn, cfg: ModelConfig, mode: str):
    if cfg.remat and mode == "train":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def _embed(cfg: ModelConfig, params, tokens):
    return params["embed"][tokens].astype(jnp.bfloat16)


def _logits(cfg: ModelConfig, params, x):
    x = _apply_norm(cfg, params["ln_f"], x)
    if cfg.tie_embeddings:
        w = params["embed"].astype(jnp.bfloat16)
        logits = jnp.einsum("bsd,vd->bsv", x, w, preferred_element_type=jnp.float32)
    else:
        logits = apply_linear(params["lm_head"], x, out_dtype=jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return shard_act(logits, "logits")


def _scan_stack(cfg, mode, body, x0, layer_params, cache):
    """Scan ``body`` over a stacked layer dim; cache threaded as xs/ys."""
    def constrained(carry, xs):
        x, aux = carry
        return body((shard_act(x, "btd"), aux), xs)

    fn = _maybe_remat(constrained, cfg, mode)
    (x, aux), new_cache = jax.lax.scan(fn, (shard_act(x0, "btd"),
                                            jnp.float32(0.0)),
                                       (layer_params, cache))
    return x, aux, new_cache


def forward(cfg: ModelConfig, params, batch: Dict[str, Any], *,
            cache: Optional[Dict] = None, cache_index=None, mode: str = "train",
            page_table=None):
    """Unified forward.  mode: train | prefill | prefill_chunk | decode.

    batch: tokens [B, S]; vlm adds patches [B, Np, D]; audio adds frames
    [B, Sf, D].  Returns (logits [B, S(+Np), V], aux_loss, new_cache).

    ``prefill_chunk`` is the continuous-batching prefill step (DESIGN.md §7):
    like prefill it writes S new positions into the cache at ``cache_index``,
    but it attends over the *cache* (earlier chunks of the same prompt are
    already there) and returns logits for every chunk position, so the
    caller can read the true last-token logits out of a padded final chunk.
    ``decode`` additionally accepts a per-row [B] ``cache_index`` (each KV
    slot at its own length — the serving scheduler's batch).

    ``page_table`` [B, pages_per_slot] (paged serving, DESIGN.md §15):
    ``cache`` leaves are page arenas [L, n_pages, page_size, ...] and each
    layer's slab is gathered/scattered through the table inside the block
    (the table is a loop-invariant capture of the layer scan — pool
    families only).
    """
    assert mode in ("train", "prefill", "prefill_chunk", "decode"), mode
    assert page_table is None or cfg.family in ("dense", "moe", "vlm"), \
        "page_table is a slot-pool-family path (dense/moe/vlm)"
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)
    positions = None

    if cfg.family == "vlm" and mode != "decode":
        patches = batch["patches"].astype(jnp.bfloat16)   # stub frontend output
        x = jnp.concatenate([patches, x], axis=1)
    if cfg.family == "vlm" and mode == "decode":
        # positions continue after the patch prefix (already in the cache)
        pass

    if cfg.family == "audio":
        return _forward_audio(cfg, params, batch, x, cache, cache_index, mode)

    # decode has 1 token per row: route every row as its OWN single-token
    # group — drop-free (capacity 1 covers each token's k distinct experts)
    # and row-independent, so one slot's tokens cannot depend on what else
    # shares the decode batch (continuous batching admits strangers and
    # rides garbage rows along in free slots; grouped routing would let
    # them steal expert capacity from real requests)
    moe_groups = None
    # prefill-from-empty: attend over local k/v (identical math; keeps the
    # KV-chunk scan off the sharded cache sequence axis)
    attend_local = mode == "prefill"

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            h, aux = carry
            lp, lcache = xs
            h, new_c, a = _tf_block_apply(cfg, lp, h, cache=lcache,
                                          cache_index=cache_index,
                                          moe_groups=moe_groups,
                                          attend_local=attend_local,
                                          page_table=page_table)
            return (h, aux + a), new_c
        x, aux, new_cache = _scan_stack(cfg, mode, body, x, params["layers"],
                                        cache)
        if mode == "prefill":   # serving needs only the last position's logits
            x = x[:, -1:]
        return _logits(cfg, params, x), aux / cfg.n_layers, new_cache

    if cfg.family == "ssm":
        return _forward_xlstm(cfg, params, x, cache, mode)
    if cfg.family == "hybrid":
        return _forward_zamba(cfg, params, x, cache, cache_index, mode,
                              attend_local)
    raise ValueError(cfg.family)


# --- xLSTM ------------------------------------------------------------------
def _forward_xlstm(cfg, params, x, cache, mode):
    g = cfg.n_layers // cfg.slstm_every
    per = cfg.slstm_every
    mcfg, scfg = cfg.mlstm_cfg(), cfg.slstm_cfg()

    def group(carry, xs):
        h, aux = carry
        h = shard_act(h, "btd")
        gp, gcache = xs

        def mblock(carry2, xs2):
            h2 = carry2
            lp, ln, lc = xs2
            state, conv = (lc["state"], lc["conv"]) if lc is not None else (None, None)
            out, (ns, ncv) = S.mlstm_forward(lp, mcfg, _apply_norm(cfg, ln, h2),
                                             state=state, conv_state=conv)
            h2 = h2 + out
            return h2, {"state": ns, "conv": ncv}

        m_cache = gcache["mlstm"] if gcache is not None else None
        h, new_m = jax.lax.scan(mblock, h,
                                (gp["mlstm"], gp["mlstm_ln"], m_cache))
        s_state = gcache["slstm"] if gcache is not None else None
        out, new_s = S.slstm_forward(gp["slstm"], scfg,
                                     _apply_norm(cfg, gp["slstm_ln"], h),
                                     state=s_state)
        h = h + out

        if cfg.d_ff:
            def fblock(carry2, xs2):
                h2 = carry2
                fp, fln = xs2
                return h2 + _ffn_apply(cfg, fp, _apply_norm(cfg, fln, h2)), None

            h, _ = jax.lax.scan(fblock, h, (gp["ffn"], gp["ffn_ln"]))
        new_cache = {"mlstm": new_m, "slstm": new_s}
        return (h, aux), new_cache

    gp = {"mlstm": params["mlstm"], "mlstm_ln": params["mlstm_ln"],
          "slstm": params["slstm"], "slstm_ln": params["slstm_ln"]}
    if cfg.d_ff:
        gp.update({"ffn": params["ffn"], "ffn_ln": params["ffn_ln"]})
    fn = _maybe_remat(group, cfg, mode)
    (x, aux), new_cache = jax.lax.scan(fn, (x, jnp.float32(0.0)), (gp, cache))
    if mode == "prefill":
        x = x[:, -1:]
    return _logits(cfg, params, x), aux, new_cache


# --- Zamba2 hybrid ------------------------------------------------------------
def _forward_zamba(cfg, params, x, cache, cache_index, mode,
                   attend_local=False):
    L, per = cfg.n_layers, cfg.attn_every
    g, rem = divmod(L, per)
    mcfg = cfg.mamba_cfg()

    def take(tree, sl, reshape=None):
        def f(a):
            v = a[sl]
            return v.reshape(reshape + v.shape[1:]) if reshape else v
        return jax.tree_util.tree_map(f, tree)

    mamba_main = take({"p": params["mamba"], "ln": params["mamba_ln"]},
                      slice(0, g * per), (g, per))
    mamba_tail = take({"p": params["mamba"], "ln": params["mamba_ln"]},
                      slice(g * per, L))

    def mblock(carry, xs):
        h = carry
        lp, lc = xs
        state, conv = (lc["state"], lc["conv"]) if lc is not None else (None, None)
        out, (ns, ncv) = S.mamba2_forward(lp["p"], mcfg,
                                          _apply_norm(cfg, lp["ln"], h),
                                          state=state, conv_state=conv)
        return h + out, {"state": ns, "conv": ncv}

    inner_block = _maybe_remat(mblock, cfg, mode)

    def group(carry, xs):
        h, aux = carry
        h = shard_act(h, "btd")
        gp, gcache = xs
        m_cache = gcache["mamba"] if gcache is not None else None
        h, new_m = jax.lax.scan(inner_block, h, (gp, m_cache))
        a_cache = gcache["attn"] if gcache is not None else None
        h, new_a, a_aux = _tf_block_apply(cfg, params["shared_attn"], h,
                                          cache=a_cache, cache_index=cache_index,
                                          attend_local=attend_local)
        new_cache = {"mamba": new_m, "attn": new_a}
        return (h, aux + a_aux), new_cache

    main_cache = cache["groups"] if cache is not None else None
    fn = _maybe_remat(group, cfg, mode)
    (x, aux), new_groups = jax.lax.scan(fn, (x, jnp.float32(0.0)),
                                        (mamba_main, main_cache))
    tail_cache = cache["tail"] if cache is not None else None
    x, new_tail = jax.lax.scan(inner_block, x, (mamba_tail, tail_cache))
    new_cache = {"groups": new_groups, "tail": new_tail}
    if mode == "prefill":
        x = x[:, -1:]
    return _logits(cfg, params, x), aux, new_cache


# --- Whisper (audio enc-dec) --------------------------------------------------
def _forward_audio(cfg, params, batch, x_dec, cache, cache_index, mode):
    Le = cfg.encoder_layers

    if mode in ("train", "prefill") or cache is None:
        frames = batch["frames"].astype(jnp.bfloat16)      # stub frontend
        enc = frames + params["enc_pos"][None, : frames.shape[1]].astype(jnp.bfloat16)

        def eblock(carry, lp):
            h, aux = carry
            h, _, a = _tf_block_apply(cfg, lp, shard_act(h, "btd"),
                                      causal=False)
            return (h, aux + a), None
        fn = _maybe_remat(eblock, cfg, mode)
        (enc, aux_e), _ = jax.lax.scan(fn, (enc, jnp.float32(0.0)),
                                       params["enc_layers"])
        enc = _apply_norm(cfg, params["enc_ln_f"], enc)
    else:
        enc = cache["enc"]
        aux_e = jnp.float32(0.0)

    base = 0 if cache_index is None else cache_index
    s = x_dec.shape[1]
    pos = jax.lax.dynamic_slice_in_dim(params["dec_pos"], base, s, axis=0) \
        if mode == "decode" else params["dec_pos"][:s]
    x = x_dec + pos[None].astype(jnp.bfloat16)

    def dblock(carry, xs):
        h, aux = carry
        lp, lcache = xs
        h, new_c, a = _tf_block_apply(cfg, lp, shard_act(h, "btd"),
                                      cache=lcache,
                                      cache_index=cache_index, enc=enc,
                                      attend_local=(mode == "prefill"))
        return (h, aux + a), new_c

    dec_cache = cache["dec"] if cache is not None else None
    fn = _maybe_remat(dblock, cfg, mode)
    (x, aux_d), new_dec = jax.lax.scan(fn, (x, jnp.float32(0.0)),
                                       (params["dec_layers"], dec_cache))
    new_cache = None if cache is None else {"enc": enc, "dec": new_dec}
    if mode == "prefill":
        x = x[:, -1:]
    return _logits(cfg, params, x), aux_e + aux_d, new_cache


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               abstract: bool = False, kv_dtype=jnp.bfloat16):
    """Stacked per-layer cache tree (zeros, or ShapeDtypeStructs).

    ``kv_dtype``: storage of the attention KV slabs — a jnp dtype / 'bf16'
    for plain slabs, or a KV quantization scheme name ('int8' / 'fp8'), in
    which case each slab is a ``QuantizedKV`` pytree node of packed codes +
    per-(position, head) scales (DESIGN.md §9).  Recurrent state (ssm /
    mamba) and the audio encoder output always stay in their native dtypes.
    """
    def kv(stack, b=batch, s=max_len):
        if cfg.use_mla:
            spec = A.mla_cache_spec(cfg.mla_cfg(), b, s, kv_dtype)
        else:
            spec = A.gqa_cache_spec(cfg.attn_cfg(), b, s, kv_dtype)
        return jax.tree_util.tree_map(
            lambda sd: jax.ShapeDtypeStruct(stack + sd.shape, sd.dtype), spec)

    def mamba_c(stack):
        sts, conv = S.mamba2_state_spec(cfg.mamba_cfg(), batch)
        f = lambda sd: jax.ShapeDtypeStruct(stack + sd.shape, sd.dtype)
        return {"state": jax.tree_util.tree_map(f, sts),
                "conv": jax.tree_util.tree_map(f, conv)}

    def mlstm_c(stack):
        sts, conv = S.mlstm_state_spec(cfg.mlstm_cfg(), batch)
        f = lambda sd: jax.ShapeDtypeStruct(stack + sd.shape, sd.dtype)
        return {"state": jax.tree_util.tree_map(f, sts),
                "conv": jax.tree_util.tree_map(f, conv)}

    if cfg.family in ("dense", "moe", "vlm"):
        spec = kv((cfg.n_layers,))
    elif cfg.family == "ssm":
        g, per = cfg.n_layers // cfg.slstm_every, cfg.slstm_every
        f = lambda sd: jax.ShapeDtypeStruct((g,) + sd.shape, sd.dtype)
        spec = {"mlstm": jax.tree_util.tree_map(
                    lambda sd: jax.ShapeDtypeStruct((g, per - 1) + sd.shape, sd.dtype),
                    mlstm_c(())),
                "slstm": jax.tree_util.tree_map(
                    f, S.slstm_state_spec(cfg.slstm_cfg(), batch))}
    elif cfg.family == "hybrid":
        g, rem = divmod(cfg.n_layers, cfg.attn_every)
        spec = {"groups": {"mamba": jax.tree_util.tree_map(
                               lambda sd: jax.ShapeDtypeStruct(
                                   (g, cfg.attn_every) + sd.shape, sd.dtype),
                               mamba_c(())),
                           "attn": kv((g,))},
                "tail": jax.tree_util.tree_map(
                    lambda sd: jax.ShapeDtypeStruct((rem,) + sd.shape, sd.dtype),
                    mamba_c(()))}
    elif cfg.family == "audio":
        Ld = cfg.n_layers - cfg.encoder_layers
        spec = {"enc": jax.ShapeDtypeStruct((batch, cfg.n_frames, cfg.d_model),
                                            jnp.bfloat16),
                "dec": kv((Ld,))}
    else:
        raise ValueError(cfg.family)

    if abstract:
        return spec
    return jax.tree_util.tree_map(lambda sd: jnp.zeros(sd.shape, sd.dtype), spec)


# ---------------------------------------------------------------------------
# Loss / train step
# ---------------------------------------------------------------------------
def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight: float = 0.01,
            z_weight: float = 1e-4):
    logits, aux, _ = forward(cfg, params, batch, mode="train")
    labels = batch["labels"]
    if cfg.family == "vlm":       # logits cover [patches + tokens]
        logits = logits[:, cfg.n_patches:]
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].clip(0), axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    xent = jnp.sum((lse - gold) * mask) / denom
    zloss = jnp.sum((lse ** 2) * mask) / denom
    loss = xent + aux_weight * aux + z_weight * zloss
    return loss, {"xent": xent, "aux": aux, "zloss": zloss}
