"""State-space / linear-recurrence layers: Mamba-2 (SSD), mLSTM, sLSTM.

One chunked engine serves both Mamba-2 and mLSTM, because both are matrix-
state linear recurrences
    H_t = exp(lf_t) * H_{t-1} + exp(li_t) * k_t v_t^T
    y_t = q_t . H_t                      (optionally normalized, mLSTM)
with per-head scalar log-decay lf <= 0 and log-gain li.  The chunked form
(SSD, Dao & Gu 2024) computes intra-chunk contributions as a masked
attention-like matmul and carries the state across chunks with a
``lax.scan`` — sub-quadratic in S and MXU-friendly, which is what makes the
``long_500k`` shapes runnable for the SSM/hybrid architectures.

mLSTM additionally tracks a normalizer state n_t = decay(n_{t-1}) + gain*k_t
and a log-stabilizer m (exponential input gating); outputs are
y = (q.H) / max(|q.n|, 1) in unscaled units — invariant to the stabilizer,
which is how the chunked path can use per-chunk cummax stabilizers while the
naive oracle uses the sequential ones.

Exactness: tests assert chunked == naive scan within fp32 tolerance for both
modes, and decode-step consistency against the parallel form.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Maker, apply_linear, rms_norm, shard_act


class SSMState(NamedTuple):
    """Carried recurrence state.  true_H = Hs * exp(m[..., None, None])."""
    Hs: jnp.ndarray   # [B, nh, dk, dv] scaled matrix state
    ns: jnp.ndarray   # [B, nh, dk]     scaled normalizer state
    m: jnp.ndarray    # [B, nh]         log stabilizer


def init_state(b, nh, dk, dv, dtype=jnp.float32) -> SSMState:
    return SSMState(jnp.zeros((b, nh, dk, dv), dtype),
                    jnp.zeros((b, nh, dk), dtype),
                    jnp.full((b, nh), 0.0, dtype))


# ---------------------------------------------------------------------------
# Naive sequential oracle (exact; tests + tiny decode)
# ---------------------------------------------------------------------------
def ssd_naive(q, k, v, lf, li, *, normalize: bool, state: Optional[SSMState] = None):
    """q,k [B,S,nh,dk]; v [B,S,nh,dv]; lf,li [B,S,nh] -> y [B,S,nh,dv], state."""
    b, s, nh, dk = q.shape
    dv = v.shape[-1]
    st = state if state is not None else init_state(b, nh, dk, dv)

    def step(carry: SSMState, inp):
        qt, kt, vt, lft, lit = inp  # [B,nh,dk] etc., [B,nh]
        Hs, ns, m = carry
        m_new = jnp.maximum(lft + m, lit) if normalize else jnp.zeros_like(m)
        decay = jnp.exp(lft + m - m_new)[..., None]
        gain = jnp.exp(lit - m_new)[..., None]
        Hs = decay[..., None] * Hs + (gain * kt)[..., None] * vt[..., None, :]
        ns = decay * ns + gain * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, Hs)
        if normalize:
            den = jnp.abs(jnp.einsum("bhk,bhk->bh", qt, ns))
            den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
            y = num / den
        else:
            y = num
        return SSMState(Hs, ns, m_new), y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (q, k, v, lf, li))
    st, ys = jax.lax.scan(step, st, xs)
    return jnp.moveaxis(ys, 0, 1), st


def ssd_step(state: SSMState, qt, kt, vt, lft, lit, *, normalize: bool):
    """Single decode step; same math as one ssd_naive iteration."""
    (st, y) = _single_step(state, qt, kt, vt, lft, lit, normalize)
    return y, st


def _single_step(carry, qt, kt, vt, lft, lit, normalize):
    Hs, ns, m = carry
    qt, kt, vt = (a.astype(jnp.float32) for a in (qt, kt, vt))
    m_new = jnp.maximum(lft + m, lit) if normalize else jnp.zeros_like(m)
    decay = jnp.exp(lft + m - m_new)[..., None]
    gain = jnp.exp(lit - m_new)[..., None]
    Hs = decay[..., None] * Hs + (gain * kt)[..., None] * vt[..., None, :]
    ns = decay * ns + gain * kt
    num = jnp.einsum("bhk,bhkv->bhv", qt, Hs)
    if normalize:
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", qt, ns))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        y = num / den
    else:
        y = num
    return SSMState(Hs, ns, m_new), y


# ---------------------------------------------------------------------------
# Chunked SSD (the parallel training/prefill path)
# ---------------------------------------------------------------------------
def ssd_chunked(q, k, v, lf, li, *, chunk: int = 128, normalize: bool = False,
                state: Optional[SSMState] = None):
    """Chunked scan; identical math to ``ssd_naive`` (fp32 tolerance)."""
    b, s, nh, dk = q.shape
    dv = v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    st = state if state is not None else init_state(b, nh, dk, dv)

    def chunk_of(a):
        a = a.astype(jnp.float32).reshape(b, nc, chunk, *a.shape[2:])
        return jnp.moveaxis(a, 1, 0)  # [nc, B, C, ...]

    qs, ks, vs, lfs, lis = map(chunk_of, (q, k, v, lf, li))

    def body(carry: SSMState, inp):
        qc, kc, vc, lfc, lic = inp     # [B,C,nh,*], [B,C,nh]
        Hs, ns, m = carry
        L = jnp.cumsum(lfc, axis=1)                     # [B,C,nh] inclusive
        Ltot = L[:, -1]                                 # [B,nh]

        if normalize:
            # per-step stabilizer s_t = L_t + max(m, cummax_{j<=t}(li_j - L_j))
            cmx = jax.lax.cummax(lic - L, axis=1)
            base = jnp.maximum(m[:, None], cmx)         # [B,C,nh]
        else:
            base = jnp.zeros_like(L)

        # intra-chunk: W[t,j] = exp(li_j - L_j - base_t + L_t) for j <= t
        expo = (lic - L)[:, None, :, :] + (L - base)[:, :, None, :]  # [B,t,j,nh]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        W = jnp.where(tri[None, :, :, None], jnp.exp(expo), 0.0)
        scores = jnp.einsum("bthd,bjhd->btjh", qc, kc)
        intra = jnp.einsum("btjh,bjhv->bthv", scores * W, vc)
        intra_n = jnp.einsum("btjh,btjh->bth", scores, W)  # q.n intra part

        # inter: q_t . H_prev_true * exp(L_t) in the same scaled units
        inter_scale = jnp.exp(m[:, None] + L - base)        # [B,C,nh]
        inter = jnp.einsum("bthd,bhdv->bthv", qc, Hs) * inter_scale[..., None]
        inter_n = jnp.einsum("bthd,bhd->bth", qc, ns) * inter_scale

        num = inter + intra
        if normalize:
            # num/den are in units of exp(base_t) (both carry an extra
            # exp(L_t) relative to the exp(-s_t) scaling — it cancels);
            # the unscaled-1 clamp is therefore exp(-base_t).
            den = jnp.maximum(jnp.abs(inter_n + intra_n), jnp.exp(-base))
            y = num / den[..., None]
        else:
            y = num

        # state update to chunk end
        g = lic + (Ltot[:, None] - L)                   # [B,C,nh]
        if normalize:
            m_loc = jnp.max(g, axis=1)                  # [B,nh]
            m_new = jnp.maximum(m + Ltot, m_loc)
        else:
            m_new = jnp.zeros_like(m)
        kg = kc * jnp.exp(g - m_new[:, None])[..., None]
        Hs_new = Hs * jnp.exp(m + Ltot - m_new)[..., None, None] + \
            jnp.einsum("bthd,bthv->bhdv", kg, vc)
        ns_new = ns * jnp.exp(m + Ltot - m_new)[..., None] + kg.sum(axis=1)
        return SSMState(Hs_new, ns_new, m_new), y

    st, ys = jax.lax.scan(body, st, (qs, ks, vs, lfs, lis))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh, dv)
    return y, st


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (Mamba front conv), width-w, with decode state
# ---------------------------------------------------------------------------
def causal_conv1d(x, w_conv, conv_state=None):
    """x [B,S,C]; w_conv [W,C] depthwise.  conv_state [B,W-1,C] for decode.
    Returns (y [B,S,C], new_state [B,W-1,C])."""
    width = w_conv.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    ext = jnp.concatenate([conv_state, x], axis=1)         # [B, S+W-1, C]
    y = sum(ext[:, i:i + x.shape[1]] * w_conv[i][None, None, :]
            for i in range(width))
    new_state = ext[:, ext.shape[1] - (width - 1):]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba-2 block (zamba2 backbone)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    d_head: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    scheme: Optional[str] = None

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.d_head


def mamba2_params(mk: Maker, cfg: Mamba2Config, stack) -> Dict[str, Any]:
    d, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    # Projections split by tensor-parallel role: z/x/dt are head-aligned
    # (shardable over 'model'); B/C are shared across heads (replicated) —
    # the Megatron-style TP layout for Mamba-2.
    return {
        "w_zx": mk.dense("ssm.w_zx", stack, d, 2 * di, scheme=cfg.scheme),
        "w_bc": mk.dense("ssm.w_bc", stack, d, 2 * ds, scheme=cfg.scheme),
        "w_dt": mk.dense("ssm.w_dt", stack, d, nh, scheme=None),
        "conv_x": mk.table("ssm.conv_x", stack, cfg.conv_width, di, scale=0.5),
        "conv_bc": mk.table("ssm.conv_bc", stack, cfg.conv_width, 2 * ds, scale=0.5),
        "A_log": mk.vector("ssm.A_log", stack, nh, init=0.0),
        "dt_bias": mk.vector("ssm.dt_bias", stack, nh, init=0.0),
        "D": mk.vector("ssm.D", stack, nh, init=1.0),
        "norm": mk.norm("ssm.norm", stack, di),
        "w_out": mk.dense("ssm.w_out", stack, di, d, scheme=cfg.scheme),
    }


def mamba2_forward(params, cfg: Mamba2Config, x, *, state=None, conv_state=None,
                   chunked: bool = True):
    """x [B,S,D] -> (y [B,S,D], (ssm_state, conv_state))."""
    b, s, _ = x.shape
    di, ds, nh, dh = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.d_head
    zx = shard_act(apply_linear(params["w_zx"], x), "btf")
    z, xc = jnp.split(zx, 2, axis=-1)
    bc = apply_linear(params["w_bc"], x)
    dt = apply_linear(params["w_dt"], x)

    cs_x = conv_state[0] if conv_state is not None else None
    cs_bc = conv_state[1] if conv_state is not None else None
    xc, new_conv_x = causal_conv1d(xc, params["conv_x"], cs_x)
    bc, new_conv_bc = causal_conv1d(bc, params["conv_bc"], cs_bc)
    new_conv = (new_conv_x, new_conv_bc)
    xc = jax.nn.silu(xc.astype(jnp.float32))
    bc = jax.nn.silu(bc.astype(jnp.float32))
    Bc, Cc = jnp.split(bc, 2, axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # [B,S,nh]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))                   # [nh] < 0
    lf = dt * A                                                          # log decay
    li = jnp.log(jnp.maximum(dt, 1e-9))                                  # log gain

    q = jnp.broadcast_to(Cc[:, :, None, :], (b, s, nh, ds))
    k = jnp.broadcast_to(Bc[:, :, None, :], (b, s, nh, ds))
    v = xc.reshape(b, s, nh, dh)

    if s == 1 and state is not None:
        y, new_state = ssd_step(state, q[:, 0], k[:, 0], v[:, 0],
                                lf[:, 0], li[:, 0], normalize=False)
        y = y[:, None]
    elif chunked and s % cfg.chunk == 0 and s > cfg.chunk:
        y, new_state = ssd_chunked(q, k, v, lf, li, chunk=cfg.chunk,
                                   normalize=False, state=state)
    else:
        y, new_state = ssd_naive(q, k, v, lf, li, normalize=False, state=state)

    y = y + params["D"][None, None, :, None] * v.astype(jnp.float32)
    y = y.reshape(b, s, di)
    y = rms_norm(y.astype(jnp.bfloat16), params["norm"]) * jax.nn.silu(
        z.astype(jnp.float32)).astype(jnp.bfloat16)
    return apply_linear(params["w_out"], y), (new_state, new_conv)


def mamba2_state_spec(cfg: Mamba2Config, batch: int):
    nh, ds, dh = cfg.n_heads, cfg.d_state, cfg.d_head
    return (
        SSMState(jax.ShapeDtypeStruct((batch, nh, ds, dh), jnp.float32),
                 jax.ShapeDtypeStruct((batch, nh, ds), jnp.float32),
                 jax.ShapeDtypeStruct((batch, nh), jnp.float32)),
        (jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, cfg.d_inner), jnp.bfloat16),
         jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, 2 * cfg.d_state), jnp.bfloat16)),
    )


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MLSTMConfig:
    d_model: int
    n_heads: int = 4
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    scheme: Optional[str] = None

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def d_head(self) -> int:
        return self.d_inner // self.n_heads


def mlstm_params(mk: Maker, cfg: MLSTMConfig, stack) -> Dict[str, Any]:
    d, di, nh = cfg.d_model, cfg.d_inner, cfg.n_heads
    return {
        "w_up": mk.dense("ssm.w_up", stack, d, 2 * di, scheme=cfg.scheme),  # [x, z]
        "conv_w": mk.table("ssm.conv_w", stack, cfg.conv_width, di, scale=0.5),
        "w_q": mk.dense("ssm.w_q", stack, di, di, scheme=cfg.scheme),
        "w_k": mk.dense("ssm.w_k", stack, di, di, scheme=cfg.scheme),
        "w_v": mk.dense("ssm.w_v", stack, di, di, scheme=cfg.scheme),
        "w_if": mk.dense("ssm.w_if", stack, di, 2 * nh, scheme=None),  # gates bf16
        "if_bias": mk.vector("ssm.if_bias", stack, 2 * nh, init=0.0),
        "norm": mk.norm("ssm.norm", stack, di),
        "w_out": mk.dense("ssm.w_out", stack, di, d, scheme=cfg.scheme),
    }


def mlstm_forward(params, cfg: MLSTMConfig, x, *, state=None, conv_state=None,
                  chunked: bool = True):
    b, s, _ = x.shape
    di, nh, dh = cfg.d_inner, cfg.n_heads, cfg.d_head
    up = shard_act(apply_linear(params["w_up"], x), "btf")
    xi, z = jnp.split(up, 2, axis=-1)
    conv_out, new_conv = causal_conv1d(xi, params["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(jnp.bfloat16)

    q = apply_linear(params["w_q"], conv_out).reshape(b, s, nh, dh)
    k = apply_linear(params["w_k"], conv_out).reshape(b, s, nh, dh) / jnp.sqrt(float(dh))
    v = apply_linear(params["w_v"], xi).reshape(b, s, nh, dh)
    gates = apply_linear(params["w_if"], conv_out, out_dtype=jnp.float32) + params["if_bias"]
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)        # [B,S,nh]
    lf = jax.nn.log_sigmoid(f_gate)
    li = i_gate                                           # exponential input gate

    if s == 1 and state is not None:
        y, new_state = ssd_step(state, q[:, 0], k[:, 0], v[:, 0],
                                lf[:, 0], li[:, 0], normalize=True)
        y = y[:, None]
    elif chunked and s % cfg.chunk == 0 and s > cfg.chunk:
        y, new_state = ssd_chunked(q, k, v, lf, li, chunk=cfg.chunk,
                                   normalize=True, state=state)
    else:
        y, new_state = ssd_naive(q, k, v, lf, li, normalize=True, state=state)

    y = y.reshape(b, s, di)
    y = rms_norm(y.astype(jnp.bfloat16), params["norm"]) * jax.nn.silu(
        z.astype(jnp.float32)).astype(jnp.bfloat16)
    return apply_linear(params["w_out"], y), (new_state, new_conv)


def mlstm_state_spec(cfg: MLSTMConfig, batch: int):
    nh, dh = cfg.n_heads, cfg.d_head
    return (
        SSMState(jax.ShapeDtypeStruct((batch, nh, dh, dh), jnp.float32),
                 jax.ShapeDtypeStruct((batch, nh, dh), jnp.float32),
                 jax.ShapeDtypeStruct((batch, nh), jnp.float32)),
        jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, cfg.d_inner), jnp.bfloat16),
    )


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM): scalar recurrence with per-head recurrent mixing
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SLSTMConfig:
    d_model: int
    n_heads: int = 4
    scheme: Optional[str] = None

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


def slstm_params(mk: Maker, cfg: SLSTMConfig, stack) -> Dict[str, Any]:
    d, nh, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    return {
        "w_gates": mk.dense("ssm.w_gates", stack, d, 4 * d, scheme=cfg.scheme),
        # per-head block-diagonal recurrent matrices, one per gate
        "r_gates": mk.table("ssm.r_gates", stack + (4, nh), dh, dh, scale=0.02),
        "b_gates": mk.vector("ssm.b_gates", stack, 4 * d, init=0.0),
        "norm": mk.norm("ssm.norm", stack, d),
        "w_out": mk.dense("ssm.w_out", stack, d, d, scheme=cfg.scheme),
    }


class SLSTMState(NamedTuple):
    c: jnp.ndarray   # [B, D] cell
    n: jnp.ndarray   # [B, D] normalizer
    h: jnp.ndarray   # [B, D] hidden (recurrent input)
    m: jnp.ndarray   # [B, D] stabilizer


def slstm_init_state(b, d):
    return SLSTMState(*(jnp.zeros((b, d), jnp.float32) for _ in range(4)))


def _slstm_step(params, cfg: SLSTMConfig, st: SLSTMState, wx_t):
    """wx_t = W x_t [B, 4D] precomputed; returns (state, h_out [B, D])."""
    b = wx_t.shape[0]
    d, nh, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    h_heads = st.h.reshape(b, nh, dh)
    rh = jnp.einsum("bhd,ghde->bghe", h_heads, params["r_gates"].astype(jnp.float32))
    rh = rh.reshape(b, 4 * d)
    zif = wx_t.astype(jnp.float32) + rh + params["b_gates"]
    z_t, i_t, f_t, o_t = jnp.split(zif, 4, axis=-1)
    z_t = jnp.tanh(z_t)
    o_t = jax.nn.sigmoid(o_t)
    lf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(lf + st.m, i_t)
    c_new = jnp.exp(lf + st.m - m_new) * st.c + jnp.exp(i_t - m_new) * z_t
    n_new = jnp.exp(lf + st.m - m_new) * st.n + jnp.exp(i_t - m_new)
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c_new, n_new, h_new, m_new), h_new


def slstm_forward(params, cfg: SLSTMConfig, x, *, state: Optional[SLSTMState] = None):
    """x [B,S,D] -> (y [B,S,D], state).  Sequential lax.scan over S."""
    b, s, d = x.shape
    st = state if state is not None else slstm_init_state(b, d)
    wx = apply_linear(params["w_gates"], x, out_dtype=jnp.float32)  # [B,S,4D]

    def step(carry, wx_t):
        return _slstm_step(params, cfg, carry, wx_t)

    st, hs = jax.lax.scan(step, st, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(jnp.bfloat16)
    y = rms_norm(y, params["norm"])
    return apply_linear(params["w_out"], y), st


def slstm_state_spec(cfg: SLSTMConfig, batch: int):
    d = cfg.d_model
    return SLSTMState(*(jax.ShapeDtypeStruct((batch, d), jnp.float32)
                        for _ in range(4)))
