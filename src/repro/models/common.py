"""Shared model-layer machinery.

Central idea: every architecture's parameter tree is built by ONE structure
walker driven by a ``Maker``.  Four makers produce, from the same walk:
  * InitMaker      real bf16 dense parameters (training / smoke tests)
  * QuantMaker     real quantized parameters (packed codes + scales) via the
                   offline numpy quantizer — mixed-precision serving
  * AbstractMaker  jax.ShapeDtypeStruct trees (dry-run: zero allocation)
  * PspecMaker     jax.sharding.PartitionSpec trees (pjit annotations)
so parameter structure, quantization plan, and sharding can never drift.

Quantized linears are ``QLinear`` pytree nodes: children = (packed, scales),
static aux = (scheme name, logical shape) — jit/scan/pjit-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels.ops import quantized_matmul
from repro.quant.schemes import (
    QuantScheme, QuantizedLinearWeights, get_scheme, quantize_weights,
)


@jax.tree_util.register_pytree_node_class
class QLinear:
    """Quantized linear weights as a pytree node (packed codes + scales).

    ``name`` is the leaf's logical name from the Maker walk ("attn.wq",
    "ffn.w_down", ...) — static aux, set identically by every Maker (so
    parameter and spec trees keep matching structures).  It is how the
    mesh kernel dispatch (kernels/ops.py) finds the leaf's sharding spec
    in ``partitioning.serve_weight_kernel_specs`` at apply time."""

    def __init__(self, packed, scales, scheme_name: str,
                 shape: Tuple[int, int], name: Optional[str] = None):
        self.packed = packed
        self.scales = scales
        self.scheme_name = scheme_name
        self.shape = tuple(shape)
        self.name = name

    def tree_flatten(self):
        return (self.packed, self.scales), (self.scheme_name, self.shape,
                                            self.name)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    def __repr__(self):
        return f"QLinear({self.scheme_name}, {self.shape}, {self.name})"


def set_use_kernel(flag: bool) -> None:
    """Deprecated shim: kernel selection is part of the execution policy
    (``kernels.ops.declare_execution`` / ``PrecisionPolicy.kernel``)."""
    from repro.kernels.ops import declare_execution
    declare_execution(kernel="pallas" if flag else "jnp")


# ---------------------------------------------------------------------------
# Activation sharding constraints (set by launch/steps.py before tracing).
# Without these GSPMD may propagate FSDP *storage* shardings into the
# computation (e.g. batch replicated, d_model sharded) — constraining the
# per-layer activation layout pins DP on batch and lets the compiler insert
# the FSDP all-gathers on weights instead.
# ---------------------------------------------------------------------------
_ACT_SHARDINGS = {"rules": None}


def set_activation_shardings(rules) -> None:
    """rules: dict kind -> NamedSharding (e.g. {'btd': ..., 'logits': ...})
    or None to disable."""
    _ACT_SHARDINGS["rules"] = rules


def shard_act(x, kind: str):
    rules = _ACT_SHARDINGS["rules"]
    if rules is None or kind not in rules or rules[kind] is None:
        return x
    s = rules[kind]
    if x.ndim != len(s.spec):
        return x
    # strip axes whose size doesn't divide the dim (e.g. 4 KV heads on a
    # 16-way model axis stay replicated)
    mesh = s.mesh
    parts = []
    changed = False
    for dim, ax in enumerate(s.spec):
        if ax is None:
            parts.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if x.shape[dim] % size == 0 and x.shape[dim] >= size:
            parts.append(ax)
        else:
            parts.append(None)
            changed = True
    if changed:
        from jax.sharding import NamedSharding, PartitionSpec
        s = NamedSharding(mesh, PartitionSpec(*parts))
    return jax.lax.with_sharding_constraint(x, s)


def apply_linear(leaf, x, out_dtype=jnp.bfloat16):
    """x [..., K] @ linear leaf -> [..., N]; dispatches dense vs quantized.

    Dots are bf16-storage: the TPU MXU accumulates in f32 natively, and
    requesting an f32 result dtype makes the CPU backend (the dry-run
    instrument) materialize f32 copies of the weights per use.
    """
    if isinstance(leaf, QLinear):
        qw = QuantizedLinearWeights(
            get_scheme(leaf.scheme_name), leaf.packed, leaf.scales,
            leaf.shape, name=leaf.name
        )
        # use_kernel=None: dispatch on the active execution policy
        # (kernels.ops.declare_execution) — shard_map'd under a declared
        # mesh, falling back per site
        return quantized_matmul(x, qw, out_dtype=out_dtype)
    return jnp.dot(x.astype(leaf.dtype), leaf).astype(out_dtype)


# ---------------------------------------------------------------------------
# Makers
# ---------------------------------------------------------------------------
class Maker:
    """Builds parameter leaves.  ``stack`` = leading layer-stack dims ()/(L,)."""

    def dense(self, name: str, stack: Tuple[int, ...], k: int, n: int,
              scheme: Optional[str] = None):
        raise NotImplementedError

    def table(self, name: str, stack: Tuple[int, ...], rows: int, cols: int,
              scale: float = 0.02):
        raise NotImplementedError

    def norm(self, name: str, stack: Tuple[int, ...], dim: int):
        raise NotImplementedError

    def vector(self, name: str, stack: Tuple[int, ...], dim: int,
               init: float = 0.0):
        raise NotImplementedError


class InitMaker(Maker):
    """Real dense bf16 parameters (ignores quantization schemes)."""

    def __init__(self, key, dtype=jnp.bfloat16):
        self._key = key
        self.dtype = dtype

    def _next(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def dense(self, name, stack, k, n, scheme=None):
        w = jax.random.normal(self._next(), stack + (k, n), jnp.float32)
        return (w / np.sqrt(k)).astype(self.dtype)

    def table(self, name, stack, rows, cols, scale=0.02):
        return (jax.random.normal(self._next(), stack + (rows, cols),
                                  jnp.float32) * scale).astype(self.dtype)

    def norm(self, name, stack, dim):
        return jnp.ones(stack + (dim,), jnp.float32)

    def vector(self, name, stack, dim, init=0.0):
        return jnp.full(stack + (dim,), init, jnp.float32)


class QuantMaker(InitMaker):
    """Real quantized parameters: dense init -> offline numpy quantizer.

    ``plan``: optional per-leaf scheme overrides, keyed by the leaf's
    logical name ("attn.wo", "ffn.w_down", "moe.w_up", ...) — the same
    names the partitioning rules use.  A plan entry wins over the config's
    ``scheme=``; 'bf16' (or None) keeps the leaf dense.  Sharding specs for
    a plan-built checkpoint must be built with the same plan
    (``partitioning.param_specs(..., plan=...)``) or the trees diverge.
    """

    def __init__(self, key, plan: Optional[Dict[str, str]] = None,
                 dtype=jnp.bfloat16):
        super().__init__(key, dtype)
        self.plan = dict(plan or {})

    def dense(self, name, stack, k, n, scheme=None):
        scheme = self.plan.get(name, scheme)
        scheme = scheme if scheme is not None else "bf16"
        if scheme == "bf16":
            return super().dense(name, stack, k, n)
        w = np.asarray(
            jax.random.normal(self._next(), stack + (k, n), jnp.float32)
        ) / np.sqrt(k)
        if stack:
            flat = w.reshape((-1, k, n))
            qws = [quantize_weights(get_scheme(scheme), flat[i])
                   for i in range(flat.shape[0])]
            packed = jnp.stack([q.packed for q in qws]).reshape(
                stack + qws[0].packed.shape)
            scales = jnp.stack([q.scales for q in qws]).reshape(
                stack + qws[0].scales.shape)
        else:
            q = quantize_weights(get_scheme(scheme), w)
            packed, scales = q.packed, q.scales
        return QLinear(packed, scales, scheme, (k, n), name)


class AbstractMaker(Maker):
    """ShapeDtypeStruct trees — dry-run parameter specs, zero allocation."""

    def __init__(self, quantize: bool = True, dtype=jnp.bfloat16):
        self.quantize = quantize
        self.dtype = dtype

    def dense(self, name, stack, k, n, scheme=None):
        if scheme is None or scheme == "bf16" or not self.quantize:
            return jax.ShapeDtypeStruct(stack + (k, n), self.dtype)
        s = get_scheme(scheme)
        from repro.quant.schemes import effective_group
        group = effective_group(s.group_size, k)
        if s.packed:
            per = 32 // s.weight_bits
            packed = jax.ShapeDtypeStruct(stack + (k // per, n), jnp.int32)
        else:  # w8a8 raw int8
            packed = jax.ShapeDtypeStruct(stack + (k, n), jnp.int8)
        scales = jax.ShapeDtypeStruct(stack + (k // group, n), jnp.float32)
        return QLinear(packed, scales, scheme, (k, n), name)

    def table(self, name, stack, rows, cols, scale=0.02):
        return jax.ShapeDtypeStruct(stack + (rows, cols), self.dtype)

    def norm(self, name, stack, dim):
        return jax.ShapeDtypeStruct(stack + (dim,), jnp.float32)

    def vector(self, name, stack, dim, init=0.0):
        return jax.ShapeDtypeStruct(stack + (dim,), jnp.float32)


class PspecMaker(Maker):
    """PartitionSpec trees.  Axis names resolved via a rule callback
    mapping the logical axes of each leaf to mesh axes."""

    def __init__(self, rule: Callable[[str, int], Optional[str]],
                 quantize: bool = True):
        self.rule = rule      # (leaf_name, logical_dim_index) -> mesh axis
        self.quantize = quantize

    def _spec(self, name, stack, dims: int) -> P:
        parts = [None] * len(stack) + [self.rule(name, d) for d in range(dims)]
        return P(*parts)

    def dense(self, name, stack, k, n, scheme=None):
        if scheme is None or scheme == "bf16" or not self.quantize:
            return self._spec(name, stack, 2)
        # packed codes and scales have different K-dim sizes than the
        # logical weight; the rule sees them under suffixed names so
        # divisibility is checked against the actual array dims
        spec_p = self._spec(name + "@packed", stack, 2)
        spec_s = self._spec(name + "@scales", stack, 2)
        return QLinear(spec_p, spec_s, scheme, (k, n), name)

    def table(self, name, stack, rows, cols, scale=0.02):
        return self._spec(name, stack, 2)

    def norm(self, name, stack, dim):
        return P(*([None] * len(stack) + [self.rule(name, 0)]))

    def vector(self, name, stack, dim, init=0.0):
        return P(*([None] * len(stack) + [self.rule(name, 0)]))


# ---------------------------------------------------------------------------
# Numerics helpers
# ---------------------------------------------------------------------------
def rms_norm(x, gamma, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma).astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(x.dtype)


def activate(kind: str, x):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":  # nemotron squared-ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def rope_frequencies(d_head: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float = 10000.0):
    """x [..., S, H, D]; positions [..., S] int32 -> rotated x (same dtype)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, dim: int):
    pos = np.arange(n_pos)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(emb, jnp.float32)
