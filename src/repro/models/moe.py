"""Mixture-of-Experts with deterministic-shape capacity dispatch.

Dispatch is *sort-free* and all-to-all-free at the JAX level: tokens are
scattered into per-expert capacity buffers via cumsum ranking + scatter-add
(GShard-style capacity semantics, tokens over capacity dropped), experts run
as ONE batched einsum over the stacked expert weights (EP: expert dim
sharded over 'model'), and results gather straight back by (expert, rank).
GSPMD inserts the actual device all-to-all when the buffer's sharding flips
from token-sharded to expert-sharded.

Routing is performed in independent **groups** so the ranking cumsum stays
small and group-local (groups align with data shards at scale).  Capacity
per group-expert: C = ceil(S_g * top_k * capacity_factor / E), so total
buffer slots = tokens * top_k * cf regardless of grouping.

The paper's technique applies to the expert FFN weights (the dominant MACs
in MoE checkpoints — Fig. 1 shows >68% of decode MACs in INT4xBF16 for
AWQ-style models); the router stays BF16.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Maker, QLinear, activate, apply_linear, shard_act


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                  # per-expert hidden dim
    n_experts: int
    top_k: int
    n_shared_experts: int = 0  # always-on experts (DeepSeek-V2)
    shared_d_ff: int = 0       # hidden dim of the shared expert block
    capacity_factor: float = 1.25
    activation: str = "silu"
    scheme: Optional[str] = None      # quantization scheme for expert weights
    renormalize: bool = True          # renormalize top-k gates to sum 1


def moe_params(mk: Maker, cfg: MoEConfig, stack: Tuple[int, ...]) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p: Dict[str, Any] = {
        "router": mk.dense("moe.router", stack, d, e, scheme=None),  # bf16 always
        "w_gate": mk.dense("moe.w_gate", stack + (e,), d, f, scheme=cfg.scheme),
        "w_up": mk.dense("moe.w_up", stack + (e,), d, f, scheme=cfg.scheme),
        "w_down": mk.dense("moe.w_down", stack + (e,), f, d, scheme=cfg.scheme),
    }
    if cfg.n_shared_experts:
        fs = cfg.shared_d_ff or f * cfg.n_shared_experts
        p["shared_gate"] = mk.dense("ffn.w_gate", stack, d, fs, scheme=cfg.scheme)
        p["shared_up"] = mk.dense("ffn.w_up", stack, d, fs, scheme=cfg.scheme)
        p["shared_down"] = mk.dense("ffn.w_down", stack, fs, d, scheme=cfg.scheme)
    return p


def capacity(group_tokens: int, cfg: MoEConfig) -> int:
    if group_tokens == 1:
        # single-token groups (per-row decode): the token's top-k experts are
        # distinct, so every assignment has rank 0 — capacity 1 is drop-free
        # and keeps the decode buffer at [E, 1, D] per row
        return 1
    c = math.ceil(group_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(4, c)  # floor avoids degenerate buffers for tiny groups


def _route(x, router_w, cfg: MoEConfig):
    """x [T, D] -> gates [T, k] f32, idx [T, k] i32, probs [T, E] f32."""
    logits = apply_linear(router_w, x, out_dtype=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.renormalize:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def _dispatch_ranks(idx, n_experts: int, cap: int):
    """idx [T, k] -> (flat_e [T*k], rank [T*k], keep [T*k]) token-major."""
    flat_e = idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)   # [T*k, E]
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    rank = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
    keep = rank < cap
    return flat_e, rank, keep


def _expert_ffn(params, cfg: MoEConfig, buf):
    """buf [E, C, D] -> [E, C, D] through the per-expert gated FFN."""
    def contract(leaf, x, out_dtype=jnp.bfloat16):
        # leaf is stacked over E: dense [E, K, N] or QLinear with E-stacked
        # packed/scales; vmap the shared linear over the expert dim.
        if isinstance(leaf, QLinear):
            per_expert = jax.vmap(
                lambda p, s, xe: apply_linear(
                    QLinear(p, s, leaf.scheme_name, leaf.shape, leaf.name),
                    xe, out_dtype)
            )
            return per_expert(leaf.packed, leaf.scales, x)
        return jnp.einsum("ecd,edf->ecf", x.astype(leaf.dtype), leaf).astype(out_dtype)

    g = contract(params["w_gate"], buf)
    u = contract(params["w_up"], buf)
    h = (activate(cfg.activation, g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(jnp.bfloat16)
    return contract(params["w_down"], h)


def _moe_group(params, cfg: MoEConfig, x, cap: int):
    """One routing group: x [T, D] -> (y [T, D], aux_loss scalar)."""
    t, d = x.shape
    gates, idx, probs = _route(x, params["router"], cfg)
    flat_e, rank, keep = _dispatch_ranks(idx, cfg.n_experts, cap)
    tok = jnp.repeat(jnp.arange(t), cfg.top_k)

    buf = jnp.zeros((cfg.n_experts, cap, d), x.dtype)
    buf = buf.at[flat_e, rank].add(
        jnp.where(keep[:, None], x[tok], 0).astype(x.dtype), mode="drop")

    out_buf = _expert_ffn(params, cfg, buf)                        # [E, C, D]

    y_flat = out_buf[flat_e, jnp.minimum(rank, cap - 1)]           # [T*k, D]
    y_flat = y_flat * (gates.reshape(-1, 1) * keep[:, None]).astype(y_flat.dtype)
    y = y_flat.reshape(t, cfg.top_k, d).sum(axis=1)

    # GShard load-balancing auxiliary loss: E * sum_e f_e * P_e
    assign1 = jax.nn.one_hot(idx[:, 0], cfg.n_experts, dtype=jnp.float32)
    f_e = assign1.mean(0)
    p_e = probs.mean(0)
    aux = cfg.n_experts * jnp.sum(f_e * p_e)
    return y, aux


def moe_forward(params, cfg: MoEConfig, x, *, n_groups: Optional[int] = None):
    """x [B, S, D] -> (y [B, S, D], aux_loss).  Routing grouped per batch row
    by default (n_groups=B); pass n_groups to re-group (e.g. data shards)."""
    b, s, d = x.shape
    g = b if n_groups is None else n_groups
    xg = x.reshape(g, (b * s) // g, d)
    cap = capacity((b * s) // g, cfg)
    y, aux = jax.vmap(lambda xe: _moe_group(params, cfg, xe, cap))(xg)
    y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        gsh = shard_act(apply_linear(params["shared_gate"], x), "btf")
        ush = shard_act(apply_linear(params["shared_up"], x), "btf")
        hsh = (activate(cfg.activation, gsh.astype(jnp.float32))
               * ush.astype(jnp.float32)).astype(jnp.bfloat16)
        y = y + apply_linear(params["shared_down"], hsh)
    return y, aux.mean()
