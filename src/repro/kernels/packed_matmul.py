"""Pallas TPU kernels: mixed-precision matmul/GEMV over packed weights.

This is the TPU realization of the paper's Section VI GEMV engine: weights
live in HBM as packed sub-byte codes (8x INT4/FP4 or 4x INT8/FP8 per int32
word — the analogue of the 512-bit HBM channel words feeding XtraMAC
chains), are streamed block-by-block into VMEM, unpacked + decoded with
XtraMAC Stage-1 semantics (DAZ, implicit-one restore), scaled, and fed to
the MXU.  Accumulation is f32 (the BF16-accumulate spec lives in core.mac;
tensor-core-style f32 accumulation is strictly more accurate and is what
the MXU provides natively — noted in DESIGN.md).

Kernels:
  * ``packed_matmul``  A[M,K] bf16 x packed W[K,N] -> f32 [M,N]
                       grid (M/bm, N/bn, K/bk), revisiting-accumulate on k
  * ``w8a8_matmul``    int8 x int8 -> int32 MXU accumulate -> scale epilogue

Block shapes are MXU/VMEM aligned by default (bn multiple of 128, bk
multiple of the packing group) and validated under interpret=True on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.schemes import (
    QuantScheme, QuantizedLinearWeights, effective_group,
)


# ---------------------------------------------------------------------------
# In-kernel arithmetic decode (no gathers — TPU-friendly), DAZ semantics
# ---------------------------------------------------------------------------
def _decode_int(codes, bits: int):
    half = 1 << (bits - 1)
    return jnp.where(codes >= half, codes - (1 << bits), codes).astype(jnp.float32)


def _decode_fp4_e2m1(codes):
    s = (codes >> 3) & 1
    e = (codes >> 1) & 3
    m = codes & 1
    mag = jnp.where(e == 0, 0.0,
                    (2 + m).astype(jnp.float32) * jnp.exp2((e - 2).astype(jnp.float32)))
    return jnp.where(s == 1, -mag, mag)


def _decode_fp8_e4m3(codes):
    s = (codes >> 7) & 1
    e = (codes >> 3) & 0xF
    m = codes & 7
    nan = (e == 0xF) & (m == 7)
    mag = jnp.where(e == 0, 0.0,
                    (8 + m).astype(jnp.float32) * jnp.exp2((e - 10).astype(jnp.float32)))
    mag = jnp.where(nan, 0.0, mag)  # weights never encode NaN; decode as 0
    return jnp.where(s == 1, -mag, mag)


def decode_codes_arith(scheme: QuantScheme, codes):
    if scheme.weight_format.startswith("int"):
        return _decode_int(codes, scheme.weight_bits)
    if scheme.weight_format == "fp4_e2m1":
        return _decode_fp4_e2m1(codes)
    if scheme.weight_format == "fp8_e4m3":
        return _decode_fp8_e4m3(codes)
    raise ValueError(scheme.weight_format)


def _unpack_block(words, bits: int):
    """int32 [bkw, bn] -> codes [bkw*per, bn] (little-endian along K)."""
    per = 32 // bits
    mask = (1 << bits) - 1
    parts = [(words >> (i * bits)) & mask for i in range(per)]
    stacked = jnp.stack(parts, axis=1)                 # [bkw, per, bn]
    return stacked.reshape(words.shape[0] * per, words.shape[1])


# ---------------------------------------------------------------------------
# packed matmul kernel
# ---------------------------------------------------------------------------
def _packed_matmul_kernel(x_ref, w_ref, s_ref, o_ref, *, scheme: QuantScheme,
                          bk: int, group: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = _unpack_block(w_ref[...], scheme.weight_bits)       # [bk, bn]
    vals = decode_codes_arith(scheme, codes)                    # f32
    ng = bk // group
    scales = s_ref[...]                                         # [ng, bn]
    vals = (vals.reshape(ng, group, vals.shape[-1]) * scales[:, None, :]) \
        .reshape(bk, vals.shape[-1])
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, vals, preferred_element_type=jnp.float32)


def _pick(block: int, dim: int) -> int:
    return min(block, dim)


def _fit_block(dim: int, want: int, quantum: int = 1) -> int:
    """Largest block <= ``want`` that divides ``dim`` and is a multiple of
    ``quantum`` (the code-packing word / scale group).  Falls back to
    ``dim`` itself (one block) when no smaller aligned divisor exists —
    irregular dims cost tiling efficiency, never correctness."""
    want = min(want, dim)
    for cand in range(want - want % quantum, 0, -quantum):
        if dim % cand == 0:
            return cand
    return dim


def packed_block_plan(m: int, k: int, n: int, scheme: QuantScheme, *,
                      bm: int = 128, bn: int = 128, bk: int = 512):
    """The (bm, bn, bk) tiling ``packed_matmul`` uses for these shapes.

    Exported so the bit-exact oracle (``ref.packed_matmul_tiled_ref``) can
    replay the exact same grid: per-element results depend on the K-block
    accumulation order and the per-tile dot shapes, so oracle and kernel
    must agree on the plan, not just the math."""
    group = effective_group(scheme.group_size, k)
    per = 32 // scheme.weight_bits
    # K blocks land on scale-group boundaries when the matrix has several
    # groups, else (per-channel: one global scale row) on word boundaries
    quantum = group if group < k else per
    return (_fit_block(m, bm), _fit_block(n, bn), _fit_block(k, bk, quantum))


def packed_shapes_legal(m: int, k: int, n: int, scheme: QuantScheme) -> bool:
    """Whether (possibly shard-local) shapes can run the packed kernel:
    K must pack whole int32 words and whole scale groups.  The per-site
    fallback predicate for mesh dispatch (kernels/ops.py)."""
    if m < 1 or n < 1 or k < 1:
        return False
    per = 32 // scheme.weight_bits
    return k % per == 0 and k % effective_group(scheme.group_size, k) == 0


@functools.partial(
    jax.jit,
    static_argnames=("scheme_name", "k", "n", "bm", "bn", "bk", "interpret"),
)
def _packed_matmul_impl(x, packed, scales, *, scheme_name: str, k: int, n: int,
                        bm: int, bn: int, bk: int, interpret: bool):
    from repro.quant.schemes import get_scheme
    scheme = get_scheme(scheme_name)
    m = x.shape[0]
    per = 32 // scheme.weight_bits
    group = effective_group(scheme.group_size, k)
    grid = (m // bm, n // bn, k // bk)
    ng = bk // group if group <= bk else 1
    if group > bk:  # per-channel (group == k): one scale row for all k-blocks
        scale_spec = pl.BlockSpec((1, bn), lambda i, j, l: (0, j))
    else:
        scale_spec = pl.BlockSpec((ng, bn), lambda i, j, l: (l, j))
    kernel = functools.partial(
        _packed_matmul_kernel, scheme=scheme, bk=bk, group=min(group, bk)
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk // per, bn), lambda i, j, l: (l, j)),
            scale_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, packed, scales)


def packed_matmul(x, qw: QuantizedLinearWeights, *, bm: int = 128, bn: int = 128,
                  bk: int = 512, interpret: bool = False):
    """x [M, K] (bf16) @ packed W [K, N] -> f32 [M, N]."""
    k, n = qw.shape
    m = x.shape[0]
    scheme = qw.scheme
    assert scheme.packed, "packed_matmul requires a sub-byte scheme"
    assert packed_shapes_legal(m, k, n, scheme), (m, k, n, scheme.name)
    bm, bn, bk = packed_block_plan(m, k, n, scheme, bm=bm, bn=bn, bk=bk)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    return _packed_matmul_impl(
        x, qw.packed, qw.scales, scheme_name=scheme.name, k=k, n=n,
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )


def packed_gemv(x, qw: QuantizedLinearWeights, *, bn: int = 256, bk: int = 1024,
                interpret: bool = False):
    """Decode-shape GEMV: x [B, K] with small B (the paper's Section VI-C)."""
    return packed_matmul(x, qw, bm=x.shape[0], bn=bn, bk=bk, interpret=interpret)


# ---------------------------------------------------------------------------
# W8A8: INT8 x INT8 -> INT32 (the paper's integer accumulate path)
# ---------------------------------------------------------------------------
def _w8a8_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # exact INT8 x INT8 -> INT32 accumulation (the paper's integer adder path)
    o_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _w8a8_impl(x_codes, w_codes, *, bm, bn, bk, interpret):
    m, k = x_codes.shape
    n = w_codes.shape[1]
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _w8a8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x_codes, w_codes)


def w8a8_matmul(x_codes, x_scale, w_codes, w_scales, *, bm: int = 128,
                bn: int = 128, bk: int = 512, interpret: bool = False):
    """INT8 codes x INT8 codes -> exact INT32 accumulate -> f32 descale.

    x_codes [M, K] int8 (per-tensor scale x_scale), w_codes [K, N] int8
    (per-channel scales [1, N]).  Output f32 [M, N] already descaled.
    """
    m, k = x_codes.shape
    n = w_codes.shape[1]
    bm, bn, bk = _fit_block(m, bm), _fit_block(n, bn), _fit_block(k, bk)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    acc = _w8a8_impl(x_codes, w_codes, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return acc.astype(jnp.float32) * (w_scales * x_scale)
