"""Pallas kernel: the XtraMAC virtual-DSP packed multiply (Eqs. 9-11).

Emulates the DSP48E2 27x18-bit wide multiplier on 32-bit TPU VPU lanes:
mantissa lanes are packed into the two port words (Eq. 9), ONE wide
multiply produces all lane products (Eq. 10), and shift-and-mask extracts
them (Eq. 11).  Because the 45-bit product exceeds int32, the wide multiply
is computed multiprecision:

  A = ahi*2^13 + alo,  B = bhi*2^9 + blo      (4 partials, each <= 2^23)
  P = p00 + p01*2^9 + p10*2^13 + p11*2^22     accumulated into 16-bit limbs

Lane extraction reads a <=17-bit window from at most two adjacent limbs at
the statically-known lane position.  Validated bit-exactly against the
int64 oracle in core.packing across every paper datatype combination and
randomized magnitudes (tests/test_kernels.py).

This kernel is the microarchitecture-fidelity artifact; the *throughput*
kernels for LLM inference are in packed_matmul.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import LanePlan


def _wide_multiply_limbs(a, b):
    """27-bit x 18-bit -> three 16-bit limbs (int32 arrays, no overflow)."""
    alo = a & 0x1FFF          # 13 bits
    ahi = a >> 13             # <= 14 bits
    blo = b & 0x1FF           # 9 bits
    bhi = b >> 9              # <= 9 bits
    p00 = alo * blo           # <= 22 bits
    p01 = alo * bhi           # <= 22 bits, weight 2^9
    p10 = ahi * blo           # <= 23 bits, weight 2^13
    p11 = ahi * bhi           # <= 23 bits, weight 2^22

    l0 = (p00 & 0xFFFF) + ((p01 & 0x7F) << 9) + ((p10 & 0x7) << 13)
    l1 = (p00 >> 16) + (p01 >> 7) + (p10 >> 3) + ((p11 & 0x3FF) << 6)
    l2 = p11 >> 10
    # carry normalization to 16-bit limbs
    l1 = l1 + (l0 >> 16)
    l0 = l0 & 0xFFFF
    l2 = l2 + (l1 >> 16)
    l1 = l1 & 0xFFFF
    return l0, l1, l2


def _extract_lane(limbs, pos: int, width: int):
    """Static shift-and-mask window [pos, pos+width) over the limb triple.

    Widths up to 19 bits can span three 16-bit limbs (e.g. INT8xFP16 lanes,
    stride 19, at offset r=15).  All shifts are int32-safe: each partial is
    < 2^width <= 2^19."""
    assert width <= 19 and pos + width <= 48
    j, r = divmod(pos, 16)
    out = limbs[j] >> r
    need1 = max(0, width - (16 - r))
    if need1 > 0 and j + 1 < len(limbs):
        out = out | ((limbs[j + 1] & ((1 << min(need1, 16)) - 1)) << (16 - r))
    need2 = max(0, width - (32 - r))
    if need2 > 0 and j + 2 < len(limbs):
        out = out | ((limbs[j + 2] & ((1 << need2) - 1)) << (32 - r))
    return out & ((1 << width) - 1)


def _vdsp_kernel(a_ref, b_ref, o_ref, *, plan: LanePlan):
    # Eq. 9: pack each port's lanes at their static offsets
    a_word = jnp.zeros_like(a_ref[:, 0])
    for i, off in enumerate(plan.offsets_a):
        a_word = a_word | (a_ref[:, i] << off)
    b_word = jnp.zeros_like(b_ref[:, 0])
    for j, off in enumerate(plan.offsets_b):
        b_word = b_word | (b_ref[:, j] << off)
    # Eq. 10: ONE wide multiply (multiprecision on int32)
    limbs = _wide_multiply_limbs(a_word, b_word)
    # Eq. 11: static shift-and-mask extraction per lane
    for lane, (_, _, pos) in enumerate(plan.lane_positions):
        o_ref[:, lane] = _extract_lane(limbs, pos, plan.stride)


@functools.partial(jax.jit, static_argnames=("plan", "bt", "interpret"))
def _vdsp_impl(a_mags, b_mags, *, plan: LanePlan, bt: int, interpret: bool):
    t = a_mags.shape[0]
    n_a, n_b = len(plan.offsets_a), len(plan.offsets_b)
    return pl.pallas_call(
        functools.partial(_vdsp_kernel, plan=plan),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, n_a), lambda i: (i, 0)),
            pl.BlockSpec((bt, n_b), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, plan.parallelism), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, plan.parallelism), jnp.int32),
        interpret=interpret,
    )(a_mags, b_mags)


def virtual_dsp_multiply(a_mags, b_mags, plan: LanePlan, *, bt: int = 1024,
                         interpret: bool = False):
    """Packed lane products [T, P] from magnitudes [T, n_a] x [T, n_b]."""
    t = a_mags.shape[0]
    bt = min(bt, t)
    assert t % bt == 0, (t, bt)
    return _vdsp_impl(jnp.asarray(a_mags, jnp.int32), jnp.asarray(b_mags, jnp.int32),
                      plan=plan, bt=bt, interpret=interpret)
