"""Pallas TPU flash-decode kernel: fused decode attention over the KV pool.

The serving hot path is ``Sq == 1`` GQA attention over a slot-pooled cache
(DESIGN.md §7).  The einsum path in ``models/attention.py`` dispatches a
score einsum, a softmax and a value einsum per layer per token — and, with
a quantized pool (DESIGN.md §9), additionally materializes a dequantized
[B, S, H, D] copy of the cache.  This kernel fuses the whole thing:

  grid (B, Hk, Sk/bk) — one program per (slot row, KV-head group, KV block)
  * stream one packed KV block [bk, D/4] int32 + scales [bk] (or a bf16
    block) from the pool slab into VMEM,
  * dequantize in-kernel — arithmetic shift/mask decode with DAZ +
    implicit-one restore (XtraMAC Stage-1 semantics; no gathers, the same
    decode ``packed_matmul`` uses for weights),
  * one split-KV online-softmax update (running max / normalizer / f32
    accumulator) — the flash-decode recurrence over the block grid axis,
  * final block normalizes and writes the [rep, D] output tile.

Numerics are f32 end-to-end after the bf16 loads: strictly more accurate
than the einsum path (which rounds scores and probabilities through bf16
storage between dispatches).  The bit-exactness contract is therefore
against ``kernels/ref.py:decode_attention_ref`` — the same block updates
(shared ``_flash_update``) as a plain jnp loop — not against the einsum
path, which agrees to bf16 rounding tolerance (DESIGN.md §9).

The running (m, l) carries live in two small revisited output tiles rather
than scratch, matching ``packed_matmul``'s revisiting-accumulate pattern
(TPU grids iterate the last axis innermost, so all Sk blocks of one
(B, Hk) pair run consecutively).  Validated under interpret=True on CPU;
the TPU-target path is the same kernel compiled.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.kv_cache import QuantizedKV
from repro.quant.schemes import kv_unpack_codes

from .packed_matmul import _decode_fp8_e4m3, _decode_int

_NEG = -1e30  # -inf stand-in; matches models/attention.py masking


# ---------------------------------------------------------------------------
# Shared block math — used verbatim by the kernel body AND the jnp oracle in
# ref.py, which is what makes interpret-mode bit-exactness a contract rather
# than a coincidence (same ops, same order, same operands).
# ---------------------------------------------------------------------------
def _flash_update(m, l, acc, q, k, v, kpos, length):
    """One online-softmax block update.

    m, l [rep, 1]; acc [rep, dh]; q [rep, dh]; k, v [bk, dh] (all f32);
    kpos [bk] absolute cache positions; length: scalar valid count.
    """
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [rep, bk]
    s = jnp.where(kpos[None, :] < length, s, _NEG)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def _block_positions(blk, bk: int):
    """Absolute cache positions [bk] of KV block ``blk`` (2-D iota: TPU)."""
    return blk * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]


def _dequant_block(scheme_name: str, packed, scales):
    """One KV block: packed [bk, dh/4] int32 + scales [bk] -> f32 [bk, dh].

    Unpacks with the shared ``kv_unpack_codes`` codec (shift/mask only —
    Pallas-safe), then decodes arithmetically (two's complement / E4M3 with
    DAZ, NaN-as-zero) — identical values to the quant.schemes LUT path,
    gather-free in-kernel.
    """
    codes = kv_unpack_codes(packed)
    vals = _decode_int(codes, 8) if scheme_name == "int8" \
        else _decode_fp8_e4m3(codes)
    return vals * scales[:, None]


def _prep_queries(q, hk: int):
    """q [B, 1, H, Dh] bf16 -> prescaled f32 [B, Hk, rep, Dh] (grouped-GQA
    layout; head h = group h//rep, repeat h%rep — as in _attend_dense)."""
    b, sq, h, dh = q.shape
    assert sq == 1, "decode kernel is the Sq == 1 path"
    scale = jnp.float32(1.0 / math.sqrt(dh))
    return (q[:, 0].astype(jnp.float32) * scale).reshape(b, hk, h // hk, dh)


def _pick_bk(sk: int, bk=None) -> int:
    """Largest power-of-two KV block (<= 512) dividing the slab capacity
    (pool capacities are prefill-chunk aligned, so this is never 1 in
    practice)."""
    if bk is not None:
        assert sk % bk == 0, (sk, bk)
        return bk
    for cand in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if sk % cand == 0:
            return cand
    raise AssertionError(sk)


# ---------------------------------------------------------------------------
# Kernel bodies (bf16 slab / packed-quantized slab)
# ---------------------------------------------------------------------------
def _decode_step(len_ref, q_ref, k, v, o_ref, m_ref, l_ref, bk: int):
    blk = pl.program_id(2)

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    m, l, acc = _flash_update(m_ref[0, 0], l_ref[0, 0], o_ref[0, 0],
                              q_ref[0, 0], k, v,
                              _block_positions(blk, bk), len_ref[0, 0])
    m_ref[0, 0] = m
    l_ref[0, 0] = l
    o_ref[0, 0] = acc

    @pl.when(blk == pl.num_programs(2) - 1)
    def _normalize():
        o_ref[...] = o_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _decode_bf16_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                        bk: int):
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # [bk, dh]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    _decode_step(len_ref, q_ref, k, v, o_ref, m_ref, l_ref, bk)


def _decode_quant_kernel(len_ref, q_ref, kp_ref, ks_ref, vp_ref, vs_ref,
                         o_ref, m_ref, l_ref, *, bk: int, scheme_name: str):
    k = _dequant_block(scheme_name, kp_ref[0, :, 0, :], ks_ref[0, :, 0])
    v = _dequant_block(scheme_name, vp_ref[0, :, 0, :], vs_ref[0, :, 0])
    _decode_step(len_ref, q_ref, k, v, o_ref, m_ref, l_ref, bk)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------
def gqa_decode_attention(q, k_cache, v_cache, kv_valid_len, *, bk=None,
                         interpret: bool = True):
    """Fused decode attention over a (possibly quantized) KV pool slab.

    q [B, 1, H, Dh] bf16; k_cache/v_cache either bf16 [B, Sk, Hk, Dh] or
    ``QuantizedKV`` (packed [B, Sk, Hk, Dh/4] int32 + scales [B, Sk, Hk]);
    kv_valid_len [B] committed positions per slot (the just-written token
    included).  Returns [B, 1, H, Dh] in q.dtype.
    """
    b, sq, h, dh = q.shape
    quant = isinstance(k_cache, QuantizedKV)
    if quant:
        sk, hk = k_cache.packed.shape[1], k_cache.packed.shape[2]
    else:
        sk, hk = k_cache.shape[1], k_cache.shape[2]
    rep = h // hk
    qg = _prep_queries(q, hk)
    bk = _pick_bk(sk, bk)
    grid = (b, hk, sk // bk)
    lens = jnp.asarray(kv_valid_len, jnp.int32).reshape(b, 1)

    len_spec = pl.BlockSpec((1, 1), lambda bi, hi, ki: (bi, 0))
    q_spec = pl.BlockSpec((1, 1, rep, dh), lambda bi, hi, ki: (bi, hi, 0, 0))
    o_spec = pl.BlockSpec((1, 1, rep, dh), lambda bi, hi, ki: (bi, hi, 0, 0))
    ml_spec = pl.BlockSpec((1, 1, rep, 1), lambda bi, hi, ki: (bi, hi, 0, 0))
    out_shape = (jax.ShapeDtypeStruct((b, hk, rep, dh), jnp.float32),
                 jax.ShapeDtypeStruct((b, hk, rep, 1), jnp.float32),
                 jax.ShapeDtypeStruct((b, hk, rep, 1), jnp.float32))

    if quant:
        dw = k_cache.packed.shape[-1]
        kv_spec = pl.BlockSpec((1, bk, 1, dw), lambda bi, hi, ki: (bi, ki, hi, 0))
        sc_spec = pl.BlockSpec((1, bk, 1), lambda bi, hi, ki: (bi, ki, hi))
        kernel = functools.partial(_decode_quant_kernel, bk=bk,
                                   scheme_name=k_cache.scheme_name)
        in_specs = [len_spec, q_spec, kv_spec, sc_spec, kv_spec, sc_spec]
        args = (lens, qg, k_cache.packed, k_cache.scales,
                v_cache.packed, v_cache.scales)
    else:
        kv_spec = pl.BlockSpec((1, bk, 1, dh), lambda bi, hi, ki: (bi, ki, hi, 0))
        kernel = functools.partial(_decode_bf16_kernel, bk=bk)
        in_specs = [len_spec, q_spec, kv_spec, kv_spec]
        args = (lens, qg, k_cache, v_cache)

    out, _, _ = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs,
        out_specs=(o_spec, ml_spec, ml_spec), out_shape=out_shape,
        interpret=interpret,
    )(*args)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mesh entry point: the kernel under shard_map (DESIGN.md §14)
# ---------------------------------------------------------------------------
def decode_attention_shard_specs(mesh, b: int, hk: int, quant: bool):
    """(q_spec, kv_spec, len_spec, out_spec) for sharding the decode
    kernel over a serving mesh: slot rows on the data axis, KV heads on
    'model' — the ``serve_pool_pspec`` layout, with the same divisibility
    guards (a non-dividing axis stays replicated; redundant compute, never
    a wrong shape).  ``kv_spec`` mirrors the cache pytree: a
    ``QuantizedKV`` node of specs for packed pools, a bare spec for bf16.

    The query head axis shards with the KV head axis: ``_prep_queries``
    groups query heads contiguously per KV head (h -> group h // rep), so
    an even split of H lands each shard exactly the query heads of its own
    KV heads.
    """
    from jax.sharding import PartitionSpec as P
    axes = dict(mesh.shape)
    dp, tp = axes.get("data", 1), axes.get("model", 1)
    slot_ax = "data" if dp > 1 and b % dp == 0 and b >= dp else None
    head_ax = "model" if tp > 1 and hk % tp == 0 and hk >= tp else None
    q_spec = P(slot_ax, None, head_ax, None)
    if quant:
        kv_spec = QuantizedKV(P(slot_ax, None, head_ax, None),
                              P(slot_ax, None, head_ax), "")
    else:
        kv_spec = P(slot_ax, None, head_ax, None)
    return q_spec, kv_spec, P(slot_ax), q_spec


def sharded_gqa_decode_attention(q, k_cache, v_cache, kv_valid_len, *, mesh,
                                 bk=None, interpret: bool = True):
    """``gqa_decode_attention`` under ``shard_map`` over the serving mesh.

    Each shard runs the unmodified kernel on its local
    [B/dp, Sk, Hk/tp, ...] slab — the softmax is per (row, head) and the
    KV sequence axis stays whole, so there is no cross-shard collective
    and the sharded output is BITWISE identical to the meshless kernel
    (hence to ``ref.decode_attention_ref``, the §9 contract).

    When NO axis actually shards (the divisibility guards leave every
    spec replicated — e.g. 2 KV heads on an 8-way model axis with dp=1),
    the kernel runs bare: GSPMD keeps a replicated custom call replicated,
    whereas a degenerate all-replicated shard_map only perturbs the
    partitioner's choices around it (observed as ulp-level drift in the
    surrounding matmuls at tp=8).
    """
    from jax.experimental.shard_map import shard_map
    b, _, h, dh = q.shape
    quant = isinstance(k_cache, QuantizedKV)
    hk = (k_cache.packed if quant else k_cache).shape[2]
    q_spec, kv_spec, len_spec, out_spec = decode_attention_shard_specs(
        mesh, b, hk, quant)
    if all(ax is None for ax in q_spec):   # nothing shards: skip shard_map
        return gqa_decode_attention(q, k_cache, v_cache, kv_valid_len,
                                    bk=bk, interpret=interpret)
    if quant:  # carry the real scheme name so spec/cache trees match
        kv_spec = QuantizedKV(kv_spec.packed, kv_spec.scales,
                              k_cache.scheme_name)
    fn = shard_map(
        functools.partial(gqa_decode_attention, bk=bk, interpret=interpret),
        mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, len_spec),
        out_specs=out_spec, check_rep=False)
    return fn(q, k_cache, v_cache, jnp.asarray(kv_valid_len, jnp.int32))
