"""Public jit'd entry points for the XtraMAC kernels.

``quantized_matmul`` is the single dispatch the model layer calls: it picks
the kernel (or the pure-jnp reference path) from the layer's quantization
scheme.  ``use_kernel=False`` (default on CPU / under pjit partitioning)
runs the mathematically-identical jnp path — packed weights either way, so
HBM traffic (the roofline memory term) is the same; the Pallas path is the
TPU-target fast path validated under interpret=True.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.quant.schemes import (
    QuantizedLinearWeights, quantize_activations_int8,
)
from . import ref
from .decode_attention import gqa_decode_attention  # noqa: F401  (re-export)
from .packed_matmul import packed_gemv, packed_matmul, w8a8_matmul
from .xtramac_mac import virtual_dsp_multiply  # noqa: F401  (re-export)


# ---------------------------------------------------------------------------
# Partitioning guard.  The Pallas kernels index global array shapes and are
# not GSPMD-partitionable: traced under a multi-device mesh they would be
# replicated per shard against shard-local views — wrong shapes, wrong
# results.  Drivers that trace steps under a mesh (serve engine,
# launch/steps cells) declare it here, and ``kernel_allowed`` downgrades
# ``use_kernel=True`` to the mathematically-identical jnp path with a loud
# warning instead of a silent wrong answer (DESIGN.md §10).  Packed weights
# stream either way, so the roofline memory term is unchanged.
# ---------------------------------------------------------------------------
_PARTITIONED = {"value": False, "warned": False}


def set_under_partitioning(flag: bool) -> None:
    """Declare that model steps are (or are no longer) traced under a
    multi-device mesh.  Global, like ``set_use_kernel`` — the two toggles
    compose via ``kernel_allowed``."""
    _PARTITIONED["value"] = bool(flag)


def under_partitioning() -> bool:
    return _PARTITIONED["value"]


def reset_downgrade_warning() -> None:
    """Re-arm the once-per-process downgrade warning (tests)."""
    _PARTITIONED["warned"] = False


def kernel_allowed(use_kernel: bool) -> bool:
    """``use_kernel``, downgraded when partitioning is active.  The
    downgrade warns ONCE per process (module-level latch): mesh serving
    loops call this on every traced step, and a warning per call would
    spam hundreds of identical lines per second of decode."""
    if use_kernel and _PARTITIONED["value"]:
        if not _PARTITIONED["warned"]:
            _PARTITIONED["warned"] = True
            warnings.warn(
                "use_kernel=True under mesh partitioning: Pallas kernels "
                "are not GSPMD-partitionable; falling back to the jnp "
                "reference path (same math, packed weights either way). "
                "Further downgrades in this process stay silent.",
                stacklevel=3)
        return False
    return use_kernel


def quantized_matmul(x, qw: QuantizedLinearWeights, *, use_kernel: bool = False,
                     interpret: bool = True, out_dtype=jnp.bfloat16):
    """x [..., K] @ quantized W [K, N] -> [..., N] in ``out_dtype``.

    Scheme dispatch (paper Table I):
      awq_int4 / mxfp4 : INTx/FP4 x BF16 -> packed sub-byte kernel
      fp8              : FP8 weights (per-channel scale) -> packed kernel
      w8a8             : INT8 x INT8 -> INT32 (activations quantized here)
      bf16             : dense bf16 matmul (attention-path MACs)
    """
    use_kernel = kernel_allowed(use_kernel)
    scheme = qw.scheme
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)

    if scheme.name == "bf16":
        out = jnp.dot(x2.astype(jnp.bfloat16), qw.packed)
    elif scheme.name == "w8a8":
        x_codes, x_scale = quantize_activations_int8(x2)
        if use_kernel:
            out = w8a8_matmul(x_codes, x_scale, qw.packed, qw.scales,
                              interpret=interpret)
        else:
            out = ref.w8a8_matmul_ref(x_codes, x_scale, qw.packed, qw.scales)
    elif scheme.packed:  # awq_int4 / mxfp4 / fp8 — sub-byte/byte packed words
        if use_kernel:
            fn = packed_gemv if x2.shape[0] <= 8 else packed_matmul
            out = fn(x2, qw, interpret=interpret)
        else:
            # jnp fallback: dequantize INTO bf16 — exactly the paper's
            # Stage-1 mapping (the INTxFP product's FP side is BF16); the
            # Pallas kernel keeps the fused f32-accumulate version
            from repro.quant.schemes import dequantize
            w = dequantize(qw, dtype=jnp.bfloat16)
            out = jnp.dot(x2.astype(jnp.bfloat16), w)
    else:
        raise ValueError(scheme.name)
    return out.reshape(*lead, -1).astype(out_dtype)
