"""Public jit'd entry points for the XtraMAC kernels.

``quantized_matmul`` is the single dispatch the model layer calls: it picks
the kernel (or the pure-jnp reference path) from the layer's quantization
scheme plus the active *execution policy*.  The jnp path is mathematically
identical — packed weights either way, so HBM traffic (the roofline memory
term) is the same; the Pallas path is the TPU-target fast path validated
under interpret=True.

Execution policy (DESIGN.md §12, §14).  Dispatch is driven by ONE
module-level execution record:

    _EXEC = {mode: 'auto'|'jnp'|'pallas', mesh: Mesh|None, partitioned: bool}

``declare_execution(kernel=..., mesh=...)`` is the single writer — drivers
resolve a ``PrecisionPolicy.kernel`` and declare their mesh before tracing.
Under a declared multi-device mesh the Pallas kernels run inside
``shard_map``: each shard executes the unmodified kernel on its
shard-local block (KV heads / slots for decode attention; the
N- or K-sharded packed weight panel for the matvec path), so 'pallas' is a
first-class mesh citizen (DESIGN.md §14) — the historical blanket
downgrade is gone.  ``kernel: 'auto'`` resolves to the jnp reference path
on a single device (the bit-exact baseline) and to pallas under a mesh.

What remains of the downgrade is PER-SITE: a call site whose shard-local
shapes cannot tile the kernel legally — or that has no registered
sharding spec (stacked-expert leaves, ad-hoc callers) — falls back to the
jnp path with a warning keyed by the site (once per site per process;
other sites in the same trace keep the kernel).  ``partitioned=True``
without a mesh (the legacy shim spelling) still downgrades every site:
with no mesh object there is nothing to shard_map over.

``set_use_kernel`` (models/common.py) and ``set_under_partitioning`` /
``kernel_allowed`` below survive as thin deprecation shims over
``declare_execution`` — no serve-path code calls them.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.schemes import (
    QuantizedLinearWeights, quantize_activations_int8,
)
from . import ref
from .decode_attention import gqa_decode_attention  # noqa: F401  (re-export)
from .packed_matmul import (
    packed_gemv, packed_matmul, packed_shapes_legal, w8a8_matmul,
)
from .xtramac_mac import virtual_dsp_multiply  # noqa: F401  (re-export)

_UNSET = object()

_EXEC = {"mode": "auto", "mesh": None, "partitioned": False}
# leaf name -> {'packed': (k_ax, n_ax), 'scales': (k_ax, n_ax)} mesh axes
# for the shard_map'd weight kernels (partitioning.serve_weight_kernel_specs)
_WSPECS = {"map": None}
_WARNED_SITES: set = set()


def declare_execution(*, kernel: Optional[str] = None,
                      partitioned: Optional[bool] = None,
                      mesh=_UNSET, weight_specs=_UNSET) -> None:
    """Declare the execution context for subsequent traces.

    ``kernel``: 'jnp' | 'pallas' pin the dispatch mode; 'auto' resets it
    to the backend default (jnp on a single device, pallas under a mesh);
    None leaves it as-is (so an engine with an 'auto' policy inherits
    whatever a driver pinned).  ``mesh``: the jax.sharding.Mesh model
    steps are traced under (None = single device) — setting it also sets
    ``partitioned``.  ``weight_specs``: the per-leaf kernel sharding map
    from ``partitioning.serve_weight_kernel_specs`` (None to clear).
    ``partitioned`` alone (no mesh) is the legacy shim spelling: it marks
    partitioned execution with nothing to shard_map over, so every kernel
    site falls back to jnp (with a per-site warning).
    """
    if kernel in ("jnp", "pallas", "auto"):
        _EXEC["mode"] = kernel
    elif kernel is not None:
        raise ValueError(
            f"kernel={kernel!r}; valid: 'auto', 'jnp', 'pallas'")
    if partitioned is not None:
        _EXEC["partitioned"] = bool(partitioned)
    if mesh is not _UNSET:
        _EXEC["mesh"] = mesh
        _EXEC["partitioned"] = mesh is not None and mesh.size > 1
    if weight_specs is not _UNSET:
        _WSPECS["map"] = weight_specs


def reset_execution() -> None:
    """Restore the default execution declaration (mode 'auto', no mesh,
    no weight specs).  A process-level driver pin (e.g. a test that
    declared ``kernel='pallas'``) otherwise outlives its owner — any
    later 'auto'-policy engine in the same process would silently inherit
    it.  The test suite resets around every test (conftest autouse) so
    kernel-mode assertions are collection-order-independent."""
    _EXEC.update(mode="auto", mesh=None, partitioned=False)
    _WSPECS["map"] = None


def kernel_mode() -> str:
    return _EXEC["mode"]


def under_partitioning() -> bool:
    return _EXEC["partitioned"]


def active_mesh():
    """The declared mesh when it is multi-device, else None."""
    m = _EXEC["mesh"]
    return m if (m is not None and m.size > 1) else None


def resolved_kernel_mode() -> str:
    """'auto' resolved: jnp on a single device (the bit-exact baseline),
    pallas under a declared multi-device mesh (the serving fast path —
    per-site legality still applies)."""
    mode = _EXEC["mode"]
    if mode != "auto":
        return mode
    return "pallas" if active_mesh() is not None else "jnp"


# ---------------------------------------------------------------------------
# Per-site fallback warnings (replaces the per-process downgrade latch)
# ---------------------------------------------------------------------------
def _warn_site(site: str, msg: str) -> None:
    if site in _WARNED_SITES:
        return
    _WARNED_SITES.add(site)
    warnings.warn(
        f"kernel site {site!r} falls back to the jnp path: {msg} "
        "(same math, packed weights either way; warned once per site)",
        stacklevel=3)


def reset_site_warnings() -> None:
    """Re-arm the per-site fallback warnings (tests)."""
    _WARNED_SITES.clear()


def kernel_allowed(use_kernel: bool) -> bool:
    """Deprecated shim for EXPLICIT ``use_kernel`` bools: a raw kernel
    request is downgraded whenever partitioned execution is declared —
    direct callers bypass the shard_map dispatch, so running the bare
    kernel under a mesh would index shard-local views with global shapes.
    Policy-driven dispatch (``use_kernel=None``) shard_maps instead."""
    if use_kernel and _EXEC["partitioned"]:
        _warn_site(
            "<explicit use_kernel>",
            "explicit use_kernel=True under partitioned execution; use the "
            "policy dispatch (use_kernel=None), which shard_maps the kernel "
            "over the declared mesh")
        return False
    return use_kernel


def active_kernel() -> bool:
    """Whether this trace dispatches Pallas at eligible sites: the
    resolved mode is 'pallas' and (meshless, or a mesh is declared for
    shard_map).  Per-site shape legality is checked at each site."""
    if resolved_kernel_mode() != "pallas":
        return False
    return not (_EXEC["partitioned"] and _EXEC["mesh"] is None)


# --- deprecation shim (pre-policy API; serve path no longer calls it) ------
def set_under_partitioning(flag: bool) -> None:
    """Deprecated: use ``declare_execution(mesh=...)``."""
    declare_execution(partitioned=flag)


# ---------------------------------------------------------------------------
# Decode-attention dispatch (the models/attention.py gate)
# ---------------------------------------------------------------------------
def fused_decode_attention(q, k_cache, v_cache, kv_valid_len):
    """The fused Pallas flash-decode when the execution policy selects it,
    else None (the caller takes the einsum path).  Under a declared mesh
    the kernel runs inside ``shard_map`` — slots on 'data', KV heads on
    'model', the ``serve_pool_pspec`` layout — and is bitwise identical
    to the meshless kernel (no cross-shard collective; DESIGN.md §14)."""
    if resolved_kernel_mode() != "pallas":
        return None
    mesh = _EXEC["mesh"]
    if _EXEC["partitioned"] and mesh is None:
        _warn_site(
            "decode_attention",
            "pallas under partitioned execution with no declared mesh — "
            "nothing to shard_map over")
        return None
    if mesh is not None and mesh.size > 1:
        from .decode_attention import sharded_gqa_decode_attention
        return sharded_gqa_decode_attention(q, k_cache, v_cache,
                                            kv_valid_len, mesh=mesh)
    return gqa_decode_attention(q, k_cache, v_cache, kv_valid_len)


# ---------------------------------------------------------------------------
# Weight-path dispatch
# ---------------------------------------------------------------------------
def _axis_size(mesh, ax) -> int:
    if ax is None:
        return 1
    size = 1
    for a in (ax if isinstance(ax, tuple) else (ax,)):
        size *= mesh.shape[a]
    return size


def _mesh_quantized_matmul(x2, qw: QuantizedLinearWeights, mesh,
                           interpret: bool, site: str):
    """The packed kernel under ``shard_map`` over the declared mesh, with
    specs from the registered per-leaf map; None when this site must fall
    back to the jnp path (no spec / illegal shard-local shapes).

    Activations stay replicated across the mesh (the serving matvec is
    weight-bound; sharding x rows would flip the GEMV/matmul block plan
    per data shard and break the meshless bit-exactness contract).
    N-sharded weights run a local kernel and keep the output N-sharded —
    bitwise equal to the meshless kernel (the K loop is untouched).
    K-sharded weights (split at the joint code-word/scale-group
    boundaries ``param_specs`` enforces) compute f32 partials and psum
    over the model axis — ``ref.sharded_packed_matmul_ref`` is the
    matching oracle.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    scheme = qw.scheme
    k, n = qw.shape
    entry = (_WSPECS["map"] or {}).get(qw.name) if qw.name else None
    if entry is None:
        _warn_site(site, "no kernel sharding spec registered for this "
                   "weight under the declared mesh (stacked-expert leaf "
                   "or unregistered call site)")
        return None
    k_ax, n_ax = entry["packed"]
    sk_ax = entry["scales"][0]
    ksz, nsz = _axis_size(mesh, k_ax), _axis_size(mesh, n_ax)

    if scheme.name == "w8a8":
        if k_ax is not None:   # per-channel scales cannot K-shard
            _warn_site(site, "w8a8 weights cannot K-shard (per-channel "
                       "scales have no K rows to split)")
            return None
        x_codes, x_scale = quantize_activations_int8(x2)
        if nsz == 1:   # nothing shards: bare kernel (GSPMD replicates it)
            return w8a8_matmul(x_codes, x_scale, qw.packed, qw.scales,
                               interpret=interpret)
        fn = shard_map(
            lambda xc, xs, wc, ws: w8a8_matmul(xc, xs, wc, ws,
                                               interpret=interpret),
            mesh=mesh,
            in_specs=(P(None, None), P(), P(None, n_ax), P(None, n_ax)),
            out_specs=P(None, n_ax), check_rep=False)
        return fn(x_codes, x_scale, qw.packed, qw.scales)

    if not packed_shapes_legal(x2.shape[0], k // ksz, n // nsz, scheme):
        _warn_site(site, f"shard-local shapes (K={k // ksz}, N={n // nsz}) "
                   "cannot tile the packed kernel")
        return None
    per = 32 // scheme.weight_bits
    gemv = x2.shape[0] <= 8   # same block-plan predicate as meshless
    if ksz == 1 and nsz == 1:  # nothing shards: bare kernel, no shard_map
        return (packed_gemv if gemv else packed_matmul)(
            x2, qw, interpret=interpret)

    def local_mm(x2, packed, scales):
        qloc = QuantizedLinearWeights(
            scheme, packed, scales, (packed.shape[0] * per, packed.shape[1]))
        out = (packed_gemv if gemv else packed_matmul)(
            x2, qloc, interpret=interpret)
        return jax.lax.psum(out, k_ax) if k_ax is not None else out

    fn = shard_map(local_mm, mesh=mesh,
                   in_specs=(P(None, k_ax), P(k_ax, n_ax), P(sk_ax, n_ax)),
                   out_specs=P(None, n_ax), check_rep=False)
    return fn(x2, qw.packed, qw.scales)


def quantized_matmul(x, qw: QuantizedLinearWeights, *,
                     use_kernel: Optional[bool] = None,
                     interpret: bool = True, out_dtype=jnp.bfloat16):
    """x [..., K] @ quantized W [K, N] -> [..., N] in ``out_dtype``.

    ``use_kernel=None`` (the model layer's call) dispatches on the active
    execution policy — shard_map'd over the declared mesh, falling back
    per-site; an explicit bool overrides the mode but is downgraded under
    partitioned execution (``kernel_allowed``).  Scheme dispatch (paper
    Table I):
      awq_int4 / mxfp4 : INTx/FP4 x BF16 -> packed sub-byte kernel
      fp8              : FP8 weights (per-channel scale) -> packed kernel
      w8a8             : INT8 x INT8 -> INT32 (activations quantized here)
      bf16             : dense bf16 matmul (attention-path MACs)
    """
    scheme = qw.scheme
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)

    if scheme.name == "bf16":
        out = jnp.dot(x2.astype(jnp.bfloat16), qw.packed)
        return out.reshape(*lead, -1).astype(out_dtype)

    if use_kernel is None:
        use_kernel = resolved_kernel_mode() == "pallas"
        site = qw.name or f"<{scheme.name} linear K={k}>"
        if use_kernel:
            mesh = _EXEC["mesh"]
            if _EXEC["partitioned"] and mesh is None:
                _warn_site(site, "pallas under partitioned execution with "
                           "no declared mesh — nothing to shard_map over")
                use_kernel = False
            elif mesh is not None and mesh.size > 1:
                out = _mesh_quantized_matmul(x2, qw, mesh, interpret, site)
                if out is not None:
                    return out.reshape(*lead, -1).astype(out_dtype)
                use_kernel = False
    else:
        use_kernel = kernel_allowed(use_kernel)

    if scheme.name == "w8a8":
        x_codes, x_scale = quantize_activations_int8(x2)
        if use_kernel:
            out = w8a8_matmul(x_codes, x_scale, qw.packed, qw.scales,
                              interpret=interpret)
        else:
            out = ref.w8a8_matmul_ref(x_codes, x_scale, qw.packed, qw.scales)
    elif scheme.packed:  # awq_int4 / mxfp4 / fp8 — sub-byte/byte packed words
        if use_kernel:
            fn = packed_gemv if x2.shape[0] <= 8 else packed_matmul
            out = fn(x2, qw, interpret=interpret)
        else:
            # jnp fallback: dequantize INTO bf16 — exactly the paper's
            # Stage-1 mapping (the INTxFP product's FP side is BF16); the
            # Pallas kernel keeps the fused f32-accumulate version
            from repro.quant.schemes import dequantize
            w = dequantize(qw, dtype=jnp.bfloat16)
            out = jnp.dot(x2.astype(jnp.bfloat16), w)
    else:
        raise ValueError(scheme.name)
    return out.reshape(*lead, -1).astype(out_dtype)
