"""Public jit'd entry points for the XtraMAC kernels.

``quantized_matmul`` is the single dispatch the model layer calls: it picks
the kernel (or the pure-jnp reference path) from the layer's quantization
scheme plus the active *execution policy*.  The jnp path is mathematically
identical — packed weights either way, so HBM traffic (the roofline memory
term) is the same; the Pallas path is the TPU-target fast path validated
under interpret=True.

Execution policy (DESIGN.md §12).  Dispatch is driven by ONE module-level
execution record instead of the two historical booleans
(``models.common.set_use_kernel`` / ``set_under_partitioning``):

    _EXEC = {mode: 'jnp'|'pallas', partitioned: bool}

``declare_execution(kernel=..., partitioned=...)`` is the single writer —
drivers resolve a ``PrecisionPolicy.kernel`` ('auto' leaves the mode
untouched; 'jnp'/'pallas' pin it) and declare their mesh before tracing.
``active_kernel()`` is the single trace-time reader, with the mesh
downgrade folded in: the Pallas kernels index global array shapes and are
not GSPMD-partitionable — traced under a multi-device mesh they would run
per shard against shard-local views (wrong shapes, wrong results), so
``partitioned=True`` downgrades 'pallas' to the jnp path with a loud
warning (once per process; mesh decode loops would otherwise spam one
warning per traced step) instead of a silent wrong answer (DESIGN.md §10).

``set_use_kernel`` (models/common.py) and ``set_under_partitioning`` /
``kernel_allowed`` below survive as thin deprecation shims over
``declare_execution`` / ``active_kernel`` — no serve-path code calls them.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax.numpy as jnp

from repro.quant.schemes import (
    QuantizedLinearWeights, quantize_activations_int8,
)
from . import ref
from .decode_attention import gqa_decode_attention  # noqa: F401  (re-export)
from .packed_matmul import packed_gemv, packed_matmul, w8a8_matmul
from .xtramac_mac import virtual_dsp_multiply  # noqa: F401  (re-export)

_EXEC = {"mode": "jnp", "partitioned": False, "warned": False}


def declare_execution(*, kernel: Optional[str] = None,
                      partitioned: Optional[bool] = None) -> None:
    """Declare the execution context for subsequent traces.

    ``kernel``: 'jnp' | 'pallas' pin the dispatch mode; 'auto' / None
    leave it as-is (the backend default — today the jnp reference path
    unless a driver pinned 'pallas').  ``partitioned``: whether model
    steps are traced under a multi-device mesh; None leaves it as-is.
    """
    if kernel in ("jnp", "pallas"):
        _EXEC["mode"] = kernel
    elif kernel not in (None, "auto"):
        raise ValueError(
            f"kernel={kernel!r}; valid: 'auto', 'jnp', 'pallas'")
    if partitioned is not None:
        _EXEC["partitioned"] = bool(partitioned)


def kernel_mode() -> str:
    return _EXEC["mode"]


def under_partitioning() -> bool:
    return _EXEC["partitioned"]


def reset_downgrade_warning() -> None:
    """Re-arm the once-per-process downgrade warning (tests)."""
    _EXEC["warned"] = False


def kernel_allowed(use_kernel: bool) -> bool:
    """``use_kernel``, downgraded when partitioning is active — the mesh
    guard applied to an explicit kernel request.  Warns ONCE per process
    (module-level latch)."""
    if use_kernel and _EXEC["partitioned"]:
        if not _EXEC["warned"]:
            _EXEC["warned"] = True
            warnings.warn(
                "use_kernel=True under mesh partitioning: Pallas kernels "
                "are not GSPMD-partitionable; falling back to the jnp "
                "reference path (same math, packed weights either way). "
                "Further downgrades in this process stay silent.",
                stacklevel=3)
        return False
    return use_kernel


def active_kernel() -> bool:
    """The trace-time kernel decision: Pallas iff the declared mode is
    'pallas' AND no multi-device mesh is active (downgrade folded in)."""
    return kernel_allowed(_EXEC["mode"] == "pallas")


# --- deprecation shim (pre-policy API; serve path no longer calls it) ------
def set_under_partitioning(flag: bool) -> None:
    """Deprecated: use ``declare_execution(partitioned=...)``."""
    declare_execution(partitioned=flag)


def quantized_matmul(x, qw: QuantizedLinearWeights, *,
                     use_kernel: Optional[bool] = None,
                     interpret: bool = True, out_dtype=jnp.bfloat16):
    """x [..., K] @ quantized W [K, N] -> [..., N] in ``out_dtype``.

    ``use_kernel=None`` (the model layer's call) dispatches on the active
    execution policy; an explicit bool overrides the mode but still takes
    the mesh downgrade.  Scheme dispatch (paper Table I):
      awq_int4 / mxfp4 : INTx/FP4 x BF16 -> packed sub-byte kernel
      fp8              : FP8 weights (per-channel scale) -> packed kernel
      w8a8             : INT8 x INT8 -> INT32 (activations quantized here)
      bf16             : dense bf16 matmul (attention-path MACs)
    """
    use_kernel = active_kernel() if use_kernel is None \
        else kernel_allowed(use_kernel)
    scheme = qw.scheme
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)

    if scheme.name == "bf16":
        out = jnp.dot(x2.astype(jnp.bfloat16), qw.packed)
    elif scheme.name == "w8a8":
        x_codes, x_scale = quantize_activations_int8(x2)
        if use_kernel:
            out = w8a8_matmul(x_codes, x_scale, qw.packed, qw.scales,
                              interpret=interpret)
        else:
            out = ref.w8a8_matmul_ref(x_codes, x_scale, qw.packed, qw.scales)
    elif scheme.packed:  # awq_int4 / mxfp4 / fp8 — sub-byte/byte packed words
        if use_kernel:
            fn = packed_gemv if x2.shape[0] <= 8 else packed_matmul
            out = fn(x2, qw, interpret=interpret)
        else:
            # jnp fallback: dequantize INTO bf16 — exactly the paper's
            # Stage-1 mapping (the INTxFP product's FP side is BF16); the
            # Pallas kernel keeps the fused f32-accumulate version
            from repro.quant.schemes import dequantize
            w = dequantize(qw, dtype=jnp.bfloat16)
            out = jnp.dot(x2.astype(jnp.bfloat16), w)
    else:
        raise ValueError(scheme.name)
    return out.reshape(*lead, -1).astype(out_dtype)
