"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function computes the same mathematical result as its kernel twin via
plain jnp (dequantize -> dense matmul), with f32 accumulation.  The
bit-level packing oracle delegates to core.packing (numpy int64 — exact).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.packing import LanePlan, packed_multiply
from repro.quant.schemes import QuantizedLinearWeights, dequantize


def packed_matmul_ref(x, qw: QuantizedLinearWeights):
    """x [M, K] bf16 @ packed W [K, N] -> f32 [M, N] (dequant-then-matmul).

    Dequantizes in f32 (fused-kernel semantics: decoded values are never
    rounded to bf16 before the MXU)."""
    w = dequantize(qw, dtype=jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def packed_gemv_ref(x, qw: QuantizedLinearWeights):
    """GEMV special case (decode shapes): x [B, K], B small."""
    return packed_matmul_ref(x, qw)


def packed_matmul_tiled_ref(x, qw: QuantizedLinearWeights, *, bm: int = 128,
                            bn: int = 128, bk: int = 512):
    """BIT-exact oracle for ``packed_matmul``: replays the kernel's grid.

    Same tiling (``packed_block_plan``), same arithmetic decode
    (``decode_codes_arith`` — shift/mask, DAZ, shared with the kernel
    body), same per-group scaling, same per-tile f32 dot shapes and same
    K-block accumulation order, as plain jnp loops.  f32 sums are not
    associative, so agreeing on the *plan* is what upgrades the
    dequant-LUT ``packed_matmul_ref`` tolerance contract to a bitwise one
    (the DESIGN.md §14 analogue of the §9 decode-attention contract).
    """
    from .packed_matmul import (_unpack_block, decode_codes_arith,
                                packed_block_plan)
    from repro.quant.schemes import effective_group

    scheme = qw.scheme
    k, n = qw.shape
    m = x.shape[0]
    bm, bn, bk = packed_block_plan(m, k, n, scheme, bm=bm, bn=bn, bk=bk)
    per = 32 // scheme.weight_bits
    group = effective_group(scheme.group_size, k)
    g = min(group, bk)
    ng = bk // g
    out = jnp.zeros((m, n), jnp.float32)
    for i in range(m // bm):
        for j in range(n // bn):
            acc = jnp.zeros((bm, bn), jnp.float32)
            for l in range(k // bk):
                words = qw.packed[l * bk // per:(l + 1) * bk // per,
                                  j * bn:(j + 1) * bn]
                vals = decode_codes_arith(
                    scheme, _unpack_block(words, scheme.weight_bits))
                if group > bk:   # per-channel: one global scale row
                    scales = qw.scales[0:1, j * bn:(j + 1) * bn]
                else:
                    scales = qw.scales[l * ng:(l + 1) * ng,
                                       j * bn:(j + 1) * bn]
                vals = (vals.reshape(ng, g, bn) * scales[:, None, :]) \
                    .reshape(bk, bn)
                xt = x[i * bm:(i + 1) * bm,
                       l * bk:(l + 1) * bk].astype(jnp.float32)
                acc = acc + jnp.dot(xt, vals,
                                    preferred_element_type=jnp.float32)
            out = out.at[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn].set(acc)
    return out


def _shard_qw(qw: QuantizedLinearWeights, tp: int, j: int, dim: int):
    """Shard ``j`` of ``tp`` of a packed weight along logical dim (0=K,
    1=N) — split at the joint code-word/scale-group boundaries that
    ``partitioning.param_specs`` enforces for K."""
    k, n = qw.shape
    if dim == 1:
        nl = n // tp
        return QuantizedLinearWeights(
            qw.scheme, qw.packed[:, j * nl:(j + 1) * nl],
            qw.scales[:, j * nl:(j + 1) * nl], (k, nl))
    kp = qw.packed.shape[0] // tp
    ks = qw.scales.shape[0] // tp
    kl = k // tp
    return QuantizedLinearWeights(
        qw.scheme, qw.packed[j * kp:(j + 1) * kp],
        qw.scales[j * ks:(j + 1) * ks], (kl, n))


def sharded_packed_matmul_ref(x, qw: QuantizedLinearWeights, *, tp: int,
                              shard_dim: int, bm: int = 128, bn: int = 128,
                              bk: int = 512):
    """Oracle for the shard_map'd weight-path kernel (kernels/ops.py).

    Decomposes exactly as the mesh dispatch does — N sharded over 'model'
    (concatenate local results), or K sharded at joint word/scale-group
    boundaries (f32 partials + psum) — and runs the bit-exact tiled oracle
    per shard.  The N-sharded path is bitwise identical to the meshless
    kernel (the K loop is untouched); the K-sharded path matches the
    shard_map'd kernel's psum association (left-to-right over shards).
    """
    parts = [packed_matmul_tiled_ref(
        x if shard_dim == 1 else x[:, (x.shape[1] // tp) * j:
                                   (x.shape[1] // tp) * (j + 1)],
        _shard_qw(qw, tp, j, shard_dim), bm=bm, bn=bn, bk=bk)
        for j in range(tp)]
    if shard_dim == 1:
        return jnp.concatenate(parts, axis=1)
    out = parts[0]
    for p in parts[1:]:
        out = out + p
    return out


def w8a8_matmul_ref(x_codes, x_scale, w_codes, w_scales):
    """INT8 x INT8 -> INT32 accumulate -> scale epilogue (SmoothQuant MAC).

    x_codes [M, K] int8; w_codes [K, N] int8; w_scales [1, N] f32.
    INT32 accumulation is exact, matching the paper's integer adder path.
    """
    acc = jnp.dot(
        x_codes.astype(jnp.int32), w_codes.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    # same association as the kernel epilogue (floats are non-associative)
    return acc.astype(jnp.float32) * (w_scales * x_scale)


def virtual_dsp_ref(plan: LanePlan, a_mags: np.ndarray, b_mags: np.ndarray):
    """Lane products via the exact int64 virtual-DSP packing (Eqs. 9-11)."""
    return packed_multiply(plan, np.asarray(a_mags), np.asarray(b_mags))


def decode_attention_ref(q, k_cache, v_cache, kv_valid_len, *, bk=None):
    """Split-KV online-softmax oracle for ``kernels/decode_attention.py``.

    Runs the *same* per-block update (`_flash_update`, shared with the
    kernel body) as a plain jnp loop over (row, KV-head, block) — so the
    interpret-mode kernel is BIT-exact against this function on bf16 and
    quantized KV alike (the DESIGN.md §9 equivalence contract).  Agreement
    with the production einsum path (`models/attention.attend`) is to bf16
    rounding tolerance only: that path rounds scores and probabilities
    through bf16 storage between dispatches, this one stays f32 after the
    loads.
    """
    from repro.quant.kv_cache import QuantizedKV

    from .decode_attention import (_NEG, _block_positions, _dequant_block,
                                   _flash_update, _pick_bk, _prep_queries)

    b, _, h, dh = q.shape
    quant = isinstance(k_cache, QuantizedKV)
    if quant:
        sk, hk = k_cache.packed.shape[1], k_cache.packed.shape[2]
    else:
        sk, hk = k_cache.shape[1], k_cache.shape[2]
    rep = h // hk
    qg = _prep_queries(q, hk)
    bk = _pick_bk(sk, bk)
    lens = jnp.asarray(kv_valid_len, jnp.int32)

    rows = []
    for bi in range(b):
        heads = []
        for hi in range(hk):
            m = jnp.full((rep, 1), _NEG, jnp.float32)
            l = jnp.zeros((rep, 1), jnp.float32)
            acc = jnp.zeros((rep, dh), jnp.float32)
            for blk in range(sk // bk):
                sl = slice(blk * bk, (blk + 1) * bk)
                if quant:
                    k = _dequant_block(k_cache.scheme_name,
                                       k_cache.packed[bi, sl, hi],
                                       k_cache.scales[bi, sl, hi])
                    v = _dequant_block(v_cache.scheme_name,
                                       v_cache.packed[bi, sl, hi],
                                       v_cache.scales[bi, sl, hi])
                else:
                    k = k_cache[bi, sl, hi].astype(jnp.float32)
                    v = v_cache[bi, sl, hi].astype(jnp.float32)
                m, l, acc = _flash_update(m, l, acc, qg[bi, hi], k, v,
                                          _block_positions(blk, bk), lens[bi])
            heads.append(acc / jnp.maximum(l, 1e-30))
        rows.append(jnp.stack(heads))                     # [hk, rep, dh]
    out = jnp.stack(rows)                                 # [b, hk, rep, dh]
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def paged_decode_attention_ref(q, k_arena, v_arena, page_table,
                               kv_valid_len, *, bk=None):
    """Paged-attention oracle (DESIGN.md §15): gather each slot's virtual
    KV slab from the page arena through its page table, then run the
    UNCHANGED split-KV oracle on the gathered slabs.

    This *is* the paged serving contract in one line: paged attention =
    page gather + slab attention.  The production path does exactly this
    inside its jitted steps (``quant.kv_cache.gather_pages`` feeding the
    einsum path or the Pallas decode kernel), so the kernel is bit-exact
    against this oracle whenever it is bit-exact against
    ``decode_attention_ref`` on the gathered slab — garbage pages gathered
    into positions >= ``kv_valid_len`` are masked to exact zero by the
    flash update, identically in both.

    ``k_arena`` / ``v_arena``: [n_pages, page_size, hk, dh] (bf16 or
    ``QuantizedKV``).  ``page_table``: [n_slots, pages_per_slot] int32.
    """
    from repro.quant.kv_cache import gather_pages

    table = jnp.asarray(page_table, jnp.int32)
    return decode_attention_ref(q, gather_pages(k_arena, table),
                                gather_pages(v_arena, table),
                                kv_valid_len, bk=bk)


def sharded_decode_attention_ref(q, k_cache, v_cache, kv_valid_len, *,
                                 dp: int = 1, tp: int = 1, bk=None):
    """Oracle for ``sharded_gqa_decode_attention``: decompose the slot and
    KV-head axes exactly as the shard_map specs do (same divisibility
    guards), run ``decode_attention_ref`` per (slot-band, head-band) shard,
    reassemble.  The sharded kernel has no cross-shard collective, so this
    equals the meshless oracle bitwise — computing it shard-by-shard pins
    the decomposition itself, not just the math."""
    from repro.quant.kv_cache import QuantizedKV

    b, _, h, dh = q.shape
    quant = isinstance(k_cache, QuantizedKV)
    hk = (k_cache.packed if quant else k_cache).shape[2]
    rep = h // hk
    nb = dp if (dp > 1 and b % dp == 0 and b >= dp) else 1
    nh = tp if (tp > 1 and hk % tp == 0 and hk >= tp) else 1
    bb, hh = b // nb, hk // nh
    lens = jnp.asarray(kv_valid_len, jnp.int32)

    def slab(c, bs, hs):
        if quant:
            return QuantizedKV(c.packed[bs][:, :, hs], c.scales[bs][:, :, hs],
                               c.scheme_name)
        return c[bs][:, :, hs]

    rows = []
    for i in range(nb):
        bs = slice(i * bb, (i + 1) * bb)
        cols = []
        for j in range(nh):
            hs = slice(j * hh, (j + 1) * hh)
            qs = slice(j * hh * rep, (j + 1) * hh * rep)
            cols.append(decode_attention_ref(
                q[bs][:, :, qs], slab(k_cache, bs, hs), slab(v_cache, bs, hs),
                lens[bs], bk=bk))
        rows.append(jnp.concatenate(cols, axis=2))
    return jnp.concatenate(rows, axis=0)
