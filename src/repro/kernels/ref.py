"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function computes the same mathematical result as its kernel twin via
plain jnp (dequantize -> dense matmul), with f32 accumulation.  The
bit-level packing oracle delegates to core.packing (numpy int64 — exact).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.packing import LanePlan, packed_multiply
from repro.quant.schemes import QuantizedLinearWeights, dequantize


def packed_matmul_ref(x, qw: QuantizedLinearWeights):
    """x [M, K] bf16 @ packed W [K, N] -> f32 [M, N] (dequant-then-matmul).

    Dequantizes in f32 (fused-kernel semantics: decoded values are never
    rounded to bf16 before the MXU)."""
    w = dequantize(qw, dtype=jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def packed_gemv_ref(x, qw: QuantizedLinearWeights):
    """GEMV special case (decode shapes): x [B, K], B small."""
    return packed_matmul_ref(x, qw)


def w8a8_matmul_ref(x_codes, x_scale, w_codes, w_scales):
    """INT8 x INT8 -> INT32 accumulate -> scale epilogue (SmoothQuant MAC).

    x_codes [M, K] int8; w_codes [K, N] int8; w_scales [1, N] f32.
    INT32 accumulation is exact, matching the paper's integer adder path.
    """
    acc = jnp.dot(
        x_codes.astype(jnp.int32), w_codes.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    # same association as the kernel epilogue (floats are non-associative)
    return acc.astype(jnp.float32) * (w_scales * x_scale)


def virtual_dsp_ref(plan: LanePlan, a_mags: np.ndarray, b_mags: np.ndarray):
    """Lane products via the exact int64 virtual-DSP packing (Eqs. 9-11)."""
    return packed_multiply(plan, np.asarray(a_mags), np.asarray(b_mags))


def decode_attention_ref(q, k_cache, v_cache, kv_valid_len, *, bk=None):
    """Split-KV online-softmax oracle for ``kernels/decode_attention.py``.

    Runs the *same* per-block update (`_flash_update`, shared with the
    kernel body) as a plain jnp loop over (row, KV-head, block) — so the
    interpret-mode kernel is BIT-exact against this function on bf16 and
    quantized KV alike (the DESIGN.md §9 equivalence contract).  Agreement
    with the production einsum path (`models/attention.attend`) is to bf16
    rounding tolerance only: that path rounds scores and probabilities
    through bf16 storage between dispatches, this one stays f32 after the
    loads.
    """
    from repro.quant.kv_cache import QuantizedKV

    from .decode_attention import (_NEG, _block_positions, _dequant_block,
                                   _flash_update, _pick_bk, _prep_queries)

    b, _, h, dh = q.shape
    quant = isinstance(k_cache, QuantizedKV)
    if quant:
        sk, hk = k_cache.packed.shape[1], k_cache.packed.shape[2]
    else:
        sk, hk = k_cache.shape[1], k_cache.shape[2]
    rep = h // hk
    qg = _prep_queries(q, hk)
    bk = _pick_bk(sk, bk)
    lens = jnp.asarray(kv_valid_len, jnp.int32)

    rows = []
    for bi in range(b):
        heads = []
        for hi in range(hk):
            m = jnp.full((rep, 1), _NEG, jnp.float32)
            l = jnp.zeros((rep, 1), jnp.float32)
            acc = jnp.zeros((rep, dh), jnp.float32)
            for blk in range(sk // bk):
                sl = slice(blk * bk, (blk + 1) * bk)
                if quant:
                    k = _dequant_block(k_cache.scheme_name,
                                       k_cache.packed[bi, sl, hi],
                                       k_cache.scales[bi, sl, hi])
                    v = _dequant_block(v_cache.scheme_name,
                                       v_cache.packed[bi, sl, hi],
                                       v_cache.scales[bi, sl, hi])
                else:
                    k = k_cache[bi, sl, hi].astype(jnp.float32)
                    v = v_cache[bi, sl, hi].astype(jnp.float32)
                m, l, acc = _flash_update(m, l, acc, qg[bi, hi], k, v,
                                          _block_positions(blk, bk), lens[bi])
            heads.append(acc / jnp.maximum(l, 1e-30))
        rows.append(jnp.stack(heads))                     # [hk, rep, dh]
    out = jnp.stack(rows)                                 # [b, hk, rep, dh]
    return out.reshape(b, 1, h, dh).astype(q.dtype)
