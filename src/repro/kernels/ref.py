"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function computes the same mathematical result as its kernel twin via
plain jnp (dequantize -> dense matmul), with f32 accumulation.  The
bit-level packing oracle delegates to core.packing (numpy int64 — exact).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.packing import LanePlan, packed_multiply
from repro.quant.schemes import QuantizedLinearWeights, dequantize


def packed_matmul_ref(x, qw: QuantizedLinearWeights):
    """x [M, K] bf16 @ packed W [K, N] -> f32 [M, N] (dequant-then-matmul).

    Dequantizes in f32 (fused-kernel semantics: decoded values are never
    rounded to bf16 before the MXU)."""
    w = dequantize(qw, dtype=jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def packed_gemv_ref(x, qw: QuantizedLinearWeights):
    """GEMV special case (decode shapes): x [B, K], B small."""
    return packed_matmul_ref(x, qw)


def w8a8_matmul_ref(x_codes, x_scale, w_codes, w_scales):
    """INT8 x INT8 -> INT32 accumulate -> scale epilogue (SmoothQuant MAC).

    x_codes [M, K] int8; w_codes [K, N] int8; w_scales [1, N] f32.
    INT32 accumulation is exact, matching the paper's integer adder path.
    """
    acc = jnp.dot(
        x_codes.astype(jnp.int32), w_codes.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    # same association as the kernel epilogue (floats are non-associative)
    return acc.astype(jnp.float32) * (w_scales * x_scale)


def virtual_dsp_ref(plan: LanePlan, a_mags: np.ndarray, b_mags: np.ndarray):
    """Lane products via the exact int64 virtual-DSP packing (Eqs. 9-11)."""
    return packed_multiply(plan, np.asarray(a_mags), np.asarray(b_mags))
