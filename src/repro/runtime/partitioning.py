"""Logical-axis partitioning rules (DP / TP / EP / SP) for every arch.

Design (DESIGN.md §5, 1000+-node posture):
  * batch        -> ('pod', 'data')   pure DP across pods; only the gradient
                                       all-reduce crosses pod ICI
  * heads / d_ff / experts / vocab -> 'model'   (TP / EP)
  * KV-cache sequence -> 'model' (+ 'data' when batch can't shard) — the
                         flash-decode split-KV axis (SP)
  * FSDP (train only): each weight's non-TP dim sharded over 'data'
    (ZeRO-3; GSPMD inserts the per-layer all-gathers under the layer scan,
    overlapping with compute)

The rules are *name-driven*: every Maker leaf was created with a logical
name ("attn.wq", "moe.w_gate", ...) and the table below maps
(name, logical dim) -> mesh axis.  ``param_specs`` runs the same Maker walk
as parameter construction, so specs and parameters cannot drift.

Divisibility guards: a dim is only sharded if its size divides the mesh
axis (e.g. GQA with 4 KV heads on a 16-way model axis leaves K/V projection
outputs replicated — the paper-shape-correct choice).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import PspecMaker
from repro.models.transformer import ModelConfig, build_params, init_cache


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mesh-axis names + sizes for rule resolution."""
    batch_axes: Tuple[str, ...]       # ('data',) or ('pod','data')
    model_axis: str = "model"
    model_size: int = 16
    fsdp_axis: Optional[str] = None   # 'data' for training, None for serving

    @property
    def data_axis(self) -> str:
        return self.batch_axes[-1]


def rules_from_mesh(mesh: Mesh, *, train: bool) -> AxisRules:
    axes = list(mesh.axis_names)
    model = "model"
    batch_axes = tuple(a for a in axes if a != model)
    return AxisRules(batch_axes=batch_axes, model_axis=model,
                     model_size=mesh.shape[model],
                     fsdp_axis="data" if train else None)


# (name-prefix) -> (axis role for dim0, dim1); roles resolved per-config.
#   'tp'   -> model axis (if divisible)
#   'fsdp' -> fsdp axis (train only, if divisible)
#   None   -> replicated
_W_RULES: Dict[str, Tuple[Optional[str], Optional[str]]] = {
    "embed": ("tp", "fsdp"),          # [vocab, d]
    "lm_head": ("fsdp", "tp"),        # [d, vocab]
    "enc_pos": (None, "fsdp"),
    "dec_pos": (None, "fsdp"),
    "attn.wq": ("fsdp", "tp"),
    "attn.wk": ("fsdp", "tp"),
    "attn.wv": ("fsdp", "tp"),
    "attn.wo": ("tp", "fsdp"),
    # MLA
    "attn.w_dq": ("fsdp", "tp"),
    "attn.w_uq": ("tp", "tp2"),       # K = q_lora (tp'd by w_dq), N = heads
    "attn.w_dkv": ("fsdp", None),     # latent stays replicated (it is cached)
    "attn.w_uk": ("fsdp", "tp"),
    "attn.w_uv": ("fsdp", "tp"),
    # FFN
    "ffn.w_gate": ("fsdp", "tp"),
    "ffn.w_up": ("fsdp", "tp"),
    "ffn.w_in": ("fsdp", "tp"),
    "ffn.w_down": ("tp", "fsdp"),
    "ffn.w_out": ("tp", "fsdp"),
    # MoE (stack carries the expert dim -> 'model'; see _stack_rule)
    "moe.router": ("fsdp", None),
    "moe.w_gate": ("fsdp", None),
    "moe.w_up": ("fsdp", None),
    "moe.w_down": (None, "fsdp"),
    # SSM
    "ssm.w_zx": ("fsdp", "tp"),       # z/x head-aligned
    "ssm.w_up": ("fsdp", "tp"),
    "ssm.w_bc": ("fsdp", None),       # B/C shared across heads
    "ssm.w_dt": ("fsdp", None),
    "ssm.w_q": ("fsdp", "tp"),
    "ssm.w_k": ("fsdp", "tp"),
    "ssm.w_v": ("fsdp", "tp"),
    "ssm.w_if": ("fsdp", None),
    "ssm.w_gates": ("fsdp", "tp"),
    "ssm.w_out": ("tp", "fsdp"),
}

# vectors / norms / conv tables: channel dim rule (dim 0 of the spec call)
_V_RULES: Dict[str, Optional[str]] = {
    "ssm.conv_x": None,    # [W, di] — dim1 handled via table rule below
}

# Attention projections whose flat [.., H*Dh] dim is reshaped to heads in
# the forward pass: that dim shards at HEAD granularity only
# (name -> (head-carrying dim, 'q' = n_heads | 'kv' = n_kv_heads)).  The
# raw dim size h*dh often divides a mesh axis that the head count does not
# (2 KV heads x 16 dims on a 4-way axis) — sharding there splits inside a
# head, which the docstring above already forbids in intent and which the
# reshape-under-2D-mesh path miscompiles in practice (DESIGN.md §10).
# Non-head dims of these leaves (e.g. w_uq's q_lora K dim) are untouched.
_HEAD_ALIGNED: Dict[str, Tuple[int, str]] = {
    "attn.wq": (1, "q"), "attn.wk": (1, "kv"), "attn.wv": (1, "kv"),
    "attn.wo": (0, "q"),
    "attn.w_uq": (1, "q"), "attn.w_uk": (1, "q"), "attn.w_uv": (1, "q"),
}


def _divides(n: int, axis_size: int) -> bool:
    return n % axis_size == 0 and n >= axis_size


class _ShapeProbe:
    """Records each leaf's logical dims so divisibility can be checked."""

    def __init__(self):
        self.dims: Dict[str, Tuple[int, ...]] = {}


def make_param_rule(cfg: ModelConfig, rules: AxisRules, dim_sizes):
    """Returns rule(name, dim) -> axis-or-None for PspecMaker."""
    model, fsdp = rules.model_axis, rules.fsdp_axis
    msize = rules.model_size
    fsize = dim_sizes.get("__fsdp_size__", 0)

    def resolve(role: Optional[str], size: int):
        if role in ("tp", "tp2") and _divides(size, msize):
            return model
        if role == "fsdp" and fsdp is not None and _divides(size, fsize):
            return fsdp
        return None

    def rule(name: str, dim: int):
        base = name.split("@")[0]
        roles = _W_RULES.get(base)
        if roles is None:
            # norms / vectors / tables: replicate (small), except conv
            # channel dims which follow their block's TP layout
            if name in ("ssm.conv_x",) and dim == 1:
                return resolve("tp", dim_sizes.get((name, 1), 0))
            return None
        size = dim_sizes.get((name, dim), 0)
        ax = resolve(roles[dim], size)
        # head-granularity guard: the head-carrying dim of an attention
        # projection shards over 'model' only when the head COUNT divides
        if ax == model and _HEAD_ALIGNED.get(base, (None,))[0] == dim:
            heads = cfg.n_kv_heads if _HEAD_ALIGNED[base][1] == "kv" \
                else cfg.n_heads
            if not _divides(heads, msize):
                ax = None
        # Quantized leaves, K axis (dim 0): a shard boundary must land on
        # BOTH an int32 code-word boundary and a scale-group boundary, and
        # packed codes and group scales must shard in lockstep (a shard has
        # to own the scale rows of its own K rows).  Sharding the packed
        # array can never split a word (each word is one element), so the
        # binding constraint is the twin leaf: shard K only when the twin's
        # K dim divides the axis the same way — otherwise replicate
        # (DESIGN.md §10).
        if ax is not None and dim == 0 and "@" in name:
            twin = base + ("@scales" if name.endswith("@packed")
                           else "@packed")
            if resolve(roles[0], dim_sizes.get((twin, 0), 0)) != ax:
                ax = None
        # never double-assign the same axis to both dims
        if dim == 1 and ax is not None:
            ax0 = rule(name, 0)
            if ax0 == ax:
                return None
        return ax

    return rule


def _collect_dim_sizes(cfg: ModelConfig, plan: Optional[Dict] = None) -> Dict:
    """Walk with a recording maker to learn each leaf's actual dims
    (including the packed-code / scale array dims of quantized leaves).
    ``plan`` applies the same per-name scheme overrides QuantMaker honors,
    so recorded dims track the checkpoint that was actually built."""
    from repro.quant.schemes import effective_group, get_scheme
    sizes: Dict = {}

    class Probe(PspecMaker):
        def __init__(self):
            super().__init__(rule=lambda n, d: None, quantize=False)

        def dense(self, name, stack, k, n, scheme=None):
            if plan:
                scheme = plan.get(name, scheme)
            sizes[(name, 0)] = k
            sizes[(name, 1)] = n
            if scheme is not None and scheme != "bf16":
                s = get_scheme(scheme)
                kp = k // (32 // s.weight_bits) if s.packed else k
                sizes[(name + "@packed", 0)] = kp
                sizes[(name + "@packed", 1)] = n
                sizes[(name + "@scales", 0)] = k // effective_group(
                    s.group_size, k)
                sizes[(name + "@scales", 1)] = n
            return super().dense(name, stack, k, n, scheme)

        def table(self, name, stack, rows, cols, scale=0.02):
            sizes[(name, 0)] = rows
            sizes[(name, 1)] = cols
            return super().table(name, stack, rows, cols, scale)

    build_params(cfg, Probe())
    return sizes


def _stack_axes(cfg: ModelConfig, rules: AxisRules, name: str,
                n_stack: int) -> Tuple[Optional[str], ...]:
    """Axes for the leading stack dims (layer stack + expert dim)."""
    if name.startswith("moe.w_") and n_stack >= 1:
        # last stack dim is the expert dim -> EP over 'model'
        ep = rules.model_axis if _divides(cfg.n_experts, rules.model_size) else None
        return (None,) * (n_stack - 1) + (ep,)
    return (None,) * n_stack


def param_specs(cfg: ModelConfig, mesh: Mesh, *, train: bool,
                quantize: Optional[bool] = None,
                plan: Optional[Dict[str, str]] = None,
                policy=None):
    """PartitionSpec tree matching build_params' structure exactly.

    ``plan``: the same per-name scheme overrides given to ``QuantMaker`` —
    specs must be built with the plan the checkpoint was built with, or the
    two trees diverge wherever the plan flips a leaf between dense and
    packed.  ``policy``: a ``quant.policy.PrecisionPolicy`` — the unified
    spelling of the same contract (DESIGN.md §12); its resolved plan is
    used, so shardings derive from the single datatype-adaptive object the
    checkpoint and the serving engine share.  Give one or the other."""
    if policy is not None:
        if plan is not None:
            raise ValueError("give either plan= or policy=, not both")
        plan = policy.resolved_plan(cfg)
    rules = rules_from_mesh(mesh, train=train)
    sizes = _collect_dim_sizes(cfg, plan)
    if rules.fsdp_axis is not None:
        sizes["__fsdp_size__"] = mesh.shape[rules.fsdp_axis]
    rule = make_param_rule(cfg, rules, sizes)
    q = (not train) if quantize is None else quantize

    class Maker(PspecMaker):
        def __init__(self):
            super().__init__(rule=rule, quantize=q)

        def dense(self, name, stack, k, n, scheme=None):
            if plan:
                scheme = plan.get(name, scheme)
            return super().dense(name, stack, k, n, scheme)

        def _spec(self, name, stack, dims):
            stack_ax = _stack_axes(cfg, rules, name, len(stack))
            parts = list(stack_ax) + [self.rule(name, d) for d in range(dims)]
            # EP consumed 'model': drop TP on the weight dims of expert mats
            if any(a == rules.model_axis for a in stack_ax):
                parts = list(stack_ax) + [
                    p if p != rules.model_axis else None
                    for p in parts[len(stack_ax):]]
            return P(*parts)

    return build_params(cfg, Maker())


def serve_weight_kernel_specs(cfg: ModelConfig, mesh: Mesh, *,
                              plan: Optional[Dict[str, str]] = None,
                              policy=None) -> Dict[str, Dict]:
    """Per-leaf mesh axes for the shard_map'd weight kernels (DESIGN.md
    §14): ``{leaf name: {'packed': (k_ax, n_ax), 'scales': (k_ax, n_ax)}}``
    for every quantized leaf the kernel path can serve.

    The axes are exactly the leaf's ``param_specs`` storage axes with the
    leading stack dims stripped — the kernel runs on the per-layer slice
    inside the scan, and its shard_map in_specs must match where the codes
    and scales already live (no resharding on the hot path).  The same
    make_param_rule produces both, so kernel specs and storage specs
    cannot drift — in particular K only shards where code words and scale
    groups split in lockstep (the joint-boundary rule).

    Stacked-expert (``moe.*``) leaves are excluded: the expert vmap wraps
    the kernel call and shard_map cannot nest inside it — those sites fall
    back to the jnp path per-site (kernels/ops.py warns once per site).
    """
    if policy is not None:
        if plan is not None:
            raise ValueError("give either plan= or policy=, not both")
        plan = policy.resolved_plan(cfg)
    rules = rules_from_mesh(mesh, train=False)
    sizes = _collect_dim_sizes(cfg, plan)
    rule = make_param_rule(cfg, rules, sizes)
    specs: Dict[str, Dict] = {}

    class Probe(PspecMaker):
        def __init__(self):
            super().__init__(rule=rule, quantize=True)

        def dense(self, name, stack, k, n, scheme=None):
            if plan:
                scheme = plan.get(name, scheme)
            if scheme is not None and scheme != "bf16" \
                    and not name.startswith("moe."):
                specs[name] = {
                    "packed": (rule(name + "@packed", 0),
                               rule(name + "@packed", 1)),
                    "scales": (rule(name + "@scales", 0),
                               rule(name + "@scales", 1)),
                }
            return super().dense(name, stack, k, n, scheme)

    build_params(cfg, Probe())
    return specs


# ---------------------------------------------------------------------------
# Input / cache / state specs
# ---------------------------------------------------------------------------
def batch_pspec(cfg: ModelConfig, rules: AxisRules, batch_size: int,
                mesh: Mesh):
    """PartitionSpecs for a train/serve input batch dict."""
    bax = rules.batch_axes
    bsize = int(np.prod([mesh.shape[a] for a in bax]))
    if batch_size % bsize != 0:   # small serve batches: fewest axes that fit
        bax = tuple(a for a in bax if batch_size % mesh.shape[a] == 0)[-1:]
    b = P(bax if bax else None, None)
    specs = {"tokens": b, "labels": b}
    if cfg.family == "vlm":
        specs["patches"] = P(bax, None, None)
    if cfg.family == "audio":
        specs["frames"] = P(bax, None, None)
    return specs


def cache_pspec(cfg: ModelConfig, rules: AxisRules, batch_size: int,
                mesh: Mesh):
    """PartitionSpecs for the decode cache: SP over the KV sequence axis.

    KV caches [L?, B, S, H, D]: B over batch axes when divisible, S over
    'model' (flash-decode split-KV).  When B == 1 (long_500k) the sequence
    axis takes BOTH axes.  Recurrent states shard B over data and heads
    over 'model' when divisible.
    """
    bax = rules.batch_axes
    bsize = int(np.prod([mesh.shape[a] for a in bax]))
    b_ok = batch_size % bsize == 0 and batch_size >= bsize
    b_ax = bax if b_ok else None
    s_ax = ("model",) if b_ok else (bax + ("model",))

    def kv_spec(nstack, ndim_tail):
        # [stack..., B, S, (H, D) or (latent,)]
        return P(*([None] * nstack), b_ax, s_ax, *([None] * ndim_tail))

    def state_spec(nstack, shape):
        # SSMState arrays [stack..., B, nh, ...]: shard nh over model
        nh = shape[nstack + 1] if len(shape) > nstack + 1 else 0
        nh_ax = "model" if _divides(nh, rules.model_size) else None
        tail = [None] * (len(shape) - nstack - 2)
        return P(*([None] * nstack), b_ax, nh_ax, *tail)

    abstract = init_cache(cfg, batch_size, 8, abstract=True)

    def classify(path, leaf):
        shape = leaf.shape
        names = [getattr(p, 'key', getattr(p, 'name', str(p))) for p in path]
        path_s = "/".join(str(n) for n in names)
        # count leading stack dims: dims before the batch-sized dim
        nstack = 0
        for d in shape:
            if d == batch_size:
                break
            nstack += 1
        if nstack >= len(shape):   # no batch dim found — replicate
            return P()
        if "conv" in path_s:
            return P(*([None] * nstack), b_ax, None, None)
        if "state" in path_s or "slstm" in path_s or path_s.endswith("m") \
                or "Hs" in path_s or "ns" in path_s:
            return state_spec(nstack, shape)
        if path_s == "enc":
            return P(b_ax, None, None)
        # KV-style: [stack..., B, S, ...]
        return kv_spec(nstack, len(shape) - nstack - 2)

    return jax.tree_util.tree_map_with_path(classify, abstract)


def serve_pool_pspec(cfg: ModelConfig, mesh: Mesh, n_slots: int, *,
                     kv_dtype="bf16"):
    """PartitionSpecs for the serving KV pool tree
    ``[L, n_slots, capacity, ...]`` (DESIGN.md §10).  ``kv_dtype`` is the
    pool's KV tier — the per-pool component of the ``PrecisionPolicy``
    (DESIGN.md §12): the engine passes ``pool.kv_dtype``, which may be a
    per-request tier rather than the policy's default.

    Contract (differs from ``cache_pspec``, which serves the static
    one-shot shapes):
      * slots (the continuous-batching batch dim) -> data axis — each DP
        shard owns a contiguous band of pool rows for a request's lifetime;
      * heads -> 'model' — TP attention keeps each shard's heads local
        end-to-end (replicated when ``n_kv_heads`` does not divide);
      * the sequence axis stays LOCAL: prefill-chunk and per-row decode
        writes land at *traced* offsets, and sharding S would turn every
        cache write into cross-shard traffic;
      * the packed code-word dim of a quantized slab never shards (4 codes
        per int32 word along d_head); its scales twin drops that dim.

    Divisibility guards mirror ``param_specs``: an axis that does not
    divide stays replicated rather than padded.
    """
    from repro.models import attention as A
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"serve_pool_pspec covers slot-pool families, not {cfg.family!r}")
    rules = rules_from_mesh(mesh, train=False)
    dax = rules.data_axis
    slot_ax = dax if _divides(n_slots, mesh.shape[dax]) else None
    if cfg.use_mla:
        per_layer = A.mla_cache_pspec(cfg.mla_cfg(), slot_ax)
    else:
        head_ax = rules.model_axis \
            if _divides(cfg.n_kv_heads, rules.model_size) else None
        per_layer = A.gqa_cache_pspec(cfg.attn_cfg(), kv_dtype,
                                      slot_ax, head_ax)
    # prepend the (L,) layer-stack dim (never sharded: lax.scan carries it)
    return jax.tree_util.tree_map(lambda p: P(None, *p), per_layer,
                                  is_leaf=lambda x: isinstance(x, P))


def serve_burst_pspec(mesh, n_slots: int) -> Dict[str, P]:
    """PartitionSpecs for the decode fast-path carries that ride the slot
    axis (DESIGN.md §11) — the non-cache inputs/outputs of the fused
    ``decode_slots`` and ``decode_burst`` jits:

      * ``row``          [n_slots]       — tokens / lengths / active mask /
                                           remaining-budget / temperatures /
                                           eos ids / sampled ids
      * ``row_keys``     [n_slots, 2]    — per-row PRNG keys (single step)
      * ``key_schedule`` [K, n_slots, 2] — the burst's precomputed
                                           per-(request, step) key schedule;
                                           the step axis stays local (it is
                                           the ``lax.scan`` axis)
      * ``burst_out``    [K, n_slots]    — stacked sampled ids / valid masks

    The slot axis follows the SAME divisibility guard as
    ``serve_pool_pspec``: it shards over the data axis iff ``n_slots``
    divides it, so burst carries and the pool cache always agree on where
    a slot row lives (a mismatch would resharding-copy the cache every
    step and kill donation)."""
    rules = rules_from_mesh(mesh, train=False)
    dax = rules.data_axis
    slot_ax = dax if _divides(n_slots, mesh.shape[dax]) else None
    return {
        "row": P(slot_ax),
        "row_keys": P(slot_ax, None),
        "key_schedule": P(None, slot_ax, None),
        "burst_out": P(None, slot_ax),
    }


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
