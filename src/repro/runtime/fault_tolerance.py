"""Fault-tolerance runtime: preemption handling, straggler detection,
restart/elastic-resume orchestration.

At thousands of nodes the failure model is: (a) planned preemptions
(SIGTERM with a grace window), (b) hard node loss (job restarts from the
latest checkpoint, possibly on a different topology), (c) stragglers
(slow-but-alive hosts degrading every synchronous step).

  * ``PreemptionHandler`` — installs SIGTERM/SIGINT hooks; the train loop
    polls ``should_stop`` at step boundaries and checkpoints before exit.
  * ``StragglerMonitor``  — per-step wall-clock EWMA + variance; flags
    steps beyond ``sigma`` deviations and keeps a counter the deployment
    layer can use to evict/re-schedule a host.
  * ``RestartManager``    — "run until done" wrapper: on simulated/real
    failures it resumes from the latest checkpoint; combined with the
    elastic loader in checkpoint/store.py this also covers mesh-shape
    changes across restarts.

Serving-side fault tolerance (DESIGN.md §16) reuses the same module:

  * ``StepFault``   — the typed failure a serving dispatch raises when a
    step dies or returns poisoned output (lost shard, NaN logits, an
    injected test fault).  The scheduler catches it on the hot path and
    recovers by preempt-and-requeue instead of process death.
  * ``RetryBudget`` — per-key bounded retry with exponential backoff:
    each fault on a key grants a backoff (1, 2, 4, ... steps) until the
    key's budget is exhausted, at which point the caller retires the
    work permanently.  Keys are whatever identifies the retried unit
    (request ids, in serving).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List, Optional


class StepFault(RuntimeError):
    """A single engine dispatch failed or produced poisoned output.

    ``kind``: 'injected' (test hook), 'nan' (non-finite / out-of-range
    step output), 'shard' (device/shard loss surfaced by the runtime), or
    any runtime-specific tag.  Raised by the engine's step primitives and
    caught by the serving scheduler, which invalidates the affected slots
    and requeues their requests (re-prefill is cheap via the paged prefix
    cache) instead of letting the process die.
    """

    def __init__(self, kind: str, detail: str = ""):
        super().__init__(f"step fault [{kind}]{': ' + detail if detail else ''}")
        self.kind = kind
        self.detail = detail


class RetryBudget:
    """Bounded retry-and-backoff bookkeeping, keyed by work unit.

    ``record_fault(key)`` returns the number of steps the caller should
    hold the key back before retrying (exponential: 1, 2, 4, ...), or
    ``None`` once the key has exhausted ``max_retries`` — the caller then
    retires the unit permanently.  ``clear(key)`` forgets a key's history
    (call it when the unit completes, so ids can be reused)."""

    def __init__(self, max_retries: int = 3):
        assert max_retries >= 0
        self.max_retries = max_retries
        self.faults: Dict = {}

    def record_fault(self, key) -> Optional[int]:
        n = self.faults.get(key, 0) + 1
        self.faults[key] = n
        if n > self.max_retries:
            return None
        return 1 << (n - 1)

    def n_faults(self, key) -> int:
        return self.faults.get(key, 0)

    def clear(self, key) -> None:
        self.faults.pop(key, None)


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = False
        self._prev = {}
        for s in signals:
            self._prev[s] = signal.signal(s, self._handler)

    def _handler(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def request_stop(self):      # tests / manual drain
        self._stop = True

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    mean_s: float
    deviations: float


class StragglerMonitor:
    """EWMA of step time; flags > ``sigma``-deviation steps."""

    def __init__(self, alpha: float = 0.1, sigma: float = 3.0,
                 warmup_steps: int = 5):
        self.alpha = alpha
        self.sigma = sigma
        self.warmup = warmup_steps
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> Optional[StragglerEvent]:
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        self._t0 = None
        self.n += 1
        if self.mean is None:
            self.mean = dt
            return None
        dev = dt - self.mean
        std = max(self.var, 1e-12) ** 0.5
        flagged = None
        if self.n > self.warmup and dev > self.sigma * std and std > 0:
            flagged = StragglerEvent(step, dt, self.mean, dev / std)
            self.events.append(flagged)
        # EWMA update (flagged steps still update slowly so a persistent
        # slowdown re-baselines instead of flagging forever)
        a = self.alpha if flagged is None else self.alpha / 4
        self.mean += a * dev
        self.var = (1 - a) * (self.var + a * dev * dev)
        return flagged

    @property
    def straggler_fraction(self) -> float:
        return len(self.events) / max(self.n, 1)


class RestartManager:
    """Run a resumable body until completion, restarting on failure.

    ``body(resume_step) -> finished_step`` raises on (simulated) failure;
    the manager retries from the latest checkpoint up to ``max_restarts``.
    """

    def __init__(self, latest_step_fn: Callable[[], Optional[int]],
                 max_restarts: int = 10):
        self.latest_step_fn = latest_step_fn
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, body: Callable[[Optional[int]], int]) -> int:
        while True:
            resume = self.latest_step_fn()
            try:
                return body(resume)
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
