"""Lane packing in the DSP multiplier bit-space (paper Section III-C).

Implements Eqs. (9)-(12):

  A_DSP = sum_i a_i << s_i         B_DSP = sum_j b_j << t_j          (9)
  P_DSP = sum_{i,j} (a_i b_j) << (s_i + t_j)                        (10)
  P_ij  = (P_DSP >> (s_i + t_j)) & (2^S - 1)                        (11)
  S >= W_lane + G;  per-port lane bound from L_A=27, L_B=18         (12)

``solve_lane_plan`` searches placements of mantissa lanes on the two DSP
ports such that every wanted product lands at an isolated bit position,
maximizing the number of parallel MAC lanes.  The solver reproduces the
paper's Fig. 6 parallelism (FP8xFP8: 4, BF16/INT8/INT4xBF16/FP4xBF16: 2)
and additionally *discovers* that FP4xFP4 admits 6 isolated lanes — more
than the paper's stated 4 (the paper caps P at 4, matching its 32-bit
output bus).  Both numbers are reported in the benchmarks.

``packed_multiply`` / ``xtramac_packed`` emulate the single wide multiply +
shift-and-mask lane extraction bit-faithfully (int64: the 27x18 product is
<= 45 bits), and are the oracle for the Pallas kernel in
kernels/xtramac_mac.py.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Tuple

import numpy as np

from .formats import Format, FloatFormat, IntFormat, get_format
from . import mac as M

DSP48E2_LA = 27
DSP48E2_LB = 18
DSP48E2_WMUL = DSP48E2_LA + DSP48E2_LB  # 45-bit utilization denominator


def magnitude_bits(fmt: Format) -> int:
    """Effective unsigned magnitude width entering the multiplier."""
    return fmt.magnitude_bits


def max_magnitude(fmt: Format) -> int:
    """Largest unsigned magnitude the mapping stage can emit for ``fmt``."""
    if isinstance(fmt, IntFormat):
        return 1 << (fmt.bits - 1)          # |-2^(b-1)|
    return (1 << fmt.magnitude_bits) - 1    # mantissa incl. implicit bit


@dataclasses.dataclass(frozen=True)
class LanePlan:
    fmt_a: Format
    fmt_b: Format
    w_a: int
    w_b: int
    stride: int                         # S = W_lane + guard
    offsets_a: Tuple[int, ...]          # s_i
    offsets_b: Tuple[int, ...]          # t_j
    guard: int = 1
    l_a: int = DSP48E2_LA
    l_b: int = DSP48E2_LB

    @property
    def w_lane(self) -> int:
        """Max product width: bitlen(max_a * max_b), NOT w_a + w_b — e.g.
        |INT8|max=128 so INT8xFP16 products are 18 bits, not 19."""
        return int(max_magnitude(self.fmt_a) * max_magnitude(self.fmt_b)).bit_length()

    @property
    def lane_positions(self) -> Tuple[Tuple[int, int, int], ...]:
        """(i, j, product bit position) for every lane product."""
        return tuple(
            (i, j, si + tj)
            for i, si in enumerate(self.offsets_a)
            for j, tj in enumerate(self.offsets_b)
        )

    @property
    def parallelism(self) -> int:
        return len(self.offsets_a) * len(self.offsets_b)

    @property
    def dsp_utilization(self) -> float:
        """Operand-bit utilization (Section II-A): per-lane (w_a + w_b),
        summed over lanes.  Reproduces the paper's reference points — e.g.
        2-lane INT8 gives (8+8)*2/45 = 71.1%, TATAA's own INT8 figure."""
        return self.parallelism * (self.w_a + self.w_b) / DSP48E2_WMUL

    def validate(self) -> None:
        assert max(self.offsets_a) + self.w_a <= self.l_a, "A-port overflow"
        assert max(self.offsets_b) + self.w_b <= self.l_b, "B-port overflow"
        pos = sorted(p for _, _, p in self.lane_positions)
        assert len(set(pos)) == len(pos), "colliding lane positions"
        for p, q in zip(pos, pos[1:]):
            assert q - p >= self.stride, f"lanes at {p},{q} closer than stride {self.stride}"
        assert pos[-1] + self.w_lane <= DSP48E2_WMUL, "product exceeds 45 bits"


def _try_plan(w_a: int, w_b: int, n_a: int, n_b: int, stride: int,
              spread_a: bool, l_a: int, l_b: int, guard: int,
              fmt_a: Format, fmt_b: Format) -> Optional[LanePlan]:
    """Regular-grid placement: one port's lanes step by S, the other by S*n."""
    if spread_a:
        offs_a = tuple(i * stride * n_b for i in range(n_a))
        offs_b = tuple(j * stride for j in range(n_b))
    else:
        offs_a = tuple(i * stride for i in range(n_a))
        offs_b = tuple(j * stride * n_a for j in range(n_b))
    plan = LanePlan(fmt_a, fmt_b, w_a, w_b, stride, offs_a, offs_b,
                    guard=guard, l_a=l_a, l_b=l_b)
    try:
        plan.validate()
    except AssertionError:
        return None
    return plan


def solve_lane_plan(
    fmt_a, fmt_b, *, l_a: int = DSP48E2_LA, l_b: int = DSP48E2_LB,
    guard: int = 1, max_parallelism: Optional[int] = None,
) -> LanePlan:
    """Find the max-parallelism packing of (fmt_a, fmt_b) lanes on the DSP."""
    fmt_a = get_format(fmt_a) if isinstance(fmt_a, str) else fmt_a
    fmt_b = get_format(fmt_b) if isinstance(fmt_b, str) else fmt_b
    w_a, w_b = magnitude_bits(fmt_a), magnitude_bits(fmt_b)
    w_lane = int(max_magnitude(fmt_a) * max_magnitude(fmt_b)).bit_length()
    stride = w_lane + guard
    best: Optional[LanePlan] = None
    max_na = max(1, l_a // w_a)
    max_nb = max(1, l_b // w_b)
    for n_a, n_b in itertools.product(range(1, max_na + 1), range(1, max_nb + 1)):
        if max_parallelism and n_a * n_b > max_parallelism:
            continue
        for spread_a in (True, False):
            plan = _try_plan(w_a, w_b, n_a, n_b, stride, spread_a, l_a, l_b,
                             guard, fmt_a, fmt_b)
            if plan and (best is None or plan.parallelism > best.parallelism):
                best = plan
    assert best is not None  # n_a = n_b = 1 always fits for supported formats
    return best


# Paper Fig. 6 / Table IV claimed parallelism (per single DSP).  These are
# the paper's *deployed* lane counts (capped at 4 by its 32-bit output bus);
# tests assert each is feasible, and separately that the uncapped solver
# meets or beats every one of them.
PAPER_PARALLELISM = {
    ("fp8_e4m3", "fp8_e4m3"): 4,
    ("fp8_e5m2", "fp8_e5m2"): 4,
    ("fp4_e2m1", "fp4_e2m1"): 4,
    ("bf16", "bf16"): 2,
    ("int8", "int8"): 2,
    ("int4", "bf16"): 2,
    ("fp4_e2m1", "bf16"): 2,
    ("fp8_e4m3", "bf16"): 2,
    ("int8", "bf16"): 2,
    ("int8", "fp16"): 2,
    ("int4", "fp16"): 2,
    ("fp4_e2m1", "fp16"): 2,
    ("fp8_e4m3", "fp16"): 2,
}

# Combos where the uncapped stride solver finds MORE isolated lanes than the
# paper deploys (beyond-paper result, reported in the benchmarks).
SOLVER_BEYOND_PAPER = {
    ("fp4_e2m1", "fp4_e2m1"): 6,   # paper: 4
    ("fp4_e2m1", "bf16"): 3,       # paper: 2
    ("int2", "bf16"): 3,           # paper: 2 (INT2-8 row)
}


# ---------------------------------------------------------------------------
# Bit-faithful packed multiply (the virtual DSP)
# ---------------------------------------------------------------------------
def pack_port(offsets: Tuple[int, ...], mags: np.ndarray) -> np.ndarray:
    """Eq. (9): mags[..., lane] -> packed port word (int64, <= 27 bits)."""
    mags = np.asarray(mags, dtype=np.int64)
    word = np.zeros(mags.shape[:-1], dtype=np.int64)
    for lane, off in enumerate(offsets):
        word = word | (mags[..., lane] << off)
    return word


def packed_multiply(plan: LanePlan, a_mags: np.ndarray, b_mags: np.ndarray) -> np.ndarray:
    """Eqs. (9)-(11): pack, ONE wide multiply, shift-and-mask extraction.

    a_mags: [..., n_a] magnitudes; b_mags: [..., n_b].
    Returns lane products [..., P] ordered as plan.lane_positions.
    """
    A = pack_port(plan.offsets_a, a_mags)
    B = pack_port(plan.offsets_b, b_mags)
    P = A * B  # the single DSP multiply (<= 45 bits, exact in int64)
    mask = (np.int64(1) << plan.stride) - 1
    out = np.stack(
        [(P >> pos) & mask for (_, _, pos) in plan.lane_positions], axis=-1
    )
    return out


def xtramac_packed(
    cfg: M.MacConfig, plan: LanePlan,
    a_bits: np.ndarray, b_bits: np.ndarray, c_bits: np.ndarray,
) -> np.ndarray:
    """Full packed MAC: P lanes through ONE virtual-DSP multiply.

    a_bits: [..., n_a] raw patterns of fmt_a;  b_bits: [..., n_b];
    c_bits: [..., P] accumulator inputs (one per lane product).
    Must be bit-identical to running ``mac.xtramac`` once per lane — that is
    the lane-isolation claim of Eq. (10), asserted in tests.
    """
    da = M.map_operand(cfg.fmt_a, np.asarray(a_bits, np.int64))   # Stage 1
    db = M.map_operand(cfg.fmt_b, np.asarray(b_bits, np.int64))
    dc = M.map_operand(cfg.fmt_c, np.asarray(c_bits, np.int64))

    prods = packed_multiply(plan, da.mag, db.mag)                 # Stage 2 (DSP)

    outs = []
    for lane, (i, j, _) in enumerate(plan.lane_positions):        # Stage 2 post + 3 + 4
        sign = da.sign[..., i] ^ db.sign[..., j]
        exp = da.exp[..., i] + db.exp[..., j]
        nan = da.nan[..., i] | db.nan[..., j]
        inf_zero = (da.inf[..., i] & (db.mag[..., j] == 0) & ~db.inf[..., j] & ~db.nan[..., j]) | (
            db.inf[..., j] & (da.mag[..., i] == 0) & ~da.inf[..., i] & ~da.nan[..., i]
        )
        nan = nan | inf_zero
        inf = (da.inf[..., i] | db.inf[..., j]) & ~nan
        prod = M.Product(sign, prods[..., lane], exp, nan, inf)

        dcl = M.Decoded(dc.sign[..., lane], dc.mag[..., lane], dc.exp[..., lane],
                        dc.nan[..., lane], dc.inf[..., lane])
        if cfg.is_int_accumulate:
            outs.append(M.accumulate_int(cfg.fmt_p, prod, dcl))
            continue
        fmt_p = cfg.fmt_p
        res = M.fp_add(prod.sign, prod.mag, prod.exp, dcl.sign, dcl.mag, dcl.exp)
        bits, overflow = M._round_encode_float(fmt_p, res.sign, res.mag, res.exp)
        nan_o = prod.nan | dcl.nan | (prod.inf & dcl.inf & (prod.sign != dcl.sign))
        inf_o = (prod.inf | dcl.inf) & ~nan_o
        inf_sign = np.where(prod.inf, prod.sign, dcl.sign)
        inf_sign = np.where(inf_o, inf_sign, res.sign)
        outs.append(M.select_output(fmt_p, bits, overflow, nan_o, inf_o, inf_sign))
    return np.stack(outs, axis=-1)


def per_lane_reference(cfg: M.MacConfig, plan: LanePlan, a_bits, b_bits, c_bits):
    """Unpacked per-lane MACs — what the packed path must reproduce exactly."""
    outs = []
    for lane, (i, j, _) in enumerate(plan.lane_positions):
        outs.append(M.xtramac(cfg, a_bits[..., i], b_bits[..., j], c_bits[..., lane]))
    return np.stack(outs, axis=-1)


# ---------------------------------------------------------------------------
# DSP-utilization comparison model (Fig. 3 / Fig. 4 / Fig. 9)
# ---------------------------------------------------------------------------
def utilization_xtramac(fmt_a, fmt_b) -> float:
    return solve_lane_plan(fmt_a, fmt_b, max_parallelism=4).dsp_utilization


def utilization_upcast(fmt_a, fmt_b, upcast_to: str = "bf16") -> float:
    """Vendor-IP style: operands promoted to one high-precision FP datapath
    (paper Fig. 2a/Fig. 3).  The datapath occupies the whole DSP multiplier;
    the useful payload is the SOURCE operands' effective magnitude bits:

        U = (w_a_src + w_b_src) / W_mul

    FP32 targets (24-bit mantissa) consume 2 DSPs (24x17 + 24x7 partials).
    """
    up = get_format(upcast_to)
    w_eff = magnitude_bits(get_format(fmt_a) if isinstance(fmt_a, str) else fmt_a) + \
        magnitude_bits(get_format(fmt_b) if isinstance(fmt_b, str) else fmt_b)
    n_dsp = 2 if up.magnitude_bits > DSP48E2_LB else 1
    return w_eff / (DSP48E2_WMUL * n_dsp)


def utilization_spatial(fmt_pairs) -> float:
    """Spatial replication: one active datapath, the rest idle (Fig. 2b)."""
    utils = [utilization_upcast(a, b, "fp32") for a, b in fmt_pairs]
    return float(np.mean(utils)) / len(fmt_pairs) * 1.0 if not utils else float(
        np.mean([u / len(fmt_pairs) for u in utils])
    )


def utilization_temporal_bf16_over_int8() -> float:
    """TATAA-style: BF16 decomposed into 4 INT8 micro-ops over 4 PEs/cycles."""
    int8_util = (8 + 8) / DSP48E2_WMUL  # one INT8xINT8 per DSP
    return int8_util / 4.0
