"""FPGA resource + frequency model for XtraMAC and the paper's baselines.

Two layers:
  1. **Measured tables** — the paper's post-synthesis numbers (Tables III,
     IV, V; Figs. 8, 10, 12) encoded verbatim.  These drive the
     paper-reproduction benchmarks and the analytical end-to-end simulator
     (perfmodel/), so every downstream number is traceable to the paper.
  2. **Parametric model** — Eqs. (7)/(8): integer adders cost alpha*w
     (carry chain), FP align/normalize shifters cost beta*w*log2(w)
     (barrel shifter), plus mapping/post-compute terms.  Coefficients are
     calibrated against the measured tables by least squares at import
     time; the model extrapolates to datatype combinations the paper did
     not synthesize, with the calibration quality reported by benchmarks.

Units: LUTs / FFs / DSP slices on an AMD UltraScale+ device (U55c / V80).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .formats import FloatFormat, Format, IntFormat, get_format
from .mac import MacConfig
from .packing import solve_lane_plan


@dataclasses.dataclass(frozen=True)
class Resources:
    lut: float
    ff: float
    dsp: float

    def __add__(self, o: "Resources") -> "Resources":
        return Resources(self.lut + o.lut, self.ff + o.ff, self.dsp + o.dsp)

    def scale(self, k: float) -> "Resources":
        return Resources(self.lut * k, self.ff * k, self.dsp * k)


# ---------------------------------------------------------------------------
# Measured tables (verbatim from the paper)
# ---------------------------------------------------------------------------
# Table III: runtime-switching XtraMAC instances (core datapath).
TABLE_III: Dict[str, Resources] = {
    "I:int4xbf16+bf16": Resources(436, 302, 1),    # Qwen3-8B-AWQ
    "II:int8xint8+int32|bf16": Resources(568, 513, 1),  # Llama-3.1-8B-W8A8
    "III:fp8xfp8+bf16|bf16": Resources(948, 622, 1),    # Qwen3/Llama FP8
    "IV:fp4xbf16+bf16|bf16": Resources(395, 274, 1),    # GPT-oss-20B
}

# Table IV: per-lane resource utilization, single-config instances with AXI
# wrapper.  key = (fmt_a, fmt_bcp);  value = (vendor IP, XtraMAC per lane).
TABLE_IV: Dict[Tuple[str, str], Tuple[Resources, Resources]] = {
    ("int8", "bf16"): (Resources(331, 222, 1), Resources(235, 124, 0.5)),
    ("int8", "fp16"): (Resources(387, 262, 1), Resources(270, 137, 0.5)),
    ("fp4_e2m1", "bf16"): (Resources(301, 226, 1), Resources(196, 115, 0.5)),
    ("fp4_e2m1", "fp16"): (Resources(357, 266, 1), Resources(251, 131, 0.5)),
    ("fp8_e4m3", "bf16"): (Resources(301, 226, 1), Resources(219, 123, 0.5)),
    ("fp8_e4m3", "fp16"): (Resources(357, 266, 1), Resources(253, 133, 0.5)),
}

# Table V: per-operation resources under INT8<->BF16 runtime switching.
TABLE_V: Dict[str, Dict[str, Resources]] = {
    "vendor": {"bf16": Resources(220.0, 310.5, 1), "int8": Resources(110.0, 155.3, 0.5)},
    "tataa": {"bf16": Resources(352.0, 467.0, 4), "int8": Resources(22.0, 29.2, 0.25)},
    "xtramac": {"bf16": Resources(142.0, 128.3, 0.25), "int8": Resources(142.0, 128.3, 0.25)},
}

# Paper-claimed average reductions vs vendor IP (Section V-E1).
PAPER_MEAN_REDUCTION = {"lut": 0.300, "ff": 0.479, "dsp": 0.500}

# Fig. 8: fmax (MHz) as datatype support is scaled up, single DSP instance.
FMAX_SCALING_MHZ: Dict[int, float] = {1: 483.0, 2: 476.0, 3: 469.0, 4: 462.0}
FMAX_VENDOR_RATIO = 0.78          # Fig. 10: XtraMAC ~22% slower on average
FMAX_FLOOR_MHZ = 400.0            # all configurations exceed 400 MHz

# Fig. 12: GEMV system frequency vs #XtraMAC instances (post-P&R).
def system_fmax_mhz(n_instances: int) -> float:
    if n_instances <= 1024:
        return 300.0
    # moderate degradation toward 1920 instances (routing congestion)
    frac = min(1.0, (n_instances - 1024) / (1920 - 1024))
    return 300.0 - frac * (300.0 - 260.0)


def fmax_mhz(n_datatypes: int) -> float:
    n = max(1, min(4, n_datatypes))
    return FMAX_SCALING_MHZ[n]


# ---------------------------------------------------------------------------
# Parametric model — Eqs. (7) and (8)
# ---------------------------------------------------------------------------
def int_adder_cost(w_int: int, alpha: float) -> float:
    """Eq. (7): C_int ~= alpha * w (ripple-carry chain)."""
    return alpha * w_int


def barrel_shifter_muxes(w_fp: int) -> float:
    """N_MUX = w * log2(w) (Pillmeier et al.)."""
    w = max(2, w_fp)
    return w * math.log2(w)


def fp_shifter_cost(w_fp: int, beta: float) -> float:
    """Eq. (8): C_shifter ~= beta * w * log2(w)."""
    return beta * barrel_shifter_muxes(w_fp)


@dataclasses.dataclass
class _InstanceStructure:
    """Structural decomposition of an XtraMAC instance for the model."""
    map_fp_bits: float      # format bits decoded by FP mapping submodules
    map_int_bits: float     # format bits decoded by INT mapping submodules
    post_fp_muxes: float    # LZC + normalize shifter muxes, all FP lanes
    adder_fp_muxes: float   # align+normalize shifter muxes, FP adder lanes
    adder_int_bits: float   # integer adder bits
    n_dtypes: int


def _mapping_shared(c1: MacConfig, c2: MacConfig, p1: int, p2: int) -> bool:
    """Config-IV rule: A-formats embeddable (zero-pad, no rounding) + same P."""
    f1, f2 = c1.fmt_a, c2.fmt_a
    if not (isinstance(f1, FloatFormat) and isinstance(f2, FloatFormat)):
        return False
    lo, hi = (f1, f2) if f1.bits <= f2.bits else (f2, f1)
    embeddable = (lo.man_bits <= hi.man_bits
                  and lo.max_unbiased_exp <= hi.max_unbiased_exp
                  and lo.min_unbiased_exp >= hi.min_unbiased_exp)
    return embeddable and p1 == p2 and c1.fmt_b.name == c2.fmt_b.name


def analyze_instance(configs: Sequence[MacConfig], max_parallelism: int = 4) -> _InstanceStructure:
    plans = [solve_lane_plan(c.fmt_a, c.fmt_b, max_parallelism=max_parallelism)
             for c in configs]
    # mapping: per config unless shared under the Config-IV rule
    map_fp_bits = map_int_bits = 0.0
    counted = [False] * len(configs)
    for i, (c, p) in enumerate(zip(configs, plans)):
        if counted[i]:
            continue
        for j in range(i + 1, len(configs)):
            if not counted[j] and _mapping_shared(c, configs[j], p.parallelism,
                                                  plans[j].parallelism):
                counted[j] = True  # folded into this mapping submodule
        bits = (c.fmt_a.bits * len(p.offsets_a) + c.fmt_b.bits * len(p.offsets_b))
        if isinstance(c.fmt_a, IntFormat) or isinstance(c.fmt_b, IntFormat):
            map_int_bits += bits
        else:
            map_fp_bits += bits
        counted[i] = True

    # post-compute: LZC + normalization shifter per FP lane (product width)
    post = 0.0
    for c, p in zip(configs, plans):
        if not c.is_int_accumulate:
            post += p.parallelism * barrel_shifter_muxes(p.w_lane)
    # decoupled accumulators, shared across configs with identical output fmt:
    # lane count = max over sharing configs (Config-III rule)
    fp_muxes = 0.0
    int_bits = 0.0
    fp_groups: Dict[str, int] = {}
    for c, p in zip(configs, plans):
        if c.is_int_accumulate:
            int_bits = max(int_bits, 0) + 0  # accumulate below
        else:
            key = c.fmt_p.name
            fp_groups[key] = max(fp_groups.get(key, 0), p.parallelism)
    for c, p in zip(configs, plans):
        if c.is_int_accumulate:
            int_bits += c.fmt_p.bits * p.parallelism
    for fmt_name, lanes in fp_groups.items():
        fmt = get_format(fmt_name)
        # align + normalize shifters over the extended mantissa width
        fp_muxes += lanes * 2 * barrel_shifter_muxes(fmt.man_bits + 4)
    return _InstanceStructure(map_fp_bits, map_int_bits, post, fp_muxes,
                              int_bits, len(configs))


def _nnls(A: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Non-negative least squares via backward elimination (cost terms are
    physical resource counts — negative coefficients are meaningless and,
    with only 4 calibration rows, plain lstsq is underdetermined)."""
    active = list(range(A.shape[1]))
    coef = np.zeros(A.shape[1])
    while active:
        c, *_ = np.linalg.lstsq(A[:, active], y, rcond=None)
        if (c >= -1e-9).all():
            coef[:] = 0.0
            for idx, v in zip(active, c):
                coef[idx] = max(v, 0.0)
            return coef
        active.pop(int(np.argmin(c)))
    return coef


# least-squares calibration of [c_map_fp, c_map_int, c_post, beta, alpha, c0]
def _calibrate() -> Tuple[np.ndarray, np.ndarray, float]:
    rows: List[List[float]] = []
    lut_t: List[float] = []
    ff_t: List[float] = []
    cases: List[List[MacConfig]] = [
        [MacConfig.make("int4", "bf16", "bf16", "bf16"),
         MacConfig.make("bf16", "bf16", "bf16", "bf16")],
        [MacConfig.make("int8", "int8", "int32", "int32"),
         MacConfig.make("bf16", "bf16", "bf16", "bf16")],
        [MacConfig.make("fp8_e4m3", "fp8_e4m3", "bf16", "bf16"),
         MacConfig.make("bf16", "bf16", "bf16", "bf16")],
        [MacConfig.make("fp4_e2m1", "bf16", "bf16", "bf16"),
         MacConfig.make("bf16", "bf16", "bf16", "bf16")],
    ]
    targets = list(TABLE_III.values())
    for cfgs, res in zip(cases, targets):
        s = analyze_instance(cfgs)
        rows.append([s.map_fp_bits, s.map_int_bits, s.post_fp_muxes,
                     s.adder_fp_muxes, s.adder_int_bits, 1.0])
        lut_t.append(res.lut)
        ff_t.append(res.ff)
    A = np.asarray(rows)
    lut_coef = _nnls(A, np.asarray(lut_t))
    ff_coef = _nnls(A, np.asarray(ff_t))
    pred = A @ lut_coef
    denom = float(np.sum((np.asarray(lut_t) - np.mean(lut_t)) ** 2))
    r2 = 1.0 - float(np.sum((pred - lut_t) ** 2)) / denom if denom else 1.0
    return lut_coef, ff_coef, r2


_LUT_COEF, _FF_COEF, CALIBRATION_R2 = _calibrate()


def estimate_instance(configs: Sequence[MacConfig], max_parallelism: int = 4) -> Resources:
    """Parametric LUT/FF/DSP estimate for an arbitrary XtraMAC instance."""
    s = analyze_instance(configs, max_parallelism)
    x = np.asarray([s.map_fp_bits, s.map_int_bits, s.post_fp_muxes,
                    s.adder_fp_muxes, s.adder_int_bits, 1.0])
    return Resources(float(x @ _LUT_COEF), float(x @ _FF_COEF), 1.0)


def xtramac_per_lane(fmt_a: str, fmt_bcp: str) -> Resources:
    """Per-lane XtraMAC cost: measured (Table IV) if available, else model."""
    key = ("int8" if fmt_a.startswith("int") else fmt_a, fmt_bcp)
    if key in TABLE_IV:
        return TABLE_IV[key][1]
    cfg = MacConfig.make(fmt_a, fmt_bcp, fmt_bcp, fmt_bcp)
    plan = solve_lane_plan(cfg.fmt_a, cfg.fmt_b, max_parallelism=4)
    est = estimate_instance([cfg])
    return est.scale(1.0 / plan.parallelism)


def vendor_per_lane(fmt_a: str, fmt_bcp: str) -> Resources:
    key = ("int8" if fmt_a.startswith("int") else fmt_a, fmt_bcp)
    if key in TABLE_IV:
        return TABLE_IV[key][0]
    # vendor IP: fixed high-precision datapath, one lane per instance
    return Resources(331, 222, 1) if fmt_bcp == "bf16" else Resources(387, 262, 1)


def compute_density(fmt_a: str, fmt_bcp: str) -> Dict[str, float]:
    """Table IV 'Comp.Den.' column: vendor / XtraMAC per-op resources."""
    v, x = vendor_per_lane(fmt_a, fmt_bcp), xtramac_per_lane(fmt_a, fmt_bcp)
    return {"lut": v.lut / x.lut, "ff": v.ff / x.ff, "dsp": v.dsp / x.dsp}
