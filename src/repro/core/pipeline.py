"""Cycle-level emulator of the XtraMAC four-stage pipeline (Section IV).

Models the architecture of Fig. 5:
  * N datatype configurations chosen at synthesis time; all mapping /
    reconstruction submodules instantiated statically.
  * A datatype-select signal registered at entry and carried through
    matched delay slices (it is consumed at Stage 1 AND Stage 4).
  * Operand C delayed to meet the Stage-2 products at Stage 3.
  * Fixed logical depth of 4 stages; per-stage extra registers can be
    configured at "synthesis" time (`stage_cycles`), trading latency for
    fmax while the initiation interval stays 1.

The emulator issues ONE operation per cycle (II = 1) and returns the result
exactly ``latency`` cycles later, independent of per-cycle datatype
switching — the paper's headline pipeline property, asserted by tests.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import mac as M
from .packing import LanePlan, packed_multiply, solve_lane_plan


@dataclasses.dataclass
class Op:
    """One pipeline issue: per-lane raw bit patterns + the datatype select."""
    dtype_sel: int
    a_bits: np.ndarray  # [n_a]
    b_bits: np.ndarray  # [n_b]
    c_bits: np.ndarray  # [P]


class XtraMACPipeline:
    """Four-stage pipelined XtraMAC instance supporting N datatypes."""

    def __init__(self, configs: Sequence[M.MacConfig],
                 stage_cycles: Tuple[int, int, int, int] = (1, 1, 1, 1),
                 max_parallelism: int = 4):
        assert len(stage_cycles) == 4 and all(c >= 1 for c in stage_cycles)
        self.configs = list(configs)
        self.stage_cycles = stage_cycles
        self.plans: List[LanePlan] = [
            solve_lane_plan(c.fmt_a, c.fmt_b, max_parallelism=max_parallelism)
            for c in self.configs
        ]
        # P of the instance = max parallelism across supported datatypes (IV-A)
        self.parallelism = max(p.parallelism for p in self.plans)
        self.latency = sum(stage_cycles)
        # matched delay slices: one register queue per stage boundary
        self._queue: List[Optional[tuple]] = [None] * self.latency
        self.cycle = 0

    # -- combinational stage functions (evaluated when the op ENTERS a stage) --
    def _stage1_map(self, op: Op):
        cfg, plan = self.configs[op.dtype_sel], self.plans[op.dtype_sel]
        da = M.map_operand(cfg.fmt_a, np.asarray(op.a_bits, np.int64))
        db = M.map_operand(cfg.fmt_b, np.asarray(op.b_bits, np.int64))
        return (op.dtype_sel, da, db, np.asarray(op.c_bits, np.int64))

    def _stage2_multiply_post(self, state):
        sel, da, db, c_bits = state
        cfg, plan = self.configs[sel], self.plans[sel]
        prods = packed_multiply(plan, da.mag, db.mag)  # single DSP multiply
        lanes = []
        for lane, (i, j, _) in enumerate(plan.lane_positions):
            sign = da.sign[i] ^ db.sign[j]
            exp = da.exp[i] + db.exp[j]
            nan = da.nan[i] | db.nan[j]
            nan = nan | (da.inf[i] & (db.mag[j] == 0) & ~db.inf[j] & ~db.nan[j]) \
                      | (db.inf[j] & (da.mag[i] == 0) & ~da.inf[i] & ~da.nan[i])
            inf = (da.inf[i] | db.inf[j]) & ~nan
            lanes.append(M.Product(sign, prods[lane], exp, nan, inf))
        return (sel, lanes, c_bits)

    def _stage3_accumulate(self, state):
        sel, lanes, c_bits = state
        cfg = self.configs[sel]
        dc = M.map_operand(cfg.fmt_c, c_bits)
        outs = []
        for lane, prod in enumerate(lanes):
            dcl = M.Decoded(dc.sign[lane], dc.mag[lane], dc.exp[lane],
                            dc.nan[lane], dc.inf[lane])
            if cfg.is_int_accumulate:
                outs.append(("int", M.accumulate_int(cfg.fmt_p, prod, dcl), None))
            else:
                res = M.fp_add(prod.sign, prod.mag, prod.exp, dcl.sign, dcl.mag, dcl.exp)
                bits, ovf = M._round_encode_float(cfg.fmt_p, res.sign, res.mag, res.exp)
                nan_o = prod.nan | dcl.nan | (prod.inf & dcl.inf & (prod.sign != dcl.sign))
                inf_o = (prod.inf | dcl.inf) & ~nan_o
                inf_sign = np.where(prod.inf, prod.sign, dcl.sign)
                inf_sign = np.where(inf_o, inf_sign, res.sign)
                outs.append(("fp", bits, (ovf, nan_o, inf_o, inf_sign)))
        return (sel, outs)

    def _stage4_select(self, state):
        sel, outs = state
        cfg = self.configs[sel]
        final = []
        for kind, bits, flags in outs:
            if kind == "int":
                final.append(int(bits))
            else:
                ovf, nan_o, inf_o, inf_sign = flags
                final.append(int(M.select_output(cfg.fmt_p, bits, ovf, nan_o, inf_o, inf_sign)))
        return np.array(final, dtype=np.int64)

    # -- temporal sequencing ------------------------------------------------
    def step(self, op: Optional[Op]) -> Optional[np.ndarray]:
        """Advance one clock cycle. Issues ``op`` (or a bubble if None) and
        returns the result of the op issued ``latency`` cycles ago."""
        # Evaluate the whole datapath when the op enters (combinational blocks
        # are pure functions of the registered operands; matched delays mean
        # the 4-stage sequencing only changes WHEN results appear, not WHAT
        # they are).  The queue models the register slices.
        result = self._queue.pop(0)
        if op is not None:
            s1 = self._stage1_map(op)
            s2 = self._stage2_multiply_post(s1)
            s3 = self._stage3_accumulate(s2)
            out = self._stage4_select(s3)
        else:
            out = None
        self._queue.append(out)
        self.cycle += 1
        return result

    def run(self, ops: Sequence[Op]) -> List[np.ndarray]:
        """Issue one op per cycle (II=1); drain; return results in order."""
        results = []
        for op in ops:
            r = self.step(op)
            if r is not None:
                results.append(r)
        for _ in range(self.latency):
            r = self.step(None)
            if r is not None:
                results.append(r)
        assert len(results) == len(ops)
        return results
