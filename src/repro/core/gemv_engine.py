"""Tile-based GEMV engine model (paper Section VI-A / VI-C, Figs. 11-13).

Models the XtraMAC-based GEMV accelerator on the U55c:
  * M tiles, one per HBM channel; weights stream from HBM, activations are
    buffered on chip; each channel feeds a chain of cascaded XtraMAC
    instances:   N_MAC = channel_bits / (w_bits * P)          (Section VI-C)
  * latency = max(memory phase, compute phase) under the streaming model —
    the kernel is bandwidth-bound at scale (the paper measures ~74%
    effective HBM utilization).

`table_vii()` reproduces the paper's Table VII FPGA rows from first
principles (bytes / effective bandwidth); the H100 rows are the paper's
measurements (a GPU measurement cannot be derived from this model) and are
carried as constants for the speedup / energy-efficiency ratios.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from .resource_model import Resources, system_fmax_mhz


@dataclasses.dataclass(frozen=True)
class GemvEngineConfig:
    n_channels: int = 30              # 30 active of 32 (1 act read, 1 writeback)
    channel_bits: int = 512
    hbm_bw_gbps: float = 460.0        # U55c peak
    hbm_utilization: float = 0.74     # paper-measured effective utilization
    weight_bits: int = 4              # INT4 / FP4 weights
    parallelism: int = 2              # P lanes per XtraMAC
    power_w: float = 85.0             # xbutil steady-state (paper)

    @property
    def n_mac_per_channel(self) -> int:
        return self.channel_bits // (self.weight_bits * self.parallelism)

    @property
    def n_instances(self) -> int:
        return self.n_channels * self.n_mac_per_channel

    @property
    def freq_hz(self) -> float:
        return system_fmax_mhz(self.n_instances) * 1e6

    @property
    def macs_per_cycle(self) -> int:
        return self.n_instances * self.parallelism


def gemv_latency_s(cfg: GemvEngineConfig, m: int, k: int, n: int) -> Dict[str, float]:
    """Latency of an m x k x n GEMV/GEMM-like workload (m = batch rows).

    Weight matrix is k x n in ``weight_bits`` precision, streamed once;
    activations (m x k, BF16) are on-chip.  Returns the phase breakdown.
    """
    weight_bytes = k * n * cfg.weight_bits / 8.0
    t_mem = weight_bytes / (cfg.hbm_bw_gbps * 1e9 * cfg.hbm_utilization)
    macs = m * k * n
    t_compute = macs / (cfg.macs_per_cycle * cfg.freq_hz)
    t = max(t_mem, t_compute)
    return {
        "time_s": t,
        "t_mem_s": t_mem,
        "t_compute_s": t_compute,
        "bound": "memory" if t_mem >= t_compute else "compute",
        "energy_j": t * cfg.power_w,
        "weight_bytes": weight_bytes,
    }


# Paper Table VII: H100 CUTLASS measurements (constants; not modelable here).
H100_MEASURED = {
    (1, 4096, 4096): {"time_s": 0.0294e-3, "power_w": 135.0},
    (1, 4096, 12288): {"time_s": 0.0879e-3, "power_w": 135.0},
}
PAPER_FPGA_MEASURED = {
    (1, 4096, 4096): 0.0246e-3,
    (1, 4096, 12288): 0.0743e-3,
}


def table_vii(cfg: GemvEngineConfig = GemvEngineConfig()) -> Dict:
    """Reproduce Table VII: model-predicted FPGA latency vs H100 baseline."""
    rows = {}
    for shape, h100 in H100_MEASURED.items():
        ours = gemv_latency_s(cfg, *shape)
        h100_e = h100["time_s"] * h100["power_w"]
        rows[shape] = {
            "xtramac_time_s": ours["time_s"],
            "xtramac_paper_time_s": PAPER_FPGA_MEASURED[shape],
            "model_vs_paper": ours["time_s"] / PAPER_FPGA_MEASURED[shape],
            "h100_time_s": h100["time_s"],
            "speedup": h100["time_s"] / ours["time_s"],
            "energy_eff": h100_e / ours["energy_j"],
            "bound": ours["bound"],
        }
    return rows


def resource_scaling(per_instance: Resources, n_instances: int) -> Resources:
    """Fig. 12: LUT/FF/DSP scale linearly with instantiated XtraMACs."""
    return per_instance.scale(n_instances)
