"""XtraMAC core — the paper's contribution as a composable library.

Layers:
  formats         datatype registry + bit codecs (INT2-8, FP4/FP8/FP16/BF16)
  mac             unified mantissa-product MAC datapath (bit-exact, 4 stages)
  ref_mac         exact unbounded-integer oracle
  packing         DSP bit-space lane packing (Eqs. 9-12) + stride solver
  pipeline        cycle-level 4-stage pipeline emulator (II=1, runtime switch)
  resource_model  LUT/FF/DSP + fmax model (Eqs. 7-8, paper tables)
  gemv_engine     tile-based GEMV engine model (Section VI)
"""
from .formats import REGISTRY, get_format, quantize_f64  # noqa: F401
from .mac import MacConfig, xtramac, xtramac_switching  # noqa: F401
from .packing import (  # noqa: F401
    PAPER_PARALLELISM, LanePlan, packed_multiply, solve_lane_plan, xtramac_packed,
)
from .pipeline import Op, XtraMACPipeline  # noqa: F401
from .ref_mac import mac_exact, mac_exact_vec  # noqa: F401
