"""Vectorized XtraMAC datapath — the paper's four-stage pipeline as array ops.

This mirrors the microarchitecture of Fig. 5 stage by stage:

  Stage 1  ``map_operand``       operand interpretation -> (s, m, e) + flags
  Stage 2  ``multiply``          datatype-invariant integer mantissa product
                                  (the virtual DSP), sign XOR, exponent add
  Stage 3  ``accumulate_float`` / ``accumulate_int``
                                  decoupled FP / INT accumulation paths
  Stage 4  ``select_output``     flag-based combinational output selection

All arithmetic is exact int64 (mantissa products are <= 24 bits; the FP
adder aligns into a 50-bit window, so guard/round/sticky analysis below
guarantees correct RN-even).  Bit-exactness against the unbounded-integer
oracle in ``ref_mac.py`` is asserted by tests/test_mac_bitexact.py.

Why numpy and not jnp: this module is the *bit-exact emulation* of the
hardware (a validation artifact + the numerics spec for quant/).  The hot
TPU path lives in kernels/ (packed GEMV / packed matmul), which use the
scaled-integer dequant formulation of the same arithmetic; their oracles
trace back to this module.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from .formats import Format, FloatFormat, IntFormat, get_format

_ALIGN_BITS = 50  # FP adder alignment window (int64-safe; >=25 guard bits)


@dataclasses.dataclass(frozen=True)
class MacConfig:
    """One supported datatype combination ``A x B + C -> P`` (a Fig. 6 row)."""

    fmt_a: Format
    fmt_b: Format
    fmt_c: Format
    fmt_p: Format

    @staticmethod
    def make(a: str, b: str, c: str, p: str) -> "MacConfig":
        return MacConfig(get_format(a), get_format(b), get_format(c), get_format(p))

    @property
    def name(self) -> str:
        return f"{self.fmt_a.name}x{self.fmt_b.name}+{self.fmt_c.name}->{self.fmt_p.name}"

    @property
    def is_int_accumulate(self) -> bool:
        return isinstance(self.fmt_p, IntFormat)


class Decoded(NamedTuple):
    """Stage-1 output: sign/magnitude/exponent + special-value flags.

    value = (-1)^sign * mag * 2^exp   (mag==0 encodes zero; DAZ applied)
    """

    sign: np.ndarray
    mag: np.ndarray
    exp: np.ndarray
    nan: np.ndarray
    inf: np.ndarray


def _bitlen(x: np.ndarray) -> np.ndarray:
    """Bit length of non-negative int64 values (exact for x < 2^52)."""
    _, e = np.frexp(x.astype(np.float64))
    return e.astype(np.int64)


# ---------------------------------------------------------------------------
# Stage 1: operand interpretation & bit-mapping
# ---------------------------------------------------------------------------
def map_operand(fmt: Format, bits: np.ndarray) -> Decoded:
    bits = np.asarray(bits, dtype=np.int64) & ((1 << fmt.bits) - 1)
    if isinstance(fmt, IntFormat):
        sign_bit = np.int64(1) << (fmt.bits - 1)
        signed = np.where(bits >= sign_bit, bits - (np.int64(1) << fmt.bits), bits)
        sign = (signed < 0).astype(np.int64)
        mag = np.abs(signed)
        z = np.zeros_like(bits, dtype=bool)
        return Decoded(sign, mag, np.zeros_like(bits), z, z)

    assert isinstance(fmt, FloatFormat)
    sign = (bits >> (fmt.exp_bits + fmt.man_bits)) & 1
    e_field = (bits >> fmt.man_bits) & fmt.exp_max_field
    m_field = bits & ((1 << fmt.man_bits) - 1)
    if fmt.special_rule == "ieee":
        nan = (e_field == fmt.exp_max_field) & (m_field != 0)
        inf = (e_field == fmt.exp_max_field) & (m_field == 0)
    elif fmt.special_rule == "e4m3":
        nan = (e_field == fmt.exp_max_field) & (m_field == (1 << fmt.man_bits) - 1)
        inf = np.zeros_like(nan)
    else:
        nan = np.zeros(bits.shape, dtype=bool)
        inf = np.zeros_like(nan)
    zero = e_field == 0  # DAZ
    mag = np.where(zero | nan | inf, 0, m_field | (np.int64(1) << fmt.man_bits))
    exp = np.where(zero | nan | inf, 0, e_field - fmt.bias - fmt.man_bits)
    return Decoded(sign.astype(np.int64), mag, exp, nan, inf)


# ---------------------------------------------------------------------------
# Stage 2: datatype-invariant multiply (integer mantissa product) + metadata
# ---------------------------------------------------------------------------
class Product(NamedTuple):
    sign: np.ndarray
    mag: np.ndarray   # exact integer mantissa product
    exp: np.ndarray
    nan: np.ndarray   # NaN in, or inf * 0
    inf: np.ndarray


def multiply(da: Decoded, db: Decoded) -> Product:
    sign = da.sign ^ db.sign
    mag = da.mag * db.mag                     # <- the shared DSP multiply
    exp = da.exp + db.exp
    nan = da.nan | db.nan
    inf_times_zero = (da.inf & (db.mag == 0) & ~db.inf & ~db.nan) | (
        db.inf & (da.mag == 0) & ~da.inf & ~da.nan
    )
    nan = nan | inf_times_zero
    inf = (da.inf | db.inf) & ~nan
    return Product(sign, mag, exp, nan, inf)


# ---------------------------------------------------------------------------
# Stage 3a: floating-point accumulation (alignment + add + LZC normalize)
# ---------------------------------------------------------------------------
def _align(mag: np.ndarray, exp: np.ndarray, e_target: np.ndarray) -> np.ndarray:
    """Shift (mag, exp) to exponent ``e_target``; sticky folds into the LSB.

    Left shifts are exact by construction (result < 2^ALIGN_BITS).  Lossy
    right shifts only happen when the operand is >2^(ALIGN_BITS-24) below
    the top — cancellation is then impossible, so LSB-sticky + >=25 guard
    bits make RN-even exact (validated exhaustively in tests).
    """
    sh = exp - e_target
    shl = np.clip(sh, 0, 63)
    shr = np.clip(-sh, 0, 63)
    left = mag << shl
    kept = mag >> shr
    sticky = (kept << shr) != mag
    right = kept | sticky.astype(np.int64)
    return np.where(sh >= 0, left, right)


class FpResult(NamedTuple):
    sign: np.ndarray
    mag: np.ndarray
    exp: np.ndarray


def fp_add(s1, m1, e1, s2, m2, e2) -> FpResult:
    """Exact-enough FP add of two (sign, mag, exp) values (magnitudes > 0 ok)."""
    neg_inf = np.int64(-(10**9))
    top1 = np.where(m1 > 0, e1 + _bitlen(m1), neg_inf)
    top2 = np.where(m2 > 0, e2 + _bitlen(m2), neg_inf)
    e_t = np.maximum(top1, top2) - _ALIGN_BITS
    a = _align(m1, e1, e_t)
    b = _align(m2, e2, e_t)
    v = np.where(s1 == 1, -a, a) + np.where(s2 == 1, -b, b)
    sign = (v < 0).astype(np.int64)
    return FpResult(sign, np.abs(v), e_t)


def _round_encode_float(fmt: FloatFormat, sign, mag, exp):
    """RN-even round of value=(-1)^s*mag*2^exp into fmt; FTZ + saturation.

    Returns (bits, overflow_mask) — overflow resolved by stage 4.
    """
    n = _bitlen(mag)
    man1 = fmt.man_bits + 1
    shift = n - man1
    shr = np.clip(shift, 0, 63)
    shl = np.clip(-shift, 0, 63)
    kept = np.where(shift > 0, mag >> shr, mag << shl)
    mask = (np.int64(1) << shr) - 1
    rem = np.where(shift > 0, mag & mask, 0)
    half = np.where(shift > 0, np.int64(1) << np.maximum(shr - 1, 0), np.int64(1))
    up = (rem > half) | ((rem == half) & (rem > 0) & ((kept & 1) == 1))
    kept = kept + up.astype(np.int64)
    carry = kept == (np.int64(1) << man1)
    kept = np.where(carry, kept >> 1, kept)
    e_val = exp + n - 1 + carry.astype(np.int64)

    zero = mag == 0
    underflow = (e_val < fmt.min_unbiased_exp) & ~zero
    overflow = (e_val > fmt.max_unbiased_exp) & ~zero
    if fmt.special_rule == "e4m3":
        overflow = overflow | (
            (e_val == fmt.max_unbiased_exp) & (kept == (1 << man1) - 1)
        )

    e_enc = np.clip(e_val, fmt.min_unbiased_exp, fmt.max_unbiased_exp)
    bits = fmt.encode(sign, e_enc, kept)
    # +0 for exact-zero results; signed zero kept only via FTZ underflow
    bits = np.where(zero, 0, bits)
    bits = np.where(underflow, sign << (fmt.bits - 1), bits)
    return bits, overflow


# ---------------------------------------------------------------------------
# Stage 3b: integer accumulation (carry-chain path; saturating)
# ---------------------------------------------------------------------------
def accumulate_int(fmt_p: IntFormat, prod: Product, dc: Decoded) -> np.ndarray:
    sp = np.where(prod.sign == 1, -prod.mag, prod.mag)
    sc = np.where(dc.sign == 1, -dc.mag, dc.mag)
    acc = sp + sc  # exact in int64 for all supported widths
    acc = np.clip(acc, fmt_p.min_value, fmt_p.max_value)
    return acc & ((np.int64(1) << fmt_p.bits) - 1)


# ---------------------------------------------------------------------------
# Stage 4: flag-driven output selection (purely combinational in hardware)
# ---------------------------------------------------------------------------
def select_output(fmt_p: FloatFormat, bits, overflow, nan, inf, inf_sign):
    if fmt_p.special_rule == "ieee" and fmt_p.has_inf:
        pos_inf, neg_inf_b = fmt_p.inf_bits(0), fmt_p.inf_bits(1)
        bits = np.where(overflow | inf, np.where(inf_sign == 1, neg_inf_b, pos_inf), bits)
        # `overflow` uses the result sign, folded into inf_sign by the caller
        bits = np.where(nan, fmt_p.qnan_bits, bits)
    elif fmt_p.special_rule == "e4m3":
        bits = np.where(overflow | inf | nan, fmt_p.qnan_bits, bits)
    else:
        maxf = np.where(inf_sign == 1, fmt_p.max_finite_bits(1), fmt_p.max_finite_bits(0))
        bits = np.where(overflow | inf, maxf, bits)
        bits = np.where(nan, 0, bits)
    return bits


# ---------------------------------------------------------------------------
# Full MAC: P = A*B + C
# ---------------------------------------------------------------------------
def xtramac(cfg: MacConfig, a_bits, b_bits, c_bits) -> np.ndarray:
    """Vectorized XtraMAC MAC over arrays of raw bit patterns."""
    a_bits, b_bits, c_bits = np.broadcast_arrays(
        np.asarray(a_bits, np.int64), np.asarray(b_bits, np.int64), np.asarray(c_bits, np.int64)
    )
    da = map_operand(cfg.fmt_a, a_bits)           # Stage 1
    db = map_operand(cfg.fmt_b, b_bits)
    dc = map_operand(cfg.fmt_c, c_bits)
    prod = multiply(da, db)                        # Stage 2

    if cfg.is_int_accumulate:
        return accumulate_int(cfg.fmt_p, prod, dc)  # Stage 3b (+4 trivial)

    fmt_p = cfg.fmt_p
    assert isinstance(fmt_p, FloatFormat)
    res = fp_add(prod.sign, prod.mag, prod.exp, dc.sign, dc.mag, dc.exp)  # Stage 3a
    bits, overflow = _round_encode_float(fmt_p, res.sign, res.mag, res.exp)

    # special-value resolution (Stage 4)
    nan = prod.nan | dc.nan | (prod.inf & dc.inf & (prod.sign != dc.sign))
    inf = (prod.inf | dc.inf) & ~nan
    inf_sign = np.where(prod.inf, prod.sign, dc.sign)
    # saturation keeps the sign of the (finite) overflowed result
    inf_sign = np.where(inf, inf_sign, res.sign)
    return select_output(fmt_p, bits, overflow, nan, inf, inf_sign)


# ---------------------------------------------------------------------------
# Runtime datatype switching: N static submodules + per-element mux (Fig. 5)
# ---------------------------------------------------------------------------
def xtramac_switching(configs, dtype_sel, a_bits, b_bits, c_bits) -> np.ndarray:
    """All N mapping/datapath variants evaluated, output muxed by dtype_sel.

    This is exactly the paper's switching mechanism: every datatype submodule
    is instantiated statically; ``dtype_sel`` picks one per element/cycle.
    Output formats may differ per config; results are returned as raw bit
    patterns (int64) of each selected config's fmt_p.
    """
    dtype_sel = np.asarray(dtype_sel)
    outs = [xtramac(cfg, a_bits, b_bits, c_bits) for cfg in configs]
    out = outs[0]
    for i in range(1, len(configs)):
        out = np.where(dtype_sel == i, outs[i], out)
    return out
