"""Exact scalar oracle for the XtraMAC MAC operation ``P = A*B + C``.

This is the ground truth the vectorized datapath (core/mac.py) and the
Pallas kernels are validated against.  All arithmetic uses unbounded Python
integers, so alignment/rounding is *exact* — no double-rounding through
float64.

Semantics (paper Section III-D / V-A):
  * DAZ on ingest, FTZ on output.
  * any NaN in -> canonical qNaN out;  inf*0 and inf+(-inf) -> qNaN.
  * overflow saturates: +/-inf (ieee formats), NaN (e4m3), max-finite (fp4).
  * float rounding: round-to-nearest-even, applied ONCE after the fused
    product+accumulate (fused-MAC semantics, as in tensor-core FMAs).
  * integer accumulate: exact product, saturating add into the output width.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .formats import Format, FloatFormat, IntFormat, get_format


def _decode(fmt: Format, bits: int):
    """bits -> (kind, sign, M, E) with value = (-1)^sign * M * 2^E, or special.

    kind in {"num", "nan", "inf"}; zero is ("num", s, 0, 0).
    """
    bits = int(bits) & ((1 << fmt.bits) - 1)
    if isinstance(fmt, IntFormat):
        sign_bit = 1 << (fmt.bits - 1)
        v = bits - (1 << fmt.bits) if bits >= sign_bit else bits
        return ("num", 1 if v < 0 else 0, abs(v), 0)
    assert isinstance(fmt, FloatFormat)
    sign = (bits >> (fmt.exp_bits + fmt.man_bits)) & 1
    e_field = (bits >> fmt.man_bits) & fmt.exp_max_field
    m_field = bits & ((1 << fmt.man_bits) - 1)
    if fmt.special_rule == "ieee":
        if e_field == fmt.exp_max_field:
            return ("nan", sign, 0, 0) if m_field != 0 else ("inf", sign, 0, 0)
    elif fmt.special_rule == "e4m3":
        if e_field == fmt.exp_max_field and m_field == (1 << fmt.man_bits) - 1:
            return ("nan", sign, 0, 0)
    if e_field == 0:  # DAZ: subnormals (and true zero) read as zero
        return ("num", sign, 0, 0)
    M = m_field | (1 << fmt.man_bits)
    E = e_field - fmt.bias - fmt.man_bits
    return ("num", sign, M, E)


def _round_to_float(fmt: FloatFormat, sign: int, M: int, E: int) -> int:
    """Exact RN-even rounding of (-1)^sign * M * 2^E into ``fmt`` bits."""
    if M == 0:
        return sign << (fmt.bits - 1)  # signed zero (FTZ output keeps sign)
    n = M.bit_length()
    shift = n - (fmt.man_bits + 1)
    if shift <= 0:
        m_out = M << (-shift)
    else:
        kept = M >> shift
        rem = M & ((1 << shift) - 1)
        half = 1 << (shift - 1)
        if rem > half or (rem == half and (kept & 1)):
            kept += 1
        if kept == (1 << (fmt.man_bits + 1)):  # rounding carried
            kept >>= 1
            shift += 1
        m_out = kept
    e_val = E + shift + fmt.man_bits  # unbiased exponent of the result
    if e_val < fmt.min_unbiased_exp:  # FTZ
        return sign << (fmt.bits - 1)
    overflow = e_val > fmt.max_unbiased_exp
    if fmt.special_rule == "e4m3":
        if e_val == fmt.max_unbiased_exp and m_out == (1 << (fmt.man_bits + 1)) - 1:
            overflow = True  # would collide with the NaN code
        if overflow:
            return fmt.qnan_bits
    elif fmt.special_rule == "none":
        if overflow:
            return fmt.max_finite_bits(sign)
    elif overflow:
        return fmt.inf_bits(sign)
    return int(fmt.encode(sign, e_val, m_out))


def mac_exact(
    fmt_a: Format, fmt_b: Format, fmt_c: Format, fmt_p: Format,
    a_bits: int, b_bits: int, c_bits: int,
) -> int:
    """Exact ``P = A*B + C`` with XtraMAC semantics; returns P's bit pattern."""
    ka, sa, Ma, Ea = _decode(fmt_a, a_bits)
    kb, sb, Mb, Eb = _decode(fmt_b, b_bits)
    kc, sc, Mc, Ec = _decode(fmt_c, c_bits)

    if isinstance(fmt_p, IntFormat):
        # pure integer MAC: exact product + saturating accumulate
        assert ka == kb == kc == "num"
        prod = (-1) ** (sa ^ sb) * (Ma << Ea) * (Mb << Eb)
        acc = prod + (-1) ** sc * Mc
        lo, hi = fmt_p.min_value, fmt_p.max_value
        acc = min(max(acc, lo), hi)  # saturation on overflow (paper V-A)
        return acc & ((1 << fmt_p.bits) - 1)

    assert isinstance(fmt_p, FloatFormat)
    # ---- special-value resolution (paper III-D) ----
    if ka == "nan" or kb == "nan" or kc == "nan":
        return fmt_p.qnan_bits
    prod_is_inf = ka == "inf" or kb == "inf"
    if prod_is_inf:
        other_zero = (kb == "num" and Mb == 0) if ka == "inf" else (ka == "num" and Ma == 0)
        if other_zero:
            return fmt_p.qnan_bits  # inf * 0
        sp = sa ^ sb
        if kc == "inf" and sc != sp:
            return fmt_p.qnan_bits  # inf + (-inf)
        return fmt_p.inf_bits(sp) if fmt_p.has_inf and fmt_p.special_rule == "ieee" else fmt_p.qnan_bits
    if kc == "inf":
        return fmt_p.inf_bits(sc) if fmt_p.has_inf and fmt_p.special_rule == "ieee" else fmt_p.qnan_bits

    # ---- exact fused product + accumulate ----
    sp = sa ^ sb
    Mp, Ep = Ma * Mb, Ea + Eb
    if Mp == 0 and Mc == 0:
        return 0  # +0 (RN convention for exact-zero sums)
    E0 = min(Ep, Ec)
    v = (-1) ** sp * (Mp << (Ep - E0)) + (-1) ** sc * (Mc << (Ec - E0))
    if v == 0:
        return 0  # additive cancellation -> +0
    return _round_to_float(fmt_p, 1 if v < 0 else 0, abs(v), E0)


def mac_exact_vec(fmt_a, fmt_b, fmt_c, fmt_p, a_bits, b_bits, c_bits) -> np.ndarray:
    """Vectorized (slow, exact) oracle over arrays of bit patterns."""
    fmt_a, fmt_b = _as_fmt(fmt_a), _as_fmt(fmt_b)
    fmt_c, fmt_p = _as_fmt(fmt_c), _as_fmt(fmt_p)
    a, b, c = np.broadcast_arrays(
        np.asarray(a_bits, dtype=np.int64),
        np.asarray(b_bits, dtype=np.int64),
        np.asarray(c_bits, dtype=np.int64),
    )
    out = np.empty(a.shape, dtype=np.int64)
    flat_a, flat_b, flat_c = a.ravel(), b.ravel(), c.ravel()
    flat_o = out.ravel()
    for i in range(flat_a.size):
        flat_o[i] = mac_exact(fmt_a, fmt_b, fmt_c, fmt_p, flat_a[i], flat_b[i], flat_c[i])
    return out


def _as_fmt(f) -> Format:
    return get_format(f) if isinstance(f, str) else f


def decode_value(fmt, bits) -> float:
    """Scalar decode of a bit pattern to a float (NaN/inf aware)."""
    fmt = _as_fmt(fmt)
    kind, s, M, E = _decode(fmt, bits)
    if kind == "nan":
        return float("nan")
    if kind == "inf":
        return float("-inf") if s else float("inf")
    return (-1.0) ** s * M * 2.0 ** E
