"""Datatype registry and bit-level codecs for XtraMAC.

Every format the paper supports (Table II "Ours" row / Fig. 6) is described
here as either an ``IntFormat`` (two's complement) or a ``FloatFormat``
(sign / exponent / mantissa with implicit leading one).  The codecs convert
between raw bit patterns (unsigned integers) and

  * exact float64 values (for oracles — all supported formats are exact in
    float64), and
  * the (sign, exponent, mantissa) field decomposition of Eq. (1)/(4) that
    the XtraMAC datapath consumes.

Numerical conventions follow the paper (Section III-D):
  * FTZ/DAZ: subnormal inputs decode to zero; subnormal outputs flush to 0.
  * Formats without an infinity encoding follow OCP conventions:
    E4M3 reserves only exponent=1111 & mantissa=111 as NaN; E2M1 (FP4) has
    no NaN/inf at all.
  * NaNs are canonical quiet NaNs on output.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class IntFormat:
    """Two's-complement signed integer format."""

    name: str
    bits: int

    @property
    def is_float(self) -> bool:
        return False

    @property
    def magnitude_bits(self) -> int:
        # |min| = 2^(bits-1) needs (bits) bits unsigned (e.g. |-8| = 0b1000).
        return self.bits

    @property
    def min_value(self) -> int:
        return -(1 << (self.bits - 1))

    @property
    def max_value(self) -> int:
        return (1 << (self.bits - 1)) - 1

    # -- codecs ------------------------------------------------------------
    def decode_to_f64(self, bits: np.ndarray) -> np.ndarray:
        """Bit pattern (uint) -> exact float64 value."""
        bits = np.asarray(bits, dtype=np.int64) & ((1 << self.bits) - 1)
        sign_bit = 1 << (self.bits - 1)
        signed = np.where(bits >= sign_bit, bits - (1 << self.bits), bits)
        return signed.astype(np.float64)

    def encode_from_int(self, value: np.ndarray) -> np.ndarray:
        """Saturating encode of an integer value into this format."""
        v = np.clip(np.asarray(value, dtype=np.int64), self.min_value, self.max_value)
        return (v & ((1 << self.bits) - 1)).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """IEEE-style float: 1 sign bit, ``exp_bits``, ``man_bits`` (explicit)."""

    name: str
    exp_bits: int
    man_bits: int
    has_inf: bool = True
    # E4M3 (OCP): only exp=max & man=all-ones is NaN; other exp=max codes are
    # normal numbers.  E2M1: no specials at all.
    special_rule: str = "ieee"  # "ieee" | "e4m3" | "none"

    @property
    def is_float(self) -> bool:
        return True

    @property
    def bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def exp_max_field(self) -> int:
        return (1 << self.exp_bits) - 1

    @property
    def magnitude_bits(self) -> int:
        # mantissa with implicit leading 1
        return self.man_bits + 1

    @property
    def max_unbiased_exp(self) -> int:
        """Largest unbiased exponent of a finite normal number."""
        if self.special_rule == "ieee":
            return self.exp_max_field - 1 - self.bias
        # e4m3 / none: exponent field all-ones still encodes finite values.
        return self.exp_max_field - self.bias

    @property
    def min_unbiased_exp(self) -> int:
        return 1 - self.bias

    @property
    def max_finite(self) -> float:
        if self.special_rule == "e4m3":
            # exp=max, man=all-ones-but-one is the largest finite (e.g. 448).
            m = (1 << self.magnitude_bits) - 2  # mantissa just below NaN code
            return m * 2.0 ** (self.max_unbiased_exp - self.man_bits)
        m = (1 << self.magnitude_bits) - 1
        return m * 2.0 ** (self.max_unbiased_exp - self.man_bits)

    # -- field decode (vectorized numpy; mirrored in jnp inside core/mac.py) --
    def fields(self, bits: np.ndarray):
        bits = np.asarray(bits, dtype=np.int64) & ((1 << self.bits) - 1)
        sign = (bits >> (self.exp_bits + self.man_bits)) & 1
        e_field = (bits >> self.man_bits) & self.exp_max_field
        m_field = bits & ((1 << self.man_bits) - 1)
        return sign, e_field, m_field

    def is_nan(self, bits: np.ndarray) -> np.ndarray:
        sign, e, m = self.fields(bits)
        if self.special_rule == "ieee":
            return (e == self.exp_max_field) & (m != 0)
        if self.special_rule == "e4m3":
            return (e == self.exp_max_field) & (m == (1 << self.man_bits) - 1)
        return np.zeros_like(e, dtype=bool)

    def is_inf(self, bits: np.ndarray) -> np.ndarray:
        sign, e, m = self.fields(bits)
        if self.special_rule == "ieee" and self.has_inf:
            return (e == self.exp_max_field) & (m == 0)
        return np.zeros_like(e, dtype=bool)

    def is_zero_daz(self, bits: np.ndarray) -> np.ndarray:
        """Zero under DAZ: exponent field == 0 (subnormals -> zero)."""
        _, e, _ = self.fields(bits)
        return e == 0

    def decode_to_f64(self, bits: np.ndarray) -> np.ndarray:
        """Bit pattern -> float64 under DAZ (subnormals read as zero)."""
        sign, e, m = self.fields(bits)
        mag = np.where(
            e == 0,
            0.0,
            (m + (1 << self.man_bits)).astype(np.float64)
            * np.exp2((e - self.bias - self.man_bits).astype(np.float64)),
        )
        val = np.where(sign == 1, -mag, mag)
        val = np.where(self.is_nan(bits), np.nan, val)
        val = np.where(self.is_inf(bits), np.where(sign == 1, -np.inf, np.inf), val)
        return val

    # -- canonical special encodings ---------------------------------------
    @property
    def qnan_bits(self) -> int:
        if self.special_rule == "ieee":
            # quiet NaN: exp all ones, MSB of mantissa set
            return (self.exp_max_field << self.man_bits) | (1 << (self.man_bits - 1))
        if self.special_rule == "e4m3":
            return (self.exp_max_field << self.man_bits) | ((1 << self.man_bits) - 1)
        raise ValueError(f"{self.name} has no NaN encoding")

    def inf_bits(self, sign: int) -> int:
        if not (self.special_rule == "ieee" and self.has_inf):
            raise ValueError(f"{self.name} has no inf encoding")
        return (sign << (self.exp_bits + self.man_bits)) | (
            self.exp_max_field << self.man_bits
        )

    def max_finite_bits(self, sign: int) -> int:
        if self.special_rule == "e4m3":
            payload = (self.exp_max_field << self.man_bits) | ((1 << self.man_bits) - 2)
        elif self.special_rule == "none":
            payload = (self.exp_max_field << self.man_bits) | ((1 << self.man_bits) - 1)
        else:
            payload = ((self.exp_max_field - 1) << self.man_bits) | (
                (1 << self.man_bits) - 1
            )
        return (sign << (self.exp_bits + self.man_bits)) | payload

    def encode(self, sign, e_unbiased, mantissa) -> np.ndarray:
        """Pack normalized fields. ``mantissa`` includes the implicit bit."""
        sign = np.asarray(sign, dtype=np.int64)
        e_field = np.asarray(e_unbiased, dtype=np.int64) + self.bias
        m_field = np.asarray(mantissa, dtype=np.int64) & ((1 << self.man_bits) - 1)
        return (
            (sign << (self.exp_bits + self.man_bits))
            | (e_field << self.man_bits)
            | m_field
        )


Format = Union[IntFormat, FloatFormat]

# ---------------------------------------------------------------------------
# Registry (Table II "Ours": Integer + floating point, all positions A/B/C/P)
# ---------------------------------------------------------------------------
INT2 = IntFormat("int2", 2)
INT3 = IntFormat("int3", 3)
INT4 = IntFormat("int4", 4)
INT5 = IntFormat("int5", 5)
INT6 = IntFormat("int6", 6)
INT7 = IntFormat("int7", 7)
INT8 = IntFormat("int8", 8)
INT16 = IntFormat("int16", 16)
INT32 = IntFormat("int32", 32)

FP4 = FloatFormat("fp4_e2m1", exp_bits=2, man_bits=1, has_inf=False, special_rule="none")
FP8_E4M3 = FloatFormat("fp8_e4m3", exp_bits=4, man_bits=3, has_inf=False, special_rule="e4m3")
FP8_E5M2 = FloatFormat("fp8_e5m2", exp_bits=5, man_bits=2, has_inf=True, special_rule="ieee")
FP16 = FloatFormat("fp16", exp_bits=5, man_bits=10, has_inf=True, special_rule="ieee")
BF16 = FloatFormat("bf16", exp_bits=8, man_bits=7, has_inf=True, special_rule="ieee")
FP32 = FloatFormat("fp32", exp_bits=8, man_bits=23, has_inf=True, special_rule="ieee")

REGISTRY: Dict[str, Format] = {
    f.name: f
    for f in [
        INT2, INT3, INT4, INT5, INT6, INT7, INT8, INT16, INT32,
        FP4, FP8_E4M3, FP8_E5M2, FP16, BF16, FP32,
    ]
}
# convenience aliases used in configs
REGISTRY["fp8"] = FP8_E4M3
REGISTRY["fp4"] = FP4


def get_format(name: str) -> Format:
    try:
        return REGISTRY[name]
    except KeyError as exc:
        raise KeyError(f"unknown XtraMAC format {name!r}; have {sorted(REGISTRY)}") from exc


# ---------------------------------------------------------------------------
# float64 <-> format quantization (RN-even), used by oracles and quant/
# ---------------------------------------------------------------------------
def quantize_f64(fmt: Format, value: np.ndarray) -> np.ndarray:
    """Round float64 values to ``fmt`` bit patterns with RN-even + FTZ.

    Overflow saturates to +/-inf (formats with inf), to NaN (e4m3), or to the
    max finite value (formats without any special encodings, e.g. FP4) —
    matching Section III-D's saturating flag-select behaviour.
    """
    if isinstance(fmt, IntFormat):
        v = np.asarray(value, dtype=np.float64)
        rounded = np.rint(v)  # rint is RN-even
        return fmt.encode_from_int(rounded.astype(np.int64))

    v = np.asarray(value, dtype=np.float64)
    out = np.zeros(v.shape, dtype=np.int64)
    sign = (np.signbit(v)).astype(np.int64)

    nan_mask = np.isnan(v)
    inf_mask = np.isinf(v)
    finite = ~(nan_mask | inf_mask)

    mag = np.abs(np.where(finite, v, 0.0))
    # frexp: mag = frac * 2^e2, frac in [0.5, 1)
    frac, e2 = np.frexp(mag)
    e_unbiased = e2 - 1  # value = 1.xxx * 2^(e_unbiased)
    # integer mantissa with man_bits fractional bits; exact scaling then RN-even
    scaled = mag * np.exp2(float(fmt.man_bits) - e_unbiased.astype(np.float64))
    m_int = np.rint(scaled).astype(np.int64)  # RN-even
    # rounding may carry: mantissa == 2^(man_bits+1)
    carry = m_int >= (1 << (fmt.man_bits + 1))
    m_int = np.where(carry, m_int >> 1, m_int)
    e_unbiased = e_unbiased + carry.astype(np.int64)

    # FTZ: anything below the min normal flushes to zero
    underflow = (e_unbiased < fmt.min_unbiased_exp) | (mag == 0.0)
    overflow = e_unbiased > fmt.max_unbiased_exp
    if fmt.special_rule == "e4m3":
        # exp=max & man=all-ones collides with NaN -> that code overflows too
        overflow = overflow | (
            (e_unbiased == fmt.max_unbiased_exp)
            & (m_int == (1 << (fmt.man_bits + 1)) - 1)
        )
    if fmt.special_rule == "none":
        overflow = np.zeros_like(overflow)
        m_clip = np.minimum(m_int, (1 << (fmt.man_bits + 1)) - 1)
        e_clip = np.minimum(e_unbiased, fmt.max_unbiased_exp)
        sat = e_unbiased > fmt.max_unbiased_exp
        m_int = np.where(sat, (1 << (fmt.man_bits + 1)) - 1, m_clip)
        e_unbiased = np.where(sat, fmt.max_unbiased_exp, e_clip)

    normal = finite & ~underflow & ~overflow
    out = np.where(normal, fmt.encode(sign, e_unbiased, m_int), out)
    out = np.where(underflow & finite, sign << (fmt.bits - 1), out)  # +/-0 (FTZ)

    if fmt.special_rule == "ieee" and fmt.has_inf:
        inf_code = np.where(sign == 1, fmt.inf_bits(1), fmt.inf_bits(0))
        out = np.where(inf_mask | (finite & overflow), inf_code, out)
        out = np.where(nan_mask, fmt.qnan_bits, out)
    elif fmt.special_rule == "e4m3":
        out = np.where(inf_mask | (finite & overflow) | nan_mask, fmt.qnan_bits, out)
    else:  # no specials: saturate everything to max finite
        maxf = np.where(sign == 1, fmt.max_finite_bits(1), fmt.max_finite_bits(0))
        out = np.where(inf_mask, maxf, out)
        out = np.where(nan_mask, 0, out)  # no NaN encoding: canonical 0
    return out.astype(np.int64)


def all_bit_patterns(fmt: Format) -> np.ndarray:
    """Every bit pattern of a (small) format — for exhaustive tests."""
    if fmt.bits > 16:
        raise ValueError("exhaustive enumeration only for <=16-bit formats")
    return np.arange(1 << fmt.bits, dtype=np.int64)
