"""Roofline analysis over the dry-run artifacts (§Roofline of EXPERIMENTS).

Per (arch x shape) cell on the single-pod mesh:

  compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory term     = HLO_bytes_per_device / HBM_bw_per_chip
  collective term = collective_bytes_per_device / ICI_link_bw

(The dry-run HLO is the per-device SPMD program, so per-device numbers
over per-chip rates equal the global-over-cluster formulation.)

Also reports MODEL_FLOPS = 6·N·D (train) / 2·N_active·tokens (decode) and
the MODEL/HLO ratio — remat & redundancy show up as ratio < 1 for train
(recompute is counted in HLO) and sharding waste as ratio << 1.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Usage:  python -m repro.launch.roofline [--mesh pod1] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link (one-link conservative model)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"


def model_params(cfg) -> Dict[str, float]:
    """Total and active parameter counts from the abstract param tree."""
    import jax
    import numpy as np
    from repro.launch.steps import abstract_params
    tree = abstract_params(cfg, quantize=False)
    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        keys = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        if "moe" in keys and ("w_gate" in keys or "w_up" in keys
                              or "w_down" in keys):
            expert += n
    active = total
    if cfg.n_experts and cfg.top_k:
        active = total - expert * (1 - cfg.top_k / cfg.n_experts)
    return {"total": float(total), "active": float(active)}


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the cell (no attention/remat terms)."""
    p = model_params(cfg)
    n_active = p["active"]
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


def analyze_cell(arch: str, shape_name: str, mesh: str = "pod1"
                 ) -> Optional[Dict]:
    from repro.configs import SHAPES, get_config
    path = RESULTS / "dryrun" / f"{arch}.{shape_name}.{mesh}.json"
    if not path.exists():
        return None
    d = json.loads(path.read_text())
    if d.get("status") != "ok":
        return {"arch": arch, "shape": shape_name, "mesh": mesh,
                "status": d.get("status"), "reason": d.get("reason")}
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    hlo = d["hlo"]
    t_compute = hlo["flops"] / PEAK_FLOPS
    t_memory = hlo["hbm_bytes"] / HBM_BW
    t_coll = hlo["collective_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_global = hlo["flops"] * d["devices"]
    coll = hlo["collectives"]
    top_coll = max(coll, key=lambda k: coll[k]["bytes"]) if any(
        v["bytes"] for v in coll.values()) else "none"
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh, "status": "ok",
        "terms_s": {k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "bound_time_s": max(terms.values()),
        "roofline_fraction": max(terms.values()) / sum(terms.values())
        if sum(terms.values()) else 0.0,
        "model_flops": mf,
        "hlo_flops_global": hlo_flops_global,
        "model_over_hlo": mf / hlo_flops_global if hlo_flops_global else 0.0,
        "top_collective": top_coll,
        "peak_gib": d["memory"]["peak_bytes_estimate"] / 2**30,
        "fits_16gib": d["memory"]["peak_bytes_estimate"] < 16 * 2**30,
    }


def note_for(row: Dict) -> str:
    """One sentence: what would move the dominant term down."""
    d = row["dominant"]
    if d == "collective":
        return (f"dominated by {row['top_collective']} traffic — reduce by "
                "re-sharding to keep that tensor local (or overlap it under "
                "the layer scan)")
    if d == "memory":
        return ("HBM-bound — shrink bytes/step: lower-precision storage "
                "(packed sub-byte weights / bf16 states) or better fusion")
    return ("compute-bound — raise MXU utilization: larger per-device tiles, "
            "less recompute (remat policy), fewer wasted FLOPs")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--json", default=str(RESULTS / "roofline.json"))
    args = ap.parse_args()
    from repro.configs import ARCH_IDS, SHAPES
    rows = []
    for arch in ARCH_IDS:
        for shape_name in SHAPES:
            r = analyze_cell(arch, shape_name, args.mesh)
            if r is not None:
                if r["status"] == "ok":
                    r["note"] = note_for(r)
                rows.append(r)
    pathlib.Path(args.json).write_text(json.dumps(rows, indent=1))

    hdr = (f"{'arch':<20} {'shape':<12} {'compute_s':>10} {'memory_s':>10} "
           f"{'collect_s':>10} {'dom':>10} {'M/H':>6} {'peak GiB':>9}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:<20} {r['shape']:<12} {'—':>10} {'—':>10} "
                  f"{'—':>10} {r['status']:>10}")
            continue
        t = r["terms_s"]
        print(f"{r['arch']:<20} {r['shape']:<12} {t['compute']:>10.4f} "
              f"{t['memory']:>10.4f} {t['collective']:>10.4f} "
              f"{r['dominant']:>10} {r['model_over_hlo']:>6.2f} "
              f"{r['peak_gib']:>9.2f}")


if __name__ == "__main__":
    main()
