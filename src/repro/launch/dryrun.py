import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh).

The two lines above MUST run before any jax import — jax locks the device
count at first init.  512 host-platform placeholder devices let
``jax.make_mesh`` build the production meshes:

  pod1: (data=16, model=16)          — 256 chips; roofline source
  pod2: (pod=2, data=16, model=16)   — 512 chips; proves the 'pod' axis

Per cell this script records ``compiled.memory_analysis()`` (proves it
fits), ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline) and the
collective-op byte census parsed from the compiled HLO, into
``results/dryrun/<arch>.<shape>.<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all [--skip-existing]   # subprocess/cell
"""
import argparse
import json
import pathlib
import re
import subprocess
import sys
import time
import traceback

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,512]' -> bytes; tuples handled by the caller via findall."""
    m = re.match(r"(\w+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_census(hlo_text: str):
    """Sum result-shape bytes of every collective op in the compiled HLO.

    Result bytes ≈ wire bytes per device for all-reduce (ring: 2(n-1)/n x)
    and all-gather ((n-1)/n x); reduce-scatter counted at operand size
    (result x shards) when replica_groups are parseable.
    """
    census = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    # lines like:  %x = (bf16[..], bf16[..]) all-gather(...), replica_groups=
    op_re = re.compile(
        r"=\s*(\([^)]*\)|\S+\[[\d,]*\]\S*)\s+(" + "|".join(_COLLECTIVES)
        + r")\b(.*)$")
    for line in hlo_text.splitlines():
        m = op_re.search(line)
        if not m:
            continue
        shapes, op, rest = m.groups()
        nbytes = sum(_shape_bytes(s) for s in
                     re.findall(r"\w+\[[\d,]*\]", shapes))
        if op == "reduce-scatter":
            g = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
            if g:
                nbytes *= len(g.group(1).split(","))
        census[op]["count"] += 1
        census[op]["bytes"] += nbytes
    census["total_bytes"] = sum(v["bytes"] for k, v in census.items()
                                if isinstance(v, dict))
    return census


def run_cell(arch: str, shape_name: str, mesh_name: str) -> dict:
    import jax
    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = shape_applicable(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    n_dev = mesh.size
    fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh)

    t0 = time.time()
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    from repro.launch.hlo_analysis import analyze
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    hlo = analyze(hlo_text)             # trip-count-aware (scan bodies x L)
    # cache the compiled HLO so the analyzer can be re-run offline
    import gzip
    hdir = RESULTS / "hlo"
    hdir.mkdir(parents=True, exist_ok=True)
    with gzip.open(hdir / f"{arch}.{shape_name}.{mesh_name}.hlo.gz", "wt") as f:
        f.write(hlo_text)

    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_bytes_estimate": (
                (getattr(mem, "argument_size_in_bytes", 0) or 0)
                + (getattr(mem, "output_size_in_bytes", 0) or 0)
                + (getattr(mem, "temp_size_in_bytes", 0) or 0)
                - (getattr(mem, "alias_size_in_bytes", 0) or 0)),
        },
        # raw XLA numbers (loop bodies counted once — kept for reference)
        "cost_raw": {"flops": cost.get("flops"),
                     "bytes_accessed": cost.get("bytes accessed"),
                     "transcendentals": cost.get("transcendentals")},
        # trip-count-aware per-device analysis (the roofline source)
        "hlo": hlo.to_json(),
        "collectives": hlo.coll,
    }
    return out


def cell_path(arch, shape, mesh) -> pathlib.Path:
    return RESULTS / f"{arch}.{shape}.{mesh}.json"


def reanalyze_all():
    """Re-run the HLO analyzer over cached compiled HLO (no recompile)."""
    import gzip
    from repro.launch.hlo_analysis import analyze
    n = 0
    for path in sorted(RESULTS.glob("*.json")):
        d = json.loads(path.read_text())
        if d.get("status") != "ok":
            continue
        hpath = RESULTS / "hlo" / (path.stem + ".hlo.gz")
        if not hpath.exists():
            continue
        with gzip.open(hpath, "rt") as f:
            hlo = analyze(f.read())
        d["hlo"] = hlo.to_json()
        d["collectives"] = hlo.coll
        path.write_text(json.dumps(d, indent=1))
        n += 1
        print(f"reanalyzed {path.stem}: hbm {hlo.hbm_bytes:.3e} B, "
              f"flops {hlo.flops:.3e}", flush=True)
    print(f"{n} cells reanalyzed")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod1", "pod2"], default="pod1")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--reanalyze", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)
    if args.reanalyze:
        reanalyze_all()
        return

    if not args.all:
        res = run_cell(args.arch, args.shape, args.mesh)
        path = cell_path(args.arch, args.shape, args.mesh)
        path.write_text(json.dumps(res, indent=1))
        print(json.dumps({k: res[k] for k in ("arch", "shape", "mesh", "status")}))
        if res["status"] == "ok":
            print(f"  peak bytes/device ~ {res['memory']['peak_bytes_estimate']/2**30:.2f} GiB, "
                  f"flops/dev {res['hlo']['flops']:.3e}, "
                  f"hbm/dev {res['hlo']['hbm_bytes']:.3e} B, "
                  f"coll/dev {res['hlo']['collective_bytes']/2**20:.1f} MiB")
        return

    # --all: one subprocess per cell (isolates compiles; resumable)
    from repro.configs import ARCH_IDS, SHAPES   # light import (no jax use)
    failures = []
    for mesh_name in ("pod1", "pod2"):
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                path = cell_path(arch, shape_name, mesh_name)
                if args.skip_existing and path.exists():
                    st = json.loads(path.read_text()).get("status")
                    if st in ("ok", "skipped"):
                        continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--mesh", mesh_name]
                print(f"=== {arch} x {shape_name} x {mesh_name}", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=7200)
                print(r.stdout, flush=True)
                if r.returncode != 0:
                    failures.append((arch, shape_name, mesh_name))
                    path.write_text(json.dumps({
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "error", "stderr": r.stderr[-4000:]}, indent=1))
                    print(r.stderr[-2000:], flush=True)
    print(f"done; {len(failures)} failures: {failures}")


if __name__ == "__main__":
    try:
        main()
    except Exception:
        traceback.print_exc()
        sys.exit(1)
