"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else sees the real (single-CPU) device.

Mesh layout (TPU v5e pods of 256 chips):
  single-pod : (data=16, model=16)               = 256 chips
  multi-pod  : (pod=2, data=16, model=16)        = 512 chips
The 'model' axis is the innermost (fastest ICI ring) — TP/EP collectives
stay on-pod; only the DP gradient all-reduce crosses the 'pod' axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_devices=None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
