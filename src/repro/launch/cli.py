"""Shared CLI plumbing for mesh-aware drivers (launch/serve.py,
benchmarks/serve_bench.py).

``force_host_devices`` must run BEFORE jax initializes its backends
(device counts are fixed at backend init), so this module imports no jax
at module level — drivers import it first, mutate the environment, and
only then import jax.
"""
from __future__ import annotations

import os
import re

_FORCE_RE = re.compile(r"--xla_force_host_platform_device_count=(\d+)")


def force_host_devices(n: int) -> None:
    """CPU validation: fake ``n`` host devices via XLA_FLAGS.  No-op when
    ``n`` is falsy or the environment already forces at least ``n``
    devices; a smaller forced count is raised to ``n`` (the user asked for
    it explicitly — leaving a stale smaller value would dead-end them on
    the very error message that suggests this flag)."""
    if not n:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    m = _FORCE_RE.search(flags)
    if m:
        if int(m.group(1)) >= n:
            return
        flags = _FORCE_RE.sub("", flags).strip()
    os.environ["XLA_FLAGS"] = \
        f"{flags} --xla_force_host_platform_device_count={n}".strip()


def serving_mesh(dp: int, tp: int):
    """``jax.Mesh`` over ('data', 'model') for a dp x tp serving run, or
    None when dp*tp == 1 (single-device jits).  Fails with the
    --force-host-devices hint when the backend is short of devices."""
    if dp * tp <= 1:
        return None
    import jax
    n = len(jax.devices())
    if n < dp * tp:
        raise SystemExit(
            f"dp={dp} x tp={tp} needs {dp * tp} devices, have {n}; "
            f"on CPU pass --force-host-devices {dp * tp}")
    return jax.make_mesh((dp, tp), ("data", "model"))
