"""Training driver: data pipeline -> jitted train_step -> checkpoints,
with preemption handling, straggler monitoring and restart/resume.

Runs anywhere: on the CPU container it trains the reduced (--smoke)
configs end-to-end; on a real cluster the same file drives the production
mesh (mesh/steps/partitioning are shared with the dry-run, which is the
point — what was dry-run-validated is what runs).

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, load_checkpoint
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.common import InitMaker
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.launch.steps import make_train_step
from repro.runtime.fault_tolerance import (PreemptionHandler,
                                           StragglerMonitor)


def _build_batch(cfg, np_batch):
    batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
    b = np_batch["tokens"].shape[0]
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((b, cfg.n_patches, cfg.d_model),
                                     jnp.bfloat16)
    elif cfg.family == "audio":
        batch["frames"] = jnp.zeros((b, cfg.n_frames, cfg.d_model),
                                    jnp.bfloat16)
    return batch


def train(arch: str, *, smoke: bool, steps: int, batch_size: int,
          seq_len: int, ckpt_dir: Optional[str], ckpt_every: int = 25,
          lr: float = 3e-3, seed: int = 0, log_every: int = 10,
          fail_at: Optional[int] = None, resume: bool = True):
    cfg = get_config(arch, smoke=smoke)
    optim_cfg = AdamWConfig(lr=lr, warmup_steps=min(20, steps // 5 + 1),
                            total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, optim_cfg), donate_argnums=(0, 1))

    params = T.build_params(cfg, InitMaker(jax.random.PRNGKey(seed)))
    opt_state = adamw_init(params, optim_cfg)

    start = 0
    manager = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if manager and resume:
        last = latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state), extra = load_checkpoint(
                ckpt_dir, last, (params, opt_state))
            params = jax.tree_util.tree_map(jnp.asarray, params)
            opt_state = jax.tree_util.tree_map(jnp.asarray, opt_state)
            start = int(extra.get("step", last))
            print(f"resumed from step {start}")

    data = SyntheticTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=seq_len, global_batch=batch_size, seed=seed),
        start_step=start)
    pre = PreemptionHandler()
    mon = StragglerMonitor()
    history = []
    try:
        for step in range(start, steps):
            mon.start_step()
            np_batch = next(data)
            batch = _build_batch(cfg, np_batch)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            flagged = mon.end_step(step)
            history.append(loss)
            if step % log_every == 0 or step == steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}"
                      + (f"  [straggler {flagged.deviations:.1f} sigma]"
                         if flagged else ""))
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"simulated failure at step {step}")
            if manager and (step + 1) % ckpt_every == 0:
                manager.save_async(step + 1, (params, opt_state),
                                   extra={"step": step + 1,
                                          "data": data.state()})
            if pre.should_stop:
                print(f"preempted at step {step}; checkpointing + exiting")
                if manager:
                    manager.save_async(step + 1, (params, opt_state),
                                       extra={"step": step + 1})
                break
    finally:
        if manager:
            manager.wait()
        data.close()
        pre.restore()
    return {"final_loss": history[-1] if history else None,
            "history": history, "stragglers": len(mon.events),
            "last_step": start + len(history)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fail-at", type=int)
    args = ap.parse_args()
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                batch_size=args.batch, seq_len=args.seq,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                lr=args.lr, fail_at=args.fail_at)
    print(json.dumps({k: v for k, v in out.items() if k != "history"}))


if __name__ == "__main__":
    main()
