"""Trip-count-aware cost analysis of compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — under a
scan-over-layers schedule that understates FLOPs/bytes by the layer count.
This module parses the compiled HLO module text and walks the call graph
from ENTRY, multiplying each while body by its ``known_trip_count``
(emitted by XLA in ``backend_config``), giving:

  * flops              — 2 * prod(result dims) * prod(contracting dims)
                         summed over every dot (fusion-nested dots included)
  * hbm_bytes          — per-instruction operand+result bytes at fusion
                         granularity (fusions are the HBM-traffic unit;
                         intra-fusion values never hit HBM)
  * collective bytes   — per collective type, result-shape bytes
                         (reduce-scatter scaled by group size = operand)

All numbers are PER DEVICE (the HLO is the per-device SPMD program).
Validated against hand-computed matmul programs in tests/test_hlo_analysis.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "iota",
               "while", "conditional", "call"}

_shape_re = re.compile(r"(\w+)\[([\d,]*)\]")


def _shapes_in(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _shape_re.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes_in(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symtab: Dict[str, str]          # value name -> type string


_header_re = re.compile(r"^(?:ENTRY\s+)?%?([^\s(]+)\s*\(.*\{\s*$")
_instr_re = re.compile(r"^\s+(?:ROOT\s+)?%?([^\s=]+)\s*=\s*(.*)$")


def _split_type_op(rest: str) -> Optional[Tuple[str, str, str]]:
    """'(f32[],..) while(%t), attrs' -> (type_str, op, tail)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                type_str, tail = rest[: i + 1], rest[i + 1:]
                break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, tail = rest[:sp], rest[sp:]
    m = re.match(r"\s*([\w\-]+)\((.*)$", tail, re.S)
    if not m:
        return None
    return type_str, m.group(1), m.group(2)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{") and ("(" in line) and "->" in line:
                m = _header_re.match(line.strip())
                if m:
                    cur = Computation(m.group(1), [], {})
                    if line.lstrip().startswith("ENTRY"):
                        entry_name = m.group(1)
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _instr_re.match(line)
        if not m:
            continue
        name, rest = m.groups()
        sto = _split_type_op(rest)
        if sto is None:
            continue
        type_str, op, tail = sto
        # first-level operand names
        depth, ops_str = 0, []
        for ch in tail:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            ops_str.append(ch)
        operands = re.findall(r"%([\w\.\-]+)", "".join(ops_str))
        instr = Instr(name, type_str, op, operands, line)
        cur.instrs.append(instr)
        cur.symtab[name] = type_str
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _dot_flops(instr: Instr, symtab: Dict[str, str]) -> float:
    out_elems = 1
    for _, dims in _shapes_in(instr.type_str):
        for d in dims:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    lhs_type = symtab.get(instr.operands[0]) if instr.operands else None
    contract = 1
    if lhs_type:
        shapes = _shapes_in(lhs_type)
        if shapes:
            dims = shapes[0][1]
            for c in cdims:
                if c < len(dims):
                    contract *= dims[c]
    return 2.0 * out_elems * contract


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    transcendentals: float = 0.0
    coll: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=lambda: {k: {"count": 0, "bytes": 0.0}
                                 for k in COLLECTIVES})

    def add(self, o: "Cost", mult: float = 1.0):
        self.flops += o.flops * mult
        self.hbm_bytes += o.hbm_bytes * mult
        self.transcendentals += o.transcendentals * mult
        for k in COLLECTIVES:
            self.coll[k]["count"] += o.coll[k]["count"] * mult
            self.coll[k]["bytes"] += o.coll[k]["bytes"] * mult

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.coll.values())

    def to_json(self):
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "transcendentals": self.transcendentals,
                "collective_bytes": self.collective_bytes,
                "collectives": self.coll}


_TRANSCENDENTAL_FUSION_HINT = re.compile(
    r"exponential|tanh|log|rsqrt|power|sine|cosine")

# ops whose real HBM traffic is the ACCESSED REGION, not the whole operand:
#  dynamic-slice / gather read ~result-sized regions of a large buffer
#  (scan xs slicing, embedding lookups); dynamic-update-slice writes the
#  update region in place (donated caches).  Counting full operands would
#  scale scan-sliced stacks by the trip count — an L x overstatement.
_REGION_OPS = {"dynamic-slice", "gather", "dynamic-update-slice"}


_PASSTHRU = {"bitcast", "reshape", "copy", "transpose", "convert"}
_SLICERS = {"dynamic-slice", "slice", "gather"}


def _param_traffic(callee: Computation, param_idx: int, full_bytes: float
                   ) -> float:
    """Traffic a fusion really does on operand ``param_idx``: if the callee
    only SLICES that parameter (scan xs / cache reads), the traffic is the
    slice size, not the whole buffer — otherwise the loop trip count would
    multiply the full stacked array (an L x - 1000 x overstatement)."""
    pnames = [i.name for i in callee.instrs if i.op == "parameter"
              and re.search(rf"parameter\({param_idx}\)", i.line)]
    if not pnames:
        return full_bytes
    frontier = set(pnames)
    consumers: List[Instr] = []
    for ins in callee.instrs:
        if any(o in frontier for o in ins.operands):
            if ins.op in _PASSTHRU:
                frontier.add(ins.name)
            else:
                consumers.append(ins)
    if consumers and all(c.op in _SLICERS for c in consumers):
        return sum(_bytes_of(c.type_str) for c in consumers)
    if consumers and all(c.op == "dynamic-update-slice" for c in consumers):
        # in-place write of an update region into the big buffer
        upd = [callee.symtab.get(c.operands[1]) for c in consumers
               if len(c.operands) > 1]
        return sum(_bytes_of(u) for u in upd if u)
    return full_bytes


def _result_traffic(ins: Instr, callee: Optional[Computation]) -> float:
    """Result-side traffic; a fusion rooted at dynamic-update-slice writes
    only the update region (output aliases the input buffer)."""
    full = _bytes_of(ins.type_str)
    if callee is None:
        return full
    roots = [i for i in callee.instrs if i.line.lstrip().startswith("ROOT")]
    if len(roots) == 1 and roots[0].op == "dynamic-update-slice":
        upd = callee.symtab.get(roots[0].operands[1]) \
            if len(roots[0].operands) > 1 else None
        if upd:
            return _bytes_of(upd)
    return full


def _instr_bytes(ins: Instr, symtab: Dict[str, str],
                 comps: Optional[Dict[str, Computation]] = None) -> float:
    if ins.op in _REGION_OPS:
        if ins.op == "dynamic-update-slice":
            upd = symtab.get(ins.operands[1]) if len(ins.operands) > 1 else None
            return 2.0 * _bytes_of(upd) if upd else 0.0
        return 2.0 * _bytes_of(ins.type_str)       # read region + write result
    callee = None
    if comps is not None and ins.op in ("fusion", "custom-call"):
        m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
        if m:
            callee = comps.get(m.group(1))
    nbytes = _result_traffic(ins, callee)
    for idx, opnd in enumerate(ins.operands):
        t = symtab.get(opnd)
        if t is None:
            continue
        full = _bytes_of(t)
        nbytes += _param_traffic(callee, idx, full) if callee else full
    return nbytes


def _flops_only(comp: Computation, comps, memo_f) -> Cost:
    """flops + collectives of a computation INCLUDING nested fusions."""
    if comp.name in memo_f:
        return memo_f[comp.name]
    c = Cost()
    for ins in comp.instrs:
        if ins.op == "dot":
            c.flops += _dot_flops(ins, comp.symtab)
        elif ins.op in COLLECTIVES:
            nbytes = _bytes_of(ins.type_str)
            if ins.op == "reduce-scatter":
                nbytes *= _group_size(ins.line)
            c.coll[ins.op]["count"] += 1
            c.coll[ins.op]["bytes"] += nbytes
        callee = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", ins.line)
        if callee and ins.op in ("fusion", "call", "custom-call"):
            sub = comps.get(callee.group(1))
            if sub is not None:
                c.add(_flops_only(sub, comps, memo_f))
    memo_f[comp.name] = c
    return c


def _cost_of(comp: Computation, comps, memo, memo_f) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    c = Cost()
    for ins in comp.instrs:
        if ins.op == "while":
            trip = 1
            m = re.search(r"known_trip_count[^0-9]*(\d+)", ins.line)
            if m:
                trip = int(m.group(1))
            body = re.search(r"body=%?([\w\.\-]+)", ins.line)
            cond = re.search(r"condition=%?([\w\.\-]+)", ins.line)
            if body and body.group(1) in comps:
                c.add(_cost_of(comps[body.group(1)], comps, memo, memo_f), trip)
            if cond and cond.group(1) in comps:
                c.add(_cost_of(comps[cond.group(1)], comps, memo, memo_f), trip)
            continue
        if ins.op == "conditional":
            branches = re.findall(r"%([\w\.\-]+)", ins.line.split("branch")[-1])
            sub = [ _cost_of(comps[b], comps, memo, memo_f)
                    for b in branches if b in comps]
            if sub:
                best = max(sub, key=lambda s: s.flops + s.hbm_bytes)
                c.add(best)
            continue
        if ins.op == "call":
            callee = re.search(r"to_apply=%?([\w\.\-]+)", ins.line)
            if callee and callee.group(1) in comps:
                c.add(_cost_of(comps[callee.group(1)], comps, memo, memo_f))
            continue
        if ins.op == "dot":
            c.flops += _dot_flops(ins, comp.symtab)
        elif ins.op in COLLECTIVES:
            nbytes = _bytes_of(ins.type_str)
            if ins.op == "reduce-scatter":
                nbytes *= _group_size(ins.line)
            c.coll[ins.op]["count"] += 1
            c.coll[ins.op]["bytes"] += nbytes
        elif ins.op in ("fusion", "custom-call"):
            callee = re.search(r"calls=%?([\w\.\-]+)", ins.line)
            if callee and callee.group(1) in comps:
                sub = _flops_only(comps[callee.group(1)], comps, memo_f)
                c.flops += sub.flops
                for k in COLLECTIVES:
                    c.coll[k]["count"] += sub.coll[k]["count"]
                    c.coll[k]["bytes"] += sub.coll[k]["bytes"]
            if _TRANSCENDENTAL_FUSION_HINT.search(ins.line):
                c.transcendentals += _bytes_of(ins.type_str) / 4.0
        # HBM bytes: fusion-granularity operand + result traffic
        if ins.op not in _SKIP_BYTES:
            c.hbm_bytes += _instr_bytes(ins, comp.symtab, comps)
    memo[comp.name] = c
    return c


def analyze(hlo_text: str) -> Cost:
    comps = parse_module(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:
        return Cost()
    return _cost_of(entry, comps, {}, {})


def top_bytes_contributors(hlo_text: str, n: int = 25):
    """Debug: (bytes*trip, op, comp, shape-str) for the heaviest instrs."""
    comps = parse_module(hlo_text)
    entry = comps.get("__entry__")
    rows = []

    def visit(comp, mult):
        for ins in comp.instrs:
            if ins.op == "while":
                trip = 1
                m = re.search(r"known_trip_count[^0-9]*(\d+)", ins.line)
                if m:
                    trip = int(m.group(1))
                body = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if body and body.group(1) in comps:
                    visit(comps[body.group(1)], mult * trip)
                continue
            if ins.op in _SKIP_BYTES:
                continue
            nb = _instr_bytes(ins, comp.symtab, comps)
            rows.append((nb * mult, ins.op, comp.name, ins.type_str[:60]))

    if entry is not None:
        visit(entry, 1)
    rows.sort(reverse=True)
    return rows[:n]
