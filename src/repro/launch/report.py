"""Render the §Roofline markdown table from results/roofline.json.

  PYTHONPATH=src python -m repro.launch.roofline --json results/roofline.json
  PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"


def markdown_table(rows) -> str:
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| M/H | peak GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            continue
        t = r["terms_s"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute']:.4g} | "
            f"{t['memory']:.4g} | {t['collective']:.4g} | {r['dominant']} | "
            f"{r['model_over_hlo']:.2f} | {r['peak_gib']:.1f} |")
    return "\n".join(out)


def main():
    rows = json.loads((RESULTS / "roofline.json").read_text())
    print(markdown_table(rows))
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    print(f"\n{len(ok)} cells analyzed, {len(skipped)} skipped")


if __name__ == "__main__":
    main()
