"""Jitted step factories shared by train.py, serve.py and dryrun.py.

Each factory returns (fn, abstract_args, in_shardings, out_shardings,
donate) so the dry-run can ``jax.jit(fn, ...).lower(*abstract).compile()``
and the real drivers can call the same jit with concrete arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.shapes import ShapeSpec, input_specs
from repro.kernels.ops import declare_execution
from repro.models.common import AbstractMaker, set_activation_shardings
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime import partitioning as PT


def abstract_params(cfg: T.ModelConfig, *, quantize: bool):
    return T.build_params(cfg, AbstractMaker(quantize=quantize))


def _declare_on_trace(fn, mesh: Mesh):
    """Sync the global kernel guard (kernels/ops.py) to ``mesh`` at TRACE
    time: the set call runs as a host side effect while ``fn``'s body is
    traced — exactly when the kernel-vs-jnp decision is baked in — so an
    interleaved engine/cell built against a different mesh cannot flip the
    flag between cell construction and first trace."""
    import functools
    partitioned = mesh.size > 1

    @functools.wraps(fn)
    def wrapped(*args):
        declare_execution(mesh=mesh if partitioned else None,
                          partitioned=partitioned)
        return fn(*args)
    return wrapped


def _named(mesh, tree):
    return PT.named(mesh, tree)


def _activation_rules(cfg: T.ModelConfig, mesh: Mesh, rules: PT.AxisRules,
                      batch_size: int, seq_len: int, kind: str):
    """Pin the per-layer activation layout.

    DP on batch always; for train/prefill the sequence axis additionally
    shards over 'model' between blocks (Megatron-SP analogue: matmuls and
    norms stay row-parallel over S; only attention gathers K/V).  This cuts
    the remat-saved per-layer residuals AND the train logits by model_size.
    """
    import numpy as np
    from jax.sharding import NamedSharding
    bax = rules.batch_axes
    bsize = int(np.prod([mesh.shape[a] for a in bax]))
    if batch_size % bsize != 0:
        bax = tuple(a for a in bax if batch_size % mesh.shape[a] == 0)[-1:]
    b = bax if bax else None
    msz = rules.model_size
    s_ax = "model" if kind in ("train", "prefill") and seq_len % msz == 0 else None
    vshard = ("model" if cfg.vocab % msz == 0 else None)
    if kind == "train":
        # vocab-sharded logits keep dW = x^T dlogits sharded on V — with
        # (b, s) both sharded the contraction would otherwise materialize
        # the FULL f32 [d, V] lm_head gradient per device (17.6 GiB for
        # nemotron-4-340b).  Falls back to S-sharding for odd vocabs.
        logits = P(b, None, vshard) if vshard else P(b, s_ax, None)
    else:
        logits = P(b, None, vshard)    # [B, 1, V]: shard vocab
    set_activation_shardings({
        # between blocks: SP (sequence over 'model') — tiny remat residuals
        "btd": NamedSharding(mesh, P(b, s_ax, None)),
        # inside blocks: TP on heads / FFN-hidden — this is what makes GSPMD
        # do Megatron-SP (gather activations over S, keep weights+grads
        # TP-sharded) instead of all-gathering the weights per layer
        "bthd": NamedSharding(mesh, P(b, None, "model", None)),
        "btf": NamedSharding(mesh, P(b, None, "model")),
        # attention scores / PV partials in flat-head layout
        "bhqk": NamedSharding(mesh, P(b, "model", None, None))
        if kind != "decode" else None,
        "bhqd": NamedSharding(mesh, P(b, "model", None, None))
        if kind != "decode" else None,
        "logits": NamedSharding(mesh, logits),
    })


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def make_train_step(cfg: T.ModelConfig, optim_cfg: AdamWConfig,
                    grad_shardings=None):
    n_micro = max(1, cfg.microbatches)

    def pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            tree, grad_shardings)

    def grad_of(params, batch):
        def loss(p):
            return T.loss_fn(cfg, p, batch)
        return jax.value_and_grad(loss, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (l, metrics), grads = grad_of(params, batch)
            grads = pin(grads)
        else:
            # gradient accumulation: peak activation memory / n_micro at the
            # cost of repeating the FSDP weight gathers per microbatch —
            # the right trade for the memory-bound big-model cells.
            acc_dtype = optim_cfg.moment_dtype
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def body(carry, mbatch):
                gacc, lacc = carry
                (l, _), g = grad_of(params, mbatch)
                gacc = pin(jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gacc, g))
                return (gacc, lacc + l), None

            g0 = pin(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype)
                if jnp.issubdtype(p.dtype, jnp.floating)
                else jnp.zeros(p.shape, p.dtype), params))
            (gacc, lsum), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), mb)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, gacc)
            l = lsum / n_micro
            metrics = {"xent": l, "aux": jnp.float32(0.0),
                       "zloss": jnp.float32(0.0)}
        new_params, new_opt, om = adamw_update(params, grads, opt_state,
                                               optim_cfg)
        return new_params, new_opt, {**metrics, **om, "loss": l}
    return train_step


def train_cell(cfg: T.ModelConfig, shape: ShapeSpec, mesh: Mesh,
               optim_cfg: Optional[AdamWConfig] = None):
    """(fn, abstract args, in_shardings, out_shardings, donate) for train."""
    optim_cfg = optim_cfg or AdamWConfig(
        moment_dtype=jnp.bfloat16 if cfg.name.startswith("nemotron-4") else jnp.float32)
    rules = PT.rules_from_mesh(mesh, train=True)
    params = abstract_params(cfg, quantize=False)
    opt_state = jax.eval_shape(lambda p: adamw_init(p, optim_cfg), params)
    batch = input_specs(cfg, shape)["batch"]

    pspec = PT.param_specs(cfg, mesh, train=True, quantize=False)
    opt_spec = type(opt_state)(P(), pspec, pspec)  # ZeRO-3: like params
    bspec_all = PT.batch_pspec(cfg, rules, shape.global_batch, mesh)
    bspec = {k: bspec_all[k] for k in batch}

    _activation_rules(cfg, mesh, rules, shape.global_batch, shape.seq_len,
                      "train")
    # Pallas kernels are not GSPMD-partitionable: the wrapper declares the
    # mesh at trace time so use_kernel=True downgrades loudly to the jnp
    # path (kernels/ops.py)
    fn = _declare_on_trace(
        make_train_step(cfg, optim_cfg, grad_shardings=_named(mesh, pspec)),
        mesh)
    in_sh = ( _named(mesh, pspec), _named(mesh, opt_spec), _named(mesh, bspec))
    out_sh = (_named(mesh, pspec), _named(mesh, opt_spec), None)
    return fn, (params, opt_state, batch), in_sh, out_sh, (0, 1)


# ---------------------------------------------------------------------------
# Serve steps (prefill / decode)
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: T.ModelConfig):
    def prefill(params, batch, cache):
        logits, _, cache = T.forward(cfg, params, batch, cache=cache,
                                     cache_index=0, mode="prefill")
        return logits[:, -1:], cache
    return prefill


def make_decode_step(cfg: T.ModelConfig):
    def decode(params, batch, cache, index):
        logits, _, cache = T.forward(cfg, params, batch, cache=cache,
                                     cache_index=index, mode="decode")
        return logits, cache
    return decode


def serve_cell(cfg: T.ModelConfig, shape: ShapeSpec, mesh: Mesh):
    """(fn, abstract args, in/out shardings, donate) for prefill/decode."""
    rules = PT.rules_from_mesh(mesh, train=False)
    params = abstract_params(cfg, quantize=True)
    specs = input_specs(cfg, shape)
    batch, cache = specs["batch"], specs["cache"]

    pspec = PT.param_specs(cfg, mesh, train=False, quantize=True)
    bspec_all = PT.batch_pspec(cfg, rules, shape.global_batch, mesh)
    bspec = {k: bspec_all.get(k, P(None, None, None)) for k in batch}
    cspec = PT.cache_pspec(cfg, rules, shape.global_batch, mesh)
    logit_spec = None   # let GSPMD choose (vocab-model-sharded upstream)
    _activation_rules(cfg, mesh, rules, shape.global_batch, shape.seq_len,
                      shape.kind)

    if shape.kind == "prefill":
        fn = _declare_on_trace(make_prefill_step(cfg), mesh)
        in_sh = (_named(mesh, pspec), _named(mesh, bspec), _named(mesh, cspec))
        out_sh = (logit_spec, _named(mesh, cspec))
        return fn, (params, batch, cache), in_sh, out_sh, (2,)

    fn = _declare_on_trace(make_decode_step(cfg), mesh)
    index = specs["index"]
    in_sh = (_named(mesh, pspec), _named(mesh, bspec), _named(mesh, cspec),
             _named(mesh, P()))
    out_sh = (logit_spec, _named(mesh, cspec))
    return fn, (params, batch, cache, index), in_sh, out_sh, (2,)


def build_cell(cfg: T.ModelConfig, shape: ShapeSpec, mesh: Mesh):
    if shape.kind == "train":
        return train_cell(cfg, shape, mesh)
    return serve_cell(cfg, shape, mesh)
