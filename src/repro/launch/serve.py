"""Serving driver: load (or synthesize) a mixed-precision checkpoint and
run batched generation — the end-to-end consumer of the paper's technique.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
      --smoke --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.common import QuantMaker
from repro.models import transformer as T
from repro.serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"building {cfg.name} with quantized weights "
          f"(proj={cfg.scheme_proj}, ffn={cfg.scheme_ffn})")
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(args.seed),
                                            plan={}))
    engine = ServingEngine(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.max_new,
        temperature=args.temperature))

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": rng.integers(
        1, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.full((args.batch, cfg.n_patches, cfg.d_model),
                                    0.02, jnp.bfloat16)
    elif cfg.family == "audio":
        batch["frames"] = jnp.full((args.batch, cfg.n_frames, cfg.d_model),
                                   0.02, jnp.bfloat16)

    t0 = time.time()
    out = engine.generate(batch, max_new_tokens=args.max_new, seed=args.seed)
    dt = time.time() - t0
    toks = out["generated"].size
    print(f"generated {out['generated'].shape} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print("first rows:", out["generated"][:2, :8].tolist())
    print(json.dumps({"batch": out["batch"], "prompt_len": out["prompt_len"],
                      "new_tokens": int(out["generated"].shape[1]),
                      "wall_s": round(dt, 2)}))


if __name__ == "__main__":
    main()
