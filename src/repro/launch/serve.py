"""Serving driver: load (or synthesize) a mixed-precision checkpoint and
run batched generation — the end-to-end consumer of the paper's technique.

Mesh-aware (DESIGN.md §10): ``--dp``/``--tp`` shard the engine across a
``data x model`` device mesh — packed weights along N on the model axis,
the KV pool slots on the data axis.  On a CPU-only box, validate the
sharded path with forced host devices:

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --dp 2 --tp 4 --force-host-devices 8 --kv-dtype int8

Reports compile time and steady-state tok/s separately: the first
generation pays the XLA compile, so a warmup pass runs the same jitted
step shapes off the clock before the timed run.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.launch.cli import force_host_devices, serving_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-dtype", default="bf16",
                    help="KV pool storage: bf16 | int8 | fp8 (DESIGN.md §9; "
                         "legacy adapter — the canonical knob is --policy)")
    ap.add_argument("--policy", default=None, metavar="POLICY_JSON",
                    help="path to a PrecisionPolicy JSON (DESIGN.md §12): "
                         "weight-scheme patterns, KV tier and kernel mode "
                         "as one artifact; overrides --kv-dtype")
    ap.add_argument("--max-burst", type=int, default=8,
                    help="device-resident decode burst cap: K tokens per "
                         "jit dispatch / host sync (1 = per-token dispatch, "
                         "DESIGN.md §11)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel mesh axis (pool slots shard here)")
    ap.add_argument("--tp", type=int, default=1,
                    help="model-parallel mesh axis (weights/heads shard here)")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="CPU validation: fake this many host devices "
                         "(sets XLA_FLAGS before jax initializes)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the timed run "
                         "(per-request spans + per-dispatch events; open in "
                         "Perfetto / chrome://tracing — DESIGN.md §13)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics registry: Prometheus-style text "
                         "exposition at PATH plus periodic JSONL snapshots "
                         "at PATH.jsonl")
    args = ap.parse_args()

    force_host_devices(args.force_host_devices)

    # jax (and everything that initializes it) imports AFTER the XLA_FLAGS
    # setup above — device counts are fixed at backend initialization
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.common import QuantMaker
    from repro.models import transformer as T
    from repro.serve import ServeConfig, ServingEngine

    from repro.quant.policy import PrecisionPolicy

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = serving_mesh(args.dp, args.tp)
    if mesh is not None:
        print(f"mesh: dp={args.dp} x tp={args.tp} over "
              f"{jax.devices()[0].platform}")

    if args.policy:
        with open(args.policy) as f:
            policy = PrecisionPolicy.from_json(f.read())
    else:
        # legacy flags keep working as a thin adapter: they emit the
        # equivalent policy (printed below so the flag set is migratable)
        policy = PrecisionPolicy.from_legacy(kv_dtype=args.kv_dtype)

    print(f"building {cfg.name} with quantized weights "
          f"(proj={cfg.scheme_proj}, ffn={cfg.scheme_ffn})")
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(args.seed)))
    engine = ServingEngine(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.max_new,
        temperature=args.temperature, policy=policy,
        max_burst=args.max_burst, mesh=mesh))
    print(f"precision policy: {engine.policy.to_json()}")

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": rng.integers(
        1, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.family == "vlm":
        import jax.numpy as jnp
        batch["patches"] = jnp.full((args.batch, cfg.n_patches, cfg.d_model),
                                    0.02, jnp.bfloat16)
    elif cfg.family == "audio":
        import jax.numpy as jnp
        batch["frames"] = jnp.full((args.batch, cfg.n_frames, cfg.d_model),
                                   0.02, jnp.bfloat16)

    # observability bundle for the timed run (DESIGN.md §13): tracing,
    # registry + snapshots, and the model-vs-measured profiler.  Only
    # built when a sink was requested — otherwise the scheduler runs its
    # zero-overhead disabled path.
    obs = None
    if args.trace or args.metrics_out:
        from repro.obs import (MetricsRegistry, Observability,
                               SnapshotWriter, StepProfiler, Tracer)
        registry = MetricsRegistry() if args.metrics_out else None
        obs = Observability(
            tracer=Tracer() if args.trace else None,
            registry=registry,
            profiler=StepProfiler(cfg),
            snapshots=SnapshotWriter(registry, args.metrics_out + ".jsonl")
            if registry is not None else None)

    # warmup: one full-shape generation compiles every jit off the clock.
    # Scheduler families compile chunk/decode/sample once regardless of
    # batch, but the legacy static-batch loop (ssm/hybrid/audio/vlm) sizes
    # its cache from (batch, prompt+max_new) — warming up with the real
    # shapes makes the timed run steady-state for every family.
    # perf_counter, not time.time(): wall deltas must be monotonic and
    # high-resolution (time.time() can step under NTP and ticks coarsely
    # on some hosts, which corrupts sub-second compile/steady windows)
    t0 = time.perf_counter()
    engine.generate(batch, max_new_tokens=args.max_new, seed=args.seed)
    compile_s = time.perf_counter() - t0
    print(f"warmup (compile + first run) {compile_s:.2f}s")

    t0 = time.perf_counter()
    out = engine.generate(batch, max_new_tokens=args.max_new, seed=args.seed,
                          obs=obs)
    dt = time.perf_counter() - t0
    new_tokens = int(out["lengths"].sum())
    print(f"generated {out['generated'].shape} in {dt:.2f}s "
          f"({new_tokens / dt:.1f} tok/s steady-state)")
    print("first rows:", out["generated"][:2, :8].tolist())
    report = {
        "batch": out["batch"], "prompt_len": out["prompt_len"],
        "new_tokens": new_tokens, "kv_dtype": engine.scfg.kv_dtype,
        "policy": engine.policy.to_dict(),
        "topology": engine.topology,
        "compile_s": round(compile_s, 2), "wall_s": round(dt, 2),
        "steady_tok_s": round(new_tokens / dt, 1)}
    if "decode_dispatches" in out:   # scheduler families: burst accounting
        report.update({
            "max_burst": args.max_burst,
            "decode_dispatches": out["decode_dispatches"],
            "decode_dispatches_per_token": round(
                out["decode_dispatches"] / max(new_tokens, 1), 4),
            "host_syncs": out["host_syncs"],
            "burst_hist": {str(k): v for k, v
                           in sorted(out["burst_hist"].items())}})
    if obs is not None:
        if obs.tracer is not None and len(obs.tracer):
            obs.tracer.write(args.trace)
            print(f"trace: {args.trace} ({len(obs.tracer)} events)")
        if obs.profiler is not None and obs.profiler.n_records:
            report["model_measured"] = obs.profiler.report()
        if obs.registry is not None:
            with open(args.metrics_out, "w") as f:
                f.write(obs.registry.expose())
            snaps = obs.snapshots.n_written if obs.snapshots else 0
            print(f"metrics: {args.metrics_out} "
                  f"(+{snaps} snapshots in {args.metrics_out}.jsonl)")
    print(json.dumps(report, allow_nan=False))


if __name__ == "__main__":
    main()
