"""Metrics registry: counters / gauges / histograms with labels, a
Prometheus-style text exposition, and a periodic JSONL snapshot writer.

This is the single bookkeeping substrate for serving-side counters
(DESIGN.md §13): the scheduler publishes queue depth, per-tier slot
occupancy, admissions/retirements and host syncs; ``ServeMetrics``
publishes its dispatch/burst accounting and latency observations into
the same registry instead of growing a second parallel system.  Nothing
here touches a device — every update is a host-side dict write, so an
attached registry adds zero host syncs to the serving hot path (the
guard test in tests/test_obs.py pins that).

Determinism: metric families expose in name order and label sets in
sorted-label order, so ``expose()`` / ``snapshot()`` output is a pure
function of the recorded values — virtual-clock runs byte-reproduce.
"""
from __future__ import annotations

import json
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# Prometheus-ish latency buckets (seconds) — wide enough for CPU smoke
# runs and real accelerators alike
DEFAULT_TIME_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                        0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _label_key(labels: Mapping[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


def _fmt(v: float) -> str:
    """Exposition value formatting: integers stay integral."""
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.values: Dict[LabelKey, float] = {}

    def _get(self, labels: Mapping[str, str]) -> LabelKey:
        return _label_key(labels)

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self.values):
            lines.append(f"{self.name}{_label_str(key)} "
                         f"{_fmt(self.values[key])}")
        return lines

    def snapshot(self):
        return [{"labels": dict(key), "value": self.values[key]}
                for key in sorted(self.values)]


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        assert amount >= 0, "counters only go up"
        key = self._get(labels)
        self.values[key] = self.values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self.values.get(_label_key(labels), 0.0)


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self.values[self._get(labels)] = float(value)

    def value(self, **labels) -> float:
        return self.values.get(_label_key(labels), 0.0)


class Histogram:
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_TIME_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(float(b) for b in buckets))
        assert self.buckets, "histogram needs at least one bucket"
        # per label set: (bucket counts [len+1 incl +Inf], sum, count)
        self.values: Dict[LabelKey, List] = {}

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        entry = self.values.get(key)
        if entry is None:
            entry = self.values[key] = [[0] * (len(self.buckets) + 1),
                                        0.0, 0]
        counts, _, _ = entry
        for i, b in enumerate(self.buckets):
            if value <= b:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
        entry[1] += float(value)
        entry[2] += 1

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} histogram"]
        for key in sorted(self.values):
            counts, total, n = self.values[key]
            cum = 0
            for b, c in zip(self.buckets, counts):
                cum += c
                lk = _label_str(key + (("le", _fmt(b)),))
                lines.append(f"{self.name}_bucket{lk} {cum}")
            lk = _label_str(key + (("le", "+Inf"),))
            lines.append(f"{self.name}_bucket{lk} {cum + counts[-1]}")
            lines.append(f"{self.name}_sum{_label_str(key)} {_fmt(total)}")
            lines.append(f"{self.name}_count{_label_str(key)} {n}")
        return lines

    def snapshot(self):
        out = []
        for key in sorted(self.values):
            counts, total, n = self.values[key]
            out.append({"labels": dict(key),
                        "buckets": {_fmt(b): c for b, c
                                    in zip(self.buckets, counts)},
                        "inf": counts[-1], "sum": total, "count": n})
        return out


class MetricsRegistry:
    """Get-or-create registry of metric families.  Re-requesting a name
    returns the existing family (kind-checked), so the scheduler and
    ``ServeMetrics`` can share one registry without coordination."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _register(self, cls, name, help, **kw):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m
        m = self._metrics[name] = cls(name, help, **kw)
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS
                  ) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets)

    def get(self, name: str):
        return self._metrics.get(name)

    # -- output ------------------------------------------------------------
    def expose(self) -> str:
        """Prometheus text exposition format (one families block per
        metric, name-sorted)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].expose())
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict:
        """JSON-able {name: [{labels, value-or-histogram}, ...]}."""
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}


class SnapshotWriter:
    """Periodic JSONL snapshots of a registry: one compact JSON object
    per line, stamped with the (scheduler) clock time that triggered it.
    ``maybe_write(now)`` is cheap when the interval has not elapsed —
    the scheduler calls it once per step."""

    def __init__(self, registry: MetricsRegistry, path: str,
                 every_s: float = 1.0):
        self.registry = registry
        self.path = path
        self.every_s = float(every_s)
        self._last: Optional[float] = None
        self.n_written = 0
        # truncate: one run = one snapshot stream
        open(path, "w").close()

    def maybe_write(self, now: float) -> bool:
        if self._last is not None and now - self._last < self.every_s:
            return False
        self.write(now)
        return True

    def write(self, now: float) -> None:
        self._last = now
        line = json.dumps({"ts": round(now, 6),
                           "metrics": self.registry.snapshot()},
                          sort_keys=True, separators=(",", ":"),
                          allow_nan=False)
        with open(self.path, "a") as f:
            f.write(line + "\n")
        self.n_written += 1
