"""Serving observability (DESIGN.md §13): structured tracing, a metrics
registry, and a model-vs-measured profiler for the continuous-batching
engine.

The three pieces are independent and individually optional; the
``Observability`` bundle is what the scheduler takes (``Scheduler(engine,
obs=...)``).  ``obs=None`` (the default) is a strict no-op: the scheduler
makes zero extra clock calls, zero extra host syncs and zero extra
dispatches — pinned by tests/test_obs.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .profiler import StepProfiler, compiled_step_cost
from .registry import (DEFAULT_TIME_BUCKETS, Counter, Gauge, Histogram,
                       MetricsRegistry, SnapshotWriter)
from .trace import PID_REQUESTS, PID_SCHEDULER, Tracer

__all__ = [
    "Counter", "DEFAULT_TIME_BUCKETS", "Gauge", "Histogram",
    "MetricsRegistry", "Observability", "PID_REQUESTS", "PID_SCHEDULER",
    "SnapshotWriter", "StepProfiler", "Tracer", "compiled_step_cost",
]


@dataclasses.dataclass
class Observability:
    """What the scheduler consumes.  Any field may be None; the scheduler
    guards every hook on the specific field it needs, so e.g. a tracer
    without a registry costs nothing registry-shaped."""
    tracer: Optional[Tracer] = None
    registry: Optional[MetricsRegistry] = None
    profiler: Optional[StepProfiler] = None
    # periodic JSONL snapshots of ``registry`` (scheduler clock timebase)
    snapshots: Optional[SnapshotWriter] = None

    def on_step(self, now: float) -> None:
        """Called by the scheduler once per step (post-round)."""
        if self.snapshots is not None:
            self.snapshots.maybe_write(now)
