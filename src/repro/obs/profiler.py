"""Model-vs-measured profiler: per-dispatch wall timings joined against
the analytical performance model (perfmodel/analytical.py), with optional
trip-count-aware FLOP/byte counts of the compiled step
(launch/hlo_analysis.py).

This is the serving-level version of the paper's compute-density
accounting (Table IV/V -> Fig. 14): the analytical model predicts what a
decode step *should* cost on the modeled hardware given its shape
(cohort rows, context, KV bytes/token at the pool's tier), the profiler
measures what each dispatch actually cost on the host wall clock, and
``report()`` joins the two into a model/measured ratio per step shape
and per KV tier.  A tier whose ratio drifts from its siblings' is a tier
whose datatype switch is NOT free — exactly the regression the paper's
II=1 claim rules out on the FPGA, surfaced here for the serving loop
(DESIGN.md §13).

Recording is deliberately cheap: ``record_decode``/``record_prefill``
append a tuple and return — all model evaluation (which walks the
abstract parameter tree) is deferred to ``report()`` and memoized per
distinct step shape.  The profiler's wall clock defaults to
``time.perf_counter`` (monotonic, real time — model/measured only means
something against real walls) and is injectable for tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class _DecodeRec:
    tier: str
    k: int                 # planned burst length (token-steps)
    rows: int              # active cohort rows in the dispatch
    context: int           # mean committed context of the cohort
    kv_bytes_per_token: int
    wall_s: float


@dataclasses.dataclass(frozen=True)
class _PrefillRec:
    tier: str
    n_tokens: int          # chunk tokens written
    wall_s: float


class StepProfiler:
    """Join per-dispatch wall timings against analytical predictions.

    ``design`` picks which arithmetic-unit deployment the model prices
    ('xtramac' or 'vendor'); ``scheme`` defaults to the config's
    projection scheme, falling back to 'w8a8' when the config's scheme
    has no deployment row (e.g. pure-bf16 configs) — the fallback is
    recorded in the report so ratios are never silently re-based.

    ``engine_model`` is the per-datatype MAC pricing source: by default
    (``"auto"``) the channel-streaming GEMV engine for the scheme
    (``perfmodel.gemv_engine_for`` — N_MAC lanes scale with the scheme's
    weight bits, paper §VI-C), so model-vs-measured ratios reflect what a
    4-bit vs 8-bit vs bf16 MAC actually costs the fabric instead of a
    flat MAC count at a fixed rate.  Pass an explicit
    ``GemvEngineConfig`` to pin the engine, or None for the legacy
    fabric-budget pricing.
    """

    def __init__(self, cfg, *, design: str = "xtramac",
                 scheme: Optional[str] = None, engine_model="auto",
                 clock: Callable[[], float] = time.perf_counter):
        from repro.perfmodel.analytical import _DEPLOY, gemv_engine_for
        self.cfg = cfg
        self.design = design
        want = scheme or cfg.scheme_proj or "w8a8"
        self.scheme = want if want in _DEPLOY else "w8a8"
        self.scheme_fallback = self.scheme != want
        self.engine_model = gemv_engine_for(self.scheme) \
            if engine_model == "auto" else engine_model
        self.clock = clock
        self._decode: List[_DecodeRec] = []
        self._prefill: List[_PrefillRec] = []
        self._model_memo: Dict = {}

    # -- recording (hot path: append only) ---------------------------------
    def record_decode(self, *, tier: str, k: int, rows: int, context: int,
                      kv_bytes_per_token: int, wall_s: float) -> None:
        self._decode.append(_DecodeRec(tier, int(k), int(rows),
                                       int(context), int(kv_bytes_per_token),
                                       float(wall_s)))

    def record_prefill(self, *, tier: str, n_tokens: int,
                       wall_s: float) -> None:
        self._prefill.append(_PrefillRec(tier, int(n_tokens), float(wall_s)))

    @property
    def n_records(self) -> int:
        return len(self._decode) + len(self._prefill)

    # -- model join --------------------------------------------------------
    def _model_step_s(self, rows: int, context: int,
                      kv_bytes_per_token: int) -> float:
        """Predicted seconds for ONE decode token-step at this shape
        (memoized — contexts repeat across bursts and tiers)."""
        key = (rows, context, kv_bytes_per_token)
        t = self._model_memo.get(key)
        if t is None:
            from repro.perfmodel.analytical import decode_latency
            t = decode_latency(
                self.cfg, self.scheme, batch=max(rows, 1),
                context=max(context, 1), design=self.design,
                kv_bytes_per_token=kv_bytes_per_token,
                engine_model=self.engine_model)["t_total_s"]
            self._model_memo[key] = t
        return t

    def report(self) -> Dict:
        """Group dispatches by (kind, tier, K, rows) and join model vs
        measured.  ``model_over_measured`` < 1 means the real dispatch
        was slower than the modeled hardware (expected on CPU smoke
        hosts by orders of magnitude — the *relative* ratios across
        tiers and step shapes are the signal); prefill dispatches are
        measured-only (the analytical model covers decode)."""
        groups: Dict = {}
        for r in self._decode:
            g = groups.setdefault(("decode", r.tier, r.k, r.rows), {
                "kind": "decode", "tier": r.tier, "k": r.k, "rows": r.rows,
                "n": 0, "measured_s": 0.0, "model_s": 0.0, "_ctx": 0})
            g["n"] += 1
            g["measured_s"] += r.wall_s
            g["model_s"] += r.k * self._model_step_s(
                r.rows, r.context, r.kv_bytes_per_token)
            g["_ctx"] += r.context
        for r in self._prefill:
            g = groups.setdefault(("prefill", r.tier, r.n_tokens), {
                "kind": "prefill_chunk", "tier": r.tier,
                "n_tokens": r.n_tokens, "n": 0, "measured_s": 0.0,
                "model_s": None})
            g["n"] += 1
            g["measured_s"] += r.wall_s

        rows = []
        for key in sorted(groups, key=str):
            g = dict(groups[key])
            ctx = g.pop("_ctx", None)
            if ctx is not None:
                g["context_mean"] = round(ctx / g["n"], 1)
            g["measured_s"] = round(g["measured_s"], 6)
            if g["model_s"] is not None:
                g["model_s"] = round(g["model_s"], 9)
                g["model_over_measured"] = (
                    round(g["model_s"] / g["measured_s"], 6)
                    if g["measured_s"] > 0 else None)
            rows.append(g)

        per_tier: Dict[str, Dict] = {}
        for r in self._decode:
            t = per_tier.setdefault(r.tier, {"dispatches": 0,
                                             "token_steps": 0,
                                             "measured_s": 0.0,
                                             "model_s": 0.0})
            t["dispatches"] += 1
            t["token_steps"] += r.k
            t["measured_s"] += r.wall_s
            t["model_s"] += r.k * self._model_step_s(
                r.rows, r.context, r.kv_bytes_per_token)
        for t in per_tier.values():
            t["measured_s"] = round(t["measured_s"], 6)
            t["model_s"] = round(t["model_s"], 9)
            t["model_over_measured"] = (
                round(t["model_s"] / t["measured_s"], 6)
                if t["measured_s"] > 0 else None)

        eng = self.engine_model
        return {"design": self.design, "scheme": self.scheme,
                "scheme_fallback": self.scheme_fallback,
                "mac_pricing": None if eng is None else {
                    "weight_bits": eng.weight_bits,
                    "lanes_quant": eng.macs_per_cycle,
                    "hbm_utilization": eng.hbm_utilization},
                "groups": rows,
                "per_tier": {k: per_tier[k] for k in sorted(per_tier)}}


def compiled_step_cost(engine, pool, k: int = 1) -> Dict:
    """Trip-count-aware FLOP/byte counts of the COMPILED decode step for
    ``pool``'s geometry (launch/hlo_analysis.py over the post-optimization
    HLO text): the static half of the compute-density accounting — what
    the program does per dispatch, independent of how long the host took.

    ``k > 1`` analyzes the K-step burst scan (the scan body is multiplied
    by its known trip count).  This lowers and compiles the step outside
    the engine's jit cache, so it is an offline/diagnostic call, not a
    hot-path one.
    """
    import jax
    import jax.numpy as jnp

    from repro.launch.hlo_analysis import analyze

    n = pool.n_slots
    f32 = jnp.float32

    def spec(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    cache = jax.tree_util.tree_map(
        lambda a: spec(a.shape, a.dtype), pool.cache)
    row_i32 = spec((n,), jnp.int32)
    paged = getattr(pool, "paged", False)
    table = (spec(pool.page_table.shape, jnp.int32),) if paged else ()
    if k <= 1:
        fn = engine._decode_slots_paged_fn if paged \
            else engine._decode_slots_fn
        lowered = jax.jit(fn).lower(
            engine.params, spec((n, 1), jnp.int32), cache, row_i32,
            spec((n, 2), jnp.uint32), spec((n,), f32), *table)
    else:
        fn = engine._decode_burst_paged_fn if paged \
            else engine._decode_burst_fn
        lowered = jax.jit(fn).lower(
            engine.params, cache, row_i32, row_i32, spec((n,), jnp.bool_),
            row_i32, spec((k, n, 2), jnp.uint32), spec((n,), f32), row_i32,
            jnp.int32(pool.max_len), *table)
    cost = analyze(lowered.compile().as_text())
    steps = k * n
    return {"k": k, "n_slots": n, "kv_dtype": pool.kv_dtype,
            "paged": paged,
            **({"n_pages": pool.n_pages} if paged else {}),
            "flops": cost.flops, "hbm_bytes": cost.hbm_bytes,
            "collective_bytes": cost.collective_bytes,
            "flops_per_token_step": round(cost.flops / steps, 1),
            "hbm_bytes_per_token_step": round(cost.hbm_bytes / steps, 1)}
